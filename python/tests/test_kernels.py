"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels/ref.py).

Hypothesis sweeps shapes and dtypes; this is the CORE correctness signal
for the kernels the AOT pipeline ships to the rust runtime.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import random_features as rf
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


def _gaussian_tol(dtype, x, w, b, m):
    """bf16 rounds the phase (x@w + b) to ~2^-8 relative precision BEFORE
    cos; the resulting error on cos is bounded by the absolute phase error.
    Scale atol accordingly (cos output is further scaled by sqrt(2/m))."""
    if dtype != jnp.bfloat16:
        return dict(rtol=1e-5, atol=1e-5)
    phase = np.abs(np.asarray(x, np.float32) @ np.asarray(w, np.float32)
                   + np.asarray(b, np.float32)).max()
    return dict(rtol=5e-2, atol=math.sqrt(2.0 / m) * (phase * 2.0**-7 + 0.05))


def _opu_tol(dtype, out):
    if dtype != jnp.bfloat16:
        return dict(rtol=1e-5, atol=1e-5)
    return dict(rtol=6e-2, atol=6e-2 * float(np.abs(np.asarray(out, np.float32)).max() + 1e-3))


shapes = st.tuples(
    st.integers(min_value=1, max_value=40),   # batch
    st.integers(min_value=1, max_value=64),   # d
    st.integers(min_value=1, max_value=96),   # m
)
dtypes = st.sampled_from([jnp.float32, jnp.bfloat16])


@settings(max_examples=40, deadline=None)
@given(shapes=shapes, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_gaussian_rf_matches_ref(shapes, dtype, seed):
    b, d, m = shapes
    g = _rng(seed)
    x = jnp.asarray(g.normal(size=(b, d)), dtype)
    w = jnp.asarray(g.normal(size=(d, m)), dtype)
    bias = jnp.asarray(g.uniform(0, 2 * math.pi, size=(m,)), dtype)
    got = rf.gaussian_rf_pallas(x, w, bias)
    # Oracle in f32 from the rounded inputs: the kernel accumulates in f32.
    want = ref.gaussian_rf(*(jnp.asarray(a, jnp.float32) for a in (x, w, bias)))
    assert got.shape == (b, m) and got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_gaussian_tol(dtype, x, w, bias, m)
    )


@settings(max_examples=40, deadline=None)
@given(shapes=shapes, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_opu_rf_matches_ref(shapes, dtype, seed):
    b, d, m = shapes
    g = _rng(seed)
    x = jnp.asarray(g.integers(0, 2, size=(b, d)), dtype)  # binary adjacency
    wr = jnp.asarray(g.normal(size=(d, m)), dtype)
    wi = jnp.asarray(g.normal(size=(d, m)), dtype)
    br = jnp.asarray(g.normal(size=(m,)), dtype)
    bi = jnp.asarray(g.normal(size=(m,)), dtype)
    got = rf.opu_rf_pallas(x, wr, wi, br, bi)
    want = ref.opu_rf(*(jnp.asarray(a, jnp.float32) for a in (x, wr, wi, br, bi)))
    assert got.shape == (b, m) and got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_opu_tol(dtype, want)
    )


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 16), d=st.integers(1, 30), m=st.integers(1, 48),
    bb=st.integers(1, 16), bm=st.integers(1, 48), seed=st.integers(0, 2**31 - 1),
)
def test_explicit_block_shapes(b, d, m, bb, bm, seed):
    """Any exact tiling must give identical results (tiling is an
    implementation detail, not a semantic knob)."""
    bb = math.gcd(b, bb) or 1
    bm = math.gcd(m, bm) or 1
    g = _rng(seed)
    x = jnp.asarray(g.normal(size=(b, d)), jnp.float32)
    w = jnp.asarray(g.normal(size=(d, m)), jnp.float32)
    bias = jnp.asarray(g.normal(size=(m,)), jnp.float32)
    got = rf.gaussian_rf_pallas(x, w, bias, block_b=bb, block_m=bm)
    want = ref.gaussian_rf(x, w, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_opu_features_nonnegative():
    """|.|^2 features are nonnegative by construction."""
    g = _rng(0)
    x = jnp.asarray(g.integers(0, 2, size=(8, 16)), jnp.float32)
    wr = jnp.asarray(g.normal(size=(16, 32)), jnp.float32)
    wi = jnp.asarray(g.normal(size=(16, 32)), jnp.float32)
    br = jnp.asarray(g.normal(size=(32,)), jnp.float32)
    bi = jnp.asarray(g.normal(size=(32,)), jnp.float32)
    out = np.asarray(rf.opu_rf_pallas(x, wr, wi, br, bi))
    assert (out >= 0).all()


def test_gaussian_features_bounded():
    """cos features are bounded by sqrt(2/m) in magnitude."""
    g = _rng(1)
    m = 64
    x = jnp.asarray(g.normal(size=(8, 9)), jnp.float32)
    w = jnp.asarray(g.normal(size=(9, m)), jnp.float32)
    bias = jnp.asarray(g.normal(size=(m,)), jnp.float32)
    out = np.asarray(rf.gaussian_rf_pallas(x, w, bias))
    assert (np.abs(out) <= math.sqrt(2.0 / m) + 1e-6).all()


def test_gaussian_kernel_approximation():
    """Sanity: phi_Gs(x).phi_Gs(y) approximates the Gaussian kernel
    exp(-||x - y||^2 / (2 sigma^2)) for w ~ N(0, 1/sigma^2)."""
    g = _rng(2)
    d, m, sigma = 6, 60_000, 1.3
    x = g.normal(size=(2, d)).astype(np.float32)
    w = (g.normal(size=(d, m)) / sigma).astype(np.float32)
    bias = g.uniform(0, 2 * math.pi, size=(m,)).astype(np.float32)
    phi = np.asarray(rf.gaussian_rf_pallas(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias),
                                           block_b=2, block_m=1000))
    approx = float(phi[0] @ phi[1])
    exact = float(np.exp(-np.sum((x[0] - x[1]) ** 2) / (2 * sigma**2)))
    assert abs(approx - exact) < 0.03, (approx, exact)


def test_opu_kernel_closed_form():
    """The OPU kernel has the closed form (Saade et al. 2016), for
    W entries ~ CN(0, 2) (unit-variance real and imaginary parts), b = 0:

      E[phi(x).phi(y)] * sqrt(m) / m = ||x||^2 ||y||^2 + |<x, y>|^2

    We verify the empirical average converges to it."""
    g = _rng(3)
    d, m = 5, 200_000
    x = g.normal(size=(d,)).astype(np.float32)
    y = g.normal(size=(d,)).astype(np.float32)
    wr = g.normal(size=(d, m)).astype(np.float32)
    wi = g.normal(size=(d, m)).astype(np.float32)
    zeros = np.zeros((m,), np.float32)
    phi = np.asarray(
        rf.opu_rf_pallas(jnp.asarray(np.stack([x, y])), jnp.asarray(wr),
                         jnp.asarray(wi), jnp.asarray(zeros), jnp.asarray(zeros),
                         block_b=2, block_m=2000)
    )
    # phi includes m^{-1/2}; the dot over m then estimates m * E[.] / m
    approx = float(phi[0] @ phi[1])
    nx2, ny2 = float(x @ x), float(y @ y)
    ip = float(x @ y)
    # E[|w.x|^2 |w.y|^2] for complex gaussian w with E|w_i|^2 = 2:
    #   4 * (||x||^2 ||y||^2 + <x,y>^2)
    exact = 4.0 * (nx2 * ny2 + ip * ip)
    assert abs(approx - exact) / exact < 0.05, (approx, exact)


@pytest.mark.parametrize("variant", ["opu", "gauss"])
def test_vmem_footprint_within_budget(variant):
    """Default tiles must fit the 16 MiB VMEM budget from DESIGN.md §Perf."""
    for batch, m, d in [(256, 5000, 64), (256, 5000, 9), (2000, 5000, 36)]:
        bb, bm = rf.default_blocks(batch, m)
        assert rf.vmem_footprint_bytes(bb, bm, d, variant) <= 16 * 2**20


def test_mxu_estimate_monotone():
    assert rf.mxu_utilization_estimate(128, 512, 64) == pytest.approx(0.5)
    assert rf.mxu_utilization_estimate(64, 512, 64) < rf.mxu_utilization_estimate(128, 512, 64)
