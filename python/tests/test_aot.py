"""AOT pipeline tests: manifest grammar, HLO emission, config matrix."""

import os
import re
import subprocess
import sys
import tempfile

import pytest

from compile import aot, configs


def test_config_matrix_covers_experiments():
    """The artifact matrix must cover every experiment in DESIGN.md §5."""
    names = {c["name"] for c in configs.all_configs()}
    # Fig 1 left: opu uniform, k in 3..6 (d 9..36) at m=5000, m sweep at k=6
    for d in (9, 16, 25, 36):
        assert f"rf_opu_xla_d{d}_m5000_b256" in names
    for m in (500, 1000, 2000, 5000):
        assert f"rf_opu_xla_d36_m{m}_b256" in names
    # Fig 2 left: gauss + gauss-eig (d = k = 6) sweeps
    for m in configs.M_SWEEP:
        assert f"rf_gauss_xla_d36_m{m}_b256" in names
        assert f"rf_gauss_xla_d6_m{m}_b256" in names
    # Fig 2 right / Table 1: all k in 3..8
    for k in configs.KS:
        assert f"rf_opu_xla_d{k * k}_m5000_b256" in names
    # Fig 3: k = 7 -> d = 49
    assert "rf_opu_xla_d49_m5000_b256" in names
    # GIN baseline
    assert "gin_train_b32_v60" in names
    assert "gin_predict_b60_v60" in names


def test_unique_names():
    names = [c["name"] for c in configs.all_configs()]
    assert len(names) == len(set(names))


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfgs = [c for c in configs.all_configs() if "_d9_m64_b32" in c["name"]]
    assert len(cfgs) >= 4
    records = ["manifest-version 1"]
    for c in cfgs:
        records.append(aot.lower_one(c, str(out)))
    (out / "manifest.txt").write_text("\n".join(records) + "\n")
    return out, cfgs


def test_hlo_files_written(small_artifacts):
    out, cfgs = small_artifacts
    for c in cfgs:
        path = out / f"{c['name']}.hlo.txt"
        text = path.read_text()
        assert text.startswith("HloModule"), c["name"]
        assert "ENTRY" in text


def test_hlo_output_is_tuple(small_artifacts):
    """The rust loader unwraps a tuple root; every artifact must return one."""
    out, cfgs = small_artifacts
    for c in cfgs:
        text = (out / f"{c['name']}.hlo.txt").read_text()
        m = re.search(r"->\s*(\([^)]*\))", text)
        assert m, f"no tuple return in {c['name']}"


def test_manifest_grammar(small_artifacts):
    out, cfgs = small_artifacts
    lines = (out / "manifest.txt").read_text().splitlines()
    assert lines[0] == "manifest-version 1"
    fields = {"artifact", "file", "kind", "meta", "input", "output", "end"}
    n_end = 0
    for line in lines[1:]:
        key = line.split()[0]
        assert key in fields, line
        n_end += key == "end"
    assert n_end == len(cfgs)


def test_manifest_shapes_match_config(small_artifacts):
    out, cfgs = small_artifacts
    text = (out / "manifest.txt").read_text()
    opu = [c for c in cfgs if c.get("variant") == "opu" and c["impl"] == "xla"][0]
    block = text.split(f"artifact {opu['name']}")[1].split("end")[0]
    assert f"input x f32 {opu['batch']},{opu['d']}" in block
    assert f"input wr f32 {opu['d']},{opu['m']}" in block
    assert f"output y f32 {opu['batch']},{opu['m']}" in block


def test_pallas_and_xla_artifacts_agree_numerically(small_artifacts):
    """Load both impls of the same config back through jax and compare —
    the AOT text must encode identical math."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from compile import model

    g = np.random.default_rng(0)
    d, m, b = 9, 64, 32
    x = g.integers(0, 2, size=(b, d)).astype(np.float32)
    wr = g.normal(size=(d, m)).astype(np.float32)
    wi = g.normal(size=(d, m)).astype(np.float32)
    br = g.normal(size=(m,)).astype(np.float32)
    bi = g.normal(size=(m,)).astype(np.float32)
    args = list(map(jnp.asarray, (x, wr, wi, br, bi)))
    y_pallas = model.rf_features("opu", "pallas")(*args)
    y_xla = model.rf_features("opu", "xla")(*args)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_xla),
                               rtol=1e-5, atol=1e-5)


def test_cli_only_filter(tmp_path):
    """aot.py --only must build just the matching artifacts."""
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "rf_gauss_pallas_d9_m64_b32"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True, env=env,
    )
    assert r.returncode == 0, r.stderr
    files = {p.name for p in tmp_path.iterdir()}
    assert files == {"rf_gauss_pallas_d9_m64_b32.hlo.txt", "manifest.txt"}
