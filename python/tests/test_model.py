"""L2 correctness: GSA embeddings and the GIN baseline."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rng(seed=0):
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# GSA embedding
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 64), d=st.integers(1, 49), m=st.integers(1, 80),
       seed=st.integers(0, 2**31 - 1))
def test_embed_is_mean_of_features(s, d, m, seed):
    g = _rng(seed)
    x = g.integers(0, 2, size=(s, d)).astype(np.float32)
    wr = g.normal(size=(d, m)).astype(np.float32)
    wi = g.normal(size=(d, m)).astype(np.float32)
    br = g.normal(size=(m,)).astype(np.float32)
    bi = g.normal(size=(m,)).astype(np.float32)
    emb = model.gsa_embed("opu", "xla")(*map(jnp.asarray, (x, wr, wi, br, bi)))
    feats = ref.opu_rf(*map(jnp.asarray, (x, wr, wi, br, bi)))
    np.testing.assert_allclose(np.asarray(emb), np.asarray(feats).mean(axis=0),
                               rtol=1e-5, atol=1e-6)


def test_embed_permutation_invariant_over_samples():
    """Averaging makes the embedding invariant to sample order (the
    graph-level permutation-invariance argument of §3.1)."""
    g = _rng(7)
    s, d, m = 32, 16, 24
    x = g.integers(0, 2, size=(s, d)).astype(np.float32)
    params = [g.normal(size=(d, m)).astype(np.float32),
              g.normal(size=(d, m)).astype(np.float32),
              g.normal(size=(m,)).astype(np.float32),
              g.normal(size=(m,)).astype(np.float32)]
    embed = model.gsa_embed("opu", "xla")
    e1 = embed(jnp.asarray(x), *map(jnp.asarray, params))
    e2 = embed(jnp.asarray(x[::-1].copy()), *map(jnp.asarray, params))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5, atol=1e-6)


def test_mmd_concentration_theorem1():
    """Empirical check of Theorem 1's structure: as m grows, the squared
    distance between embeddings of two DIFFERENT subgraph distributions
    concentrates; we verify the error to the m->inf limit shrinks."""
    g = _rng(42)
    d, s = 9, 4000
    # Two distinct distributions over binary vectors (sparse vs dense).
    xa = (g.random(size=(s, d)) < 0.2).astype(np.float32)
    xb = (g.random(size=(s, d)) < 0.7).astype(np.float32)
    errs = []
    ms = [50, 500, 5000]
    # "Ground truth" MMD^2 via a very large m.
    def sqdist(m, seed):
        gg = _rng(seed)
        w = (gg.normal(size=(d, m)) / 1.0).astype(np.float32)
        b = gg.uniform(0, 2 * math.pi, size=(m,)).astype(np.float32)
        fa = np.asarray(ref.gaussian_rf(jnp.asarray(xa), jnp.asarray(w), jnp.asarray(b))).mean(0)
        fb = np.asarray(ref.gaussian_rf(jnp.asarray(xb), jnp.asarray(w), jnp.asarray(b))).mean(0)
        return float(((fa - fb) ** 2).sum())
    truth = np.mean([sqdist(20000, 100 + i) for i in range(3)])
    for m in ms:
        errs.append(abs(np.mean([sqdist(m, 200 + r) for r in range(5)]) - truth))
    # error at m=5000 must be well below error at m=50
    assert errs[-1] < errs[0] * 0.5 + 1e-4, (errs, truth)


# --------------------------------------------------------------------------
# GIN baseline
# --------------------------------------------------------------------------

def _random_adj(g, b, v, p=0.15):
    a = (g.random(size=(b, v, v)) < p).astype(np.float32)
    a = np.triu(a, 1)
    return a + a.transpose(0, 2, 1)


def test_gin_forward_shapes():
    g = _rng(0)
    params = model.gin_init_params(jax.random.PRNGKey(0))
    adj = jnp.asarray(_random_adj(g, 6, 60))
    logits = model.gin_forward(params, adj)
    assert logits.shape == (6, model.GIN_CLASSES)
    assert np.isfinite(np.asarray(logits)).all()


def test_gin_permutation_invariance():
    """GIN with sum readout is invariant to node relabelling."""
    g = _rng(1)
    v = 20
    params = model.gin_init_params(jax.random.PRNGKey(1))
    adj = _random_adj(g, 1, v)
    perm = g.permutation(v)
    adj_p = adj[:, perm][:, :, perm]
    l1 = np.asarray(model.gin_forward(params, jnp.asarray(adj)))
    l2 = np.asarray(model.gin_forward(params, jnp.asarray(adj_p)))
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)


def test_gin_train_step_decreases_loss():
    """A few Adam steps on a separable toy task must reduce the loss, and
    the lowered signature (flat param/m/v lists) must round-trip."""
    g = _rng(2)
    b, v = 16, 60
    # class 0: sparse graphs; class 1: dense graphs
    adj = np.concatenate([_random_adj(g, b // 2, v, 0.05),
                          _random_adj(g, b // 2, v, 0.4)])
    labels = np.array([0] * (b // 2) + [1] * (b // 2), np.int32)
    params = [np.asarray(p) for p in model.gin_init_params(jax.random.PRNGKey(2))]
    m_st = [np.zeros_like(p) for p in params]
    v_st = [np.zeros_like(p) for p in params]
    step_fn = jax.jit(model.gin_train_step(lr=5e-2))
    losses = []
    for t in range(1, 41):
        out = step_fn(jnp.float32(t), jnp.asarray(adj), jnp.asarray(labels),
                      *map(jnp.asarray, params), *map(jnp.asarray, m_st),
                      *map(jnp.asarray, v_st))
        loss, rest = out[0], out[1:]
        n = len(params)
        params = [np.asarray(a) for a in rest[:n]]
        m_st = [np.asarray(a) for a in rest[n:2 * n]]
        v_st = [np.asarray(a) for a in rest[2 * n:]]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_gin_predict_consistent_with_forward():
    g = _rng(3)
    params = model.gin_init_params(jax.random.PRNGKey(3))
    adj = jnp.asarray(_random_adj(g, 4, 60))
    pred, logits = model.gin_predict()(adj, *params)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(jnp.argmax(logits, -1)).astype(np.int32))


def test_gin_param_shapes_count():
    shapes = model.gin_param_shapes()
    assert len(shapes) == model.GIN_LAYERS * 4 + 4
    assert shapes[0][1] == (1, model.GIN_HIDDEN)
    assert shapes[-2][1] == (model.GIN_HIDDEN, model.GIN_CLASSES)
    assert shapes[-1][1] == (model.GIN_CLASSES,)
