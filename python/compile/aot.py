"""AOT compiler: lower every configured jax function to HLO text + manifest.

Run once by `make artifacts`; the rust runtime consumes only the outputs:

  artifacts/<name>.hlo.txt   one HLO module per artifact (text format)
  artifacts/manifest.txt     line-oriented index parsed by runtime::manifest

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Manifest grammar (one token-separated record per artifact):

  manifest-version 1
  artifact <name>
  file <name>.hlo.txt
  kind rf|embed|gin_train|gin_predict
  meta <key>=<value> ...          # variant/impl/d/m/batch/s/v as relevant
  input <name> <dtype> <d0,d1,..> # in positional order
  output <name> <dtype> <d0,..>   # outputs of the (always) returned tuple
  end
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model

_DTYPES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def to_hlo_text(lowered) -> str:
    """Lower jax's stablehlo to XLA HLO text via an XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_shape(s):
    return ",".join(str(d) for d in s.shape) if s.shape else "scalar"


def _manifest_record(cfg, in_names, in_specs, out_names, out_specs, fname):
    lines = [f"artifact {cfg['name']}", f"file {fname}", f"kind {cfg['kind']}"]
    meta = " ".join(
        f"{k}={cfg[k]}" for k in ("variant", "impl", "d", "m", "batch", "s", "v")
        if k in cfg
    )
    if meta:
        lines.append(f"meta {meta}")
    for n, s in zip(in_names, in_specs):
        lines.append(f"input {n} {_DTYPES[s.dtype]} {_fmt_shape(s)}")
    for n, s in zip(out_names, out_specs):
        lines.append(f"output {n} {_DTYPES[s.dtype]} {_fmt_shape(s)}")
    lines.append("end")
    return "\n".join(lines)


def build_rf(cfg):
    """(fn, input names, input specs, output names)."""
    d, m, b = cfg["d"], cfg["m"], cfg["batch"]
    fn = model.rf_features(cfg["variant"], cfg["impl"])
    if cfg["variant"] == "opu":
        names = ["x", "wr", "wi", "br", "bi"]
        specs = [spec((b, d)), spec((d, m)), spec((d, m)), spec((m,)), spec((m,))]
    else:
        names = ["x", "w", "b"]
        specs = [spec((b, d)), spec((d, m)), spec((m,))]
    return fn, names, specs, ["y"]


def build_embed(cfg):
    d, m, s = cfg["d"], cfg["m"], cfg["s"]
    fn = model.gsa_embed(cfg["variant"], cfg["impl"])
    if cfg["variant"] == "opu":
        names = ["x", "wr", "wi", "br", "bi"]
        specs = [spec((s, d)), spec((d, m)), spec((d, m)), spec((m,)), spec((m,))]
    else:
        names = ["x", "w", "b"]
        specs = [spec((s, d)), spec((d, m)), spec((m,))]
    return fn, names, specs, ["f"]


def build_gin_train(cfg):
    b, v = cfg["batch"], cfg["v"]
    shapes = model.gin_param_shapes()
    fn = model.gin_train_step()
    names = ["step", "adj", "labels"]
    specs = [spec(()), spec((b, v, v)), spec((b,), jnp.int32)]
    for prefix in ("p", "m", "v"):
        for pname, pshape in shapes:
            names.append(f"{prefix}_{pname}")
            specs.append(spec(pshape))
    out_names = ["loss"]
    for prefix in ("p", "m", "v"):
        out_names += [f"{prefix}_{pname}" for pname, _ in shapes]
    return fn, names, specs, out_names


def build_gin_predict(cfg):
    b, v = cfg["batch"], cfg["v"]
    shapes = model.gin_param_shapes()
    fn = model.gin_predict()
    names = ["adj"] + [f"p_{pname}" for pname, _ in shapes]
    specs = [spec((b, v, v))] + [spec(pshape) for _, pshape in shapes]
    return fn, names, specs, ["pred", "logits"]


_BUILDERS = {
    "rf": build_rf,
    "embed": build_embed,
    "gin_train": build_gin_train,
    "gin_predict": build_gin_predict,
}


def lower_one(cfg, out_dir):
    fn, in_names, in_specs, out_names = _BUILDERS[cfg["kind"]](cfg)
    lowered = jax.jit(fn).lower(*in_specs)
    text = to_hlo_text(lowered)
    fname = f"{cfg['name']}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # Output specs from the lowering itself (authoritative).
    out_avals = lowered.out_info
    flat = jax.tree_util.tree_leaves(out_avals)
    out_specs = [spec(o.shape, o.dtype) for o in flat]
    assert len(out_specs) == len(out_names), (cfg["name"], len(out_specs), len(out_names))
    return _manifest_record(cfg, in_names, in_specs, out_names, out_specs, fname)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    cfgs = configs.all_configs()
    if args.only:
        cfgs = [c for c in cfgs if args.only in c["name"]]
    records = ["manifest-version 1"]
    t0 = time.time()
    for i, cfg in enumerate(cfgs):
        t = time.time()
        records.append(lower_one(cfg, out_dir))
        print(f"[{i + 1}/{len(cfgs)}] {cfg['name']} ({time.time() - t:.2f}s)",
              file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(records) + "\n")
    print(f"wrote {len(cfgs)} artifacts + manifest to {out_dir} "
          f"in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
