"""L1 Pallas kernels: tiled random-feature projections.

The compute hot-spot of GSA-phi is a dense random projection of a batch of
flattened graphlet adjacencies followed by an elementwise nonlinearity:

  gaussian : y = sqrt(2/m) * cos(x @ W + b)            (phi_Gs, paper eq. 8)
  opu      : y = m^{-1/2} * ((x@Wr+br)^2 + (x@Wi+bi)^2) (phi_OPU, simulated)

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's "device"
is an optical matrix multiplier; on a TPU the same workload is MXU-shaped.
We tile the (B, m) output into (block_b, block_m) VMEM blocks via BlockSpec,
keep the full d-panel of x and W resident per block (d = k^2 <= 64, tiny),
and fuse the nonlinearity into the same kernel so the projection never
round-trips to HBM. The grid iterates row-major over B blocks so the W
column panel is reused across consecutive grid steps.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO, which is
what the rust runtime loads. Correctness vs kernels/ref.py is enforced by
python/tests/test_kernels.py (hypothesis sweeps shapes and dtypes).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>=1). Used to pick block sizes
    that tile the batch/feature dims exactly, so no masking is needed."""
    for c in range(min(n, cap), 0, -1):
        if n % c == 0:
            return c
    return 1


def default_blocks(batch: int, m: int) -> tuple[int, int]:
    """Default (block_b, block_m) tiling.

    Chosen so the working set (x-block + two W panels + out-block) fits a
    16 MiB VMEM budget with room for double buffering; see DESIGN.md §Perf
    for the footprint table. Both must divide their dims exactly.
    """
    return _largest_divisor_leq(batch, 128), _largest_divisor_leq(m, 512)


def _gaussian_kernel(x_ref, w_ref, b_ref, o_ref, *, scale):
    """One (block_b, block_m) output tile of sqrt(2/m)*cos(x@W + b)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (scale * jnp.cos(acc + b_ref[...][None, :])).astype(o_ref.dtype)


def _opu_kernel(x_ref, wr_ref, wi_ref, br_ref, bi_ref, o_ref, *, scale):
    """One (block_b, block_m) output tile of m^{-1/2}*|x@W + b|^2.

    Two MXU dots (real and imaginary panel) share the same x block; the
    squared-modulus epilogue is fused so only the final tile hits HBM.
    """
    x = x_ref[...]
    re = jnp.dot(x, wr_ref[...], preferred_element_type=jnp.float32)
    im = jnp.dot(x, wi_ref[...], preferred_element_type=jnp.float32)
    re = re + br_ref[...][None, :]
    im = im + bi_ref[...][None, :]
    o_ref[...] = (scale * (re * re + im * im)).astype(o_ref.dtype)


def gaussian_rf_pallas(x, w, b, *, block_b=None, block_m=None):
    """Pallas phi_Gs: sqrt(2/m) * cos(x @ w + b).

    Args:
      x: (B, d); w: (d, m); b: (m,). Any float dtype; accumulation in f32.
      block_b, block_m: optional tile sizes (must divide B and m).
    Returns: (B, m) array with x's dtype.
    """
    batch, d = x.shape
    d2, m = w.shape
    assert d == d2, f"x/w contraction mismatch: {d} vs {d2}"
    assert b.shape == (m,)
    bb = block_b or default_blocks(batch, m)[0]
    bm = block_m or default_blocks(batch, m)[1]
    assert batch % bb == 0 and m % bm == 0, (batch, m, bb, bm)
    grid = (batch // bb, m // bm)
    return pl.pallas_call(
        functools.partial(_gaussian_kernel, scale=math.sqrt(2.0 / m)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m), x.dtype),
        interpret=True,
    )(x, w, b)


def opu_rf_pallas(x, wr, wi, br, bi, *, block_b=None, block_m=None):
    """Pallas phi_OPU: m^{-1/2} * ((x@wr+br)^2 + (x@wi+bi)^2).

    Args:
      x: (B, d); wr, wi: (d, m); br, bi: (m,).
    Returns: (B, m) array with x's dtype.
    """
    batch, d = x.shape
    d2, m = wr.shape
    assert d == d2 and wi.shape == (d, m)
    assert br.shape == (m,) and bi.shape == (m,)
    bb = block_b or default_blocks(batch, m)[0]
    bm = block_m or default_blocks(batch, m)[1]
    assert batch % bb == 0 and m % bm == 0, (batch, m, bb, bm)
    grid = (batch // bb, m // bm)
    return pl.pallas_call(
        functools.partial(_opu_kernel, scale=1.0 / math.sqrt(m)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bm), lambda i, j: (0, j)),
            pl.BlockSpec((d, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
            pl.BlockSpec((bm,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m), x.dtype),
        interpret=True,
    )(x, wr, wi, br, bi)


def vmem_footprint_bytes(block_b: int, block_m: int, d: int, variant: str) -> int:
    """Estimated VMEM bytes for one grid step (f32), used by the §Perf
    tables in DESIGN.md/EXPERIMENTS.md: x block + W panel(s) + bias(es) +
    out block, x2 for double buffering of the streamed operands."""
    panels = 2 if variant == "opu" else 1
    x_b = block_b * d * 4
    w_b = panels * d * block_m * 4
    bias_b = panels * block_m * 4
    out_b = block_b * block_m * 4
    return 2 * (x_b + w_b + bias_b) + out_b


def mxu_utilization_estimate(block_b: int, block_m: int, d: int) -> float:
    """Fraction of 128x128 MXU systolic-tile slots doing useful work for a
    (block_b, d) x (d, block_m) dot — the structural utilization bound for
    this kernel on TPU (d <= 64 always under-fills the contraction dim)."""
    eff_b = min(block_b, 128) / 128.0
    eff_d = min(d, 128) / 128.0
    eff_m = min(block_m, 128) / 128.0
    return eff_b * eff_d * eff_m
