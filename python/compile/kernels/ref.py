"""Pure-jnp oracles for the random-feature kernels.

These are the ground truth the Pallas kernels (random_features.py) are
checked against in python/tests/, and they double as the `impl=xla`
artifact bodies: the same mathematical map lowered without Pallas, which
XLA-CPU fuses into a single dot + elementwise epilogue (the fast path the
rust runtime uses by default; the Pallas path validates the TPU-shaped
kernel structure).

Conventions (match the paper, §3.3):
  gaussian_rf : phi_Gs(x)  = sqrt(2/m) * cos(x @ W + b)      (eq. 8)
  opu_rf      : phi_OPU(x) = m^{-1/2} * |x @ (Wr + i Wi) + (br + i bi)|^2
x is a batch of flattened graphlet adjacency matrices (B, d) with d = k*k,
or a batch of sorted-eigenvalue vectors (B, k) for the Gs+eig variant.
"""

import jax.numpy as jnp


def gaussian_rf(x, w, b):
    """Gaussian random features: sqrt(2/m) * cos(x @ w + b).

    Args:
      x: (B, d) float array, flattened graphlet adjacencies.
      w: (d, m) float array, iid N(0, 1/sigma^2)-scaled Gaussian frequencies.
      b: (m,)  float array, iid U[0, 2*pi) phases.
    Returns:
      (B, m) float array of random features.
    """
    m = w.shape[1]
    return jnp.sqrt(2.0 / m) * jnp.cos(x @ w + b)


def opu_rf(x, wr, wi, br, bi):
    """Simulated OPU features: m^{-1/2} * |x @ W + b|^2, W complex Gaussian.

    The physical OPU computes the squared modulus of a random complex
    projection of the (binary) input through a scattering medium; we
    simulate it with an explicit complex Gaussian matrix W = wr + i*wi and
    bias b = br + i*bi (DESIGN.md §2).

    Args:
      x:  (B, d) float array.
      wr, wi: (d, m) float arrays, real/imaginary parts of W.
      br, bi: (m,)  float arrays, real/imaginary parts of the bias.
    Returns:
      (B, m) float array of optical random features.
    """
    m = wr.shape[1]
    re = x @ wr + br
    im = x @ wi + bi
    return (re * re + im * im) / jnp.sqrt(m * 1.0)
