"""The artifact matrix: every HLO module `make artifacts` produces.

Keyed by the experiments in DESIGN.md §5:
  - Fig 1 left  : opu, d = k^2 for k in 3..6, m in {500..5000}, uniform
  - Fig 1 right : opu RW k in 3..6 (d 9..36) + GIN train/predict
  - Fig 2 left  : opu / gauss / gauss-eig sweeps over m at k = 6
  - Fig 2 right + Table 1 : timing over k in 3..8 (d 9..64)
  - Fig 3       : k = 7 (d = 49), m sweep, s = 4000
The rust runtime looks artifacts up by name; aot.py writes the manifest.

impl notes: 'xla' lowers the pure-jnp body (kernels/ref.py) — XLA-CPU fuses
it into dot + epilogue and it is the runtime fast path. 'pallas' lowers the
L1 kernel in interpret mode — structurally the TPU kernel, used for
validation and the L1-vs-L2 perf comparison (EXPERIMENTS.md §Perf).
"""

# k values used across the experiments and the matching flattened dims
KS = [3, 4, 5, 6, 7, 8]
M_SWEEP = [100, 500, 1000, 2000, 5000]
DEFAULT_BATCH = 256

GIN_BATCH_TRAIN = 32
GIN_BATCH_PREDICT = 60
GIN_NODES = 60  # SBM graphs are v = 60 (paper §4.1)


def rf_name(variant, impl, d, m, batch):
    return f"rf_{variant}_{impl}_d{d}_m{m}_b{batch}"


def embed_name(variant, impl, d, m, s):
    return f"embed_{variant}_{impl}_d{d}_m{m}_s{s}"


def rf_configs():
    """List of dicts describing every random-feature artifact."""
    cfgs = []

    def add(variant, impl, d, m, batch=DEFAULT_BATCH):
        cfgs.append(
            dict(kind="rf", variant=variant, impl=impl, d=d, m=m, batch=batch,
                 name=rf_name(variant, impl, d, m, batch))
        )

    # Full xla-impl matrix over adjacency dims (d = k^2) and the m sweep.
    for k in KS:
        for m in M_SWEEP:
            add("opu", "xla", k * k, m)
            add("gauss", "xla", k * k, m)
    # Gs+eig variant: gaussian features on sorted-eigenvalue vectors, d = k.
    for k in KS:
        for m in M_SWEEP:
            add("gauss", "xla", k, m)
    # Pallas validation/perf artifacts (kernel correctness is covered by
    # pytest across many shapes; these exercise the AOT->PJRT path).
    for variant in ("opu", "gauss"):
        add(variant, "pallas", 36, 500)
        add(variant, "pallas", 36, 5000)
        add(variant, "pallas", 9, 64, batch=32)
        add(variant, "xla", 9, 64, batch=32)  # smoke-test twin
    return cfgs


def embed_configs():
    """Fused (s,d)->(m,) per-graph embedding artifacts (fast path when the
    per-graph sample count is fixed; avoids returning (s, m) to the host)."""
    cfgs = []
    for variant, impl, d, m, s in [
        ("opu", "xla", 36, 5000, 2000),
        ("opu", "xla", 49, 5000, 4000),
        ("opu", "pallas", 36, 5000, 2000),
    ]:
        cfgs.append(dict(kind="embed", variant=variant, impl=impl, d=d, m=m,
                         s=s, name=embed_name(variant, impl, d, m, s)))
    return cfgs


def gin_configs():
    return [
        dict(kind="gin_train", batch=GIN_BATCH_TRAIN, v=GIN_NODES,
             name=f"gin_train_b{GIN_BATCH_TRAIN}_v{GIN_NODES}"),
        dict(kind="gin_predict", batch=GIN_BATCH_PREDICT, v=GIN_NODES,
             name=f"gin_predict_b{GIN_BATCH_PREDICT}_v{GIN_NODES}"),
    ]


def all_configs():
    return rf_configs() + embed_configs() + gin_configs()
