"""L2: the GSA-phi compute graphs and the GIN baseline, in jax.

Everything here is build-time only: aot.py lowers these functions to HLO
text which the rust runtime loads via PJRT. Nothing in this file runs on
the request path.

Artifact families
-----------------
rf features   : (B, d) batch of flattened graphlet adjacencies (or sorted
                eigenvalue vectors for the Gs+eig variant, d = k) plus the
                random-feature parameters -> (B, m) features. The rust
                coordinator averages features per graph (eq. 3), which
                keeps s (samples per graph) flexible at runtime.
gsa embed     : (s, d) subgraphs of ONE graph -> (m,) mean embedding, the
                fused fast path used when s is fixed; saves transferring
                (s, m) back to the host.
gin train/qry : the GNN baseline of Fig 1 (right): 5 GIN layers (hidden 4)
                + 2 fully-connected layers, trained with Adam from rust.

Eigenvalue note: phi_Gs+eig(F) = phi_Gs(lambda(F)). We deliberately do NOT
lower eigvalsh: on CPU it becomes a LAPACK custom-call that xla_extension
0.5.1 cannot execute. The rust side computes sorted eigenvalues with its
own Jacobi solver (k <= 8) and feeds them to a d = k gaussian artifact.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import random_features as rf
from .kernels import ref


# --------------------------------------------------------------------------
# Random-feature artifact bodies
# --------------------------------------------------------------------------

def rf_features(variant: str, impl: str):
    """Return the (B,d)->(B,m) feature function for a variant/impl pair.

    variant: 'opu' (x, wr, wi, br, bi) or 'gauss' (x, w, b)
    impl:    'pallas' (L1 kernel) or 'xla' (pure-jnp reference body)
    """
    if variant == "opu":
        return rf.opu_rf_pallas if impl == "pallas" else ref.opu_rf
    if variant == "gauss":
        return rf.gaussian_rf_pallas if impl == "pallas" else ref.gaussian_rf
    raise ValueError(f"unknown variant {variant!r}")


def gsa_embed(variant: str, impl: str):
    """(s, d) subgraph batch of one graph -> (m,) mean embedding (eq. 3)."""
    feat = rf_features(variant, impl)

    def embed(x, *params):
        return jnp.mean(feat(x, *params), axis=0)

    return embed


# --------------------------------------------------------------------------
# GIN baseline (Fig 1 right): 5 GIN layers, hidden width 4, 2 FC layers
# --------------------------------------------------------------------------

GIN_LAYERS = 5
GIN_HIDDEN = 4
GIN_CLASSES = 2
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def gin_param_shapes(in_dim: int = 1):
    """Ordered list of (name, shape) for all GIN parameters.

    Order is the wire format between aot.py's manifest and the rust gnn
    driver: parameters are passed positionally in exactly this order.
    """
    shapes = []
    d = in_dim
    for layer in range(GIN_LAYERS):
        shapes.append((f"gin{layer}_w1", (d, GIN_HIDDEN)))
        shapes.append((f"gin{layer}_b1", (GIN_HIDDEN,)))
        shapes.append((f"gin{layer}_w2", (GIN_HIDDEN, GIN_HIDDEN)))
        shapes.append((f"gin{layer}_b2", (GIN_HIDDEN,)))
        d = GIN_HIDDEN
    shapes.append(("fc1_w", (GIN_HIDDEN, GIN_HIDDEN)))
    shapes.append(("fc1_b", (GIN_HIDDEN,)))
    shapes.append(("fc2_w", (GIN_HIDDEN, GIN_CLASSES)))
    shapes.append(("fc2_b", (GIN_CLASSES,)))
    return shapes


def gin_init_params(key, in_dim: int = 1):
    """Glorot-ish init, returned as a flat list in gin_param_shapes order.

    Biases start small-positive: with hidden width 4, a zero-bias ReLU
    layer can initialize fully dead, which is a permanent fixed point
    (zero activations and zero gradients). Mirrors rust gnn::GinModel.
    """
    params = []
    for _, shape in gin_param_shapes(in_dim):
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            scale = math.sqrt(2.0 / (shape[0] + shape[1]))
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
        else:
            params.append(jnp.full(shape, 0.05, jnp.float32))
    return params


def gin_forward(params, adj):
    """GIN forward pass on dense adjacency.

    Args:
      params: flat list in gin_param_shapes order.
      adj: (B, v, v) float adjacency matrices (no node features available:
           input feature = degree / v, per the structure-only protocol).
    Returns: (B, 2) class logits.
    """
    v = adj.shape[-1]
    h = jnp.sum(adj, axis=-1, keepdims=True) / float(v)  # (B, v, 1) degrees
    idx = 0
    for _ in range(GIN_LAYERS):
        w1, b1, w2, b2 = params[idx : idx + 4]
        idx += 4
        # (1 + eps) * h + sum_neighbours h, eps fixed at 0 (GIN-0)
        z = h + adj @ h
        z = jax.nn.relu(z @ w1 + b1)
        h = jax.nn.relu(z @ w2 + b2)
    g = jnp.sum(h, axis=1)  # (B, hidden) sum readout
    fc1_w, fc1_b, fc2_w, fc2_b = params[idx : idx + 4]
    g = jax.nn.relu(g @ fc1_w + fc1_b)
    return g @ fc2_w + fc2_b


def gin_loss(params, adj, labels):
    """Mean softmax cross-entropy over the batch; labels (B,) int32."""
    logits = gin_forward(params, adj)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def gin_train_step(lr: float = 1e-2):
    """Build the Adam train-step function lowered for the rust driver.

    Signature (all f32 unless noted):
      (step, adj(B,v,v), labels(B,) i32, *params, *adam_m, *adam_v)
        -> (loss, *new_params, *new_m, *new_v)
    `step` is the 1-based Adam timestep as an f32 scalar.
    """
    n = len(gin_param_shapes())

    def train_step(step, adj, labels, *state):
        params = list(state[:n])
        m_st = list(state[n : 2 * n])
        v_st = list(state[2 * n :])
        loss, grads = jax.value_and_grad(gin_loss)(params, adj, labels)
        bc1 = 1.0 - ADAM_B1**step
        bc2 = 1.0 - ADAM_B2**step
        new_p, new_m, new_v = [], [], []
        for p, g, mm, vv in zip(params, grads, m_st, v_st):
            mm = ADAM_B1 * mm + (1.0 - ADAM_B1) * g
            vv = ADAM_B2 * vv + (1.0 - ADAM_B2) * g * g
            p = p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS)
            new_p.append(p)
            new_m.append(mm)
            new_v.append(vv)
        return (loss, *new_p, *new_m, *new_v)

    return train_step


def gin_predict(params_and_adj_sig=None):
    """(adj, *params) -> (B,) int32 argmax class prediction + (B,2) logits."""

    def predict(adj, *params):
        logits = gin_forward(list(params), adj)
        return (jnp.argmax(logits, axis=-1).astype(jnp.int32), logits)

    return predict
