//! Integration tests for the observability layer (`crate::obs` + the
//! `metrics` / `trace` serve ops).
//!
//! Pins the PR's acceptance contract:
//! - histogram bucket boundaries straddle powers of two exactly, and
//!   percentiles are a deterministic function of the bucket array;
//! - the span ring evicts oldest-first at capacity, and a span deposits
//!   **exactly once** no matter how many threads held handles on it;
//! - a live daemon's `metrics` op shows non-zero queue-wait /
//!   projection / cache-probe histograms after real traffic, and the
//!   `trace` op returns the request's stage stamps;
//! - with `slow_ms = 0` every request is captured as a slow span,
//!   each exactly once;
//! - tracing on vs off is **bitwise invisible** to embeddings, and so
//!   is a client hammering the HTTP `/metrics` endpoint during traffic;
//! - two in-process daemons report fully isolated registries;
//! - a live daemon's `/metrics` scrape passes a Prometheus text-format
//!   lint (HELP/TYPE before samples, cumulative monotone `le` series,
//!   `+Inf` == `_count`);
//! - the sampling profiler at full rate (997 Hz) is **bitwise
//!   invisible** to embeddings, the `profile` op and `/profile`
//!   endpoint only ever emit stages from the closed vocabulary, and
//!   busy fractions separate a spinning thread from a sleeping one.
//!
//! Registries are **instance-scoped** — every daemon owns one — so the
//! daemon-side count assertions here are direct equalities on exact
//! values against a fresh daemon, no before/after delta-diffing, even
//! though the harness runs tests concurrently in one process.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use graphlet_rf::coordinator::{
    embed_dataset, fwht_threads_from_env_or, EngineMode, GraphJob, GsaConfig, StreamingPipeline,
};
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::obs::metrics::{bucket_index, bucket_upper_us, NUM_BUCKETS, OVERFLOW_BUCKET};
use graphlet_rf::obs::profile::is_stage;
use graphlet_rf::obs::{cpu_clock_supported, Registry, SpanRing, ThreadRegistry, TraceCtx};
use graphlet_rf::serve::{embed_request, parse_embed_reply, send_shutdown, ServeConfig, Server};
use graphlet_rf::util::{Json, Rng};

// ---------------------------------------------------------------------------
// Histogram bucket battery
// ---------------------------------------------------------------------------

#[test]
fn bucket_boundaries_straddle_powers_of_two() {
    // Bucket 0 is exactly zero.
    assert_eq!(bucket_index(0), 0);
    assert_eq!(bucket_upper_us(0), Some(0));

    // Every finite bucket i covers [2^(i-1), 2^i): both edges land
    // inside it, and one below the lower edge lands in the previous.
    for i in 1..OVERFLOW_BUCKET {
        let lo = 1u64 << (i - 1);
        let hi = (1u64 << i) - 1;
        assert_eq!(bucket_index(lo), i, "lower edge of bucket {i}");
        assert_eq!(bucket_index(hi), i, "upper edge of bucket {i}");
        assert_eq!(bucket_index(lo - 1), i - 1, "just below bucket {i}");
        assert_eq!(bucket_upper_us(i), Some(hi), "inclusive upper bound of bucket {i}");
    }

    // The overflow bucket starts at 2^39 µs and has no static bound.
    assert_eq!(bucket_index((1u64 << 39) - 1), OVERFLOW_BUCKET - 1);
    assert_eq!(bucket_index(1u64 << 39), OVERFLOW_BUCKET);
    assert_eq!(bucket_index(u64::MAX), OVERFLOW_BUCKET);
    assert_eq!(bucket_upper_us(OVERFLOW_BUCKET), None);
    assert_eq!(NUM_BUCKETS, OVERFLOW_BUCKET + 1);
}

#[test]
fn percentiles_are_a_pure_function_of_the_buckets() {
    let r = Registry::new();

    // Empty histogram: all percentiles 0.
    let _ = r.histo("t.empty");
    let snap = r.histo_snapshot("t.empty").unwrap();
    assert_eq!(snap.percentile_us(50.0), 0);
    assert_eq!(snap.percentile_us(99.0), 0);

    // 1..=100 µs: p50 rank 50 falls in bucket [32,63] (cumulative
    // 1+2+4+8+16+32 = 63 ≥ 50), p99 rank 99 in bucket [64,127]
    // (cumulative 100). The exact max rides along.
    let h = r.histo("t.lat");
    for us in 1..=100u64 {
        h.record_us(us);
    }
    let snap = r.histo_snapshot("t.lat").unwrap();
    assert_eq!(snap.count, 100);
    assert_eq!(snap.max_us, 100);
    assert_eq!(snap.percentile_us(50.0), 63);
    assert_eq!(snap.percentile_us(99.0), 127);
    assert_eq!(snap.percentile_us(100.0), 127);
    assert!((snap.mean_us() - 50.5).abs() < 1e-9);

    // Overflow-bucket percentile reports the exact recorded max, not a
    // fictitious power of two.
    let h = r.histo("t.over");
    h.record_us(1u64 << 39);
    h.record_us((1u64 << 39) + 12345);
    let snap = r.histo_snapshot("t.over").unwrap();
    assert_eq!(snap.percentile_us(50.0), (1u64 << 39) + 12345);

    // Same multiset, different insertion order → identical snapshots
    // (the determinism the cross-PR perf comparisons rely on).
    let a = r.histo("t.fwd");
    let b = r.histo("t.rev");
    for us in [0u64, 1, 7, 8, 100, 4096, 1_000_000] {
        a.record_us(us);
    }
    for us in [1_000_000u64, 4096, 100, 8, 7, 1, 0] {
        b.record_us(us);
    }
    let (sa, sb) = (r.histo_snapshot("t.fwd").unwrap(), r.histo_snapshot("t.rev").unwrap());
    assert_eq!(sa.buckets, sb.buckets);
    for p in [50.0, 90.0, 99.0] {
        assert_eq!(sa.percentile_us(p), sb.percentile_us(p), "p{p}");
    }
}

// ---------------------------------------------------------------------------
// Span ring
// ---------------------------------------------------------------------------

#[test]
fn ring_evicts_oldest_and_recent_n_returns_newest() {
    let ring = SpanRing::new(4, u64::MAX);
    for tag in 0..9u64 {
        drop(TraceCtx::new("embed", tag, ring.clone()));
    }
    let tags: Vec<u64> = ring.recent(100).iter().map(|s| s.tag).collect();
    assert_eq!(tags, [5, 6, 7, 8], "capacity 4: oldest five evicted, order preserved");
    let tail: Vec<u64> = ring.recent(2).iter().map(|s| s.tag).collect();
    assert_eq!(tail, [7, 8]);
    assert_eq!(ring.slow_emitted(), 0, "slow capture disabled at u64::MAX");
    assert!(ring.slow().is_empty());
}

#[test]
fn span_deposits_exactly_once_across_threads() {
    // slow_ms = 0 marks every span slow, so `slow_emitted` counts
    // deposits — the emission site runs once per span, inside Drop.
    let ring = SpanRing::new(64, 0);
    for tag in 0..8u64 {
        let t = TraceCtx::new("embed", tag, ring.clone());
        let stampers: Vec<_> = (0..4)
            .map(|_| {
                let c = t.clone();
                std::thread::spawn(move || {
                    c.stamp("projection");
                })
            })
            .collect();
        drop(t);
        for h in stampers {
            h.join().unwrap();
        }
    }
    assert_eq!(ring.slow_emitted(), 8, "one deposit per span, however many handles");
    assert_eq!(ring.recent(64).len(), 8);
    let mut tags: Vec<u64> = ring.slow().iter().map(|s| s.tag).collect();
    tags.sort_unstable();
    assert_eq!(tags, (0..8).collect::<Vec<_>>(), "no span captured twice");
}

// ---------------------------------------------------------------------------
// Live-daemon round trips
// ---------------------------------------------------------------------------

fn test_gsa() -> GsaConfig {
    GsaConfig {
        k: 3,
        s: 100,
        m: 64,
        batch: 32,
        workers: 3,
        shards: 2,
        // Same engine/threads matrix discipline as tests/serve.rs: the
        // observability contract is engine-agnostic.
        engine: EngineMode::from_env_or(EngineMode::Cpu),
        fwht_threads: fwht_threads_from_env_or(1),
        seed: 42,
        ..Default::default()
    }
}

fn start_server(cfg: ServeConfig) -> (SocketAddr, JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", cfg, None).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

/// Like [`start_server`] but with an ephemeral HTTP sidecar attached;
/// also returns the sidecar's address.
fn start_server_http(cfg: ServeConfig) -> (SocketAddr, SocketAddr, JoinHandle<()>) {
    let server =
        Server::bind("127.0.0.1:0", ServeConfig { http_port: Some(0), ..cfg }, None).unwrap();
    let addr = server.local_addr();
    let http = server.http_addr().expect("http sidecar requested");
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, http, handle)
}

/// One-shot GET against the HTTP sidecar: returns (status line, body).
fn http_get(http: SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(http).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: test\r\nAccept: text/plain\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("malformed HTTP reply");
    let status = head.lines().next().unwrap_or("").to_string();
    (status, body.to_string())
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server closed the connection");
        reply
    }
}

fn histo_count(metrics: &Json, name: &str) -> u64 {
    metrics
        .get("histograms")
        .and_then(|h| h.get(name))
        .and_then(|h| h.get("count"))
        .and_then(Json::as_u64)
        .unwrap_or(0)
}

fn counter_value(metrics: &Json, name: &str) -> u64 {
    metrics.get("counters").and_then(|c| c.get(name)).and_then(Json::as_u64).unwrap_or(0)
}

/// Spans deposit when the *last* handle drops — for pipeline-computed
/// rows the shard briefly holds a clone after the client has already
/// read its reply, so ring-content assertions poll.
fn poll_trace<F: Fn(&Json) -> bool>(client: &mut Client, pred: F, what: &str) -> Json {
    for _ in 0..200 {
        // n = the daemon's full ring depth, so the polling's own trace
        // spans can't push the spans under test out of the window.
        let reply = client.roundtrip(r#"{"op":"trace","id":7,"n":256}"#);
        let j = Json::parse(reply.trim()).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{reply}");
        if pred(&j) {
            return j;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    panic!("trace op never showed: {what}");
}

/// Does any span in the reply's `spans` array have this op and carry
/// all of these stage stamps?
fn has_span_with(j: &Json, op: &str, stages: &[&str]) -> bool {
    let Some(spans) = j.get("spans").and_then(Json::as_array) else {
        return false;
    };
    spans.iter().any(|s| {
        s.get("op").and_then(Json::as_str) == Some(op)
            && stages.iter().all(|st| {
                s.get("stages").and_then(|m| m.get(st)).and_then(Json::as_u64).is_some()
            })
    })
}

#[test]
fn metrics_and_trace_ops_roundtrip_against_a_live_daemon() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let (addr, server) = start_server(ServeConfig { gsa: test_gsa(), ..Default::default() });
    let mut client = Client::connect(addr);

    let before = Json::parse(client.roundtrip(r#"{"op":"metrics","id":1}"#).trim()).unwrap();
    assert_eq!(before.get("ok").and_then(Json::as_bool), Some(true));
    // The snapshot shape is scrapable: bucket bounds ride along once.
    let uppers = before.get("bucket_uppers_us").and_then(Json::as_array).unwrap();
    assert_eq!(uppers.len(), OVERFLOW_BUCKET);
    // The registry is this daemon's own: a fresh daemon starts at zero,
    // whatever the other tests in this process are doing concurrently.
    assert_eq!(histo_count(&before, "serve.request_us.embed"), 0);

    // Fresh graph indices force every embed through the pipeline.
    let n = ds.len();
    for g in 0..n {
        let (_, row, cached) =
            parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
                .unwrap();
        assert_eq!(row.len(), 64);
        assert!(!cached, "graph {g} must be a cold miss");
    }

    // Acceptance criterion: after real traffic the stage histograms
    // moved — direct values, no deltas (instance-scoped registry). The
    // daemon records the request histogram before flushing the reply
    // bytes, so the embed count is already final here: exactly n, this
    // client being the daemon's only traffic source.
    let after = Json::parse(client.roundtrip(r#"{"op":"metrics","id":2}"#).trim()).unwrap();
    for name in
        ["pipeline.queue_wait_us", "shard.projection_us", "cache.probe_us", "shard.batch_wait_us"]
    {
        assert!(histo_count(&after, name) > 0, "{name} must move under embed traffic: {after}");
    }
    let embeds = histo_count(&after, "serve.request_us.embed");
    assert_eq!(embeds, n as u64, "daemon counted {embeds} embeds, client sent exactly {n}");

    // The trace op returns the spans with their stage stamps. The
    // pipeline path stamps cache_probe → admission → queue_wait →
    // projection → reply_write into one span.
    let j = poll_trace(
        &mut client,
        |j| {
            has_span_with(
                j,
                "embed",
                &["cache_probe", "admission", "queue_wait", "projection", "reply_write"],
            )
        },
        "an embed span with all pipeline stages",
    );
    assert!(j.get("slow_emitted").and_then(Json::as_u64).is_some());
    assert!(j.get("slow").and_then(Json::as_array).is_some());

    // Span totals are monotone vs their own stamps: every stage offset
    // was taken before the span closed.
    for s in j.get("spans").and_then(Json::as_array).unwrap() {
        let total = s.get("total_us").and_then(Json::as_u64).unwrap();
        if let Some(Json::Obj(stages)) = s.get("stages") {
            for (name, at) in stages {
                let at = at.as_u64().unwrap();
                assert!(at <= total, "stage {name} stamped after the span closed");
            }
        }
    }

    // Malformed trace op: n must be positive; the error is per-request.
    let reply = client.roundtrip(r#"{"op":"trace","id":9,"n":0}"#);
    assert!(reply.contains("\"ok\":false"), "{reply}");
    assert!(reply.contains("positive"), "{reply}");
    let pong = client.roundtrip(r#"{"op":"ping","id":10}"#);
    assert!(pong.contains("\"ok\":true"), "{pong}");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

#[test]
fn slow_ms_zero_captures_every_request_exactly_once() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let mut gsa = test_gsa();
    gsa.s = 50;
    gsa.m = 16;
    // Every span is "slow" — the GRAPHLET_RF_TEST_OBS CI axis flips the
    // same switch for the whole serve suite via the config default.
    let (addr, server) = start_server(ServeConfig { gsa, slow_ms: 0, ..Default::default() });
    let mut client = Client::connect(addr);

    let n = 4usize;
    for g in 0..n {
        parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]))).unwrap();
    }

    // All n embed spans land in the slow list (deposit may lag the
    // reply — poll), and none lands twice: request ids are unique, so
    // duplicate (op, tag) pairs would mean a double deposit.
    let j = poll_trace(
        &mut client,
        |j| {
            let Some(slow) = j.get("slow").and_then(Json::as_array) else { return false };
            slow.iter()
                .filter(|s| s.get("op").and_then(Json::as_str) == Some("embed"))
                .count()
                >= n
        },
        "every embed captured as a slow span",
    );
    let mut embed_tags: Vec<u64> = j
        .get("slow")
        .and_then(Json::as_array)
        .unwrap()
        .iter()
        .filter(|s| s.get("op").and_then(Json::as_str) == Some("embed"))
        .map(|s| s.get("tag").and_then(Json::as_u64).unwrap())
        .collect();
    embed_tags.sort_unstable();
    let deduped = {
        let mut t = embed_tags.clone();
        t.dedup();
        t
    };
    assert_eq!(embed_tags, deduped, "a slow span was captured twice");
    assert_eq!(embed_tags, (0..n as u64).collect::<Vec<_>>());

    // The counter behind the stderr lines saw at least those spans
    // (trace/metrics requests on this daemon are slow too — ≥, not ==).
    let emitted = j.get("slow_emitted").and_then(Json::as_u64).unwrap();
    assert!(emitted >= n as u64, "slow_emitted = {emitted}");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

// ---------------------------------------------------------------------------
// Instance-scoped registries + the HTTP scrape endpoint
// ---------------------------------------------------------------------------

/// Two in-process daemons must report fully isolated numbers: direct
/// value asserts on each one's registry, no delta-diffing. If the
/// registries were shared, A would see B's errors and B would see A's
/// embeds.
#[test]
fn two_daemons_report_fully_isolated_registries() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let mk_gsa = || {
        let mut g = test_gsa();
        g.s = 50;
        g.m = 16;
        g
    };
    let (addr_a, server_a) = start_server(ServeConfig { gsa: mk_gsa(), ..Default::default() });
    let (addr_b, server_b) = start_server(ServeConfig { gsa: mk_gsa(), ..Default::default() });
    let mut a = Client::connect(addr_a);
    let mut b = Client::connect(addr_b);

    // A: exactly 3 clean embeds. B: exactly 1 embed plus 2 parse
    // errors (op "error" — the request never parsed far enough to name
    // one).
    for g in 0..3 {
        parse_embed_reply(&a.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]))).unwrap();
    }
    parse_embed_reply(&b.roundtrip(&embed_request(0, 0, &ds.graphs[0]))).unwrap();
    for _ in 0..2 {
        let reply = b.roundtrip("this is not json");
        assert!(reply.contains("\"ok\":false"), "{reply}");
    }

    let ma = Json::parse(a.roundtrip(r#"{"op":"metrics","id":50}"#).trim()).unwrap();
    let mb = Json::parse(b.roundtrip(r#"{"op":"metrics","id":51}"#).trim()).unwrap();
    assert_eq!(histo_count(&ma, "serve.request_us.embed"), 3, "A's exact embed count");
    assert_eq!(histo_count(&mb, "serve.request_us.embed"), 1, "B's exact embed count");
    assert_eq!(counter_value(&ma, "serve.errors.error"), 0, "A saw no errors");
    assert_eq!(counter_value(&mb, "serve.errors.error"), 2, "B's exact error count");

    // The stats op surfaces the same per-op error counts.
    let errs = |j: &Json, op: &str| {
        j.get("server")
            .and_then(|s| s.get("errors_by_op"))
            .and_then(|e| e.get(op))
            .and_then(Json::as_u64)
            .unwrap_or(0)
    };
    let sa = Json::parse(a.roundtrip(r#"{"op":"stats","id":52}"#).trim()).unwrap();
    let sb = Json::parse(b.roundtrip(r#"{"op":"stats","id":53}"#).trim()).unwrap();
    assert_eq!(errs(&sa, "error"), 0);
    assert_eq!(errs(&sb, "error"), 2);

    drop(a);
    drop(b);
    send_shutdown(&addr_a.to_string()).unwrap();
    send_shutdown(&addr_b.to_string()).unwrap();
    server_a.join().unwrap();
    server_b.join().unwrap();
}

/// Scraping `/metrics` in a tight loop for the whole traffic window
/// must not move an embedding bit: the scraped daemon's rows are
/// bitwise identical to an unscraped reference daemon's.
#[test]
fn continuous_metrics_scraping_changes_no_embedding_bits() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let n = ds.len();

    // Reference rows from a plain daemon, no HTTP sidecar.
    let (addr, server) = start_server(ServeConfig { gsa: test_gsa(), ..Default::default() });
    let mut client = Client::connect(addr);
    let mut want = Vec::with_capacity(n);
    for g in 0..n {
        let (_, row, _) =
            parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
                .unwrap();
        want.push(row);
    }
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // The same config with a sidecar being hammered concurrently.
    let (addr, http, server) =
        start_server_http(ServeConfig { gsa: test_gsa(), ..Default::default() });
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let (status, body) = http_get(http, "/metrics");
                assert_eq!(status, "HTTP/1.1 200 OK", "scrape {scrapes} failed");
                assert!(body.contains("graphlet_rf_build_info{"), "scrape {scrapes} lost build info");
                scrapes += 1;
            }
            scrapes
        })
    };
    let mut client = Client::connect(addr);
    for g in 0..n {
        let (_, row, _) =
            parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
                .unwrap();
        for (i, (a, b)) in want[g].iter().zip(&row).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "graph {g} dim {i}: scraping moved a bit");
        }
    }
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().unwrap();
    assert!(scrapes > 0, "the scraper never completed a scrape");
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// A live daemon's `/metrics` scrape, after a little of everything
/// (embeds, an error), must pass the exposition-format lint.
#[test]
fn live_scrape_passes_the_exposition_format_lint() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let mut gsa = test_gsa();
    gsa.s = 50;
    gsa.m = 16;
    let (addr, http, server) = start_server_http(ServeConfig { gsa, ..Default::default() });
    let mut client = Client::connect(addr);
    for g in 0..2 {
        parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]))).unwrap();
    }
    let reply = client.roundtrip("not json");
    assert!(reply.contains("\"ok\":false"), "{reply}");

    let (status, body) = http_get(http, "/metrics");
    assert_eq!(status, "HTTP/1.1 200 OK");
    // Exact counts — this client is the daemon's only traffic source.
    assert!(
        body.contains(r#"serve_request_us_count{op="embed"} 2"#),
        "exact embed count missing:\n{body}"
    );
    assert!(
        body.contains(r#"serve_errors{op="error"} 1"#),
        "exact error count missing:\n{body}"
    );
    lint_prometheus_text(&body);

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// Structural lint for Prometheus text format v0.0.4: every sample's
/// family has `# HELP` and `# TYPE` lines before its first sample;
/// histogram `le` series are strictly increasing with monotone
/// cumulative values, end at `+Inf`, and the `+Inf` value equals the
/// `_count` sample for the same label set. Label parsing here splits on
/// commas, which is fine for the daemon's label values (op names never
/// contain commas or quotes).
fn lint_prometheus_text(body: &str) {
    use std::collections::{BTreeMap, HashSet};
    let mut helped: HashSet<String> = HashSet::new();
    let mut typed: HashSet<String> = HashSet::new();
    let mut buckets: BTreeMap<(String, String), Vec<(f64, u64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), u64> = BTreeMap::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            helped.insert(rest.split_whitespace().next().unwrap().to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            typed.insert(rest.split_whitespace().next().unwrap().to_string());
            continue;
        }
        assert!(!line.starts_with('#'), "unknown comment form: {line}");
        let name_end = line
            .find(|c| c == '{' || c == ' ')
            .unwrap_or_else(|| panic!("unparseable sample: {line}"));
        let name = &line[..name_end];
        // `_bucket`/`_sum`/`_count` suffixes belong to a histogram
        // family; anything else (or a genuine metric ending in one of
        // those words with its own headers) is its own family.
        let family = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|f| typed.contains(*f))
            .unwrap_or(name)
            .to_string();
        assert!(helped.contains(&family), "sample before # HELP {family}: {line}");
        assert!(typed.contains(&family), "sample before # TYPE {family}: {line}");
        let (labels, value) = match line[name_end..].strip_prefix('{') {
            Some(rest) => {
                let close =
                    rest.rfind('}').unwrap_or_else(|| panic!("unclosed label braces: {line}"));
                (&rest[..close], rest[close + 1..].trim())
            }
            None => ("", line[name_end..].trim()),
        };
        let value: f64 =
            value.parse().unwrap_or_else(|_| panic!("unparseable sample value: {line}"));
        if name.ends_with("_bucket") {
            let (le, others) =
                split_le(labels).unwrap_or_else(|| panic!("bucket sample without le: {line}"));
            let le = if le == "+Inf" {
                f64::INFINITY
            } else {
                le.parse().unwrap_or_else(|_| panic!("unparseable le {le:?}: {line}"))
            };
            buckets.entry((family, others)).or_default().push((le, value as u64));
        } else if name.ends_with("_count") && typed.contains(&family) && family != name {
            counts.insert((family, labels.to_string()), value as u64);
        }
    }
    assert!(!buckets.is_empty(), "no histogram series in the scrape");
    for ((family, labels), series) in &buckets {
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_v = 0u64;
        for (le, v) in series {
            assert!(*le > prev_le, "{family}{{{labels}}}: le not strictly increasing");
            assert!(*v >= prev_v, "{family}{{{labels}}}: cumulative value decreased at le={le}");
            prev_le = *le;
            prev_v = *v;
        }
        let (last_le, last_v) = series.last().unwrap();
        assert!(last_le.is_infinite(), "{family}{{{labels}}}: series does not end at +Inf");
        let count = counts
            .get(&(family.clone(), labels.clone()))
            .unwrap_or_else(|| panic!("{family}{{{labels}}}: no matching _count sample"));
        assert_eq!(last_v, count, "{family}{{{labels}}}: +Inf bucket != _count");
    }
}

/// Pull `le="…"` out of a bucket sample's label selector, returning the
/// value and the selector with the le pair removed.
fn split_le(labels: &str) -> Option<(String, String)> {
    let mut le = None;
    let mut others = Vec::new();
    for pair in labels.split(',') {
        match pair.strip_prefix("le=\"").and_then(|v| v.strip_suffix('"')) {
            Some(v) => le = Some(v.to_string()),
            None => others.push(pair),
        }
    }
    Some((le?, others.join(",")))
}

// ---------------------------------------------------------------------------
// Tracing must not move a bit
// ---------------------------------------------------------------------------

/// `embed_dataset` runs every job with a live `TraceCtx`; the same jobs
/// submitted by hand with `trace: None` must produce bitwise-identical
/// rows. This is the pin that lets every other layer record freely.
#[test]
fn tracing_on_and_off_are_bitwise_identical() {
    let gsa = test_gsa();
    let m = gsa.m;
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let n = ds.len();

    // Traced: the production path.
    let (want, _) = embed_dataset(&ds, &gsa, None).unwrap();

    // Untraced: identical jobs, trace: None.
    let pipeline = StreamingPipeline::new(&gsa, None).unwrap();
    let seeds = pipeline.graph_seeds(n);
    let (tx, rx) = mpsc::channel();
    for (g_idx, g) in ds.graphs.iter().enumerate() {
        pipeline
            .submit(GraphJob {
                graph: Arc::new(g.clone()),
                seed: seeds[g_idx],
                tag: g_idx as u64,
                done: tx.clone(),
                trace: None,
            })
            .unwrap();
    }
    drop(tx);
    let mut got = vec![0.0f32; n * m];
    let mut seen = 0usize;
    for done in rx {
        assert!(done.error.is_none(), "job {}: {:?}", done.tag, done.error);
        let g = done.tag as usize;
        got[g * m..(g + 1) * m].copy_from_slice(&done.row);
        seen += 1;
    }
    assert_eq!(seen, n);
    pipeline.shutdown().unwrap();

    for g in 0..n {
        for (i, (a, b)) in want[g * m..(g + 1) * m]
            .iter()
            .zip(&got[g * m..(g + 1) * m])
            .enumerate()
        {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "graph {g} dim {i}: traced {a} vs untraced {b}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Sampling profiler
// ---------------------------------------------------------------------------

/// A daemon sampled at full rate (997 Hz, well above the 19 Hz
/// default) must produce rows bitwise identical to a profiler-off
/// daemon. The sampler only *reads* per-thread CPU clocks and stage
/// slots; this pin is what lets it stay always-on in production.
#[test]
fn full_rate_profiler_changes_no_embedding_bits() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let n = ds.len();

    // Reference rows with the profiler off.
    let (addr, server) =
        start_server(ServeConfig { gsa: test_gsa(), profile_hz: 0, ..Default::default() });
    let mut client = Client::connect(addr);
    let mut want = Vec::with_capacity(n);
    for g in 0..n {
        let (_, row, _) =
            parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
                .unwrap();
        want.push(row);
    }
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // The same config hammered by the sampler for the whole window.
    let (addr, server) =
        start_server(ServeConfig { gsa: test_gsa(), profile_hz: 997, ..Default::default() });
    let mut client = Client::connect(addr);
    for g in 0..n {
        let (_, row, _) =
            parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
                .unwrap();
        for (i, (a, b)) in want[g].iter().zip(&row).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "graph {g} dim {i}: sampling moved a bit");
        }
    }

    // The pin proves nothing if the sampler never actually ran.
    let j = Json::parse(client.roundtrip(r#"{"op":"profile","id":90}"#).trim()).unwrap();
    assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");
    assert!(
        j.get("ticks").and_then(Json::as_u64).unwrap() > 0,
        "997 Hz sampler never ticked during the traffic window: {j}"
    );

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// The `profile` op's stage table and thread list: every stage comes
/// from the closed vocabulary, the pipeline roles are registered, and
/// every busy fraction is a valid [0, 1] ratio.
#[test]
fn profile_op_reports_stage_table_and_thread_busy_fractions() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let (addr, server) =
        start_server(ServeConfig { gsa: test_gsa(), profile_hz: 499, ..Default::default() });
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]))).unwrap();
    }

    // Poll until the sampler has caught the live threads at least once.
    let mut j = Json::Null;
    for _ in 0..500 {
        j = Json::parse(client.roundtrip(r#"{"op":"profile","id":91}"#).trim()).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j}");
        if j.get("samples").and_then(Json::as_u64).unwrap_or(0) > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(j.get("op").and_then(Json::as_str), Some("profile"));
    assert_eq!(j.get("profile_hz").and_then(Json::as_u64), Some(499));
    assert!(j.get("cpu_clock").and_then(Json::as_bool).is_some());
    assert!(j.get("samples").and_then(Json::as_u64).unwrap_or(0) > 0, "sampler idle: {j}");

    // Stage table: closed vocabulary only, counts present on each row.
    let stages = j.get("stages").and_then(Json::as_array).unwrap();
    assert!(!stages.is_empty());
    for row in stages {
        let stage = row.get("stage").and_then(Json::as_str).unwrap();
        assert!(is_stage(stage), "unknown stage {stage:?} in {row}");
        assert!(!row.get("role").and_then(Json::as_str).unwrap().is_empty());
        for field in ["samples", "cpu_us", "entered"] {
            assert!(row.get(field).and_then(Json::as_u64).is_some(), "{field} missing: {row}");
        }
    }

    // Thread list: the long-lived pipeline roles all registered, and
    // busy is a fraction. (conn threads come and go; these four live
    // for the daemon.)
    let threads = j.get("threads").and_then(Json::as_array).unwrap();
    let roles: Vec<&str> =
        threads.iter().filter_map(|t| t.get("role").and_then(Json::as_str)).collect();
    for role in ["worker", "shard", "profiler", "conn_reader"] {
        assert!(roles.contains(&role), "role {role} not registered: {roles:?}");
    }
    for t in threads {
        let busy = t.get("busy").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&busy), "busy {busy} out of range: {t}");
        assert!(is_stage(t.get("stage").and_then(Json::as_str).unwrap()));
        assert!(t.get("cpu_us").and_then(Json::as_u64).is_some());
        assert!(t.get("wall_us").and_then(Json::as_u64).is_some());
    }

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// `/profile` emits collapsed-stack text: every line is exactly
/// `role;stage N` with a stage from the closed vocabulary, and the
/// traffic this test generated shows up as conn frames. `/debug/threads`
/// lists the registered threads as JSON.
#[test]
fn http_profile_collapsed_lines_use_the_stage_vocabulary() {
    let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11));
    let (addr, http, server) =
        start_server_http(ServeConfig { gsa: test_gsa(), profile_hz: 499, ..Default::default() });
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]))).unwrap();
    }

    let (status, body) = http_get(http, "/profile");
    assert_eq!(status, "HTTP/1.1 200 OK");
    assert!(!body.trim().is_empty(), "collapsed-stack output empty after traffic");
    for line in body.lines() {
        let (frame, weight) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no weight: {line}"));
        weight.parse::<u64>().unwrap_or_else(|_| panic!("bad weight: {line}"));
        let (role, stage) = frame.split_once(';').unwrap_or_else(|| panic!("no ';': {line}"));
        assert!(!role.is_empty(), "empty role: {line}");
        assert!(is_stage(stage), "stage {stage:?} not in the vocabulary: {line}");
    }
    // This client's requests ran through a conn reader; stage *entry*
    // counts surface deterministically even if sampling missed them.
    assert!(
        body.lines().any(|l| l.starts_with("conn_reader;")),
        "no conn_reader frame after real traffic:\n{body}"
    );

    let (status, body) = http_get(http, "/debug/threads");
    assert_eq!(status, "HTTP/1.1 200 OK");
    let j = Json::parse(&body).unwrap();
    assert!(j.get("cpu_clock").and_then(Json::as_bool).is_some());
    let threads = j.get("threads").and_then(Json::as_array).unwrap();
    assert!(!threads.is_empty());
    for t in threads {
        assert!(is_stage(t.get("stage").and_then(Json::as_str).unwrap()));
        let busy = t.get("busy").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&busy), "busy {busy} out of range: {t}");
    }

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// Direct registry exercise: register/deregister lifecycle and the
/// busy-fraction contract — a spinning thread attributes (nearly) all
/// of its wall time to CPU, a sleeping thread almost none.
#[test]
fn busy_fractions_separate_spin_from_sleep() {
    let reg = Arc::new(ThreadRegistry::default());
    let stop = Arc::new(AtomicBool::new(false));
    let spinner = {
        let (reg, stop) = (reg.clone(), stop.clone());
        std::thread::spawn(move || {
            let prof = reg.register("worker", 0);
            prof.set_stage("spin");
            let mut x = 0u64;
            while !stop.load(Ordering::Relaxed) {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            x
        })
    };
    let sleeper = {
        let (reg, stop) = (reg.clone(), stop.clone());
        std::thread::spawn(move || {
            let prof = reg.register("worker", 1);
            prof.set_stage("sleep");
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        })
    };

    // Give both threads a real window, sampling as a profiler would.
    for _ in 0..20 {
        std::thread::sleep(std::time::Duration::from_millis(10));
        reg.sample_once();
    }
    let snap = reg.snapshot();
    let busy_of = |idx: usize| {
        snap.iter()
            .find(|t| t.role == "worker" && t.index == idx)
            .unwrap_or_else(|| panic!("worker {idx} not registered: {snap:?}"))
            .busy
    };
    for idx in [0, 1] {
        assert!((0.0..=1.0).contains(&busy_of(idx)), "busy {} out of range", busy_of(idx));
    }
    if cpu_clock_supported() {
        // Thresholds leave wide margins for CI noise; without a
        // per-thread CPU clock busy falls back to wall time and the
        // two are indistinguishable.
        assert!(busy_of(0) >= 0.5, "spinning thread busy = {}", busy_of(0));
        assert!(busy_of(1) <= 0.1, "sleeping thread busy = {}", busy_of(1));
    }

    // Deregistration: after the guards drop, the next sample prunes the
    // slots from the live list but keeps their stage history.
    stop.store(true, Ordering::Relaxed);
    spinner.join().unwrap();
    sleeper.join().unwrap();
    reg.sample_once();
    assert!(
        reg.snapshot().iter().all(|t| t.role != "worker"),
        "deregistered threads still listed"
    );
    let table = reg.stage_table();
    for stage in ["spin", "sleep"] {
        let row = table
            .iter()
            .find(|r| r.role == "worker" && r.stage == stage)
            .unwrap_or_else(|| panic!("stage {stage} lost at deregistration"));
        assert!(row.entered >= 1, "stage {stage} entry count lost");
    }
}
