//! CLI contract tests, run against the real binary
//! (`CARGO_BIN_EXE_graphlet-rf`): `help` goes to stdout with exit 0,
//! unrecognized subcommands go to stderr with a nonzero exit.

use std::process::Command;

fn run(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_graphlet-rf"))
        .args(args)
        .output()
        .expect("spawning graphlet-rf")
}

#[test]
fn unknown_subcommand_exits_nonzero_with_usage_on_stderr() {
    let out = run(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2), "unknown subcommand must exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown subcommand"), "{stderr}");
    assert!(stderr.contains("\"frobnicate\""), "{stderr}");
    assert!(stderr.contains("USAGE"), "usage text must go to stderr: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!stdout.contains("USAGE"), "usage must not leak to stdout: {stdout}");
}

#[test]
fn help_prints_usage_to_stdout_and_exits_zero() {
    for args in [&["help"][..], &[][..]] {
        let out = run(args);
        assert!(out.status.success(), "help must exit 0");
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains("USAGE"), "{stdout}");
        assert!(stdout.contains("serve"), "help must mention the serve subcommand: {stdout}");
        assert!(stdout.contains("cpu-sorf"), "help must list the cpu-sorf engine: {stdout}");
        assert!(stdout.contains("--store-dir"), "help must document the store flag: {stdout}");
        assert!(stdout.contains("--cache-policy"), "help must document eviction: {stdout}");
        assert!(stdout.contains("--data-dir"), "help must document real TU data: {stdout}");
    }
}

/// `serve-bench --store-dir` through the real binary: hosts the daemon,
/// restarts it over the same segment log, and self-checks that the
/// `warm_l2` pass recomputed nothing. The last stdout line is the
/// machine-readable JSON result.
#[test]
fn serve_bench_restart_mode_reports_all_three_passes() {
    let dir = std::env::temp_dir()
        .join(format!("graphlet_cli_storebench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = run(&[
        "serve-bench",
        "--store-dir",
        dir.to_str().unwrap(),
        "--clients",
        "2",
        "--requests",
        "4",
        "--engine",
        "cpu",
        "--k",
        "3",
        "--s",
        "40",
        "--m",
        "16",
        "--batch",
        "8",
        "--workers",
        "2",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "restart bench failed:\n{stdout}\n{stderr}");
    for label in ["cold:", "warm_l1:", "warm_l2:"] {
        assert!(stdout.contains(label), "missing pass {label}:\n{stdout}");
    }
    assert!(
        stdout.contains("warm_l2: requests=8 errors=0 cached=8 recomputed=0"),
        "restart pass must serve everything from the store:\n{stdout}"
    );
    let json = stdout.lines().last().unwrap_or_default();
    assert!(
        json.contains("\"bench\":\"serve\"") && json.contains("\"label\":\"warm_l2\""),
        "last line must be the JSON result: {json}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--engine cpu-sorf` runs the full quickstart flow (SBM → sampling →
/// SORF features → SVM) through the real binary.
#[test]
fn quickstart_runs_with_cpu_sorf_engine() {
    let out = run(&[
        "quickstart",
        "--engine",
        "cpu-sorf",
        "--per-class",
        "4",
        "--k",
        "3",
        "--s",
        "50",
        "--m",
        "32",
        "--batch",
        "16",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "quickstart --engine cpu-sorf failed:\n{stdout}\n{stderr}");
    assert!(stdout.contains("CpuSorf"), "run banner must show the engine: {stdout}");
    assert!(stdout.contains("test accuracy"), "{stdout}");
}

/// A bogus engine name is a graceful CLI error naming the accepted
/// engines (cpu-sorf included), not a panic.
#[test]
fn unknown_engine_is_graceful_error() {
    let out = run(&["quickstart", "--engine", "warp-drive"]);
    assert!(!out.status.success(), "bogus engine must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown engine"), "{stderr}");
    assert!(stderr.contains("cpu-sorf"), "error must list cpu-sorf: {stderr}");
    assert!(!stderr.contains("panicked"), "must be an error, not a panic: {stderr}");
}
