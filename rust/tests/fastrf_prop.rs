//! Differential battery for the batch-major FWHT/SORF execution path.
//!
//! The PR 4 refactor rewrote the SORF hot loop from row-at-a-time to
//! batch-major panels with an optional thread budget; its whole
//! contract is that no execution shape moves a single bit. This
//! battery pins that, seeded and randomized, across the full grid:
//!
//! - `fwht_batch` / `fwht_batch_par` vs the scalar `fwht_inplace` vs
//!   the naive `O(p²)` sign-sum reference, for every power of two
//!   `p ≤ 4096` and batch sizes `{1, 3, B, B+1}` (B = the test
//!   pipeline's compiled batch size);
//! - the involution law `H(Hx) = p·x`, exact on `{-1, 0, 1}` inputs
//!   (all intermediates stay ≤ 2²⁴, so f32 arithmetic is exact);
//! - `SorfMap::map_batch_threads` / `DenseMap::map_batch_threads` vs
//!   their row-at-a-time scalar evaluation, across thread budgets.
//!
//! The thread axis additionally honors `GRAPHLET_RF_TEST_THREADS`
//! (the CI matrix runs 1 and 4) so the parallel path is exercised on
//! every push, not just where a test hardcodes it.

use graphlet_rf::coordinator::fwht_threads_from_env_or;
use graphlet_rf::fastrf::{
    fwht_batch, fwht_batch_par, fwht_inplace, naive_hadamard, DenseMap, SorfMap, SorfParams,
};
use graphlet_rf::features::{CpuFeatureMap, RfParams, Variant};
use graphlet_rf::util::Rng;

/// The compiled-size batch B of the differential grid (matches the
/// small-test pipeline batch used across tests/).
const B: usize = 32;

/// Every power of two up to 4096.
fn pow2_grid() -> Vec<usize> {
    (0..=12).map(|e| 1usize << e).collect()
}

fn batch_grid() -> [usize; 4] {
    [1, 3, B, B + 1]
}

/// Integer-valued panel in [-8, 8]: every FWHT intermediate for
/// p ≤ 4096 stays ≤ 8·4096 = 2¹⁵ ≪ 2²⁴, so f32 sums are exact and
/// bitwise comparison against the naive sign-sum is meaningful.
fn integer_panel(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.usize(17) as f32 - 8.0).collect()
}

#[test]
fn fwht_batch_matches_scalar_and_naive_across_grid() {
    let mut rng = Rng::new(0xBA77E41);
    for p in pow2_grid() {
        for rows in batch_grid() {
            let panel = integer_panel(&mut rng, rows * p);

            // Scalar path: the per-row in-place butterfly.
            let mut scalar = panel.clone();
            for row in scalar.chunks_exact_mut(p) {
                fwht_inplace(row);
            }

            // Batch-major path.
            let mut batch = panel.clone();
            fwht_batch(&mut batch, p);
            assert_eq!(batch, scalar, "fwht_batch vs scalar: p={p} rows={rows}");

            // Naive O(p²) reference, bit-for-bit on integer inputs.
            // Capped at p ≤ 256 to keep the battery fast in debug
            // builds; the scalar path itself is pinned against the
            // naive reference at these sizes by the fwht unit tests,
            // so transitivity covers the rest of the grid.
            if p <= 256 {
                for (br, pr) in batch.chunks_exact(p).zip(panel.chunks_exact(p)) {
                    assert_eq!(br, &naive_hadamard(pr)[..], "naive: p={p} rows={rows}");
                }
            }
        }
    }
}

#[test]
fn fwht_batch_par_matches_serial_across_grid_and_threads() {
    let env_threads = fwht_threads_from_env_or(2);
    let mut rng = Rng::new(0xBA77E42);
    for p in pow2_grid() {
        for rows in batch_grid() {
            // Gaussian inputs: identical per-row butterfly order means
            // identical bits with no integer restriction.
            let mut panel = vec![0.0f32; rows * p];
            rng.fill_gaussian(&mut panel, 1.0);
            let mut reference = panel.clone();
            fwht_batch(&mut reference, p);
            for threads in [1usize, 2, 4, env_threads, rows + 1] {
                let mut got = panel.clone();
                fwht_batch_par(&mut got, p, threads);
                assert_eq!(got, reference, "p={p} rows={rows} threads={threads}");
            }
        }
    }
}

#[test]
fn fwht_involution_recovers_p_times_input_exactly() {
    let mut rng = Rng::new(0xBA77E43);
    for p in pow2_grid() {
        for rows in [1usize, 3] {
            // {-1, 0, 1} inputs: after two unnormalized transforms the
            // magnitudes reach at most p² = 2²⁴, still exact in f32.
            let panel: Vec<f32> = (0..rows * p).map(|_| rng.usize(3) as f32 - 1.0).collect();
            let mut twice = panel.clone();
            fwht_batch(&mut twice, p);
            fwht_batch(&mut twice, p);
            let scaled: Vec<f32> = panel.iter().map(|&v| v * p as f32).collect();
            assert_eq!(twice, scaled, "H(Hx) != p·x at p={p} rows={rows}");
        }
    }
}

/// SORF batch execution vs its own scalar path: evaluating the map one
/// row at a time (batch = 1 calls) is the row-at-a-time execution the
/// refactor replaced; every batch size and thread budget must
/// reproduce it bit for bit, for both feature variants and for
/// single-block (m ≤ p) and stacked (m > p) shapes.
#[test]
fn sorf_map_batch_differential_vs_scalar_rows() {
    let env_threads = fwht_threads_from_env_or(2);
    let mut rng = Rng::new(0x50FF);
    for (d, m) in [(9usize, 12usize), (9, 100), (25, 2048), (6, 130)] {
        for variant in [Variant::Gauss, Variant::Opu] {
            let params = SorfParams::generate(variant, d, m, 0.7, &mut rng);
            let map = SorfMap::new(params);
            for rows in batch_grid() {
                let mut x = vec![0.0f32; rows * d];
                rng.fill_gaussian(&mut x, 1.0);
                // Scalar path: one row per call.
                let mut scalar = vec![0.0f32; rows * m];
                for (xr, or) in x.chunks_exact(d).zip(scalar.chunks_exact_mut(m)) {
                    map.map_batch(xr, 1, or);
                }
                for threads in [1usize, 2, 4, env_threads] {
                    let mut got = vec![0.0f32; rows * m];
                    map.map_batch_threads(&x, rows, &mut got, threads);
                    assert_eq!(
                        got, scalar,
                        "sorf {variant:?} d={d} m={m} rows={rows} threads={threads}"
                    );
                }
            }
        }
    }
}

/// The dense engine's symmetric entry point: row-parallel dispatch vs
/// the unblocked per-row reference map, bitwise.
#[test]
fn dense_map_batch_differential_vs_scalar_rows() {
    let env_threads = fwht_threads_from_env_or(2);
    let mut rng = Rng::new(0xDE4511);
    for (d, m) in [(9usize, 40usize), (25, 300)] {
        for variant in [Variant::Gauss, Variant::Opu] {
            let params = RfParams::generate(variant, d, m, 0.7, &mut rng);
            let map = DenseMap::new(params.clone());
            let reference = CpuFeatureMap::new(params);
            for rows in batch_grid() {
                let mut x = vec![0.0f32; rows * d];
                rng.fill_gaussian(&mut x, 1.0);
                let mut scalar = vec![0.0f32; rows * m];
                for (xr, or) in x.chunks_exact(d).zip(scalar.chunks_exact_mut(m)) {
                    reference.map_batch(xr, 1, or);
                }
                for threads in [1usize, 2, env_threads] {
                    let mut got = vec![0.0f32; rows * m];
                    map.map_batch_threads(&x, rows, &mut got, threads);
                    assert_eq!(
                        got, scalar,
                        "dense {variant:?} d={d} m={m} rows={rows} threads={threads}"
                    );
                }
            }
        }
    }
}
