//! The mmap differential battery: the zero-copy sealed-segment read
//! path pinned bitwise against the legacy seek+read+verify path, plus a
//! compaction/rotation race hammered by concurrent readers.
//!
//! Contracts pinned here (the PR's acceptance criteria):
//! - two stores fed identical operations — one `mmap: true`, one
//!   `mmap: false`, both set explicitly so the `GRAPHLET_RF_TEST_MMAP`
//!   CI axis cannot skew this file — answer every `get`,
//!   `snapshot_row_data`, and ANN `nearest` **bitwise identically**,
//!   across corpus sizes {0, 1, 63, 500} × dims {64, 128} × three
//!   compaction generations;
//! - an ANN index built from view-backed rows is the same index as one
//!   built from owned rows: identical neighbors, bitwise distances, and
//!   identical probed/scanned effort — and at probe 1.0 both stay the
//!   exact brute-force oracle;
//! - on the mapped store every post-reopen read is served off a sealed
//!   mapping (`mmap_reads` counts them all) and the view-backed index
//!   owns ~zero row bytes, while the legacy index owns every row;
//! - readers holding `RowData` views across the store lock — including
//!   an ANN index built from a snapshot — stay valid and bitwise-intact
//!   while a writer thread supersedes rows, rotates segments, and
//!   compacts generations out from under them: a row is always exactly
//!   one generation, never a mix, and never a torn read.
//!
//! Every assert carries the corpus seed so a failure is replayable.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use graphlet_rf::ann::{l2_distance, neighbor_cmp, AnnConfig, AnnIndex, Neighbor};
use graphlet_rf::store::codec::record_len;
use graphlet_rf::store::{CacheKey, EmbeddingStore, RowData, StoreConfig};
use graphlet_rf::util::Rng;

fn key(i: u64) -> CacheKey {
    CacheKey { graph_hash: i.wrapping_mul(0x9E37_79B9_7F4A_7C15), config_fp: 0x33A9, seed: i }
}

/// A seeded gaussian corpus with adversarial float bit patterns planted
/// in row 0 — negative zero, the smallest normal, a subnormal, and
/// `f32::MAX` — the values a lossy read path would normalize away.
fn corpus(n: usize, dim: usize, seed: u64) -> Vec<(CacheKey, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    let mut rows: Vec<(CacheKey, Vec<f32>)> = (0..n)
        .map(|i| (key(i as u64), (0..dim).map(|_| rng.gaussian_f32()).collect()))
        .collect();
    if n > 0 && dim >= 4 {
        rows[0].1[0] = -0.0;
        rows[0].1[1] = f32::MIN_POSITIVE;
        rows[0].1[2] = 1.0e-42; // subnormal
        rows[0].1[3] = f32::MAX;
    }
    rows
}

fn bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|v| v.to_bits()).collect()
}

/// Independent oracle: sort ALL rows by `(distance, key)`, keep k.
fn brute_oracle(entries: &BTreeMap<CacheKey, Vec<f32>>, query: &[f32], k: usize) -> Vec<Neighbor> {
    let mut all: Vec<Neighbor> = entries
        .iter()
        .map(|(key, row)| Neighbor { key: *key, distance: l2_distance(query, row) })
        .collect();
    all.sort_unstable_by(neighbor_cmp);
    all.truncate(k);
    all
}

/// Both read paths can reinterpret mapped bytes as `&[f32]` here; other
/// targets fall back to owned decoding (still differentially checked,
/// just not zero-copy), so the ownership asserts are gated on this.
fn zero_copy_target() -> bool {
    cfg!(all(unix, target_endian = "little", target_pointer_width = "64"))
}

/// One generation's full differential sweep over freshly reopened
/// stores: stats, every `get`, the snapshot, and ANN at probe 1.0.
fn check_generation(
    mapped: &mut EmbeddingStore,
    legacy: &mut EmbeddingStore,
    expected: &BTreeMap<CacheKey, Vec<f32>>,
    dim: usize,
    ctx: &str,
) {
    assert_eq!(mapped.len(), expected.len(), "{ctx}: mapped live records");
    assert_eq!(legacy.len(), expected.len(), "{ctx}: legacy live records");
    assert_eq!(legacy.stats().mmap_segments, 0, "{ctx}: legacy store must map nothing");

    // Every get: bitwise identical on both paths, and — because the
    // reopen sealed everything — every mapped-store read comes off a
    // mapping (the counter is the proof the fast path actually ran).
    let reads0 = mapped.stats().mmap_reads;
    for (k, want) in expected {
        let a = mapped.get_row(k).unwrap_or_else(|| panic!("{ctx}: mapped miss {k:?}"));
        let b = legacy.get_row(k).unwrap_or_else(|| panic!("{ctx}: legacy miss {k:?}"));
        if zero_copy_target() {
            assert!(matches!(a, RowData::View(_)), "{ctx}: sealed row must be a view");
        }
        assert!(matches!(b, RowData::Owned(_)), "{ctx}: legacy row must be owned");
        assert_eq!(bits(&a.to_vec()), bits(want), "{ctx}: mapped get {k:?}");
        assert_eq!(bits(&b.to_vec()), bits(want), "{ctx}: legacy get {k:?}");
    }
    assert_eq!(
        mapped.stats().mmap_reads - reads0,
        expected.len() as u64,
        "{ctx}: every post-reopen get must take the mapped path"
    );

    // Snapshots: same key order (sorted), same bits, complete.
    let snap_m = mapped.snapshot_row_data();
    let snap_l = legacy.snapshot_row_data();
    assert_eq!(snap_m.len(), expected.len(), "{ctx}: mapped snapshot size");
    assert_eq!(snap_l.len(), expected.len(), "{ctx}: legacy snapshot size");
    for (((km, rm), (kl, rl)), (ke, re)) in snap_m.iter().zip(&snap_l).zip(expected) {
        assert_eq!((km, kl), (ke, ke), "{ctx}: snapshot key order");
        assert_eq!(bits(&rm.to_vec()), bits(re), "{ctx}: mapped snapshot row {ke:?}");
        assert_eq!(bits(&rl.to_vec()), bits(re), "{ctx}: legacy snapshot row {ke:?}");
    }

    // ANN over the two snapshots: the view-backed index owns (nearly)
    // nothing, the owned-backed one owns everything — and both answer
    // every query identically, pinned to the brute-force oracle at
    // probe 1.0.
    let cfg = AnnConfig::default();
    let index_m = AnnIndex::build(snap_m, dim, &cfg);
    let index_l = AnnIndex::build(snap_l, dim, &cfg);
    if zero_copy_target() {
        assert_eq!(index_m.indexed_bytes(), 0, "{ctx}: view-backed index must own no rows");
    }
    assert_eq!(
        index_l.indexed_bytes(),
        (expected.len() * dim * 4) as u64,
        "{ctx}: owned-backed index must own every row"
    );

    let mut rng = Rng::new(0x0FF5E7 ^ expected.len() as u64 ^ (dim as u64) << 32);
    let mut queries: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..dim).map(|_| rng.gaussian_f32()).collect())
        .collect();
    if let Some(row) = expected.values().next() {
        queries.push(row.clone()); // exact hit: distance-0 tiebreak
    }
    for (qi, q) in queries.iter().enumerate() {
        for k in [1usize, 10] {
            let want = brute_oracle(expected, q, k);
            let a = index_m.nearest(q, k, 1.0);
            let b = index_l.nearest(q, k, 1.0);
            let qctx = format!("{ctx} query={qi} k={k}");
            assert_eq!(a.probed, b.probed, "{qctx}: probed lists");
            assert_eq!(a.scanned, b.scanned, "{qctx}: scanned rows");
            for (rank, pair) in a.neighbors.iter().zip(&want).enumerate() {
                assert_eq!(pair.0.key, pair.1.key, "{qctx}: mapped key at rank {rank}");
                assert_eq!(
                    pair.0.distance.to_bits(),
                    pair.1.distance.to_bits(),
                    "{qctx}: mapped distance at rank {rank}"
                );
            }
            for (rank, pair) in b.neighbors.iter().zip(&want).enumerate() {
                assert_eq!(pair.0.key, pair.1.key, "{qctx}: legacy key at rank {rank}");
                assert_eq!(
                    pair.0.distance.to_bits(),
                    pair.1.distance.to_bits(),
                    "{qctx}: legacy distance at rank {rank}"
                );
            }
            assert_eq!(a.neighbors.len(), want.len(), "{qctx}: mapped count");
            assert_eq!(b.neighbors.len(), want.len(), "{qctx}: legacy count");
        }
    }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("graphlet_mmap_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The tentpole differential: identical operation streams into a mapped
/// and a legacy store, swept across sizes × dims × three generations
/// (fresh, one compaction, two compactions), checked after a reopen so
/// the mapped store genuinely serves sealed views.
#[test]
fn mmap_and_legacy_read_paths_are_bitwise_identical_across_generations() {
    for dim in [64usize, 128] {
        for n in [0usize, 1, 63, 500] {
            let seed = 0x33A9_5EED ^ ((n as u64) << 8) ^ dim as u64;
            // Small segments: the corpus spans many sealed segments and
            // compaction's rewrite re-rotates mid-stream, so each
            // generation mixes mapped and tail rows before its reopen.
            let segment_bytes = 8 + 16 * record_len(dim) as u64;
            let base = StoreConfig {
                segment_bytes,
                compact_min_bytes: u64::MAX, // compaction is driven manually
                ..StoreConfig::new(temp_dir(&format!("diff_m_{n}_{dim}")))
            };
            let cfg_m = StoreConfig { mmap: true, ..base.clone() };
            let cfg_l = StoreConfig {
                mmap: false,
                dir: temp_dir(&format!("diff_l_{n}_{dim}")),
                ..base
            };

            let mut expected: BTreeMap<CacheKey, Vec<f32>> = BTreeMap::new();
            let mut entries = corpus(n, dim, seed);
            Rng::new(seed ^ 7).shuffle(&mut entries);
            {
                let mut sm = EmbeddingStore::open(cfg_m.clone()).unwrap();
                let mut sl = EmbeddingStore::open(cfg_l.clone()).unwrap();
                for (k, row) in &entries {
                    sm.put(*k, row).unwrap();
                    sl.put(*k, row).unwrap();
                    expected.insert(*k, row.clone());
                }
            }

            for gen in 0u64..3 {
                let ctx = format!("n={n} dim={dim} gen={gen} seed={seed:#x}");
                let mut sm = EmbeddingStore::open(cfg_m.clone()).unwrap();
                let mut sl = EmbeddingStore::open(cfg_l.clone()).unwrap();
                check_generation(&mut sm, &mut sl, &expected, dim, &ctx);

                // Next generation: supersede a third of the keys with
                // fresh rows, then compact both stores — the mapped one
                // unlinks and remaps a whole generation of files.
                let fresh = corpus(n, dim, seed ^ (gen + 1).wrapping_mul(0x9E37));
                for (i, (k, row)) in fresh.iter().enumerate() {
                    if i as u64 % 3 == gen % 3 {
                        sm.put(*k, row).unwrap();
                        sl.put(*k, row).unwrap();
                        expected.insert(*k, row.clone());
                    }
                }
                sm.compact().unwrap();
                sl.compact().unwrap();
                assert_eq!(sm.stats().dead_bytes, 0, "{ctx}: mapped compaction reclaims");
                assert_eq!(sl.stats().dead_bytes, 0, "{ctx}: legacy compaction reclaims");
            }
            let _ = std::fs::remove_dir_all(&cfg_m.dir);
            let _ = std::fs::remove_dir_all(&cfg_l.dir);
        }
    }
}

// ---------------------------------------------------------------------------
// Concurrency: reader threads hold views (and whole view-backed ANN
// indexes) across the store lock while a writer supersedes every key,
// rotates segments, and compacts generations away. Rows are generation-
// uniform by construction, so any torn or mixed-generation read — and
// any SIGBUS from a view into an unlinked segment — fails loudly.
// ---------------------------------------------------------------------------

const RACE_SEED: u64 = 0x52ACE;
const RACE_KEYS: u64 = 32;
const RACE_DIM: usize = 16;
const RACE_GENS: u64 = 24;

fn race_key(i: u64) -> CacheKey {
    CacheKey { graph_hash: i, config_fp: RACE_SEED, seed: i ^ 0xF00D }
}

/// Generation-uniform row: every element is `i*1000 + gen` (exact in
/// f32 for these ranges), so a single out-of-place element convicts a
/// torn read and the decoded value names the generation it came from.
fn race_row(i: u64, gen: u64) -> Vec<f32> {
    vec![(i * 1000 + gen) as f32; RACE_DIM]
}

/// Assert `row` is exactly ONE generation of key `i`, and return it.
fn race_generation_of(row: &[f32], i: u64, who: &str) -> u64 {
    assert_eq!(row.len(), RACE_DIM, "{who}: row width (seed={RACE_SEED:#x})");
    let head = row[0];
    for (j, v) in row.iter().enumerate() {
        assert_eq!(
            v.to_bits(),
            head.to_bits(),
            "{who}: torn row for key {i} at elem {j} (seed={RACE_SEED:#x})"
        );
    }
    let raw = head as u64;
    assert!(
        raw >= i * 1000 && raw <= i * 1000 + RACE_GENS,
        "{who}: key {i} decoded {raw}, not one of its generations (seed={RACE_SEED:#x})"
    );
    raw - i * 1000
}

#[test]
fn views_stay_single_generation_while_compaction_races_readers() {
    let cfg = StoreConfig {
        // ~8 records per segment: the writer's churn rotates constantly.
        segment_bytes: 8 + 8 * record_len(RACE_DIM) as u64,
        compact_min_bytes: u64::MAX,
        mmap: true,
        ..StoreConfig::new(temp_dir("race"))
    };
    {
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        for i in 0..RACE_KEYS {
            s.put(race_key(i), &race_row(i, 0)).unwrap();
        }
    }
    // Reopen seals generation 0: readers start on real mapped views.
    let store = Arc::new(Mutex::new(EmbeddingStore::open(cfg.clone()).unwrap()));
    let done = Arc::new(AtomicBool::new(false));

    let mut readers = Vec::new();
    for t in 0..2u64 {
        let store = Arc::clone(&store);
        let done = Arc::clone(&done);
        readers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(RACE_SEED ^ t);
            let who = format!("get-reader-{t}");
            while !done.load(Ordering::Relaxed) {
                let i = rng.gen_range(RACE_KEYS);
                // Take the view under the lock, read it AFTER release:
                // the writer may compact its segment away in between —
                // the view's Arc must keep the pages valid.
                let data = store
                    .lock()
                    .unwrap()
                    .get_row(&race_key(i))
                    .unwrap_or_else(|| panic!("{who}: key {i} vanished (seed={RACE_SEED:#x})"));
                race_generation_of(&data.to_vec(), i, &who);
            }
        }));
    }
    {
        let store = Arc::clone(&store);
        let done = Arc::clone(&done);
        readers.push(std::thread::spawn(move || {
            let mut rng = Rng::new(RACE_SEED ^ 0xA22);
            let who = "ann-reader";
            while !done.load(Ordering::Relaxed) {
                // Snapshot under the lock (one consistent cut), build
                // and query the index outside it while the writer moves
                // the store generations ahead.
                let snap = store.lock().unwrap().snapshot_row_data();
                assert_eq!(snap.len() as u64, RACE_KEYS, "{who} (seed={RACE_SEED:#x})");
                let index = AnnIndex::build(snap, RACE_DIM, &AnnConfig::default());
                let qi = rng.gen_range(RACE_KEYS);
                let q = race_row(qi, 0);
                let res = index.nearest(&q, 5, 1.0);
                assert_eq!(res.neighbors.len(), 5, "{who} (seed={RACE_SEED:#x})");
                for nb in &res.neighbors {
                    // The distance must be explainable by exactly one
                    // generation of the neighbor's key — recomputed with
                    // the same kernel, so an untorn row matches bitwise.
                    let i = nb.key.graph_hash;
                    let ok = (0..=RACE_GENS).any(|g| {
                        l2_distance(&q, &race_row(i, g)).to_bits() == nb.distance.to_bits()
                    });
                    assert!(
                        ok,
                        "{who}: neighbor {i} distance {} matches no single generation \
                         (seed={RACE_SEED:#x})",
                        nb.distance
                    );
                }
            }
        }));
    }

    let writer = {
        let store = Arc::clone(&store);
        std::thread::spawn(move || {
            for gen in 1..=RACE_GENS {
                for i in 0..RACE_KEYS {
                    // Lock per put: readers interleave with every append.
                    store.lock().unwrap().put(race_key(i), &race_row(i, gen)).unwrap();
                }
                if gen % 4 == 0 {
                    store.lock().unwrap().compact().unwrap();
                }
            }
        })
    };
    writer.join().unwrap();
    done.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }

    // Quiesced: every key sits at the final generation, and survives a
    // fresh recovery scan + reseal bitwise.
    let mut s = Arc::try_unwrap(store).ok().expect("sole owner").into_inner().unwrap();
    for i in 0..RACE_KEYS {
        let row = s.get(&race_key(i)).unwrap();
        assert_eq!(bits(&row), bits(&race_row(i, RACE_GENS)), "final gen, key {i}");
    }
    drop(s);
    let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
    for i in 0..RACE_KEYS {
        let row = s.get(&race_key(i)).unwrap();
        assert_eq!(bits(&row), bits(&race_row(i, RACE_GENS)), "reopen, key {i}");
    }
    let _ = std::fs::remove_dir_all(&cfg.dir);
}
