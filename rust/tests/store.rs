//! Daemon-level durability tests for the persistent embedding store:
//! a real serve daemon over a temp `--store-dir`, killed and restarted.
//!
//! Pins the PR's acceptance contract:
//! - after a daemon restart over the same store directory, previously
//!   requested embeddings are served with `l2_hits > 0`, **zero**
//!   pipeline recomputes, and rows **bitwise identical** to a fresh
//!   `embed_dataset` run;
//! - a torn final record (crash mid-append) is skipped gracefully with
//!   `corrupt_skipped` visible in `stats` — never a panic — and the
//!   lost row is recomputed and re-persisted on the next request.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;

use graphlet_rf::coordinator::{embed_dataset, fwht_threads_from_env_or, EngineMode, GsaConfig};
use graphlet_rf::data::Dataset;
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::serve::{
    embed_request, nearest_request, parse_embed_reply, parse_nearest_reply, send_shutdown,
    ServeConfig, Server,
};
use graphlet_rf::util::{Json, Rng};

fn test_ds() -> Dataset {
    SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11))
}

fn test_gsa() -> GsaConfig {
    GsaConfig {
        k: 3,
        s: 100,
        m: 64,
        batch: 32,
        workers: 3,
        shards: 2,
        // The CI engine matrix reruns this file per CPU engine; the
        // durability contract (bitwise restart recovery) is identical.
        engine: EngineMode::from_env_or(EngineMode::Cpu),
        fwht_threads: fwht_threads_from_env_or(1),
        seed: 42,
        ..Default::default()
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("graphlet_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn start_server(cfg: ServeConfig) -> (SocketAddr, JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", cfg, None).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server closed the connection");
        reply
    }

    fn stats(&mut self) -> Json {
        Json::parse(self.roundtrip(r#"{"op":"stats","id":900}"#).trim()).unwrap()
    }
}

/// Sequentially embed graph `g` at stream position `g`; returns
/// (row, cached). Sequential roundtrips make the store's append order
/// (and so the torn-tail victim) deterministic: the writer thread
/// persists a fresh row before it writes the reply line.
fn embed(client: &mut Client, ds: &Dataset, g: usize) -> (Vec<f32>, bool) {
    let reply = client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]));
    let (id, row, cached) = parse_embed_reply(&reply).unwrap();
    assert_eq!(id, g as u64);
    (row, cached)
}

fn u64_at(stats: &Json, obj: &str, field: &str) -> u64 {
    stats
        .get(obj)
        .and_then(|o| o.get(field))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("stats missing {obj}.{field}: {stats}"))
}

/// The highest-numbered (active) segment file in a store dir.
fn active_segment(dir: &Path) -> PathBuf {
    let mut segs: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("seg-") && n.ends_with(".log"))
        })
        .collect();
    segs.sort();
    segs.pop().expect("store dir holds no segment files")
}

#[test]
fn daemon_restart_serves_bitwise_rows_from_disk_with_zero_recompute() {
    let gsa = test_gsa();
    let ds = test_ds();
    let m = gsa.m;
    let (want, _) = embed_dataset(&ds, &gsa, None).unwrap();
    let dir = temp_dir("restart");
    let cfg = ServeConfig { gsa, store_dir: Some(dir.clone()), ..Default::default() };

    // Daemon #1: compute every graph once; rows are written through to
    // the segment log as each reply goes out.
    let (addr, server) = start_server(cfg.clone());
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        let (row, cached) = embed(&mut client, &ds, g);
        assert!(!cached, "first sight of graph {g} must be computed");
        assert_eq!(&want[g * m..(g + 1) * m], &row[..], "daemon #1 drifted vs embed_dataset");
    }
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len());
    assert_eq!(u64_at(&stats, "store", "corrupt_skipped"), 0);
    // Daemon identity in stats: engine mode by name, the config
    // fingerprint as 16 hex digits (the hex baked into cache keys),
    // and an uptime that exists from the first scrape.
    let server_obj = stats.get("server").expect("stats.server");
    assert_eq!(
        server_obj.get("engine").and_then(Json::as_str),
        Some(EngineMode::from_env_or(EngineMode::Cpu).name()),
    );
    let fp1 = server_obj.get("config_fp").and_then(Json::as_str).expect("config_fp").to_string();
    assert_eq!(fp1.len(), 16, "config_fp must be 16 hex digits: {fp1}");
    assert!(fp1.chars().all(|c| c.is_ascii_hexdigit()), "{fp1}");
    assert!(server_obj.get("uptime_secs").and_then(Json::as_u64).is_some());
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // Daemon #2: fresh pipeline, empty L1, same store directory. Every
    // previously requested row must come off the disk log — bitwise
    // equal to a fresh embed_dataset run, with zero pipeline work.
    let (addr, server) = start_server(cfg);
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        let (row, cached) = embed(&mut client, &ds, g);
        assert!(cached, "graph {g} must be served from the reopened store");
        assert_eq!(
            &want[g * m..(g + 1) * m],
            &row[..],
            "graph {g}: restart-recovered row is not bitwise identical"
        );
    }
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "cache", "l2_hits") as usize, ds.len());
    assert_eq!(u64_at(&stats, "cache", "l2_promotions") as usize, ds.len());
    assert_eq!(u64_at(&stats, "cache", "l2_misses"), 0, "no key may miss both tiers");
    assert_eq!(
        u64_at(&stats, "pipeline", "graphs"),
        0,
        "the restarted daemon must not recompute anything"
    );
    assert_eq!(u64_at(&stats, "store", "corrupt_skipped"), 0);
    // The restarted daemon reports the *same* config fingerprint — the
    // precondition for its cache keys matching the persisted ones.
    let fp2 = stats
        .get("server")
        .and_then(|s| s.get("config_fp"))
        .and_then(Json::as_str)
        .expect("config_fp");
    assert_eq!(fp2, fp1, "restart changed the config fingerprint");

    // Promoted rows now live in L1: a re-request is a pure RAM hit and
    // the L2 counters stay put.
    let (row, cached) = embed(&mut client, &ds, 0);
    assert!(cached);
    assert_eq!(&want[..m], &row[..]);
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "cache", "l2_hits") as usize, ds.len());
    assert!(u64_at(&stats, "cache", "hits") >= 1);

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Restarting the daemon rebuilds the ANN index from the reopened
/// segment log — and serves **identical** neighbors: same keys, same
/// order, bitwise-equal distances. In daemon #1 the corpus lives in the
/// pending tail (the open-time build saw an empty store); in daemon #2
/// it is fully indexed — the two code paths must agree exactly.
#[test]
fn restart_rebuilds_ann_index_and_serves_identical_neighbors() {
    let gsa = test_gsa();
    let ds = test_ds();
    let dir = temp_dir("ann_restart");
    let cfg = ServeConfig { gsa, store_dir: Some(dir.clone()), ..Default::default() };
    let k = 3usize;

    // Daemon #1: the open-time build runs over the empty store; every
    // embed then lands in the pending tail (too few rows to trigger a
    // background rebuild).
    let (addr, server) = start_server(cfg.clone());
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        embed(&mut client, &ds, g);
    }
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "ann", "builds"), 1, "exactly the open-time build");
    assert_eq!(u64_at(&stats, "ann", "indexed"), 0, "daemon #1 opened an empty store");
    assert_eq!(u64_at(&stats, "ann", "pending") as usize, ds.len());

    let mut want = Vec::new();
    for g in 0..ds.len() {
        let reply =
            client.roundtrip(&nearest_request(g as u64, g, k, Some(1.0), &ds.graphs[g]));
        let (_, neighbors, _, scanned) = parse_nearest_reply(&reply).unwrap();
        assert_eq!(neighbors.len(), k, "graph {g}");
        assert_eq!(scanned, ds.len(), "graph {g}: probe 1.0 must scan the full corpus");
        want.push(neighbors);
    }
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // Daemon #2: the open-time build now indexes all persisted rows;
    // the pending tail is empty. Same queries, identical answers.
    let (addr, server) = start_server(cfg);
    let mut client = Client::connect(addr);
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "ann", "builds"), 1);
    assert_eq!(u64_at(&stats, "ann", "indexed") as usize, ds.len(), "rebuilt from disk");
    assert_eq!(u64_at(&stats, "ann", "pending"), 0);
    assert!(u64_at(&stats, "ann", "centroids") >= 1);

    for g in 0..ds.len() {
        let reply =
            client.roundtrip(&nearest_request(g as u64, g, k, Some(1.0), &ds.graphs[g]));
        let (_, neighbors, _, _) = parse_nearest_reply(&reply).unwrap();
        assert_eq!(neighbors.len(), k, "graph {g}");
        for (rank, (a, b)) in neighbors.iter().zip(&want[g]).enumerate() {
            assert_eq!(a.key, b.key, "graph {g} rank {rank}: neighbor key changed on restart");
            assert_eq!(
                a.distance.to_bits(),
                b.distance.to_bits(),
                "graph {g} rank {rank}: distance not bitwise across restart"
            );
        }
    }
    // Retrieval stayed read-only across both daemons.
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len());

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_tail_is_skipped_counted_and_recomputed() {
    let gsa = test_gsa();
    let ds = test_ds();
    let m = gsa.m;
    let (want, _) = embed_dataset(&ds, &gsa, None).unwrap();
    let dir = temp_dir("torn");
    let cfg = ServeConfig { gsa, store_dir: Some(dir.clone()), ..Default::default() };

    // Daemon #1 populates the log in request order (sequential client).
    let (addr, server) = start_server(cfg.clone());
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        let (_, cached) = embed(&mut client, &ds, g);
        assert!(!cached);
    }
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // "SIGKILL mid-append": tear the last appended record (the final
    // graph's row) by truncating the active segment mid-checksum.
    let seg = active_segment(&dir);
    let len = std::fs::metadata(&seg).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
    f.set_len(len - 5).unwrap();
    drop(f);

    // Daemon #2 must open the damaged log without panicking, skip the
    // torn record with a visible counter, and keep serving.
    let last = ds.len() - 1;
    let (addr, server) = start_server(cfg);
    let mut client = Client::connect(addr);
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "store", "corrupt_skipped"), 1, "torn tail must be counted");
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len() - 1);

    // Undamaged rows still come off the disk log, bitwise.
    let (row, cached) = embed(&mut client, &ds, 0);
    assert!(cached, "undamaged row must be an L2 hit");
    assert_eq!(&want[..m], &row[..]);

    // The torn row reads as a miss, recomputes to the identical bits,
    // and is re-persisted.
    let (row, cached) = embed(&mut client, &ds, last);
    assert!(!cached, "the torn row must be recomputed, not served");
    assert_eq!(&want[last * m..(last + 1) * m], &row[..], "recomputed row drifted");
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "pipeline", "graphs"), 1, "exactly the torn row recomputes");
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len(), "row re-persisted");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
