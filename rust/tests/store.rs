//! Daemon-level durability tests for the persistent embedding store:
//! a real serve daemon over a temp `--store-dir`, killed and restarted.
//!
//! Pins the PR's acceptance contract:
//! - after a daemon restart over the same store directory, previously
//!   requested embeddings are served with `l2_hits > 0`, **zero**
//!   pipeline recomputes, and rows **bitwise identical** to a fresh
//!   `embed_dataset` run;
//! - a torn final record (crash mid-append) is skipped gracefully with
//!   `corrupt_skipped` visible in `stats` — never a panic — and the
//!   lost row is recomputed and re-persisted on the next request;
//! - a fault-injection battery (direct `EmbeddingStore`, mmap on)
//!   corrupts sealed segments at every record boundary and mid-payload
//!   — truncations and single-byte flips — and pins the exact
//!   `corrupt_skipped` count, the precise lost-key set, and bitwise
//!   survivors for every scenario.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;

use graphlet_rf::coordinator::{embed_dataset, fwht_threads_from_env_or, EngineMode, GsaConfig};
use graphlet_rf::data::Dataset;
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::serve::{
    embed_request, nearest_request, parse_embed_reply, parse_nearest_reply, send_shutdown,
    ServeConfig, Server,
};
use graphlet_rf::store::codec::{record_len, SEGMENT_MAGIC};
use graphlet_rf::store::{CacheKey, EmbeddingStore, StoreConfig};
use graphlet_rf::util::{Json, Rng};

fn test_ds() -> Dataset {
    SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11))
}

fn test_gsa() -> GsaConfig {
    GsaConfig {
        k: 3,
        s: 100,
        m: 64,
        batch: 32,
        workers: 3,
        shards: 2,
        // The CI engine matrix reruns this file per CPU engine; the
        // durability contract (bitwise restart recovery) is identical.
        engine: EngineMode::from_env_or(EngineMode::Cpu),
        fwht_threads: fwht_threads_from_env_or(1),
        seed: 42,
        ..Default::default()
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("graphlet_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn start_server(cfg: ServeConfig) -> (SocketAddr, JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", cfg, None).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server closed the connection");
        reply
    }

    fn stats(&mut self) -> Json {
        Json::parse(self.roundtrip(r#"{"op":"stats","id":900}"#).trim()).unwrap()
    }
}

/// Sequentially embed graph `g` at stream position `g`; returns
/// (row, cached). Sequential roundtrips make the store's append order
/// (and so the torn-tail victim) deterministic: the writer thread
/// persists a fresh row before it writes the reply line.
fn embed(client: &mut Client, ds: &Dataset, g: usize) -> (Vec<f32>, bool) {
    let reply = client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g]));
    let (id, row, cached) = parse_embed_reply(&reply).unwrap();
    assert_eq!(id, g as u64);
    (row, cached)
}

fn u64_at(stats: &Json, obj: &str, field: &str) -> u64 {
    stats
        .get(obj)
        .and_then(|o| o.get(field))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("stats missing {obj}.{field}: {stats}"))
}

/// The highest-numbered (active) segment file in a store dir.
fn active_segment(dir: &Path) -> PathBuf {
    let mut segs: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("seg-") && n.ends_with(".log"))
        })
        .collect();
    segs.sort();
    segs.pop().expect("store dir holds no segment files")
}

#[test]
fn daemon_restart_serves_bitwise_rows_from_disk_with_zero_recompute() {
    let gsa = test_gsa();
    let ds = test_ds();
    let m = gsa.m;
    let (want, _) = embed_dataset(&ds, &gsa, None).unwrap();
    let dir = temp_dir("restart");
    let cfg = ServeConfig { gsa, store_dir: Some(dir.clone()), ..Default::default() };

    // Daemon #1: compute every graph once; rows are written through to
    // the segment log as each reply goes out.
    let (addr, server) = start_server(cfg.clone());
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        let (row, cached) = embed(&mut client, &ds, g);
        assert!(!cached, "first sight of graph {g} must be computed");
        assert_eq!(&want[g * m..(g + 1) * m], &row[..], "daemon #1 drifted vs embed_dataset");
    }
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len());
    assert_eq!(u64_at(&stats, "store", "corrupt_skipped"), 0);
    // Daemon identity in stats: engine mode by name, the config
    // fingerprint as 16 hex digits (the hex baked into cache keys),
    // and an uptime that exists from the first scrape.
    let server_obj = stats.get("server").expect("stats.server");
    assert_eq!(
        server_obj.get("engine").and_then(Json::as_str),
        Some(EngineMode::from_env_or(EngineMode::Cpu).name()),
    );
    let fp1 = server_obj.get("config_fp").and_then(Json::as_str).expect("config_fp").to_string();
    assert_eq!(fp1.len(), 16, "config_fp must be 16 hex digits: {fp1}");
    assert!(fp1.chars().all(|c| c.is_ascii_hexdigit()), "{fp1}");
    assert!(server_obj.get("uptime_secs").and_then(Json::as_u64).is_some());
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // Daemon #2: fresh pipeline, empty L1, same store directory. Every
    // previously requested row must come off the disk log — bitwise
    // equal to a fresh embed_dataset run, with zero pipeline work.
    let (addr, server) = start_server(cfg);
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        let (row, cached) = embed(&mut client, &ds, g);
        assert!(cached, "graph {g} must be served from the reopened store");
        assert_eq!(
            &want[g * m..(g + 1) * m],
            &row[..],
            "graph {g}: restart-recovered row is not bitwise identical"
        );
    }
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "cache", "l2_hits") as usize, ds.len());
    assert_eq!(u64_at(&stats, "cache", "l2_promotions") as usize, ds.len());
    assert_eq!(u64_at(&stats, "cache", "l2_misses"), 0, "no key may miss both tiers");
    assert_eq!(
        u64_at(&stats, "pipeline", "graphs"),
        0,
        "the restarted daemon must not recompute anything"
    );
    assert_eq!(u64_at(&stats, "store", "corrupt_skipped"), 0);
    // The restarted daemon reports the *same* config fingerprint — the
    // precondition for its cache keys matching the persisted ones.
    let fp2 = stats
        .get("server")
        .and_then(|s| s.get("config_fp"))
        .and_then(Json::as_str)
        .expect("config_fp");
    assert_eq!(fp2, fp1, "restart changed the config fingerprint");

    // Promoted rows now live in L1: a re-request is a pure RAM hit and
    // the L2 counters stay put.
    let (row, cached) = embed(&mut client, &ds, 0);
    assert!(cached);
    assert_eq!(&want[..m], &row[..]);
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "cache", "l2_hits") as usize, ds.len());
    assert!(u64_at(&stats, "cache", "hits") >= 1);

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Restarting the daemon rebuilds the ANN index from the reopened
/// segment log — and serves **identical** neighbors: same keys, same
/// order, bitwise-equal distances. In daemon #1 the corpus lives in the
/// pending tail (the open-time build saw an empty store); in daemon #2
/// it is fully indexed — the two code paths must agree exactly.
#[test]
fn restart_rebuilds_ann_index_and_serves_identical_neighbors() {
    let gsa = test_gsa();
    let ds = test_ds();
    let dir = temp_dir("ann_restart");
    let cfg = ServeConfig { gsa, store_dir: Some(dir.clone()), ..Default::default() };
    let k = 3usize;

    // Daemon #1: the open-time build runs over the empty store; every
    // embed then lands in the pending tail (too few rows to trigger a
    // background rebuild).
    let (addr, server) = start_server(cfg.clone());
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        embed(&mut client, &ds, g);
    }
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "ann", "builds"), 1, "exactly the open-time build");
    assert_eq!(u64_at(&stats, "ann", "indexed"), 0, "daemon #1 opened an empty store");
    assert_eq!(u64_at(&stats, "ann", "pending") as usize, ds.len());

    let mut want = Vec::new();
    for g in 0..ds.len() {
        let reply =
            client.roundtrip(&nearest_request(g as u64, g, k, Some(1.0), &ds.graphs[g]));
        let (_, neighbors, _, scanned) = parse_nearest_reply(&reply).unwrap();
        assert_eq!(neighbors.len(), k, "graph {g}");
        assert_eq!(scanned, ds.len(), "graph {g}: probe 1.0 must scan the full corpus");
        want.push(neighbors);
    }
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // Daemon #2: the open-time build now indexes all persisted rows;
    // the pending tail is empty. Same queries, identical answers.
    let (addr, server) = start_server(cfg);
    let mut client = Client::connect(addr);
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "ann", "builds"), 1);
    assert_eq!(u64_at(&stats, "ann", "indexed") as usize, ds.len(), "rebuilt from disk");
    assert_eq!(u64_at(&stats, "ann", "pending"), 0);
    assert!(u64_at(&stats, "ann", "centroids") >= 1);

    for g in 0..ds.len() {
        let reply =
            client.roundtrip(&nearest_request(g as u64, g, k, Some(1.0), &ds.graphs[g]));
        let (_, neighbors, _, _) = parse_nearest_reply(&reply).unwrap();
        assert_eq!(neighbors.len(), k, "graph {g}");
        for (rank, (a, b)) in neighbors.iter().zip(&want[g]).enumerate() {
            assert_eq!(a.key, b.key, "graph {g} rank {rank}: neighbor key changed on restart");
            assert_eq!(
                a.distance.to_bits(),
                b.distance.to_bits(),
                "graph {g} rank {rank}: distance not bitwise across restart"
            );
        }
    }
    // Retrieval stayed read-only across both daemons.
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len());

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Fault-injection battery (direct store, no daemon): corrupt segment files
// at every record boundary and mid-payload — truncations and single-byte
// flips — then reopen with mmap and pin the EXACT recovery outcome: the
// `corrupt_skipped` count, the precise set of lost keys, bitwise-intact
// survivors through both `get` and `snapshot_row_data`, and an appendable
// store afterwards. No scenario may panic, fail the open, or SIGBUS.
//
// Corruption is only ever applied to a CLOSED store. A sealed segment under
// a live store is immutable by the single-writer contract — external
// mutation of a mapped file is the one fault class documented as out of
// scope (see store::mmap) — so the battery models what crashes actually
// produce: damaged bytes discovered at the NEXT open.
// ---------------------------------------------------------------------------

const FB_DIM: usize = 8;
const FB_ROWS: u64 = 12;
const FB_PER_SEG: usize = 4;

fn fb_key(n: u64) -> CacheKey {
    CacheKey { graph_hash: 0x9A00 + n, config_fp: 0xFB17, seed: n ^ 0x5A }
}

fn fb_row(n: u64) -> Vec<f32> {
    (0..FB_DIM as u64).map(|j| (n * 31 + j) as f32 * 0.5 - 3.0).collect()
}

fn fb_bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|v| v.to_bits()).collect()
}

/// Build a closed 12-row store forced into three 4-record segments, and
/// return its layout read back off disk: `(segment path, record count,
/// ordinal of its first key)`. Appends are sequential and segment ids
/// ascend, so key ordinals run left-to-right across the sorted files.
fn fb_build(tag: &str) -> (StoreConfig, Vec<(PathBuf, usize, u64)>) {
    let rec = record_len(FB_DIM) as u64;
    let dir = temp_dir(&format!("fault_{tag}"));
    let cfg = StoreConfig {
        segment_bytes: SEGMENT_MAGIC.len() as u64 + FB_PER_SEG as u64 * rec,
        mmap: true,
        ..StoreConfig::new(dir.clone())
    };
    let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
    for n in 0..FB_ROWS {
        s.put(fb_key(n), &fb_row(n)).unwrap();
    }
    drop(s);
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|e| e == "log"))
        .collect();
    paths.sort(); // zero-padded ids: name order == id order == append order
    let mut layout = Vec::new();
    let mut first = 0u64;
    for path in paths {
        let data = std::fs::metadata(&path).unwrap().len() - SEGMENT_MAGIC.len() as u64;
        assert_eq!(data % rec, 0, "a clean build must end on a record boundary");
        let count = (data / rec) as usize;
        layout.push((path, count, first));
        first += count as u64;
    }
    assert_eq!(first, FB_ROWS, "every appended record must be on disk");
    assert_eq!(layout.len(), 3, "12 rows at 4/segment must span 3 files");
    (cfg, layout)
}

/// Reopen the damaged store (mmap on) and pin the exact outcome.
fn fb_check(cfg: &StoreConfig, lost: &[u64], skipped: u64, ctx: &str) {
    let mut s = EmbeddingStore::open(cfg.clone())
        .unwrap_or_else(|e| panic!("open must survive damage, got {e} [{ctx}]"));
    let st = s.stats();
    assert_eq!(st.corrupt_skipped, skipped, "corrupt_skipped [{ctx}]");
    assert_eq!(st.records as u64 + lost.len() as u64, FB_ROWS, "live records [{ctx}]");
    assert!(st.mmap_segments >= 2, "reopen must map the sealed segments [{ctx}]");
    for n in 0..FB_ROWS {
        let got = s.get(&fb_key(n));
        if lost.contains(&n) {
            assert!(got.is_none(), "damaged row {n} must read as a miss [{ctx}]");
        } else {
            let row = got.unwrap_or_else(|| panic!("intact row {n} lost [{ctx}]"));
            assert_eq!(fb_bits(&row), fb_bits(&fb_row(n)), "survivor {n} bitwise [{ctx}]");
        }
    }
    let snap = s.snapshot_row_data();
    assert_eq!(snap.len() as u64 + lost.len() as u64, FB_ROWS, "snapshot size [{ctx}]");
    for (k, r) in &snap {
        let n = k.graph_hash - 0x9A00;
        assert_eq!(fb_bits(&r.to_vec()), fb_bits(&fb_row(n)), "snapshot row {n} [{ctx}]");
    }
    // Recovery leaves the store appendable: a damaged row recomputes and
    // re-persists exactly like the daemon's miss path would.
    if let Some(&n) = lost.first() {
        s.put(fb_key(n), &fb_row(n)).unwrap();
        let row = s.get(&fb_key(n)).unwrap_or_else(|| panic!("re-persist lost [{ctx}]"));
        assert_eq!(fb_bits(&row), fb_bits(&fb_row(n)), "re-persisted row [{ctx}]");
    }
    let _ = std::fs::remove_dir_all(&cfg.dir);
}

#[test]
fn fault_battery_truncation_at_every_record_boundary() {
    let rec = record_len(FB_DIM) as u64;
    for file_idx in 0..3usize {
        for cut in 0..=FB_PER_SEG {
            let (cfg, layout) = fb_build(&format!("bnd{file_idx}_{cut}"));
            let (path, count, first) = &layout[file_idx];
            let f = std::fs::OpenOptions::new().write(true).open(path).unwrap();
            f.set_len(SEGMENT_MAGIC.len() as u64 + cut as u64 * rec).unwrap();
            drop(f);
            // A cut on a record boundary looks like a segment that simply
            // ended there: no torn bytes, so nothing is *counted* — the
            // records past the cut are cleanly gone and recomputable.
            let lost: Vec<u64> = (*first + cut as u64..*first + *count as u64).collect();
            let ctx = format!("boundary cut: file={file_idx} after record {cut}");
            fb_check(&cfg, &lost, 0, &ctx);
        }
    }
}

#[test]
fn fault_battery_mid_payload_truncation_tears_the_segment_tail() {
    let rec = record_len(FB_DIM);
    for file_idx in 0..3usize {
        for i in 0..FB_PER_SEG {
            // Tear inside the length prefix, the float payload, and the
            // trailing checksum — every torn shape a crash can leave.
            for (name, delta) in [("len-prefix", 2), ("payload", rec / 2), ("checksum", rec - 1)]
            {
                let (cfg, layout) = fb_build(&format!("mid{file_idx}_{i}_{delta}"));
                let (path, count, first) = &layout[file_idx];
                let at = SEGMENT_MAGIC.len() + i * rec + delta;
                let f = std::fs::OpenOptions::new().write(true).open(path).unwrap();
                f.set_len(at as u64).unwrap();
                drop(f);
                // One counted Truncated skip; record i and everything after
                // it in this file is unreachable (framing cannot resume past
                // a tear). Records in the other files are untouched.
                let lost: Vec<u64> = (*first + i as u64..*first + *count as u64).collect();
                let ctx = format!("mid-record tear: file={file_idx} record={i} in {name}");
                fb_check(&cfg, &lost, 1, &ctx);
            }
        }
    }
}

#[test]
fn fault_battery_single_byte_flips_lose_exactly_one_record() {
    let rec = record_len(FB_DIM);
    for file_idx in 0..3usize {
        for i in 0..FB_PER_SEG {
            // Flip a byte of the stored key and a byte of the float data —
            // both under the checksum, leaving the framing intact.
            for (name, delta) in [("key", 4 + 3), ("floats", 4 + 28 + 5)] {
                let (cfg, layout) = fb_build(&format!("flip{file_idx}_{i}_{delta}"));
                let (path, _, first) = &layout[file_idx];
                let at = SEGMENT_MAGIC.len() + i * rec + delta;
                let mut bytes = std::fs::read(path).unwrap();
                bytes[at] ^= 0x40;
                std::fs::write(path, &bytes).unwrap();
                // Checksum fails with intact framing: the scan resyncs past
                // exactly this record — one flipped bit costs one row, and
                // the rows AFTER it in the same segment survive.
                let ctx = format!("bit flip: file={file_idx} record={i} in {name}");
                fb_check(&cfg, &[*first + i as u64], 1, &ctx);
            }
        }
    }
}

#[test]
fn torn_tail_is_skipped_counted_and_recomputed() {
    let gsa = test_gsa();
    let ds = test_ds();
    let m = gsa.m;
    let (want, _) = embed_dataset(&ds, &gsa, None).unwrap();
    let dir = temp_dir("torn");
    let cfg = ServeConfig { gsa, store_dir: Some(dir.clone()), ..Default::default() };

    // Daemon #1 populates the log in request order (sequential client).
    let (addr, server) = start_server(cfg.clone());
    let mut client = Client::connect(addr);
    for g in 0..ds.len() {
        let (_, cached) = embed(&mut client, &ds, g);
        assert!(!cached);
    }
    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();

    // "SIGKILL mid-append": tear the last appended record (the final
    // graph's row) by truncating the active segment mid-checksum.
    let seg = active_segment(&dir);
    let len = std::fs::metadata(&seg).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
    f.set_len(len - 5).unwrap();
    drop(f);

    // Daemon #2 must open the damaged log without panicking, skip the
    // torn record with a visible counter, and keep serving.
    let last = ds.len() - 1;
    let (addr, server) = start_server(cfg);
    let mut client = Client::connect(addr);
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "store", "corrupt_skipped"), 1, "torn tail must be counted");
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len() - 1);

    // Undamaged rows still come off the disk log, bitwise.
    let (row, cached) = embed(&mut client, &ds, 0);
    assert!(cached, "undamaged row must be an L2 hit");
    assert_eq!(&want[..m], &row[..]);

    // The torn row reads as a miss, recomputes to the identical bits,
    // and is re-persisted.
    let (row, cached) = embed(&mut client, &ds, last);
    assert!(!cached, "the torn row must be recomputed, not served");
    assert_eq!(&want[last * m..(last + 1) * m], &row[..], "recomputed row drifted");
    let stats = client.stats();
    assert_eq!(u64_at(&stats, "pipeline", "graphs"), 1, "exactly the torn row recomputes");
    assert_eq!(u64_at(&stats, "store", "records") as usize, ds.len(), "row re-persisted");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
