//! Integration tests for the serve subsystem: a real daemon on an
//! ephemeral port, driven over loopback TCP.
//!
//! Pins the PR's acceptance contract:
//! - concurrent clients get embeddings **bitwise identical** to
//!   `embed_dataset` for the same seed/config;
//! - repeated submissions hit the embedding cache (hit counter > 0);
//! - malformed JSON, oversized graphs, and mid-request disconnects fail
//!   per-request without killing the daemon.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread::JoinHandle;

use graphlet_rf::coordinator::{embed_dataset, fwht_threads_from_env_or, EngineMode, GsaConfig};
use graphlet_rf::data::Dataset;
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::serve::{
    embed_request, nearest_request, parse_embed_reply, parse_nearest_reply, send_shutdown,
    ServeConfig, Server,
};
use graphlet_rf::util::{Json, Rng};

fn quickstart_ds() -> Dataset {
    // The quickstart generator at test scale (SBM, fixed seed).
    SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11))
}

fn test_gsa() -> GsaConfig {
    GsaConfig {
        k: 3,
        s: 100,
        m: 64,
        batch: 32,
        workers: 3,
        shards: 2,
        // Engine-agnostic tests: the CI engine matrix reruns this
        // whole file per CPU engine via GRAPHLET_RF_TEST_ENGINE
        // (cpu-sorf included) — the daemon contract is identical.
        engine: EngineMode::from_env_or(EngineMode::Cpu),
        // Likewise per FWHT budget (GRAPHLET_RF_TEST_THREADS 1 and 4):
        // a scheduling knob, so every daemon reply stays bitwise equal.
        fwht_threads: fwht_threads_from_env_or(1),
        seed: 42,
        ..Default::default()
    }
}

/// Start a daemon; with `GRAPHLET_RF_TEST_STORE=1` (the CI store axis)
/// or `GRAPHLET_RF_TEST_ANN=1` (the ANN axis) a fresh per-test temp-dir
/// segment log is attached, so every leg of the engine matrix also runs
/// the daemon contract with the L2 tier — and its IVFFlat retrieval
/// side-car — enabled: the wire protocol, bitwise replies, and error
/// semantics must be identical either way.
fn start_server(tag: &str, mut cfg: ServeConfig) -> (SocketAddr, JoinHandle<()>) {
    let axis_on = |var: &str| std::env::var(var).as_deref() == Ok("1");
    if cfg.store_dir.is_none()
        && (axis_on("GRAPHLET_RF_TEST_STORE") || axis_on("GRAPHLET_RF_TEST_ANN"))
    {
        let dir = std::env::temp_dir()
            .join(format!("graphlet_rf_serve_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        cfg.store_dir = Some(dir);
    }
    start_server_ram_only(cfg)
}

/// Start a daemon exactly as configured (no store axis): for tests
/// whose assertions pin L1-only semantics — with an L2 tier an
/// L1-evicted row is *still* served `cached:true` from disk, which is
/// the tiering working as designed, not an eviction bug.
///
/// With `GRAPHLET_RF_TEST_HTTP=1` (the CI HTTP axis) every daemon also
/// carries an ephemeral HTTP sidecar and must scrape clean right after
/// bind: `/readyz` reports ready (bind is synchronous — pipeline up,
/// store recovered, ANN cell built) and `/metrics` serves the
/// exposition format with the build-info series. The TCP-side
/// assertions of every test then run against a scraped daemon.
fn start_server_ram_only(mut cfg: ServeConfig) -> (SocketAddr, JoinHandle<()>) {
    let http_axis = std::env::var("GRAPHLET_RF_TEST_HTTP").as_deref() == Ok("1");
    if http_axis && cfg.http_port.is_none() {
        cfg.http_port = Some(0);
    }
    let server = Server::bind("127.0.0.1:0", cfg, None).unwrap();
    let addr = server.local_addr();
    if let Some(http) = server.http_addr() {
        let (status, body) = http_get(http, "/readyz");
        assert!(status.starts_with("HTTP/1.1 200"), "/readyz after bind: {status} {body}");
        let (status, body) = http_get(http, "/metrics");
        assert!(status.starts_with("HTTP/1.1 200"), "/metrics after bind: {status}");
        assert!(
            body.contains("graphlet_rf_build_info{"),
            "/metrics missing the build-info series:\n{body}"
        );
        let (status, _) = http_get(http, "/healthz");
        assert!(status.starts_with("HTTP/1.1 200"), "/healthz after bind: {status}");
    }
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

/// One-shot GET against a daemon's HTTP sidecar: (status line, body).
fn http_get(http: SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(http).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: test\r\nAccept: text/plain\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("malformed HTTP reply");
    (head.lines().next().unwrap_or("").to_string(), body.to_string())
}

/// A tiny blocking request/reply client.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    fn recv(&mut self) -> String {
        let mut reply = String::new();
        self.reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server closed the connection");
        reply
    }

    fn roundtrip(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

#[test]
fn concurrent_clients_bitwise_match_embed_dataset_and_hit_cache() {
    let gsa = test_gsa();
    let ds = quickstart_ds();
    let m = gsa.m;
    let (want, _) = embed_dataset(&ds, &gsa, None).unwrap();
    let (addr, server) = start_server("bitwise", ServeConfig { gsa, ..Default::default() });

    // Two concurrent clients submit interleaved halves of the dataset,
    // pipelining all their requests before reading replies — this is
    // what actually exercises cross-request batching.
    let results: Vec<(usize, Vec<f32>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2usize)
            .map(|c| {
                let ds = &ds;
                scope.spawn(move || {
                    let mut client = Client::connect(addr);
                    let mine: Vec<usize> = (0..ds.len()).filter(|g| g % 2 == c).collect();
                    for &g in &mine {
                        client.send(&embed_request(g as u64, g, &ds.graphs[g]));
                    }
                    let mut out = Vec::new();
                    for _ in &mine {
                        let (id, row, _) = parse_embed_reply(&client.recv()).unwrap();
                        out.push((id as usize, row));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(results.len(), ds.len());
    for (g, row) in &results {
        assert_eq!(row.len(), m);
        assert_eq!(&want[g * m..(g + 1) * m], &row[..], "graph {g} drifted vs embed_dataset");
    }

    // Resubmitting a graph must be served from the cache, bitwise equal.
    let mut client = Client::connect(addr);
    let (id, row, cached) =
        parse_embed_reply(&client.roundtrip(&embed_request(99, 0, &ds.graphs[0]))).unwrap();
    assert_eq!(id, 99);
    assert!(cached, "second submission of graph 0 must hit the cache");
    assert_eq!(&want[..m], &row[..]);

    // And the hit shows up in the stats op.
    let stats = Json::parse(client.roundtrip(r#"{"op":"stats","id":5}"#).trim()).unwrap();
    assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true));
    let hits = stats
        .get("cache")
        .and_then(|c| c.get("hits"))
        .and_then(Json::as_u64)
        .unwrap();
    assert!(hits >= 1, "cache hits = {hits}");
    let graphs = stats
        .get("pipeline")
        .and_then(|p| p.get("graphs"))
        .and_then(Json::as_u64)
        .unwrap();
    assert_eq!(graphs as usize, ds.len(), "pipeline computed each graph exactly once");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

#[test]
fn protocol_errors_are_per_request_and_daemon_survives() {
    let mut gsa = test_gsa();
    gsa.s = 50;
    gsa.m = 16;
    let cfg = ServeConfig { gsa, max_nodes: 80, max_edges: 500, ..Default::default() };
    let (addr, server) = start_server("protocol", cfg);
    let mut client = Client::connect(addr);

    // Malformed JSON line.
    let reply = client.roundtrip("this is not json");
    let err = parse_embed_reply(&reply).unwrap_err();
    assert!(err.contains("bad json"), "{err}");

    // Unknown op (id still echoed).
    let reply = client.roundtrip(r#"{"op":"warp","id":3}"#);
    assert!(reply.contains("unknown op"), "{reply}");
    assert!(Json::parse(reply.trim()).unwrap().get("id").and_then(Json::as_u64) == Some(3));

    // Oversized graph (node guard).
    let reply = client.roundtrip(r#"{"op":"embed","id":4,"v":5000,"edges":[[0,1]]}"#);
    assert!(reply.contains("too large"), "{reply}");

    // Edge out of range.
    let reply = client.roundtrip(r#"{"op":"embed","id":5,"v":5,"edges":[[0,9]]}"#);
    assert!(reply.contains("out of range"), "{reply}");

    // Graph smaller than the graphlet size.
    let reply = client.roundtrip(r#"{"op":"embed","id":6,"v":2,"edges":[[0,1]]}"#);
    assert!(reply.contains("requires at least k"), "{reply}");

    // Absurd graph_index (seed derivation is O(index) — must be capped,
    // not walked).
    let reply = client.roundtrip(
        r#"{"op":"embed","id":9,"v":5,"edges":[[0,1]],"graph_index":4503599627370496}"#,
    );
    assert!(reply.contains("graph_index"), "{reply}");

    // After all those failures, the same connection still serves a
    // valid request…
    let ds = quickstart_ds();
    let (id, row, _) =
        parse_embed_reply(&client.roundtrip(&embed_request(7, 0, &ds.graphs[0]))).unwrap();
    assert_eq!(id, 7);
    assert_eq!(row.len(), 16);
    assert!(row.iter().all(|v| v.is_finite()));

    // …and so does a fresh connection.
    let mut client2 = Client::connect(addr);
    let pong = client2.roundtrip(r#"{"op":"ping","id":8}"#);
    assert!(pong.contains("\"ok\":true"), "{pong}");

    drop(client);
    drop(client2);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// LRU eviction order through the real daemon: a row kept hot by cache
/// hits must survive an insert at capacity; the least-recently-used
/// row must be the victim. (Under the old FIFO policy the hot row —
/// inserted first — would have been evicted instead.)
#[test]
fn cache_eviction_is_lru_through_the_daemon() {
    let mut gsa = test_gsa();
    gsa.s = 50;
    gsa.m = 16;
    // RAM-only deliberately: this test pins the L1 eviction order via
    // the `cached` flag, and an attached store would (correctly) serve
    // evicted rows from disk.
    let cfg = ServeConfig { gsa, cache_capacity: 2, ..Default::default() };
    let (addr, server) = start_server_ram_only(cfg);
    let ds = quickstart_ds();
    let mut client = Client::connect(addr);
    // Sequential roundtrips make cache state deterministic: the writer
    // inserts a fresh row before it writes the reply line.
    let embed = |client: &mut Client, id: u64, g: usize| {
        let (rid, row, cached) =
            parse_embed_reply(&client.roundtrip(&embed_request(id, g, &ds.graphs[g]))).unwrap();
        assert_eq!(rid, id);
        assert_eq!(row.len(), 16);
        cached
    };
    assert!(!embed(&mut client, 0, 0), "first sight of graph 0");
    assert!(!embed(&mut client, 1, 1), "first sight of graph 1");
    assert!(embed(&mut client, 2, 0), "graph 0 must hit — and be bumped to most-recent");
    // Cache is full {0, 1} with 1 least-recently-used: inserting graph
    // 2 must evict 1, not the FIFO victim 0.
    assert!(!embed(&mut client, 3, 2), "first sight of graph 2");
    assert!(embed(&mut client, 4, 0), "recently used graph 0 must survive the eviction");
    assert!(!embed(&mut client, 5, 1), "LRU graph 1 must have been evicted");

    // Capacity semantics are unchanged: never more than 2 rows.
    let stats = Json::parse(client.roundtrip(r#"{"op":"stats","id":9}"#).trim()).unwrap();
    let cache = stats.get("cache").unwrap();
    assert_eq!(cache.get("len").and_then(Json::as_u64), Some(2));
    assert_eq!(cache.get("capacity").and_then(Json::as_u64), Some(2));
    let hits = cache.get("hits").and_then(Json::as_u64).unwrap();
    assert!(hits >= 2, "hits = {hits}");
    // Eviction telemetry: the sequence above evicted exactly twice
    // (graph 2's insert dropped LRU graph 1; graph 1's re-insert
    // dropped LRU graph 2) — sequential roundtrips make this exact.
    assert_eq!(cache.get("evictions").and_then(Json::as_u64), Some(2));

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// Backpressure telemetry through the real daemon: with one worker
/// pinned on a slow job, queued work must show up as `queue_depth > 0`
/// in the `stats` op *before* admission control starts rejecting — and
/// once the bound is exceeded, `Overloaded` errors must actually fire.
/// `shard_occupancy` rides along with one gauge per shard.
#[test]
fn stats_expose_queue_depth_before_overload_fires() {
    let mut gsa = test_gsa();
    gsa.workers = 1;
    gsa.shards = 1;
    gsa.queue_cap = 4; // job-queue capacity = queue_cap * workers = 4
    gsa.s = 30_000; // each job pins the lone worker for a long time
    gsa.m = 8;
    let (addr, server) = start_server("backpressure", ServeConfig { gsa, ..Default::default() });
    let ds = quickstart_ds();
    let mut client = Client::connect(addr);

    // Pipeline admitted-but-slow work without reading replies: the lone
    // worker claims at most one job instantly, the rest sit in the
    // bounded queue.
    for id in 0..4u64 {
        client.send(&embed_request(id, id as usize, &ds.graphs[0]));
    }
    // Stats replies are synthetic (written ahead of the slow embeds),
    // so the snapshot is readable while the jobs are still queued.
    client.send(r#"{"op":"stats","id":100}"#);
    let stats = loop {
        let line = client.recv();
        let v = Json::parse(line.trim()).unwrap();
        if v.get("op").and_then(Json::as_str) == Some("stats") {
            break v;
        }
    };
    let pipe = stats.get("pipeline").unwrap();
    let depth = pipe.get("queue_depth").and_then(Json::as_u64).unwrap();
    assert!(depth > 0, "backlog behind a busy worker must be visible, got depth {depth}");
    let occupancy = pipe.get("shard_occupancy").and_then(Json::as_array).unwrap();
    assert_eq!(occupancy.len(), 1, "one gauge per shard");
    assert!(occupancy[0].as_u64().is_some(), "occupancy is a counter");

    // Now push past the bound: the queue (cap 4) already holds the
    // backlog, so a burst of extra submits must trip admission control.
    for id in 200..208u64 {
        client.send(&embed_request(id, (id - 200) as usize, &ds.graphs[1]));
    }
    // At most one burst submit can have found a free queue slot, so at
    // least 7 rejections reply instantly — reading 6 never blocks on a
    // slow accepted job.
    let mut overloaded = 0usize;
    for _ in 0..6 {
        let line = client.recv();
        if line.contains("overloaded") {
            overloaded += 1;
        }
    }
    assert!(overloaded > 0, "a burst past the queue bound must be rejected as overloaded");

    // Slam the connection shut without draining the slow embeds; the
    // daemon must still answer a fresh connection and shut down clean.
    drop(client);
    let mut client2 = Client::connect(addr);
    let pong = client2.roundtrip(r#"{"op":"ping","id":1}"#);
    assert!(pong.contains("\"ok\":true"), "{pong}");
    drop(client2);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// A daemon with a store attached for the `nearest` tests; the store
/// lives in a fresh per-test temp dir, returned so the test can clean
/// it up after shutdown.
fn start_server_with_store(
    tag: &str,
    mut cfg: ServeConfig,
) -> (SocketAddr, JoinHandle<()>, std::path::PathBuf) {
    let dir = std::env::temp_dir()
        .join(format!("graphlet_rf_serve_ann_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    cfg.store_dir = Some(dir.clone());
    let (addr, handle) = start_server_ram_only(cfg);
    (addr, handle, dir)
}

fn u64_at(stats: &Json, obj: &str, field: &str) -> u64 {
    stats
        .get(obj)
        .and_then(|o| o.get(field))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("stats missing {obj}.{field}: {stats}"))
}

/// Every malformed `nearest` request fails that request only — bad k,
/// bad probe, malformed edges, k beyond the corpus — and the same
/// connection keeps serving embeds, retrievals, and pings afterwards.
#[test]
fn nearest_error_paths_are_per_request_and_daemon_survives() {
    let mut gsa = test_gsa();
    gsa.s = 50;
    gsa.m = 16;
    let (addr, server, dir) =
        start_server_with_store("errors", ServeConfig { gsa, ..Default::default() });
    let ds = quickstart_ds();
    let mut client = Client::connect(addr);

    // k=1 against the still-empty corpus: a clean per-request error.
    let reply = client.roundtrip(&nearest_request(1, 0, 1, None, &ds.graphs[0]));
    let err = parse_nearest_reply(&reply).unwrap_err();
    assert!(err.contains("exceeds"), "{err}");

    // Grow the corpus to 3 rows.
    for g in 0..3 {
        parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
            .unwrap();
    }

    // Missing k.
    let reply = client.roundtrip(r#"{"op":"nearest","id":10,"v":5,"edges":[[0,1]]}"#);
    assert!(reply.contains("\\\"k\\\"") || reply.contains("\"k\""), "{reply}");
    assert!(reply.contains("\"ok\":false"), "{reply}");

    // k = 0.
    let reply = client.roundtrip(r#"{"op":"nearest","id":11,"v":5,"edges":[[0,1]],"k":0}"#);
    assert!(reply.contains("at least 1"), "{reply}");

    // k beyond the 3 stored rows.
    let reply = client.roundtrip(r#"{"op":"nearest","id":12,"v":5,"edges":[[0,1]],"k":99}"#);
    assert!(reply.contains("exceeds"), "{reply}");

    // Malformed edges.
    let reply = client.roundtrip(r#"{"op":"nearest","id":13,"v":5,"edges":[[0]],"k":1}"#);
    assert!(reply.contains("pair"), "{reply}");

    // Probe outside (0, 1].
    for bad in [r#""probe":1.5"#, r#""probe":0"#] {
        let line = format!(r#"{{"op":"nearest","id":14,"v":5,"edges":[[0,1]],"k":1,{bad}}}"#);
        let reply = client.roundtrip(&line);
        assert!(reply.contains("probe"), "{bad}: {reply}");
        assert!(reply.contains("\"ok\":false"), "{bad}: {reply}");
    }

    // After every failure, a valid retrieval still works: graph 0 is
    // cached, so this is the hit fast path; probe 1.0 → exact, self at
    // rank 0 with a bitwise-zero distance.
    let reply = client.roundtrip(&nearest_request(20, 0, 3, Some(1.0), &ds.graphs[0]));
    let (id, neighbors, _, scanned) = parse_nearest_reply(&reply).unwrap();
    assert_eq!(id, 20);
    assert_eq!(neighbors.len(), 3);
    assert_eq!(scanned, 3, "probe 1.0 over 3 rows must scan all 3");
    assert_eq!(neighbors[0].distance.to_bits(), 0.0f32.to_bits(), "self must rank first");
    for pair in neighbors.windows(2) {
        assert!(pair[0].distance <= pair[1].distance, "neighbors must be sorted");
    }

    // …and so does the rest of the protocol.
    let pong = client.roundtrip(r#"{"op":"ping","id":21}"#);
    assert!(pong.contains("\"ok\":true"), "{pong}");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Without `--store-dir` there is no corpus: `nearest` must fail with a
/// pointer at the flag, per-request, and the daemon keeps serving.
#[test]
fn nearest_without_a_store_is_a_per_request_error() {
    let mut gsa = test_gsa();
    gsa.s = 50;
    gsa.m = 16;
    // RAM-only deliberately (and immune to the store/ANN env axes):
    // this test pins the no-store error path.
    let (addr, server) = start_server_ram_only(ServeConfig { gsa, ..Default::default() });
    let ds = quickstart_ds();
    let mut client = Client::connect(addr);

    let reply = client.roundtrip(&nearest_request(1, 0, 1, None, &ds.graphs[0]));
    let err = parse_nearest_reply(&reply).unwrap_err();
    assert!(err.contains("--store-dir"), "{err}");

    let pong = client.roundtrip(r#"{"op":"ping","id":2}"#);
    assert!(pong.contains("\"ok\":true"), "{pong}");

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}

/// The daemon's `nearest` distances are **bitwise** what the client can
/// compute from the embed replies — and the op is read-only: a query
/// through the uncached (pipeline) path never grows the stored corpus.
#[test]
fn nearest_is_bitwise_exact_and_read_only_through_the_daemon() {
    let gsa = test_gsa();
    let m = gsa.m;
    let (addr, server, dir) =
        start_server_with_store("bitwise", ServeConfig { gsa, ..Default::default() });
    let ds = quickstart_ds();
    let mut client = Client::connect(addr);

    // Embed the whole dataset, keeping the rows as the client-side
    // ground truth for distances.
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for g in 0..ds.len() {
        let (_, row, _) =
            parse_embed_reply(&client.roundtrip(&embed_request(g as u64, g, &ds.graphs[g])))
                .unwrap();
        assert_eq!(row.len(), m);
        rows.push(row);
    }
    let n = ds.len();

    // Every stored graph queried at probe 1.0: the reply's distance
    // sequence must equal the client-recomputed distances, sorted
    // ascending, bit for bit (cache-hit path: the rows are in L1).
    for g in 0..n {
        let reply = client.roundtrip(&nearest_request(g as u64, g, n, Some(1.0), &ds.graphs[g]));
        let (_, neighbors, _, scanned) = parse_nearest_reply(&reply).unwrap();
        assert_eq!(neighbors.len(), n, "graph {g}");
        assert_eq!(scanned, n, "graph {g}: probe 1.0 must scan the whole corpus");
        let mut want: Vec<f32> =
            rows.iter().map(|r| graphlet_rf::ann::l2_distance(&rows[g], r)).collect();
        want.sort_unstable_by(|a, b| a.total_cmp(b));
        for (rank, (got, want)) in neighbors.iter().zip(&want).enumerate() {
            assert_eq!(
                got.distance.to_bits(),
                want.to_bits(),
                "graph {g} rank {rank}: daemon distance {} vs client {}",
                got.distance,
                want
            );
        }
        assert_eq!(neighbors[0].distance.to_bits(), 0.0f32.to_bits(), "self must rank first");
    }

    let stats = Json::parse(client.roundtrip(r#"{"op":"stats","id":800}"#).trim()).unwrap();
    assert_eq!(u64_at(&stats, "store", "records") as usize, n);
    assert_eq!(u64_at(&stats, "ann", "queries") as usize, n);
    assert_eq!(
        u64_at(&stats, "ann", "indexed") + u64_at(&stats, "ann", "pending"),
        n as u64,
        "index ∪ pending must cover the whole corpus: {stats}"
    );

    // Read-only through the *uncached* path: a fresh graph_index forces
    // the query row through the pipeline (PendingReply::Nearest), and
    // the stored corpus must not grow.
    let reply =
        client.roundtrip(&nearest_request(900, n + 100, n, Some(1.0), &ds.graphs[0]));
    let (id, neighbors, _, _) = parse_nearest_reply(&reply).unwrap();
    assert_eq!(id, 900);
    assert_eq!(neighbors.len(), n);
    let stats = Json::parse(client.roundtrip(r#"{"op":"stats","id":801}"#).trim()).unwrap();
    assert_eq!(
        u64_at(&stats, "store", "records") as usize,
        n,
        "a nearest query row must never be persisted"
    );

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mid_request_disconnect_keeps_daemon_serving() {
    let mut gsa = test_gsa();
    gsa.s = 2000; // slow enough that the job is still in flight on close
    gsa.m = 16;
    let (addr, server) = start_server("disconnect", ServeConfig { gsa, ..Default::default() });
    let ds = quickstart_ds();

    // Fire a request and slam the connection shut without reading the
    // reply: the in-flight job completes into a closed channel.
    {
        let mut doomed = Client::connect(addr);
        doomed.send(&embed_request(1, 0, &ds.graphs[0]));
    } // both halves dropped here

    // The daemon must keep serving new connections and new work.
    let mut client = Client::connect(addr);
    let (id, row, _) =
        parse_embed_reply(&client.roundtrip(&embed_request(2, 1, &ds.graphs[1]))).unwrap();
    assert_eq!(id, 2);
    assert_eq!(row.len(), 16);

    drop(client);
    send_shutdown(&addr.to_string()).unwrap();
    server.join().unwrap();
}
