//! The ANN differential battery: the IVFFlat index pinned against an
//! independent brute-force oracle.
//!
//! Contracts pinned here (the PR's acceptance criteria):
//! - at `probe = 1.0` the index returns **exactly** the brute-force
//!   result — same keys, same order, bitwise-equal distances — on every
//!   seeded corpus (sizes straddling the brute threshold × two dims),
//!   through all three query paths (`nearest` dispatch, `nearest_brute`,
//!   and `nearest_ivf` forced past the dispatch);
//! - an index built from a store's `snapshot_rows` answers identically
//!   to one built from the in-memory entries, including the scan-effort
//!   counters (build determinism survives the disk roundtrip);
//! - at the default probe factor, recall@10 against the oracle is
//!   ≥ 0.9 on a corpus of real SBM-family embeddings.
//!
//! Every assert carries the corpus seed so a failure is replayable.

use std::collections::HashSet;

use graphlet_rf::ann::{
    l2_distance, neighbor_cmp, AnnConfig, AnnIndex, Neighbor, DEFAULT_MIN_BRUTE, DEFAULT_PROBE,
};
use graphlet_rf::coordinator::{embed_dataset, fwht_threads_from_env_or, EngineMode, GsaConfig};
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::store::{CacheKey, EmbeddingStore, StoreConfig};
use graphlet_rf::util::Rng;

fn key(i: u64) -> CacheKey {
    CacheKey {
        graph_hash: i.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        config_fp: 0xC0FFEE,
        seed: i,
    }
}

/// A seeded gaussian corpus of `n` rows of width `dim`.
fn corpus(n: usize, dim: usize, seed: u64) -> Vec<(CacheKey, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let row: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            (key(i as u64), row)
        })
        .collect()
}

/// The oracle: sort ALL rows by `(distance, key)` and keep k. Shares
/// only the two leaf functions (`l2_distance`, `neighbor_cmp`) with the
/// index — no centroids, no lists, no shared traversal code.
fn brute_oracle(entries: &[(CacheKey, Vec<f32>)], query: &[f32], k: usize) -> Vec<Neighbor> {
    let mut all: Vec<Neighbor> = entries
        .iter()
        .map(|(key, row)| Neighbor { key: *key, distance: l2_distance(query, row) })
        .collect();
    all.sort_unstable_by(neighbor_cmp);
    all.truncate(k);
    all
}

fn assert_same(got: &[Neighbor], want: &[Neighbor], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: neighbor count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.key, w.key, "{ctx}: key at rank {i}");
        assert_eq!(
            g.distance.to_bits(),
            w.distance.to_bits(),
            "{ctx}: distance at rank {i} not bitwise ({} vs {})",
            g.distance,
            w.distance
        );
    }
}

/// Tentpole contract: exhaustive-probe IVF ≡ brute force, bitwise, on
/// every corpus size straddling the brute-force threshold and on both
/// feature widths, for gaussian queries and exact-copy queries
/// (distance-0 ties resolved by key order).
#[test]
fn probe_one_is_bitwise_equal_to_brute_force_across_sizes_and_dims() {
    let sizes = [0usize, 1, DEFAULT_MIN_BRUTE - 1, DEFAULT_MIN_BRUTE + 1, 500];
    for dim in [64usize, 128] {
        for n in sizes {
            let seed = 0x5EED ^ ((n as u64) << 8) ^ dim as u64;
            let entries = corpus(n, dim, seed);
            let index = AnnIndex::build(entries.clone(), dim, &AnnConfig::default());
            assert_eq!(index.len(), n, "seed {seed:#x}");

            let mut queries: Vec<Vec<f32>> = Vec::new();
            let mut rng = Rng::new(seed ^ 0x0FF5E7);
            for _ in 0..8 {
                queries.push((0..dim).map(|_| rng.gaussian_f32()).collect());
            }
            if n > 0 {
                // Exact copies of stored rows: distance 0 to self, and
                // (for duplicate-free gaussian data) a guaranteed
                // distance-0 tie candidate exercising the key tiebreak.
                queries.push(entries[0].1.clone());
                queries.push(entries[n / 2].1.clone());
            }

            for (qi, q) in queries.iter().enumerate() {
                for k in [1usize, 10, n] {
                    let want = brute_oracle(&entries, q, k);
                    let paths = [
                        ("nearest", index.nearest(q, k, 1.0)),
                        ("nearest_brute", index.nearest_brute(q, k)),
                        // Forced past the dispatch: the IVF machinery
                        // itself must be exact at full probe, even on
                        // corpora small enough to normally brute-force.
                        ("nearest_ivf", index.nearest_ivf(q, k, 1.0)),
                    ];
                    for (path, got) in paths {
                        let ctx =
                            format!("{path} n={n} dim={dim} k={k} query={qi} seed={seed:#x}");
                        assert_same(&got.neighbors, &want, &ctx);
                    }
                }
            }
        }
    }
}

/// Build determinism across the disk roundtrip: rows inserted into a
/// segment log in shuffled order, snapshotted back, must build an index
/// that answers every query identically — keys, bitwise distances, and
/// the probed/scanned effort counters — to one built from the original
/// in-memory entries.
#[test]
fn store_snapshot_builds_the_same_index_as_in_memory_entries() {
    let (n, dim, seed) = (100usize, 32usize, 0xB00C_u64);
    let entries = corpus(n, dim, seed);

    let dir = std::env::temp_dir()
        .join(format!("graphlet_ann_snap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = EmbeddingStore::open(StoreConfig::new(dir.clone())).unwrap();
    let mut shuffled = entries.clone();
    Rng::new(seed ^ 7).shuffle(&mut shuffled);
    for (key, row) in &shuffled {
        store.put(*key, row).unwrap();
    }
    let snapshot = store.snapshot_rows();
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
    assert_eq!(snapshot.len(), n, "seed {seed:#x}");

    let cfg = AnnConfig::default();
    let from_disk = AnnIndex::build(snapshot, dim, &cfg);
    let from_ram = AnnIndex::build(entries, dim, &cfg);
    assert_eq!(from_disk.nlist(), from_ram.nlist(), "seed {seed:#x}");

    let mut rng = Rng::new(seed ^ 0x0FF5E7);
    for qi in 0..8 {
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        // n = 100 ≥ DEFAULT_MIN_BRUTE, so the default probe genuinely
        // walks the IVF path; 1.0 covers the brute dispatch too.
        for probe in [DEFAULT_PROBE, 1.0] {
            let a = from_disk.nearest(&q, 10, probe);
            let b = from_ram.nearest(&q, 10, probe);
            let ctx = format!("query={qi} probe={probe} seed={seed:#x}");
            assert_eq!(a.probed, b.probed, "{ctx}: probed lists");
            assert_eq!(a.scanned, b.scanned, "{ctx}: scanned rows");
            assert_same(&a.neighbors, &b.neighbors, &ctx);
        }
    }
}

/// Retrieval quality at the default probe factor on realistic data:
/// five SBM families with widely spread expected degree embed into
/// well-separated clusters; mean recall@10 vs the brute-force oracle
/// must be ≥ 0.9.
#[test]
fn recall_at_10_beats_090_at_default_probe_on_sbm_corpus() {
    let seed = 0xA11CE_u64;
    let gsa = GsaConfig {
        k: 3,
        s: 100,
        m: 64,
        batch: 32,
        workers: 3,
        shards: 2,
        engine: EngineMode::from_env_or(EngineMode::Cpu),
        fwht_threads: fwht_threads_from_env_or(1),
        seed: 42,
        ..Default::default()
    };
    let m = gsa.m;
    let mut entries: Vec<(CacheKey, Vec<f32>)> = Vec::new();
    for (family, degree) in [4.0f64, 8.0, 14.0, 22.0, 30.0].into_iter().enumerate() {
        let ds = SbmConfig { expected_degree: degree, per_class: 12, ..Default::default() }
            .generate(&mut Rng::new(seed ^ family as u64));
        let (rows, _) = embed_dataset(&ds, &gsa, None).unwrap();
        for g in 0..ds.len() {
            entries.push((key(entries.len() as u64), rows[g * m..(g + 1) * m].to_vec()));
        }
    }
    let index = AnnIndex::build(entries.clone(), m, &AnnConfig::default());
    assert!(
        index.len() >= DEFAULT_MIN_BRUTE,
        "corpus of {} rows would dispatch to brute force — the recall test must walk the \
         IVF path (seed {seed:#x})",
        index.len()
    );

    let mut recall_sum = 0.0f64;
    for (_, row) in &entries {
        let want: HashSet<CacheKey> =
            brute_oracle(&entries, row, 10).iter().map(|n| n.key).collect();
        let got = index.nearest(row, 10, DEFAULT_PROBE);
        assert!(got.probed > 0, "default probe must scan at least one list (seed {seed:#x})");
        let hits = got.neighbors.iter().filter(|n| want.contains(&n.key)).count();
        recall_sum += hits as f64 / want.len() as f64;
    }
    let recall = recall_sum / entries.len() as f64;
    assert!(
        recall >= 0.9,
        "recall@10 = {recall:.3} < 0.9 at probe {DEFAULT_PROBE} over {} rows (seed {seed:#x})",
        entries.len()
    );
}
