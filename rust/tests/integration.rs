//! Integration tests: the full system composed end to end.
//!
//! These exercise real multi-module flows (dataset -> pipeline ->
//! classifier; runtime + gnn over real artifacts; experiments harness)
//! rather than per-module units. PJRT-dependent tests skip cleanly when
//! `make artifacts` has not run.

use graphlet_rf::classify::{train_and_eval, TrainConfig};
use graphlet_rf::coordinator::{embed_dataset, fwht_threads_from_env_or, EngineMode, GsaConfig};
use graphlet_rf::data::Dataset;
use graphlet_rf::features::Variant;
use graphlet_rf::gen::{DdLikeConfig, RedditLikeConfig, SbmConfig};
use graphlet_rf::iso::GraphletRegistry;
use graphlet_rf::mmd::{embedding_sq_distance, theorem1_bound};
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::sample::sampler_by_name;
use graphlet_rf::util::Rng;

fn engine() -> Option<Engine> {
    graphlet_rf::runtime::try_engine(&artifacts_dir())
}

/// Full GSA-phi_OPU flow on an easy SBM task must reach high accuracy —
/// through the real PJRT artifact path when available.
#[test]
fn end_to_end_sbm_classification() {
    let engine = engine();
    let ds = SbmConfig { per_class: 25, r: 2.5, ..Default::default() }
        .generate(&mut Rng::new(42));
    let cfg = GsaConfig {
        k: 6,
        s: 500,
        m: 1000,
        batch: 256,
        // The CI engine matrix reruns this flow per CPU engine via
        // GRAPHLET_RF_TEST_ENGINE (cpu-sorf included).
        engine: if engine.is_some() {
            EngineMode::Pjrt
        } else {
            EngineMode::from_env_or(EngineMode::CpuInline)
        },
        seed: 7,
        ..Default::default()
    };
    let (emb, metrics) = embed_dataset(&ds, &cfg, engine.as_ref()).unwrap();
    assert_eq!(metrics.samples, ds.len() * cfg.s);
    let split = ds.split(0.8, &mut Rng::new(1));
    let acc = train_and_eval(&emb, &ds.labels, cfg.m, &split.train, &split.test,
                             &TrainConfig::default());
    assert!(acc >= 0.9, "end-to-end accuracy {acc}");
}

/// The three engine modes must agree numerically on the same seed.
#[test]
fn engine_modes_numerically_consistent() {
    let ds = SbmConfig { per_class: 4, r: 1.5, ..Default::default() }
        .generate(&mut Rng::new(9));
    let mk = |mode| GsaConfig {
        k: 3,
        s: 200,
        m: 64,
        batch: 32,
        engine: mode,
        seed: 3,
        ..Default::default()
    };
    let (cpu, _) = embed_dataset(&ds, &mk(EngineMode::Cpu), None).unwrap();
    let (inline, _) = embed_dataset(&ds, &mk(EngineMode::CpuInline), None).unwrap();
    for (a, b) in cpu.iter().zip(&inline) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    if let Some(engine) = engine() {
        let (pjrt, _) = embed_dataset(&ds, &mk(EngineMode::Pjrt), Some(&engine)).unwrap();
        for (a, b) in cpu.iter().zip(&pjrt) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }
}

/// GSA with the Gs+eig variant composes the Jacobi eigensolver with the
/// gaussian artifact (d = k) end to end.
#[test]
fn gauss_eig_end_to_end() {
    let engine = engine();
    let ds = SbmConfig { per_class: 5, r: 2.0, ..Default::default() }
        .generate(&mut Rng::new(10));
    let cfg = GsaConfig {
        k: 6,
        s: 300,
        m: 500,
        batch: 256,
        variant: Variant::GaussEig,
        sigma: 0.5,
        engine: if engine.is_some() { EngineMode::Pjrt } else { EngineMode::CpuInline },
        seed: 11,
        ..Default::default()
    };
    let (emb, _) = embed_dataset(&ds, &cfg, engine.as_ref()).unwrap();
    assert_eq!(emb.len(), ds.len() * cfg.m);
    assert!(emb.iter().all(|v| v.is_finite()));
}

/// Synthetic real-data substitutes run through the whole pipeline with
/// variable graph sizes (CSR path).
#[test]
fn real_data_substitutes_pipeline() {
    for ds in [
        DdLikeConfig { per_class: 8, ..Default::default() }.generate(&mut Rng::new(2)),
        RedditLikeConfig { per_class: 8, ..Default::default() }.generate(&mut Rng::new(3)),
    ] {
        let cfg = GsaConfig {
            k: 7,
            s: 200,
            m: 100,
            batch: 64,
            engine: EngineMode::from_env_or(EngineMode::CpuInline),
            seed: 4,
            ..Default::default()
        };
        let (emb, metrics) = embed_dataset(&ds, &cfg, None).unwrap();
        assert_eq!(metrics.graphs, 16);
        assert!(emb.iter().all(|v| v.is_finite()), "{}", ds.name);
    }
}

/// The sharded executor is a pure refactor of the dataflow: on a
/// variable-size CSR dataset (the hardest layout: graphs of different
/// sizes interleaved round-robin over shards), embeddings must be
/// bitwise identical for every (shards, workers) combination, in both
/// CPU engine modes.
#[test]
fn sharded_pipeline_bitwise_stable_on_variable_size_graphs() {
    let ds = DdLikeConfig { per_class: 6, ..Default::default() }.generate(&mut Rng::new(8));
    for mode in [EngineMode::Cpu, EngineMode::CpuInline, EngineMode::CpuSorf] {
        let mk = |shards: usize, workers: usize| GsaConfig {
            k: 5,
            s: 120,
            m: 48,
            batch: 32,
            shards,
            workers,
            // The CI matrix reruns this whole test at FWHT budgets 1
            // and 4 (GRAPHLET_RF_TEST_THREADS), so shard/worker
            // stability is pinned on the parallel panel path too.
            fwht_threads: fwht_threads_from_env_or(1),
            engine: mode,
            seed: 21,
            ..Default::default()
        };
        let (reference, _) = embed_dataset(&ds, &mk(1, 1), None).unwrap();
        assert!(reference.iter().all(|v| v.is_finite()));
        for shards in [2usize, 4] {
            for workers in [1usize, 4] {
                let (e, m) = embed_dataset(&ds, &mk(shards, workers), None).unwrap();
                assert_eq!(
                    e, reference,
                    "bitwise drift: mode={mode:?} shards={shards} workers={workers}"
                );
                assert_eq!(m.samples, ds.len() * 120);
                assert_eq!(m.shard_feature_secs.len(), shards);
            }
        }
    }
}

/// The `--fwht-threads` budget is the fourth scheduling axis the
/// bitwise invariant quantifies over: cpu-sorf embeddings must be
/// identical across budgets {1, 2, 4} for every shard × worker combo
/// the sharded-stability test already pins — batch-major panels,
/// block-parallel dispatch, and row-parallel FWHT all included.
#[test]
fn sorf_bitwise_stable_across_fwht_thread_budgets() {
    let ds = DdLikeConfig { per_class: 6, ..Default::default() }.generate(&mut Rng::new(8));
    let mk = |fwht_threads: usize, shards: usize, workers: usize| GsaConfig {
        k: 5,
        s: 120,
        m: 48,
        batch: 32,
        shards,
        workers,
        fwht_threads,
        engine: EngineMode::CpuSorf,
        seed: 21,
        ..Default::default()
    };
    let (reference, _) = embed_dataset(&ds, &mk(1, 1, 1), None).unwrap();
    assert!(reference.iter().all(|v| v.is_finite()));
    for fwht_threads in [2usize, 4] {
        for shards in [1usize, 2, 4] {
            for workers in [1usize, 4] {
                let (e, m) = embed_dataset(&ds, &mk(fwht_threads, shards, workers), None).unwrap();
                assert_eq!(
                    e, reference,
                    "bitwise drift: fwht_threads={fwht_threads} shards={shards} workers={workers}"
                );
                assert_eq!(m.samples, ds.len() * 120);
            }
        }
    }
}

/// phi_match and phi_OPU must see the SAME subgraph distribution: the
/// sampled graphlet edge-count histogram matches between the kernelgk
/// path and a manual sampler run with the same seed discipline.
#[test]
fn samplers_shared_across_paths() {
    let ds = Dataset::new(
        "one",
        vec![SbmConfig::default().sample_graph(1, &mut Rng::new(5))],
        vec![1],
    );
    let sampler = sampler_by_name("rw");
    let mut reg = GraphletRegistry::new();
    let mut rng = Rng::new(77);
    let spec = graphlet_rf::kernelgk::k_spectrum(
        &ds.graphs[0], 5, 400, sampler.as_ref(), &mut reg, &mut rng,
    );
    let total: f32 = spec.iter().map(|&(_, v)| v).sum();
    assert!((total - 1.0).abs() < 1e-6);
    // Same seed -> same sample stream -> identical spectrum.
    let mut reg2 = GraphletRegistry::new();
    let mut rng2 = Rng::new(77);
    let spec2 = graphlet_rf::kernelgk::k_spectrum(
        &ds.graphs[0], 5, 400, sampler.as_ref(), &mut reg2, &mut rng2,
    );
    assert_eq!(spec, spec2);
}

/// Theorem 1, integrated: embedding distances from the REAL pipeline
/// concentrate within the bound (single trial at a forgiving operating
/// point; the statistical sweep lives in examples/thm1_concentration.rs).
#[test]
fn theorem1_bound_holds_through_pipeline() {
    let cfg = SbmConfig { r: 2.0, ..Default::default() };
    let mut rng = Rng::new(21);
    let ga = cfg.sample_graph(0, &mut rng);
    let gb = cfg.sample_graph(1, &mut rng);
    let ds = Dataset::new("pair", vec![ga, gb], vec![0, 1]);
    let emb_cfg = |m: usize, s: usize, seed: u64| GsaConfig {
        k: 3,
        s,
        m,
        batch: 256,
        variant: Variant::Gauss,
        sigma: 1.0,
        sampler: "uniform".into(),
        engine: EngineMode::CpuInline,
        seed,
        ..Default::default()
    };
    // Reference at large (m, s).
    let (big, _) = embed_dataset(&ds, &emb_cfg(8000, 20000, 1), None).unwrap();
    let mmd_ref = embedding_sq_distance(&big[..8000], &big[8000..]);
    // Operating point.
    let (emb, _) = embed_dataset(&ds, &emb_cfg(1000, 2000, 2), None).unwrap();
    let d = embedding_sq_distance(&emb[..1000], &emb[1000..]);
    let bound = theorem1_bound(1000, 2000, 0.05);
    assert!(
        (d - mmd_ref).abs() <= bound,
        "deviation {} exceeds bound {bound}",
        (d - mmd_ref).abs()
    );
}

/// Tentpole acceptance for the fastrf subsystem: SBM two-class
/// embeddings via `cpu-sorf` are statistically interchangeable with
/// the dense engines' — same task, same protocol, classification
/// accuracy within noise and class-separation (the squared MMD the
/// classifier sees) within a constant factor. SORF is a different
/// random-feature *family*, so nothing here is bitwise; the margins
/// are many times wider than the estimator noise at these sizes.
#[test]
fn sorf_embeddings_statistically_close_to_dense() {
    let ds = SbmConfig { per_class: 25, r: 3.0, ..Default::default() }
        .generate(&mut Rng::new(5));
    let m = 512usize;
    for variant in [Variant::Opu, Variant::Gauss] {
        let mk = |engine| GsaConfig {
            k: 4,
            s: 400,
            m,
            batch: 64,
            variant,
            sigma: 0.1,
            engine,
            seed: 13,
            ..Default::default()
        };
        let (dense, _) = embed_dataset(&ds, &mk(EngineMode::Cpu), None).unwrap();
        let (sorf, _) = embed_dataset(&ds, &mk(EngineMode::CpuSorf), None).unwrap();
        assert!(sorf.iter().all(|v| v.is_finite()));

        // 50/50 split: 25 test graphs, so one flipped prediction moves
        // accuracy by only 4% and the agreement margins below are many
        // flips wide.
        let split = ds.split(0.5, &mut Rng::new(1));
        let tc = TrainConfig::default();
        let acc_dense = train_and_eval(&dense, &ds.labels, m, &split.train, &split.test, &tc);
        let acc_sorf = train_and_eval(&sorf, &ds.labels, m, &split.train, &split.test, &tc);
        if variant == Variant::Opu {
            // The OPU setup is the one the dense accuracy tests already
            // pin well above 0.8 on this task; a broken SORF engine
            // would sit at chance (~0.5).
            assert!(acc_dense > 0.75, "opu: dense baseline degenerate ({acc_dense})");
            assert!(acc_sorf > 0.75, "opu: sorf accuracy off ({acc_sorf})");
        }
        // phi_Gs at the paper's sigma is a near-delta kernel on the
        // equal-degree SBM (deliberately hard, see kernelgk tests), so
        // for it only the engine *agreement* is asserted, not an
        // absolute floor.
        assert!(
            (acc_dense - acc_sorf).abs() <= 0.25,
            "{variant:?}: dense {acc_dense} vs sorf {acc_sorf}"
        );

        // Squared distance between class-mean embeddings: both feature
        // families estimate the same population MMD.
        let class_mmd = |emb: &[f32]| {
            let mut mean = [vec![0.0f32; m], vec![0.0f32; m]];
            let mut count = [0usize; 2];
            for (i, &label) in ds.labels.iter().enumerate() {
                let row = &emb[i * m..(i + 1) * m];
                for (a, &v) in mean[label as usize].iter_mut().zip(row) {
                    *a += v;
                }
                count[label as usize] += 1;
            }
            for (c, mv) in count.iter().zip(mean.iter_mut()) {
                for v in mv.iter_mut() {
                    *v /= *c as f32;
                }
            }
            embedding_sq_distance(&mean[0], &mean[1])
        };
        let (mmd_dense, mmd_sorf) = (class_mmd(&dense), class_mmd(&sorf));
        assert!(mmd_dense > 0.0 && mmd_sorf > 0.0, "{variant:?}: degenerate class separation");
        let ratio = mmd_sorf / mmd_dense;
        // Near-delta phi_Gs sits closer to its estimator noise floor
        // than phi_OPU, so its band is wider.
        let (lo, hi) = match variant {
            Variant::Opu => (0.5, 2.0),
            _ => (0.25, 4.0),
        };
        assert!(
            (lo..=hi).contains(&ratio),
            "{variant:?}: MMD ratio {ratio} (dense {mmd_dense}, sorf {mmd_sorf})"
        );
    }
}

/// GIN baseline trains through the artifact and beats chance on a
/// degree-separable task (pins rust<->L2 wiring end to end).
#[test]
fn gin_end_to_end() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(6);
    let mut graphs = Vec::new();
    let mut labels = Vec::new();
    for i in 0..40usize {
        let class = (i % 2) as u8;
        let p = if class == 0 { 0.05 } else { 0.4 };
        let mut g = graphlet_rf::graph::DenseGraph::new(60);
        for a in 0..60 {
            for b in (a + 1)..60 {
                if rng.bool(p) {
                    g.add_edge(a, b);
                }
            }
        }
        graphs.push(graphlet_rf::graph::AnyGraph::Dense(g));
        labels.push(class);
    }
    let ds = Dataset::new("density", graphs, labels);
    let split = ds.split(0.8, &mut Rng::new(7));
    let cfg = graphlet_rf::gnn::GinConfig { steps: 300, seed: 1, log_every: 30 };
    let (acc, curve) = graphlet_rf::gnn::GinModel::train_and_eval(&engine, &ds, &split, &cfg)
        .unwrap();
    assert!(curve.last().unwrap().1 < curve.first().unwrap().1);
    assert!(acc > 0.75, "acc={acc}");
}
