//! GIN baseline driver (Fig. 1 right): rust owns the training loop and
//! parameter state; the forward/backward/Adam step is an AOT-compiled
//! artifact (`gin_train_b32_v60`) built from python/compile/model.py.
//!
//! The model matches the paper's comparison GNN: 5 GIN layers (hidden
//! width 4) + 2 fully-connected layers, trained with Adam on softmax
//! cross-entropy, node feature = degree (structure only).

use anyhow::{bail, Context, Result};

use crate::data::{Dataset, Split};
use crate::runtime::{Engine, HostTensor};
use crate::util::Rng;

/// Parameter shapes in wire order — MUST mirror
/// `python/compile/model.py::gin_param_shapes`.
pub fn gin_param_shapes() -> Vec<(&'static str, Vec<usize>)> {
    let mut shapes: Vec<(&'static str, Vec<usize>)> = Vec::new();
    let names_w1 = ["gin0_w1", "gin1_w1", "gin2_w1", "gin3_w1", "gin4_w1"];
    let names_b1 = ["gin0_b1", "gin1_b1", "gin2_b1", "gin3_b1", "gin4_b1"];
    let names_w2 = ["gin0_w2", "gin1_w2", "gin2_w2", "gin3_w2", "gin4_w2"];
    let names_b2 = ["gin0_b2", "gin1_b2", "gin2_b2", "gin3_b2", "gin4_b2"];
    let mut d_in = 1usize;
    for layer in 0..5 {
        shapes.push((names_w1[layer], vec![d_in, 4]));
        shapes.push((names_b1[layer], vec![4]));
        shapes.push((names_w2[layer], vec![4, 4]));
        shapes.push((names_b2[layer], vec![4]));
        d_in = 4;
    }
    shapes.push(("fc1_w", vec![4, 4]));
    shapes.push(("fc1_b", vec![4]));
    shapes.push(("fc2_w", vec![4, 2]));
    shapes.push(("fc2_b", vec![2]));
    shapes
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct GinConfig {
    /// SGD steps (each step samples a random batch of 32 with replacement).
    pub steps: usize,
    pub seed: u64,
    /// Log the loss every `log_every` steps into the returned curve.
    pub log_every: usize,
}

impl Default for GinConfig {
    fn default() -> Self {
        GinConfig { steps: 300, seed: 0, log_every: 10 }
    }
}

/// The GIN model state (parameters + Adam moments), living on the host
/// between artifact calls.
pub struct GinModel {
    params: Vec<Vec<f32>>,
    m_state: Vec<Vec<f32>>,
    v_state: Vec<Vec<f32>>,
    step: usize,
    pub train_batch: usize,
    pub predict_batch: usize,
    pub nodes: usize,
}

impl GinModel {
    /// Glorot-ish init matching the python initializer's scale. Biases
    /// start at a small positive value: with hidden width 4 and ReLU, a
    /// zero-bias init can produce an all-dead layer, which is a permanent
    /// fixed point (zero activations AND zero gradients forever).
    pub fn init(seed: u64) -> GinModel {
        let shapes = gin_param_shapes();
        let mut rng = Rng::new(seed);
        let mut params = Vec::new();
        for (_, shape) in &shapes {
            let n: usize = shape.iter().product();
            let mut buf = vec![0.05f32; n];
            if shape.len() == 2 {
                let scale = (2.0 / (shape[0] + shape[1]) as f32).sqrt();
                rng.fill_gaussian(&mut buf, scale);
            }
            params.push(buf);
        }
        let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        GinModel {
            m_state: zeros.clone(),
            v_state: zeros,
            params,
            step: 0,
            train_batch: 32,
            predict_batch: 60,
            nodes: 60,
        }
    }

    fn state_tensors(&self) -> Vec<HostTensor> {
        self.params
            .iter()
            .chain(&self.m_state)
            .chain(&self.v_state)
            .map(|p| HostTensor::F32(p.clone()))
            .collect()
    }

    /// One Adam step on a batch of graphs; returns the loss.
    pub fn train_step(
        &mut self,
        engine: &Engine,
        adj: &[f32],
        labels: &[i32],
    ) -> Result<f32> {
        let b = self.train_batch;
        let v = self.nodes;
        anyhow::ensure!(adj.len() == b * v * v && labels.len() == b);
        self.step += 1;
        let mut inputs = vec![
            HostTensor::F32(vec![self.step as f32]),
            HostTensor::F32(adj.to_vec()),
            HostTensor::I32(labels.to_vec()),
        ];
        inputs.extend(self.state_tensors());
        let name = format!("gin_train_b{}_v{}", b, v);
        let mut out = engine.execute(&name, &inputs)?.into_iter();
        let loss = match out.next().context("missing loss output")? {
            HostTensor::F32(l) => l[0],
            _ => bail!("loss must be f32"),
        };
        let n = self.params.len();
        let rest: Vec<HostTensor> = out.collect();
        anyhow::ensure!(rest.len() == 3 * n, "train-step output arity");
        for (i, t) in rest.into_iter().enumerate() {
            let HostTensor::F32(buf) = t else { bail!("state must be f32") };
            let slot = i % n;
            match i / n {
                0 => self.params[slot] = buf,
                1 => self.m_state[slot] = buf,
                _ => self.v_state[slot] = buf,
            }
        }
        Ok(loss)
    }

    /// Predict classes for up to `predict_batch` graphs (padded; trimmed).
    pub fn predict(&self, engine: &Engine, adj: &[f32], n_graphs: usize) -> Result<Vec<u8>> {
        let b = self.predict_batch;
        let v = self.nodes;
        anyhow::ensure!(n_graphs <= b && adj.len() == n_graphs * v * v);
        let mut padded = adj.to_vec();
        padded.resize(b * v * v, 0.0);
        let mut inputs = vec![HostTensor::F32(padded)];
        inputs.extend(self.params.iter().map(|p| HostTensor::F32(p.clone())));
        let name = format!("gin_predict_b{}_v{}", b, v);
        let out = engine.execute(&name, &inputs)?;
        let HostTensor::I32(pred) = &out[0] else { bail!("pred must be i32") };
        Ok(pred[..n_graphs].iter().map(|&p| p as u8).collect())
    }

    /// Full train/eval protocol on a dataset of fixed-size graphs.
    /// Returns (test accuracy, loss curve).
    pub fn train_and_eval(
        engine: &Engine,
        ds: &Dataset,
        split: &Split,
        cfg: &GinConfig,
    ) -> Result<(f64, Vec<(usize, f32)>)> {
        let mut model = GinModel::init(cfg.seed);
        let v = model.nodes;
        for g in &ds.graphs {
            anyhow::ensure!(g.v() == v, "GIN artifact is compiled for v={v}");
        }
        let mut rng = Rng::new(cfg.seed ^ 0x5EED);
        let b = model.train_batch;
        let mut adj = vec![0.0f32; b * v * v];
        let mut labels = vec![0i32; b];
        let mut curve = Vec::new();
        for step in 0..cfg.steps {
            for slot in 0..b {
                let idx = split.train[rng.usize(split.train.len())];
                let flat = ds.graphs[idx].flat_adj(v);
                adj[slot * v * v..(slot + 1) * v * v].copy_from_slice(&flat);
                labels[slot] = ds.labels[idx] as i32;
            }
            let loss = model.train_step(engine, &adj, &labels)?;
            anyhow::ensure!(loss.is_finite(), "GIN loss diverged at step {step}");
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                curve.push((step, loss));
            }
        }
        // Evaluate in predict-batch chunks.
        let mut correct = 0usize;
        for chunk in split.test.chunks(model.predict_batch) {
            let mut adj = Vec::with_capacity(chunk.len() * v * v);
            for &idx in chunk {
                adj.extend_from_slice(&ds.graphs[idx].flat_adj(v));
            }
            let preds = model.predict(engine, &adj, chunk.len())?;
            correct += preds
                .iter()
                .zip(chunk)
                .filter(|&(&p, &idx)| p == ds.labels[idx])
                .count();
        }
        Ok((correct as f64 / split.test.len() as f64, curve))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SbmConfig;
    use crate::runtime::artifacts_dir;

    fn engine() -> Option<Engine> {
        crate::runtime::try_engine(&artifacts_dir())
    }

    #[test]
    fn param_shapes_match_manifest() {
        let Some(engine) = engine() else { return };
        let spec = engine.manifest().get("gin_train_b32_v60").unwrap();
        // step + adj + labels + 3 * params
        let n = gin_param_shapes().len();
        assert_eq!(spec.inputs.len(), 3 + 3 * n);
        assert_eq!(spec.outputs.len(), 1 + 3 * n);
        for (i, (name, shape)) in gin_param_shapes().iter().enumerate() {
            let input = &spec.inputs[3 + i];
            assert_eq!(&input.dims, shape, "param {name}");
            assert!(input.name.ends_with(name), "{} vs {name}", input.name);
        }
    }

    /// Density-separable task: class 0 sparse ER, class 1 dense ER. The
    /// degree input feature makes this trivially learnable, so it pins
    /// the rust<->artifact wiring (the equal-degree SBM task is, per the
    /// paper, genuinely hard for feature-less GNNs — see fig1_right).
    fn density_dataset(n_per_class: usize, seed: u64) -> crate::data::Dataset {
        let mut rng = Rng::new(seed);
        let mut graphs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..2 * n_per_class {
            let class = (i % 2) as u8;
            let p = if class == 0 { 0.05 } else { 0.4 };
            let mut g = crate::graph::DenseGraph::new(60);
            for a in 0..60 {
                for b in (a + 1)..60 {
                    if rng.bool(p) {
                        g.add_edge(a, b);
                    }
                }
            }
            graphs.push(crate::graph::AnyGraph::Dense(g));
            labels.push(class);
        }
        crate::data::Dataset::new("density", graphs, labels)
    }

    #[test]
    fn loss_decreases_and_classifies_density_task() {
        let Some(engine) = engine() else { return };
        let ds = density_dataset(20, 3);
        let split = ds.split(0.8, &mut Rng::new(4));
        let cfg = GinConfig { steps: 120, seed: 1, log_every: 10 };
        let (acc, curve) = GinModel::train_and_eval(&engine, &ds, &split, &cfg).unwrap();
        let first = curve.first().unwrap().1;
        let last = curve.last().unwrap().1;
        assert!(last < first * 0.8, "loss did not decrease: {first} -> {last}");
        assert!(acc > 0.8, "density task should be easy for GIN: acc={acc}");
    }

    #[test]
    fn predict_shape_and_determinism() {
        let Some(engine) = engine() else { return };
        let ds = SbmConfig { per_class: 4, ..Default::default() }.generate(&mut Rng::new(5));
        let model = GinModel::init(7);
        let v = model.nodes;
        let mut adj = Vec::new();
        for g in &ds.graphs {
            adj.extend_from_slice(&g.flat_adj(v));
        }
        let p1 = model.predict(&engine, &adj, ds.len()).unwrap();
        let p2 = model.predict(&engine, &adj, ds.len()).unwrap();
        assert_eq!(p1.len(), 8);
        assert_eq!(p1, p2);
        assert!(p1.iter().all(|&p| p <= 1));
    }
}
