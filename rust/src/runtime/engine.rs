//! PJRT execution engine: load HLO-text artifacts, compile once, execute
//! many times.
//!
//! Follows the reference wiring of /opt/xla-example/load_hlo: HLO *text*
//! -> `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `PjRtClient::compile` -> `execute`. All artifacts are lowered with
//! `return_tuple=True`, so outputs are decomposed from a single tuple
//! literal.
//!
//! Threading note: PJRT handles are raw pointers without `Sync`; the
//! coordinator therefore confines one [`Engine`] to one feature-engine
//! thread and communicates through channels (coordinator/pipeline.rs).
//! The sharded pipeline runs N feature shards by giving each shard
//! thread its **own** engine, built via [`Engine::with_manifest`] from
//! the artifacts dir plus an already-parsed [`Manifest`] clone — the
//! manifest is read and parsed once per run, not once per shard.
//! XLA-CPU itself multithreads the heavy dots internally.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// Host-side tensor handed to / returned by the engine.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Default artifacts directory: `$GRAPHLET_RF_ARTIFACTS`, else
/// `<manifest dir>/artifacts` (so tests work from any cwd), else
/// `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("GRAPHLET_RF_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest_rel = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if manifest_rel.exists() {
        return manifest_rel;
    }
    PathBuf::from("artifacts")
}

/// Best-effort engine over `dir`: `Some` when the artifacts manifest
/// exists and the PJRT runtime starts, `None` otherwise (with a skip
/// note on stderr). The standard "PJRT or skip" gate shared by tests,
/// benches, and examples — with the vendored xla stub this always
/// returns `None`, which is what routes everything onto the CPU
/// engines.
pub fn try_engine(dir: &Path) -> Option<Engine> {
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping PJRT: no artifacts at {}", dir.display());
        return None;
    }
    match Engine::new(dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT: engine unavailable ({err})");
            None
        }
    }
}

/// A compiled artifact plus its spec (shape checking on every call).
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with host tensors; validates shapes against the manifest.
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals = self.to_literals(inputs)?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        self.decompose_outputs(result)
    }

    /// Execute with pre-uploaded device buffers (fast path: RF parameter
    /// matrices stay resident across calls).
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {}: got {} inputs, want {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        self.decompose_outputs(result)
    }

    fn to_literals(&self, inputs: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {}: got {} inputs, want {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            literals.push(host_to_literal(t, spec)?);
        }
        Ok(literals)
    }

    fn decompose_outputs(&self, result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
        let buffer = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?;
        let literal = buffer.to_literal_sync()?;
        let parts = literal.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {}: got {} outputs, want {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| literal_to_host(&lit, spec))
            .collect()
    }
}

fn host_to_literal(t: &HostTensor, spec: &TensorSpec) -> Result<xla::Literal> {
    anyhow::ensure!(
        t.len() == spec.element_count(),
        "input {}: got {} elements, want {} ({:?})",
        spec.name,
        t.len(),
        spec.element_count(),
        spec.dims
    );
    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
    let lit = match (t, spec.dtype) {
        (HostTensor::F32(v), DType::F32) => xla::Literal::vec1(v).reshape(&dims)?,
        (HostTensor::I32(v), DType::I32) => xla::Literal::vec1(v).reshape(&dims)?,
        _ => bail!("input {}: dtype mismatch", spec.name),
    };
    Ok(lit)
}

fn literal_to_host(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
    let out = match spec.dtype {
        DType::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
        DType::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
    };
    anyhow::ensure!(
        out.len() == spec.element_count(),
        "output {}: got {} elements, want {}",
        spec.name,
        out.len(),
        spec.element_count()
    );
    Ok(out)
}

/// The engine: one PJRT CPU client + compile cache over the manifest.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, Rc<LoadedArtifact>>>,
}

impl Engine {
    /// Create an engine over an artifacts directory (see
    /// [`artifacts_dir`] for the default).
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        Self::with_manifest(dir, manifest)
    }

    /// Create an engine from an already-parsed manifest — the per-shard
    /// construction path of the sharded pipeline. `Manifest` is `Clone +
    /// Send` while the engine itself is neither, so the coordinator
    /// parses the artifact index once on the caller's engine and ships
    /// (dir, manifest) clones to the shard threads, which each pay only
    /// for their own PJRT client and compile cache.
    pub fn with_manifest(dir: &Path, manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: Default::default(),
        })
    }

    pub fn with_default_dir() -> Result<Engine> {
        Self::new(&artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The artifacts directory this engine loads from (shard threads
    /// combine it with a manifest clone to replicate the engine).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<LoadedArtifact>> {
        if let Some(hit) = self.cache.borrow().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let loaded = Rc::new(LoadedArtifact { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Upload a host f32 tensor to the device (for resident parameters).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    /// One-call convenience: load (cached) + execute host tensors.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?.execute(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` and a real PJRT runtime;
    /// they are skipped (cleanly) when the artifacts directory is absent
    /// or the engine cannot start (e.g. the vendored xla stub), so
    /// `cargo test` works in a fresh offline checkout too.
    fn engine() -> Option<Engine> {
        try_engine(&artifacts_dir())
    }

    #[test]
    fn loads_and_executes_smoke_artifact() {
        let Some(engine) = engine() else { return };
        let art = engine.load("rf_opu_xla_d9_m64_b32").unwrap();
        let (b, d, m) = (32, 9, 64);
        let inputs = vec![
            HostTensor::F32(vec![1.0; b * d]),
            HostTensor::F32(vec![0.1; d * m]),
            HostTensor::F32(vec![0.2; d * m]),
            HostTensor::F32(vec![0.0; m]),
            HostTensor::F32(vec![0.0; m]),
        ];
        let out = art.execute(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].as_f32();
        assert_eq!(y.len(), b * m);
        // |9*0.1|^2 + |9*0.2|^2 = 0.81 + 3.24 = 4.05, scaled by 1/sqrt(64).
        let want = 4.05f32 / 8.0;
        assert!((y[0] - want).abs() < 1e-4, "{} vs {want}", y[0]);
        assert!(y.iter().all(|&v| (v - want).abs() < 1e-4));
    }

    #[test]
    fn pallas_and_xla_artifacts_agree() {
        let Some(engine) = engine() else { return };
        let (b, d, m) = (32, 9, 64);
        let mut rng = crate::util::Rng::new(7);
        let mut mk = |n: usize| {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian(&mut v, 1.0);
            v
        };
        let inputs = vec![
            HostTensor::F32(mk(b * d)),
            HostTensor::F32(mk(d * m)),
            HostTensor::F32(mk(d * m)),
            HostTensor::F32(mk(m)),
            HostTensor::F32(mk(m)),
        ];
        let y_xla = engine.execute("rf_opu_xla_d9_m64_b32", &inputs).unwrap();
        let y_pal = engine.execute("rf_opu_pallas_d9_m64_b32", &inputs).unwrap();
        crate::util::check::assert_allclose(
            y_pal[0].as_f32(),
            y_xla[0].as_f32(),
            1e-4,
            1e-4,
        );
    }

    #[test]
    fn device_resident_buffers_match_literal_path() {
        let Some(engine) = engine() else { return };
        let art = engine.load("rf_gauss_xla_d9_m64_b32").unwrap();
        let (b, d, m) = (32, 9, 64);
        let mut rng = crate::util::Rng::new(8);
        let mut x = vec![0.0f32; b * d];
        let mut w = vec![0.0f32; d * m];
        let mut bias = vec![0.0f32; m];
        rng.fill_gaussian(&mut x, 1.0);
        rng.fill_gaussian(&mut w, 1.0);
        rng.fill_gaussian(&mut bias, 1.0);
        let via_literal = art
            .execute(&[
                HostTensor::F32(x.clone()),
                HostTensor::F32(w.clone()),
                HostTensor::F32(bias.clone()),
            ])
            .unwrap();
        let xb = engine.upload_f32(&x, &[b, d]).unwrap();
        let wb = engine.upload_f32(&w, &[d, m]).unwrap();
        let bb = engine.upload_f32(&bias, &[m]).unwrap();
        let via_buffers = art.execute_buffers(&[&xb, &wb, &bb]).unwrap();
        crate::util::check::assert_allclose(
            via_buffers[0].as_f32(),
            via_literal[0].as_f32(),
            1e-6,
            1e-6,
        );
    }

    #[test]
    fn engine_matches_cpu_feature_map() {
        // The PJRT path and the rust CPU fallback must compute the same
        // math given the same parameters — this pins L2<->L3 agreement.
        let Some(engine) = engine() else { return };
        let (b, d, m) = (32, 9, 64);
        let mut rng = crate::util::Rng::new(9);
        let params = crate::features::RfParams::generate(
            crate::features::Variant::Opu,
            d,
            m,
            1.0,
            &mut rng,
        );
        let mut x = vec![0.0f32; b * d];
        for v in x.iter_mut() {
            *v = rng.bool(0.4) as u8 as f32;
        }
        let out = engine
            .execute(
                "rf_opu_xla_d9_m64_b32",
                &[
                    HostTensor::F32(x.clone()),
                    HostTensor::F32(params.mats[0].clone()),
                    HostTensor::F32(params.mats[1].clone()),
                    HostTensor::F32(params.biases[0].clone()),
                    HostTensor::F32(params.biases[1].clone()),
                ],
            )
            .unwrap();
        let mut cpu_out = vec![0.0f32; b * m];
        crate::features::CpuFeatureMap::new(params).map_batch(&x, b, &mut cpu_out);
        crate::util::check::assert_allclose(out[0].as_f32(), &cpu_out, 1e-4, 1e-4);
    }

    #[test]
    fn shape_mismatch_is_error() {
        let Some(engine) = engine() else { return };
        let art = engine.load("rf_gauss_xla_d9_m64_b32").unwrap();
        let bad = vec![
            HostTensor::F32(vec![0.0; 5]), // wrong element count
            HostTensor::F32(vec![0.0; 9 * 64]),
            HostTensor::F32(vec![0.0; 64]),
        ];
        assert!(art.execute(&bad).is_err());
        assert!(art.execute(&bad[..2]).is_err());
    }
}
