//! Parser for `artifacts/manifest.txt`, the line-oriented index written by
//! `python/compile/aot.py` (grammar documented there). No serde offline,
//! so the format is deliberately trivial to parse.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Element type of a tensor (only what the artifacts use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

/// Shape + dtype + positional name of one artifact input/output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    /// Empty for scalars.
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact record from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: PathBuf,
    /// rf | embed | gin_train | gin_predict
    pub kind: String,
    /// Free-form key=value metadata (variant, impl, d, m, batch, s, v).
    pub meta: BTreeMap<String, String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Typed metadata accessor.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("artifact {}: missing meta {key}", self.name))?
            .parse()
            .with_context(|| format!("artifact {}: meta {key} not an integer", self.name))
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta.get(key).map(|s| s.as_str())
    }
}

/// The parsed manifest: artifact specs by name. `Clone + Send` so the
/// sharded coordinator can parse it once and hand copies to shard
/// threads, which rebuild their own engines from it
/// (`Engine::with_manifest`) without re-reading the file.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Parse `manifest.txt` content.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
        match lines.next() {
            Some("manifest-version 1") => {}
            other => bail!("unsupported manifest header: {other:?}"),
        }
        let mut artifacts = BTreeMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        for line in lines {
            let mut parts = line.split_whitespace();
            let key = parts.next().unwrap();
            match key {
                "artifact" => {
                    if cur.is_some() {
                        bail!("artifact record not closed with 'end'");
                    }
                    let name = parts.next().context("artifact without name")?;
                    cur = Some(ArtifactSpec {
                        name: name.to_string(),
                        file: PathBuf::new(),
                        kind: String::new(),
                        meta: BTreeMap::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                "file" => {
                    let a = cur.as_mut().context("file outside artifact")?;
                    a.file = PathBuf::from(parts.next().context("file without path")?);
                }
                "kind" => {
                    let a = cur.as_mut().context("kind outside artifact")?;
                    a.kind = parts.next().context("kind without value")?.to_string();
                }
                "meta" => {
                    let a = cur.as_mut().context("meta outside artifact")?;
                    for kv in parts {
                        let (k, v) = kv
                            .split_once('=')
                            .with_context(|| format!("bad meta token {kv:?}"))?;
                        a.meta.insert(k.to_string(), v.to_string());
                    }
                }
                "input" | "output" => {
                    let a = cur.as_mut().context("tensor outside artifact")?;
                    let name = parts.next().context("tensor without name")?;
                    let dtype = DType::parse(parts.next().context("tensor without dtype")?)?;
                    let shape_tok = parts.next().context("tensor without shape")?;
                    let dims: Vec<usize> = if shape_tok == "scalar" {
                        Vec::new()
                    } else {
                        shape_tok
                            .split(',')
                            .map(|d| d.parse().context("bad dim"))
                            .collect::<Result<_>>()?
                    };
                    let spec = TensorSpec { name: name.to_string(), dtype, dims };
                    if key == "input" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "end" => {
                    let a = cur.take().context("end outside artifact")?;
                    if a.file.as_os_str().is_empty() || a.kind.is_empty() {
                        bail!("artifact {} missing file/kind", a.name);
                    }
                    artifacts.insert(a.name.clone(), a);
                }
                other => bail!("unknown manifest key {other:?}"),
            }
        }
        if cur.is_some() {
            bail!("unterminated artifact record");
        }
        Ok(Manifest { artifacts })
    }

    /// Load from `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!("artifact {name:?} not in manifest — re-run `make artifacts`")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
manifest-version 1
artifact rf_opu_xla_d9_m64_b32
file rf_opu_xla_d9_m64_b32.hlo.txt
kind rf
meta variant=opu impl=xla d=9 m=64 batch=32
input x f32 32,9
input wr f32 9,64
input wi f32 9,64
input br f32 64
input bi f32 64
output y f32 32,64
end
artifact gin_train_b32_v60
file gin_train_b32_v60.hlo.txt
kind gin_train
meta batch=32 v=60
input step f32 scalar
input adj f32 32,60,60
input labels i32 32
output loss f32 scalar
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("rf_opu_xla_d9_m64_b32").unwrap();
        assert_eq!(a.kind, "rf");
        assert_eq!(a.meta_usize("m").unwrap(), 64);
        assert_eq!(a.meta_str("variant"), Some("opu"));
        assert_eq!(a.inputs.len(), 5);
        assert_eq!(a.inputs[0].dims, vec![32, 9]);
        assert_eq!(a.inputs[0].element_count(), 288);
        assert_eq!(a.outputs[0].dtype, DType::F32);
        let g = m.get("gin_train_b32_v60").unwrap();
        assert!(g.inputs[0].dims.is_empty(), "scalar");
        assert_eq!(g.inputs[2].dtype, DType::I32);
    }

    #[test]
    fn missing_artifact_is_helpful_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_clones_are_independent_and_complete() {
        // The sharded pipeline ships manifest clones across threads.
        fn assert_send_clone<T: Clone + Send>() {}
        assert_send_clone::<Manifest>();
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.clone();
        assert_eq!(c.artifacts.len(), m.artifacts.len());
        assert_eq!(
            c.get("rf_opu_xla_d9_m64_b32").unwrap().inputs.len(),
            m.get("rf_opu_xla_d9_m64_b32").unwrap().inputs.len()
        );
    }

    #[test]
    fn rejects_bad_header() {
        assert!(Manifest::parse("manifest-version 2\n").is_err());
        assert!(Manifest::parse("").is_err());
    }

    #[test]
    fn rejects_unclosed_record() {
        let text = "manifest-version 1\nartifact a\nfile f\nkind rf\n";
        assert!(Manifest::parse(text).is_err());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_dims() {
        assert!(Manifest::parse("manifest-version 1\nbogus x\n").is_err());
        let text = "manifest-version 1\nartifact a\nfile f\nkind rf\ninput x f32 3,x\nend\n";
        assert!(Manifest::parse(text).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Integration smoke: if `make artifacts` has run, the real
        // manifest must parse and contain the quickstart artifact.
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 50);
            assert!(m.get("rf_opu_xla_d36_m5000_b256").is_ok());
        }
    }
}
