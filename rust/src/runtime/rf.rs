//! Random-feature executor: one artifact + device-resident parameters.
//!
//! The parameter matrices (W / Wr, Wi and biases) are uploaded to the
//! device **once** and reused across every batch — per call only the
//! (batch, d) input crosses the host/device boundary. This mirrors the
//! physical OPU, whose transmission matrix is literally baked into the
//! scattering medium.
//!
//! Shard usage: the sharded coordinator constructs one [`RfExecutor`]
//! per feature shard, each over that shard's own [`Engine`]
//! (`Engine::with_manifest`). The executor holds no thread affinity of
//! its own beyond the engine's PJRT handles, and all shards upload the
//! **same** parameter draw, so shard count never changes the math.

use anyhow::{bail, Context, Result};

use super::engine::{Engine, HostTensor, LoadedArtifact};
use crate::features::{RfParams, Variant};

/// Naming helper mirroring python/compile/configs.py.
pub fn rf_artifact_name(variant: Variant, impl_: &str, d: usize, m: usize, batch: usize) -> String {
    let v = match variant {
        Variant::Opu => "opu",
        // gauss-eig shares the gaussian artifact at d = k (DESIGN.md §3).
        Variant::Gauss | Variant::GaussEig => "gauss",
        Variant::Match => panic!("phi_match has no artifact"),
    };
    format!("rf_{v}_{impl_}_d{d}_m{m}_b{batch}")
}

/// A ready-to-run random-feature map on the PJRT device.
pub struct RfExecutor {
    artifact: std::rc::Rc<LoadedArtifact>,
    params: Vec<xla::PjRtBuffer>,
    pub variant: Variant,
    pub d: usize,
    pub m: usize,
    pub batch: usize,
    /// Scratch for padding partial batches.
    pad_buf: std::cell::RefCell<Vec<f32>>,
}

impl RfExecutor {
    /// Load the artifact for (variant, impl, d, m, batch) and pin the
    /// given parameters on device.
    pub fn new(
        engine: &Engine,
        impl_: &str,
        params: &RfParams,
        batch: usize,
    ) -> Result<RfExecutor> {
        let name = rf_artifact_name(params.variant, impl_, params.d, params.m, batch);
        let artifact = engine
            .load(&name)
            .with_context(|| format!("loading RF artifact {name}"))?;
        let expected_inputs = match params.variant {
            Variant::Opu => 5,
            _ => 3,
        };
        if artifact.spec.inputs.len() != expected_inputs {
            bail!("artifact {name}: unexpected input arity");
        }
        let mut bufs = Vec::new();
        for mat in &params.mats {
            bufs.push(engine.upload_f32(mat, &[params.d, params.m])?);
        }
        for bias in &params.biases {
            bufs.push(engine.upload_f32(bias, &[params.m])?);
        }
        Ok(RfExecutor {
            artifact,
            params: bufs,
            variant: params.variant,
            d: params.d,
            m: params.m,
            batch,
            pad_buf: Default::default(),
        })
    }

    /// Map `rows` rows of input (row-major rows*d) to features
    /// (rows*m). `rows` may be <= batch; partial batches are zero-padded
    /// on upload and trimmed on return.
    pub fn map(&self, engine: &Engine, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(rows > 0 && rows <= self.batch, "rows {rows} vs batch {}", self.batch);
        anyhow::ensure!(x.len() == rows * self.d, "input length mismatch");
        let x_buf = if rows == self.batch {
            engine.upload_f32(x, &[self.batch, self.d])?
        } else {
            let mut pad = self.pad_buf.borrow_mut();
            pad.clear();
            pad.resize(self.batch * self.d, 0.0);
            pad[..x.len()].copy_from_slice(x);
            engine.upload_f32(&pad, &[self.batch, self.d])?
        };
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.params.len());
        args.push(&x_buf);
        // Parameter order matches the artifact signature: for opu
        // (x, wr, wi, br, bi); for gauss (x, w, b). `params` holds
        // [mats.., biases..] which is exactly (wr, wi, br, bi) / (w, b).
        match self.variant {
            Variant::Opu => {
                args.push(&self.params[0]);
                args.push(&self.params[1]);
                args.push(&self.params[2]);
                args.push(&self.params[3]);
            }
            _ => {
                args.push(&self.params[0]);
                args.push(&self.params[1]);
            }
        }
        let out = self.artifact.execute_buffers(&args)?;
        let HostTensor::F32(mut y) = out.into_iter().next().context("no output")? else {
            bail!("expected f32 output");
        };
        y.truncate(rows * self.m);
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::CpuFeatureMap;
    use crate::runtime::artifacts_dir;
    use crate::util::Rng;

    fn engine() -> Option<Engine> {
        crate::runtime::try_engine(&artifacts_dir())
    }

    #[test]
    fn rf_executor_matches_cpu_map_full_batch() {
        let Some(engine) = engine() else { return };
        let mut rng = Rng::new(1);
        let params = RfParams::generate(Variant::Opu, 9, 64, 1.0, &mut rng);
        let exec = RfExecutor::new(&engine, "xla", &params, 32).unwrap();
        let mut x = vec![0.0f32; 32 * 9];
        for v in x.iter_mut() {
            *v = rng.bool(0.3) as u8 as f32;
        }
        let y = exec.map(&engine, &x, 32).unwrap();
        let mut want = vec![0.0f32; 32 * 64];
        CpuFeatureMap::new(params).map_batch(&x, 32, &mut want);
        crate::util::check::assert_allclose(&y, &want, 1e-4, 1e-4);
    }

    #[test]
    fn rf_executor_partial_batch_padding() {
        let Some(engine) = engine() else { return };
        let mut rng = Rng::new(2);
        let params = RfParams::generate(Variant::Gauss, 9, 64, 1.0, &mut rng);
        let exec = RfExecutor::new(&engine, "xla", &params, 32).unwrap();
        let rows = 7;
        let mut x = vec![0.0f32; rows * 9];
        rng.fill_gaussian(&mut x, 1.0);
        let y = exec.map(&engine, &x, rows).unwrap();
        assert_eq!(y.len(), rows * 64);
        let mut want = vec![0.0f32; rows * 64];
        CpuFeatureMap::new(params).map_batch(&x, rows, &mut want);
        crate::util::check::assert_allclose(&y, &want, 1e-4, 1e-4);
    }

    #[test]
    fn artifact_name_matches_python_configs() {
        assert_eq!(
            rf_artifact_name(Variant::Opu, "xla", 36, 5000, 256),
            "rf_opu_xla_d36_m5000_b256"
        );
        assert_eq!(
            rf_artifact_name(Variant::GaussEig, "xla", 6, 500, 256),
            "rf_gauss_xla_d6_m500_b256"
        );
    }
}
