//! Runtime layer: the bridge from rust to the AOT-compiled XLA artifacts.
//!
//! `manifest` parses the artifact index written by `python/compile/aot.py`;
//! `engine` owns the PJRT CPU client, the compile cache, and typed
//! execution; `rf` wraps the random-feature artifacts with device-resident
//! parameters (the pipeline's fast path).

pub mod engine;
pub mod manifest;
pub mod rf;

pub use engine::{artifacts_dir, try_engine, Engine, HostTensor, LoadedArtifact};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
pub use rf::RfExecutor;
