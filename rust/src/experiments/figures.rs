//! Figure regeneration harnesses (one per paper figure; DESIGN.md §5).

use anyhow::Result;

use super::{print_row, run_gsa, run_gsa_sigma_search, run_match, ExpContext, Scale, R_GRID};
use crate::coordinator::GsaConfig;
use crate::data::Dataset;
use crate::features::Variant;
use crate::gen::{DdLikeConfig, RedditLikeConfig, SbmConfig};
use crate::gnn::{GinConfig, GinModel};
use crate::util::{Json, Rng};

fn sbm_dataset(r: f64, per_class: usize, seed: u64) -> Dataset {
    SbmConfig { r, per_class, ..Default::default() }.generate(&mut Rng::new(seed))
}

/// Batch size compiled into the RF artifact matrix.
const ARTIFACT_BATCH: usize = 256;

fn base_cfg(k: usize, s: usize, m: usize) -> GsaConfig {
    GsaConfig { k, s, m, batch: ARTIFACT_BATCH, ..Default::default() }
}

/// Fig 1 (left): GSA-phi_OPU, uniform sampling. Series 1: k in 3..6 at
/// m = m_max; series 2: m sweep at k = 6. X axis: r.
pub fn fig1_left(ctx: &ExpContext, scale: &Scale, seed: u64) -> Result<Json> {
    println!("# Fig 1 (left): GSA-phi_OPU, uniform sampling, s={}", scale.s);
    let mut out = Json::obj().set("figure", "fig1_left").set("s", scale.s);
    let mut series = Json::arr();
    for &k in &[3usize, 4, 5, 6] {
        let mut accs = Vec::new();
        for &r in R_GRID.iter() {
            let ds = sbm_dataset(r, scale.per_class, seed ^ (r * 1000.0) as u64);
            let mut cfg = base_cfg(k, scale.s, scale.m_max);
            cfg.sampler = "uniform".into();
            let acc = run_gsa(ctx, &ds, &cfg, scale.reps, seed)?;
            print_row(&[format!("k={k}"), format!("r={r:.2}"), format!("acc={acc:.3}")]);
            accs.push(acc);
        }
        series.push(
            Json::obj()
                .set("label", format!("k={k} m={}", scale.m_max))
                .set("r", R_GRID.to_vec())
                .set("acc", accs),
        );
    }
    for m in scale.m_sweep() {
        if m == scale.m_max {
            continue; // covered by the k=6 series above
        }
        let mut accs = Vec::new();
        for &r in R_GRID.iter() {
            let ds = sbm_dataset(r, scale.per_class, seed ^ (r * 1000.0) as u64);
            let mut cfg = base_cfg(6, scale.s, m);
            cfg.sampler = "uniform".into();
            let acc = run_gsa(ctx, &ds, &cfg, scale.reps, seed)?;
            print_row(&[format!("m={m}"), format!("r={r:.2}"), format!("acc={acc:.3}")]);
            accs.push(acc);
        }
        series.push(
            Json::obj()
                .set("label", format!("k=6 m={m}"))
                .set("r", R_GRID.to_vec())
                .set("acc", accs),
        );
    }
    out = out.set("series", series);
    ctx.write_json("fig1_left", &out)?;
    Ok(out)
}

/// Fig 1 (right): GSA-phi_OPU with RW sampling (k in 3..6) vs
/// GSA-phi_match (k = 6, same s) vs the GIN baseline.
pub fn fig1_right(ctx: &ExpContext, scale: &Scale, seed: u64) -> Result<Json> {
    println!("# Fig 1 (right): RW-sampled OPU vs phi_match vs GIN, s={}", scale.s);
    let mut out = Json::obj().set("figure", "fig1_right").set("s", scale.s);
    let mut series = Json::arr();
    for &k in &[3usize, 4, 5, 6] {
        let mut accs = Vec::new();
        for &r in R_GRID.iter() {
            let ds = sbm_dataset(r, scale.per_class, seed ^ (r * 1000.0) as u64);
            let cfg = base_cfg(k, scale.s, scale.m_max); // default sampler: rw
            let acc = run_gsa(ctx, &ds, &cfg, scale.reps, seed)?;
            print_row(&[format!("opu-rw k={k}"), format!("r={r:.2}"), format!("acc={acc:.3}")]);
            accs.push(acc);
        }
        series.push(
            Json::obj()
                .set("label", format!("opu-rw k={k}"))
                .set("r", R_GRID.to_vec())
                .set("acc", accs),
        );
    }
    // phi_match baseline at k = 6 with the same sample budget.
    let mut match_accs = Vec::new();
    for &r in R_GRID.iter() {
        let ds = sbm_dataset(r, scale.per_class, seed ^ (r * 1000.0) as u64);
        let acc = run_match(&ds, 6, scale.s, "uniform", seed)?;
        print_row(&["match k=6".into(), format!("r={r:.2}"), format!("acc={acc:.3}")]);
        match_accs.push(acc);
    }
    series.push(
        Json::obj()
            .set("label", "match k=6")
            .set("r", R_GRID.to_vec())
            .set("acc", match_accs),
    );
    // GIN baseline (needs the PJRT engine; skipped on CPU-only runs).
    if let Some(engine) = &ctx.engine {
        let mut gin_accs = Vec::new();
        for &r in R_GRID.iter() {
            let ds = sbm_dataset(r, scale.per_class, seed ^ (r * 1000.0) as u64);
            let split = ds.split(0.8, &mut Rng::new(seed ^ 0xACC));
            let cfg = GinConfig { steps: 60.max(scale.s / 10), seed, ..Default::default() };
            let (acc, _) = GinModel::train_and_eval(engine, &ds, &split, &cfg)?;
            print_row(&["gin".into(), format!("r={r:.2}"), format!("acc={acc:.3}")]);
            gin_accs.push(acc);
        }
        series.push(
            Json::obj()
                .set("label", "gin")
                .set("r", R_GRID.to_vec())
                .set("acc", gin_accs),
        );
    } else {
        eprintln!("(skipping GIN series: no PJRT artifacts)");
    }
    out = out.set("series", series);
    ctx.write_json("fig1_right", &out)?;
    Ok(out)
}

/// Fig 2 (left): accuracy vs m for phi_OPU / phi_Gs / phi_Gs+eig at
/// r = 1.1 (sigma^2 grid-searched on validation, as in the paper).
pub fn fig2_left(ctx: &ExpContext, scale: &Scale, seed: u64) -> Result<Json> {
    println!("# Fig 2 (left): accuracy vs m at r=1.1, s={}", scale.s);
    let ds = sbm_dataset(1.1, scale.per_class, seed);
    let sigmas = [0.05f32, 0.1, 0.3, 1.0, 3.0];
    let mut out = Json::obj().set("figure", "fig2_left").set("r", 1.1).set("s", scale.s);
    let mut series = Json::arr();
    for (variant, label) in [
        (Variant::Opu, "opu"),
        (Variant::Gauss, "gauss"),
        (Variant::GaussEig, "gauss-eig"),
    ] {
        let mut accs = Vec::new();
        for m in scale.m_sweep() {
            let mut cfg = base_cfg(6, scale.s, m);
            cfg.variant = variant;
            let acc = match variant {
                Variant::Opu => run_gsa(ctx, &ds, &cfg, scale.reps, seed)?,
                _ => run_gsa_sigma_search(ctx, &ds, &cfg, &sigmas, seed)?.0,
            };
            print_row(&[label.into(), format!("m={m}"), format!("acc={acc:.3}")]);
            accs.push(acc);
        }
        series.push(
            Json::obj()
                .set("label", label)
                .set("m", scale.m_sweep())
                .set("acc", accs),
        );
    }
    out = out.set("series", series);
    ctx.write_json("fig2_left", &out)?;
    Ok(out)
}

/// Fig 3: real-data protocol on the D&D-like / Reddit-like datasets
/// (or real TU data via --tu-dir): accuracy vs m vs the phi_match
/// baseline, k = 7, s = 4000 at full scale.
pub fn fig3(
    ctx: &ExpContext,
    scale: &Scale,
    dataset: &str,
    tu_dir: Option<&std::path::Path>,
    seed: u64,
) -> Result<Json> {
    let (ds, k, s) = match (dataset, tu_dir) {
        // `--dataset dd|reddit` selects the same data in both modes:
        // the short name maps onto the TU archive's file prefix here.
        (name, Some(dir)) => {
            (crate::data::load_tu_dataset(dir, crate::data::tu_name(name))?, 7, scale.s)
        }
        ("dd", None) => {
            let per_class = scale.per_class.max(30) * 2;
            (DdLikeConfig { per_class, ..Default::default() }.generate(&mut Rng::new(seed)), 7, scale.s)
        }
        ("reddit", None) => {
            let per_class = scale.per_class.max(30) * 2;
            (
                RedditLikeConfig { per_class, ..Default::default() }
                    .generate(&mut Rng::new(seed)),
                7,
                scale.s,
            )
        }
        (other, None) => anyhow::bail!("unknown dataset {other:?} (dd|reddit)"),
    };
    println!("# Fig 3 ({dataset}): {}", ds.summary());
    let mut out = Json::obj()
        .set("figure", format!("fig3_{dataset}"))
        .set("k", k)
        .set("s", s)
        .set("summary", ds.summary());
    // phi_match baseline.
    let match_acc = run_match(&ds, k, s, "rw", seed)?;
    print_row(&["match".into(), format!("acc={match_acc:.3}")]);
    out = out.set("match_acc", match_acc);
    // OPU sweep over m, multiple runs (paper: 3-4 runs per m).
    let mut ms = Vec::new();
    let mut accs = Vec::new();
    let mut stds = Vec::new();
    for m in scale.m_sweep() {
        let mut runs = Vec::new();
        for rep in 0..scale.reps.max(2) {
            let mut cfg = base_cfg(k, s, m);
            cfg.variant = Variant::Opu;
            runs.push(run_gsa(ctx, &ds, &cfg, 1, seed ^ (rep as u64 + 1))?);
        }
        let mean = runs.iter().sum::<f64>() / runs.len() as f64;
        let var =
            runs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / runs.len() as f64;
        print_row(&[
            format!("opu m={m}"),
            format!("acc={mean:.3}"),
            format!("std={:.3}", var.sqrt()),
        ]);
        ms.push(m);
        accs.push(mean);
        stds.push(var.sqrt());
    }
    out = out
        .set("m", ms)
        .set("opu_acc", accs)
        .set("opu_std", stds);
    ctx.write_json(&format!("fig3_{dataset}"), &out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineMode;

    fn tiny_ctx() -> ExpContext {
        let mut c =
            ExpContext::new(None, std::env::temp_dir().join("graphlet_rf_fig_tests"));
        c.engine_mode = Some(EngineMode::CpuInline);
        c
    }

    fn tiny_scale() -> Scale {
        Scale { per_class: 8, s: 60, m_max: 100, reps: 1 }
    }

    #[test]
    fn fig2_left_produces_all_series() {
        let out = fig2_left(&tiny_ctx(), &tiny_scale(), 3).unwrap();
        let s = out.to_string();
        assert!(s.contains("\"opu\"") && s.contains("\"gauss\"") && s.contains("gauss-eig"));
    }

    #[test]
    fn fig3_dd_and_reddit_run() {
        for name in ["dd", "reddit"] {
            let out = fig3(&tiny_ctx(), &tiny_scale(), name, None, 4).unwrap();
            let s = out.to_string();
            assert!(s.contains("match_acc"), "{s}");
            assert!(s.contains("opu_acc"), "{s}");
        }
    }

    #[test]
    fn fig1_left_runs_at_tiny_scale() {
        // Shrunk grid via the scale; just exercise the full code path.
        let out = fig1_left(&tiny_ctx(), &tiny_scale(), 5).unwrap();
        assert!(out.to_string().contains("fig1_left"));
    }
}
