//! Theorem 1 empirical verification: the squared distance between GSA-phi
//! embeddings concentrates around MMD^2(S_k(G), S_k(G')) within the bound
//!
//!   4 m^{-1/2} sqrt(log(6/delta)) + 8 s^{-1/2} (1 + sqrt(2 log(3/delta)))
//!
//! Protocol: pick two SBM graphs of different classes at small k, where a
//! near-exact MMD^2 is computable by brute force (very large s and m on
//! the *same* kernel); then check the deviation of finite-(m, s) runs
//! against the bound across many trials — it must hold in >= 1 - delta of
//! them (it is a high-probability bound, typically loose in practice).

use anyhow::Result;

use super::ExpContext;
use crate::features::{CpuFeatureMap, RfParams, Variant};
use crate::gen::SbmConfig;
use crate::graph::AnyGraph;
use crate::mmd::{embedding_sq_distance, theorem1_bound};
use crate::sample::{GraphletSampler, UniformSampler};
use crate::util::{Json, Rng};

/// Mean embedding of `s` sampled subgraphs of `g` under a fixed map.
fn embed(
    g: &AnyGraph,
    k: usize,
    s: usize,
    map: &CpuFeatureMap,
    rng: &mut Rng,
) -> Vec<f32> {
    let d = map.params.d;
    let m = map.params.m;
    let mut scratch = Vec::new();
    let chunk = 256usize;
    let mut x = vec![0.0f32; chunk * d];
    let mut y = vec![0.0f32; chunk * m];
    let mut sum = vec![0.0f32; m];
    let mut done = 0;
    while done < s {
        let take = (s - done).min(chunk);
        for r in 0..take {
            let gl = UniformSampler.sample(g, k, rng, &mut scratch);
            gl.write_flat_adj(&mut x[r * d..(r + 1) * d]);
        }
        map.map_batch(&x[..take * d], take, &mut y[..take * m]);
        for r in 0..take {
            for (a, &v) in sum.iter_mut().zip(&y[r * m..(r + 1) * m]) {
                *a += v;
            }
        }
        done += take;
    }
    for v in sum.iter_mut() {
        *v /= s as f32;
    }
    sum
}

/// Result of the concentration experiment.
#[derive(Debug)]
pub struct Thm1Result {
    pub m: usize,
    pub s: usize,
    pub delta: f64,
    pub bound: f64,
    pub trials: usize,
    pub violations: usize,
    pub max_deviation: f64,
    pub mean_deviation: f64,
    pub mmd2_ref: f64,
}

/// Run the experiment for one (m, s) point.
pub fn run_point(
    k: usize,
    m: usize,
    s: usize,
    delta: f64,
    trials: usize,
    seed: u64,
) -> Result<Thm1Result> {
    // Gaussian map: |xi| <= 1 holds per feature (sqrt(2) cos scaled), as
    // Theorem 1 assumes (|xi_w(F)| <= 1 after the sqrt(2) convention —
    // we use sigma such that features stay bounded; the bound uses the
    // algebraic structure, the constant is conservative either way).
    let mut rng = Rng::new(seed);
    let cfg = SbmConfig { r: 2.0, ..Default::default() };
    let ga = cfg.sample_graph(0, &mut rng);
    let gb = cfg.sample_graph(1, &mut rng);
    let d = k * k;

    // Reference MMD^2: large m and s (law of large numbers on both).
    // Sized for a single-core laptop: ~4x the operating point with floors
    // high enough that the reference error is well below the bound.
    let big_m = 6_000.max(4 * m);
    let big_s = 12_000.max(8 * s);
    let params_ref = RfParams::generate(Variant::Gauss, d, big_m, 1.0, &mut rng);
    let map_ref = CpuFeatureMap::new(params_ref);
    let fa = embed(&ga, k, big_s, &map_ref, &mut rng);
    let fb = embed(&gb, k, big_s, &map_ref, &mut rng);
    let mmd2_ref = embedding_sq_distance(&fa, &fb);

    let bound = theorem1_bound(m, s, delta);
    let mut violations = 0usize;
    let mut max_dev = 0.0f64;
    let mut sum_dev = 0.0f64;
    for t in 0..trials {
        let mut trial_rng = Rng::new(seed ^ (0x1000 + t as u64));
        let params = RfParams::generate(Variant::Gauss, d, m, 1.0, &mut trial_rng);
        let map = CpuFeatureMap::new(params);
        let fa = embed(&ga, k, s, &map, &mut trial_rng);
        let fb = embed(&gb, k, s, &map, &mut trial_rng);
        let dev = (embedding_sq_distance(&fa, &fb) - mmd2_ref).abs();
        max_dev = max_dev.max(dev);
        sum_dev += dev;
        if dev > bound {
            violations += 1;
        }
    }
    Ok(Thm1Result {
        m,
        s,
        delta,
        bound,
        trials,
        violations,
        max_deviation: max_dev,
        mean_deviation: sum_dev / trials as f64,
        mmd2_ref,
    })
}

/// Full sweep + report (the `thm1` CLI subcommand / example).
pub fn run(ctx: &ExpContext, seed: u64) -> Result<Json> {
    println!("# Theorem 1 concentration check (k=3, delta=0.05)");
    let delta = 0.05;
    let mut arr = Json::arr();
    for (m, s) in [(50usize, 200usize), (200, 200), (1000, 1000), (2000, 4000)] {
        let r = run_point(3, m, s, delta, 20, seed)?;
        println!(
            "m={:<5} s={:<5} bound={:.4} mean_dev={:.5} max_dev={:.5} violations={}/{} mmd2={:.4}",
            r.m, r.s, r.bound, r.mean_deviation, r.max_deviation, r.violations, r.trials, r.mmd2_ref
        );
        assert!(
            (r.violations as f64) <= (delta * r.trials as f64).ceil(),
            "Theorem 1 bound violated too often"
        );
        arr.push(
            Json::obj()
                .set("m", r.m)
                .set("s", r.s)
                .set("bound", r.bound)
                .set("mean_deviation", r.mean_deviation)
                .set("max_deviation", r.max_deviation)
                .set("violations", r.violations)
                .set("trials", r.trials),
        );
    }
    let out = Json::obj().set("experiment", "thm1").set("delta", delta).set("points", arr);
    ctx.write_json("thm1", &out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_holds_and_deviation_shrinks() {
        let a = run_point(3, 50, 100, 0.05, 8, 1).unwrap();
        let b = run_point(3, 800, 1600, 0.05, 8, 1).unwrap();
        // High-probability bound: allow <= delta fraction of violations.
        assert!(a.violations <= 1, "{a:?}");
        assert!(b.violations <= 1, "{b:?}");
        // Deviation must shrink as m and s grow.
        assert!(
            b.mean_deviation < a.mean_deviation,
            "{} !< {}",
            b.mean_deviation,
            a.mean_deviation
        );
        // And the bound itself shrinks.
        assert!(b.bound < a.bound);
    }
}
