//! Fig 2 (right) + Table 1: per-subgraph computation time vs k for each
//! feature map, plus the complexity-scaling fits.
//!
//! Measures, per k in 3..=8, the cost of mapping one sampled subgraph
//! through: phi_match (canonical form + registry), phi_Gs (CPU),
//! phi_Gs+eig (Jacobi + CPU map), phi_OPU simulation (CPU), the PJRT
//! batched path when artifacts exist, and the analytic physical-OPU
//! model (constant; DESIGN.md §2). The paper's claim to reproduce:
//! phi_match grows exponentially in k, Gaussian maps polynomially, OPU
//! stays flat.

use anyhow::Result;

use super::ExpContext;
use crate::features::{opu_model_time, CpuFeatureMap, RfParams, Variant};
use crate::gen::SbmConfig;
use crate::graph::Graphlet;
use crate::iso::GraphletRegistry;
use crate::runtime::RfExecutor;
use crate::sample::{GraphletSampler, UniformSampler};
use crate::util::{bench, Json, Rng};

/// One measured series: seconds per subgraph for each k.
#[derive(Debug, Clone)]
pub struct TimingSeries {
    pub label: String,
    pub ks: Vec<usize>,
    pub secs_per_subgraph: Vec<f64>,
}

/// Sample a pool of subgraphs of one SBM graph for timing inputs.
fn graphlet_pool(k: usize, n: usize, seed: u64) -> Vec<Graphlet> {
    let g = SbmConfig::default().sample_graph(1, &mut Rng::new(seed));
    let mut rng = Rng::new(seed ^ 1);
    let mut scratch = Vec::new();
    (0..n)
        .map(|_| UniformSampler.sample(&g, k, &mut rng, &mut scratch))
        .collect()
}

/// Measure all series. `m` is the feature dimension for the RF maps;
/// `pool` controls how many subgraphs each measurement batches over.
pub fn measure(ctx: &ExpContext, ks: &[usize], m: usize, pool: usize) -> Result<Vec<TimingSeries>> {
    let mut out = Vec::new();
    let mut rng = Rng::new(0x71);

    // --- phi_match: canonicalize + classify each subgraph --------------
    {
        let mut secs = Vec::new();
        for &k in ks {
            let graphlets = graphlet_pool(k, pool, 42 + k as u64);
            let mut reg = GraphletRegistry::new();
            let t = bench(1, 5, || {
                for g in &graphlets {
                    std::hint::black_box(reg.classify(g));
                }
            });
            secs.push(t / pool as f64);
        }
        out.push(TimingSeries { label: "match".into(), ks: ks.to_vec(), secs_per_subgraph: secs });
    }

    // --- CPU feature maps ----------------------------------------------
    for (variant, label) in [
        (Variant::Gauss, "gauss"),
        (Variant::GaussEig, "gauss-eig"),
        (Variant::Opu, "opu-sim"),
    ] {
        let mut secs = Vec::new();
        for &k in ks {
            let d = variant.input_dim(k);
            let params = RfParams::generate(variant, d, m, 0.1, &mut rng);
            let map = CpuFeatureMap::new(params);
            let graphlets = graphlet_pool(k, pool, 7 + k as u64);
            let mut x = vec![0.0f32; pool * d];
            let mut y = vec![0.0f32; pool * m];
            let t = bench(1, 5, || {
                // Include the input transform (flatten / eigensolve):
                // it is part of the per-subgraph cost in Table 1.
                for (i, g) in graphlets.iter().enumerate() {
                    variant.write_input(g, &mut x[i * d..(i + 1) * d]);
                }
                map.map_batch(&x, pool, &mut y);
                std::hint::black_box(&y);
            });
            secs.push(t / pool as f64);
        }
        out.push(TimingSeries { label: label.into(), ks: ks.to_vec(), secs_per_subgraph: secs });
    }

    // --- PJRT batched path (when artifacts are compiled) ----------------
    if let Some(engine) = &ctx.engine {
        let batch = 256usize;
        let mut secs = Vec::new();
        let mut ok = true;
        for &k in ks {
            let d = k * k;
            let params = RfParams::generate(Variant::Opu, d, m, 1.0, &mut rng);
            match RfExecutor::new(engine, "xla", &params, batch) {
                Ok(exec) => {
                    let graphlets = graphlet_pool(k, batch, 9 + k as u64);
                    let mut x = vec![0.0f32; batch * d];
                    for (i, g) in graphlets.iter().enumerate() {
                        g.write_flat_adj(&mut x[i * d..(i + 1) * d]);
                    }
                    let t = bench(2, 5, || {
                        std::hint::black_box(exec.map(engine, &x, batch).unwrap());
                    });
                    secs.push(t / batch as f64);
                }
                Err(e) => {
                    eprintln!("skipping pjrt series at k={k}: {e}");
                    ok = false;
                    break;
                }
            }
        }
        if ok {
            out.push(TimingSeries {
                label: "opu-sim-pjrt".into(),
                ks: ks.to_vec(),
                secs_per_subgraph: secs,
            });
        }
    }

    // --- physical OPU analytic model -------------------------------------
    out.push(TimingSeries {
        label: "opu-physical-model".into(),
        ks: ks.to_vec(),
        secs_per_subgraph: ks.iter().map(|_| opu_model_time(1)).collect(),
    });

    Ok(out)
}

/// Fit log(time) against k (exponential rate) and log(k) (polynomial
/// degree); Table 1's empirical complexity check.
pub fn scaling_fits(series: &TimingSeries) -> (f64, f64) {
    let xs_exp: Vec<f64> = series.ks.iter().map(|&k| k as f64).collect();
    let xs_poly: Vec<f64> = series.ks.iter().map(|&k| (k as f64).ln()).collect();
    let ys: Vec<f64> = series.secs_per_subgraph.iter().map(|&t| t.max(1e-12).ln()).collect();
    (slope(&xs_exp, &ys), slope(&xs_poly, &ys))
}

fn slope(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var.max(1e-300)
}

/// Run + print + persist the whole Fig 2 (right) / Table 1 study.
pub fn fig2_right(ctx: &ExpContext, ks: &[usize], m: usize, pool: usize) -> Result<Json> {
    println!("# Fig 2 (right) / Table 1: per-subgraph time vs k (m={m})");
    let series = measure(ctx, ks, m, pool)?;
    let mut out = Json::obj().set("figure", "fig2_right").set("m", m);
    let mut arr = Json::arr();
    for s in &series {
        let (exp_rate, poly_deg) = scaling_fits(s);
        println!(
            "{:<20} {}",
            s.label,
            s.ks
                .iter()
                .zip(&s.secs_per_subgraph)
                .map(|(k, t)| format!("k={k}: {:.3}us", t * 1e6))
                .collect::<Vec<_>>()
                .join("  ")
        );
        println!(
            "{:<20} exp-rate/k={exp_rate:.2} poly-degree={poly_deg:.2}",
            ""
        );
        arr.push(
            Json::obj()
                .set("label", s.label.as_str())
                .set("k", s.ks.clone())
                .set("secs_per_subgraph", s.secs_per_subgraph.clone())
                .set("exp_rate", exp_rate)
                .set("poly_degree", poly_deg),
        );
    }
    out = out.set("series", arr);
    ctx.write_json("fig2_right", &out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineMode;

    fn tiny_ctx() -> ExpContext {
        let mut c = ExpContext::new(None, std::env::temp_dir().join("graphlet_rf_timing"));
        c.engine_mode = Some(EngineMode::CpuInline);
        c
    }

    #[test]
    fn measures_all_cpu_series() {
        let series = measure(&tiny_ctx(), &[3, 4], 32, 64).unwrap();
        let labels: Vec<&str> = series.iter().map(|s| s.label.as_str()).collect();
        for want in ["match", "gauss", "gauss-eig", "opu-sim", "opu-physical-model"] {
            assert!(labels.contains(&want), "{labels:?}");
        }
        for s in &series {
            assert!(s.secs_per_subgraph.iter().all(|&t| t >= 0.0));
        }
    }

    #[test]
    fn match_time_grows_with_k() {
        let series = measure(&tiny_ctx(), &[3, 6], 16, 64).unwrap();
        let m = series.iter().find(|s| s.label == "match").unwrap();
        assert!(
            m.secs_per_subgraph[1] > m.secs_per_subgraph[0],
            "{:?}",
            m.secs_per_subgraph
        );
    }

    #[test]
    fn physical_model_is_flat() {
        let series = measure(&tiny_ctx(), &[3, 4, 5], 16, 16).unwrap();
        let m = series.iter().find(|s| s.label == "opu-physical-model").unwrap();
        assert!(m.secs_per_subgraph.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn slope_fits_line() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((slope(&xs, &ys) - 2.0).abs() < 1e-12);
    }
}
