//! Experiment harnesses: one function per paper figure/table
//! (DESIGN.md §5 maps each to its module and driver).
//!
//! Every harness prints the figure's rows/series to stdout and writes a
//! JSON result file under `results/` so EXPERIMENTS.md can quote exact
//! numbers. Paper-scale parameters are behind [`Scale::full`]; the
//! default [`Scale::quick`] keeps every figure reproducible in minutes on
//! a laptop while preserving the qualitative shape (who wins, where the
//! curves cross).

pub mod figures;
pub mod thm1;
pub mod timing;

use std::path::PathBuf;

use anyhow::Result;

use crate::classify::{train_and_eval, TrainConfig};
use crate::coordinator::{embed_dataset, EngineMode, GsaConfig};
use crate::data::Dataset;

use crate::kernelgk;
use crate::runtime::Engine;
use crate::sample::sampler_by_name;
use crate::util::{Json, Rng};

/// Experiment scale knobs.
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// SBM graphs per class.
    pub per_class: usize,
    /// Subgraph samples per graph (paper: 2000 / 4000 for real data).
    pub s: usize,
    /// Largest m in sweeps (paper: 5000).
    pub m_max: usize,
    /// Repetitions per configuration (averaged).
    pub reps: usize,
}

impl Scale {
    /// Paper-scale parameters (§4).
    pub fn full() -> Scale {
        Scale { per_class: 150, s: 2000, m_max: 5000, reps: 3 }
    }

    /// Minutes-not-hours defaults preserving the figures' shape.
    pub fn quick() -> Scale {
        Scale { per_class: 40, s: 400, m_max: 2000, reps: 2 }
    }

    /// Mid scale: the single-core sweet spot — full m sweep, readable
    /// curves, ~tens of minutes for the whole suite.
    pub fn mid() -> Scale {
        Scale { per_class: 60, s: 1000, m_max: 5000, reps: 2 }
    }

    /// Parse a scale name ("quick" | "mid" | "full").
    pub fn parse(name: &str) -> Scale {
        match name {
            "quick" => Scale::quick(),
            "mid" => Scale::mid(),
            "full" => Scale::full(),
            other => panic!("--scale {other:?}: expected quick|mid|full"),
        }
    }

    /// Clamp an m-sweep to the scale's maximum (keeps artifact names in
    /// the compiled matrix: {100, 500, 1000, 2000, 5000}).
    pub fn m_sweep(&self) -> Vec<usize> {
        [100usize, 500, 1000, 2000, 5000]
            .into_iter()
            .filter(|&m| m <= self.m_max)
            .collect()
    }
}

/// Shared context: PJRT engine (if artifacts are built) + output dir.
pub struct ExpContext {
    pub engine: Option<Engine>,
    pub out_dir: PathBuf,
    /// Force an engine mode (None = Pjrt when available, else CpuInline).
    pub engine_mode: Option<EngineMode>,
}

impl ExpContext {
    pub fn new(engine: Option<Engine>, out_dir: PathBuf) -> Self {
        std::fs::create_dir_all(&out_dir).ok();
        ExpContext { engine, out_dir, engine_mode: None }
    }

    pub fn mode(&self) -> EngineMode {
        self.engine_mode.unwrap_or(if self.engine.is_some() {
            EngineMode::Pjrt
        } else {
            EngineMode::CpuInline
        })
    }

    pub fn write_json(&self, name: &str, json: &Json) -> Result<()> {
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, json.to_string())?;
        eprintln!("wrote {}", path.display());
        Ok(())
    }
}

/// Train the linear tail with the L2 strength chosen on a validation
/// split (mirrors the paper's hyperparameter protocol), then report test
/// accuracy. Embeddings are computed once; classifier passes are cheap.
pub fn eval_with_lambda_search(
    emb: &[f32],
    ds: &Dataset,
    m: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed ^ 0xACC);
    let split = ds.split(0.8, &mut rng);
    let n_val = (split.train.len() / 4).max(1);
    let (val, tr) = split.train.split_at(n_val);
    let mut best = (f64::NEG_INFINITY, 1e-2f32);
    for lambda in [1e-1f32, 1e-2, 1e-3] {
        let cfg = TrainConfig { lambda, seed, ..Default::default() };
        let acc = train_and_eval(emb, &ds.labels, m, tr, val, &cfg);
        if acc > best.0 {
            best = (acc, lambda);
        }
    }
    let cfg = TrainConfig { lambda: best.1, seed, ..Default::default() };
    train_and_eval(emb, &ds.labels, m, &split.train, &split.test, &cfg)
}

/// Run one GSA-phi configuration end to end; returns mean test accuracy
/// over `reps` re-splits (fresh RF draw + split per rep).
pub fn run_gsa(
    ctx: &ExpContext,
    ds: &Dataset,
    cfg: &GsaConfig,
    reps: usize,
    seed: u64,
) -> Result<f64> {
    let mut accs = Vec::new();
    for rep in 0..reps.max(1) {
        let mut cfg = cfg.clone();
        cfg.seed = seed ^ (rep as u64) << 32 | rep as u64;
        cfg.engine = ctx.mode();
        // PJRT artifacts exist only for the compiled batch size.
        let (emb, _metrics) = embed_dataset(ds, &cfg, ctx.engine.as_ref())?;
        accs.push(eval_with_lambda_search(&emb, ds, cfg.m, cfg.seed));
    }
    Ok(accs.iter().sum::<f64>() / accs.len() as f64)
}

/// Gaussian variants: pick sigma on a validation split (the paper tunes
/// sigma^2 to maximize validation accuracy, §4.3).
pub fn run_gsa_sigma_search(
    ctx: &ExpContext,
    ds: &Dataset,
    cfg: &GsaConfig,
    sigmas: &[f32],
    seed: u64,
) -> Result<(f64, f32)> {
    let mut best = (f64::NEG_INFINITY, sigmas[0]);
    for &sigma in sigmas {
        let mut c = cfg.clone();
        c.sigma = sigma;
        c.seed = seed;
        c.engine = ctx.mode();
        let (emb, _) = embed_dataset(ds, &c, ctx.engine.as_ref())?;
        // Split train into train/val for the search.
        let mut rng = Rng::new(seed ^ 0x5161);
        let split = ds.split(0.8, &mut rng);
        let n_val = split.train.len() / 4;
        let (val, tr) = split.train.split_at(n_val);
        let acc = train_and_eval(
            &emb,
            &ds.labels,
            c.m,
            tr,
            val,
            &TrainConfig { seed, ..Default::default() },
        );
        if acc > best.0 {
            best = (acc, sigma);
        }
    }
    // Final run at the chosen sigma on the real split.
    let mut c = cfg.clone();
    c.sigma = best.1;
    let acc = run_gsa(ctx, ds, &c, 1, seed)?;
    Ok((acc, best.1))
}

/// The exact graphlet-kernel baseline (GSA-phi_match): sampled k-spectra
/// + the same linear tail.
pub fn run_match(ds: &Dataset, k: usize, s: usize, sampler: &str, seed: u64) -> Result<f64> {
    let sampler = sampler_by_name(sampler);
    let mut rng = Rng::new(seed);
    let (spectra, dim) = kernelgk::dataset_spectra(ds, k, s, sampler.as_ref(), &mut rng);
    let mut split_rng = Rng::new(seed ^ 0xACC);
    let split = ds.split(0.8, &mut split_rng);
    Ok(train_and_eval(
        &spectra,
        &ds.labels,
        dim,
        &split.train,
        &split.test,
        &TrainConfig { seed, ..Default::default() },
    ))
}

/// Default r grid for the SBM sweeps (1 = indistinguishable classes).
pub const R_GRID: [f64; 6] = [1.0, 1.05, 1.1, 1.2, 1.35, 1.5];

/// Printable accuracy table row.
pub fn print_row(cols: &[String]) {
    println!("{}", cols.join("\t"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::Variant;
    use crate::gen::SbmConfig;

    fn ctx() -> ExpContext {
        let mut c = ExpContext::new(None, std::env::temp_dir().join("graphlet_rf_test_results"));
        c.engine_mode = Some(EngineMode::CpuInline);
        c
    }

    #[test]
    fn run_gsa_beats_chance_on_easy_task() {
        let ds = SbmConfig { per_class: 25, r: 3.0, ..Default::default() }
            .generate(&mut Rng::new(1));
        let cfg = GsaConfig { k: 4, s: 300, m: 128, batch: 64, ..Default::default() };
        let acc = run_gsa(&ctx(), &ds, &cfg, 1, 7).unwrap();
        assert!(acc > 0.75, "acc={acc}");
    }

    #[test]
    fn run_match_beats_chance_on_easy_task() {
        // Density-separable classes (see kernelgk tests for why the
        // equal-degree SBM is intentionally hard for phi_match).
        let mut rng = Rng::new(2);
        let mut graphs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..40usize {
            let class = (i % 2) as u8;
            let p = if class == 0 { 0.08 } else { 0.25 };
            let mut g = crate::graph::DenseGraph::new(40);
            for a in 0..40 {
                for b in (a + 1)..40 {
                    if rng.bool(p) {
                        g.add_edge(a, b);
                    }
                }
            }
            graphs.push(crate::graph::AnyGraph::Dense(g));
            labels.push(class);
        }
        let ds = Dataset::new("density", graphs, labels);
        let acc = run_match(&ds, 4, 800, "rw", 3).unwrap();
        assert!(acc > 0.85, "acc={acc}");
    }

    #[test]
    fn sigma_search_returns_grid_member() {
        let ds = SbmConfig { per_class: 12, r: 2.0, ..Default::default() }
            .generate(&mut Rng::new(3));
        let cfg = GsaConfig {
            k: 3,
            s: 150,
            m: 64,
            batch: 64,
            variant: Variant::GaussEig,
            ..Default::default()
        };
        let sigmas = [0.1f32, 1.0];
        let (acc, sigma) = run_gsa_sigma_search(&ctx(), &ds, &cfg, &sigmas, 5).unwrap();
        assert!(sigmas.contains(&sigma));
        assert!(acc >= 0.0 && acc <= 1.0);
    }

    #[test]
    fn scale_m_sweep_respects_max() {
        assert_eq!(Scale::quick().m_sweep(), vec![100, 500, 1000, 2000]);
        assert_eq!(Scale::full().m_sweep(), vec![100, 500, 1000, 2000, 5000]);
    }
}
