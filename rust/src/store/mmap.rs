//! Memory-mapped sealed segments and zero-copy row views.
//!
//! Sealed segments (every segment except the active one) are
//! **immutable after rotation**: the recovery scan verified their
//! records at open, or this process wrote and sealed them itself, and
//! no code path appends to or rewrites a sealed file in place
//! (compaction writes a *new* generation and deletes the old files).
//! That invariant is what makes it safe to map a sealed segment once
//! and serve `&[f32]` views straight out of the page cache — no
//! syscall, no copy, no per-read checksum.
//!
//! The mapping is hand-rolled: `mmap(2)`/`munmap(2)` are declared as
//! direct `extern "C"` symbols (std already links libc on unix), so the
//! zero-dependency rule holds. The raw-syscall path is gated to
//! 64-bit unix targets where `off_t` is 64-bit and the constants below
//! (`PROT_READ = 1`, `MAP_PRIVATE = 2` on both Linux and the BSDs)
//! match the ABI; everywhere else — and whenever the syscall itself
//! fails — [`SegmentMap::map`] degrades to reading the file into an
//! owned buffer behind the same API, so behavior differs only in cost.
//!
//! ## Generation lifetime
//!
//! A [`RowView`] holds an `Arc<SegmentMap>`, so a view handed to the
//! ANN index keeps its segment's mapping alive even after compaction
//! unlinks the file (on unix, unlinking a mapped file is safe: the
//! pages stay valid until the last mapping is dropped). Swapping the
//! ANN index generation under `AnnCell`'s single-flight is therefore
//! atomic from a reader's point of view: old views stay readable until
//! the last `Arc` drops, then `munmap` + the kernel reclaim the pages.
//!
//! ## `SIGBUS` caveat
//!
//! A memory map is a promise about file *length*: if some other
//! process truncates a mapped segment file, touching pages past the
//! new end of file raises `SIGBUS` — there is no way to catch that
//! from safe Rust. The store's own code never shrinks a sealed file
//! (immutable-after-rotation), so this can only happen under external
//! interference with a live store directory, which the single-writer
//! contract already forbids. Crash/corruption damage to files *at
//! rest* is handled fine: the open-time recovery scan runs on `read`,
//! not on the map, and only verified records are ever resolved through
//! a mapping. The fault-injection battery in `tests/store.rs` pins
//! exactly this: damage, reopen, serve — no panic, no `SIGBUS`.

use std::fs::File;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    // Stable across Linux and the BSDs/macOS for the read-only private
    // mapping we need; see the module docs for the target gating.
    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    /// Map `len` bytes of `file` read-only; `None` on syscall failure
    /// (the caller falls back to an owned read).
    pub fn map_readonly(file: &File, len: usize) -> Option<*const u8> {
        // SAFETY: a fresh private read-only mapping of a file we hold
        // open; the kernel validates every argument and reports
        // MAP_FAILED ((void*)-1) on error.
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr.is_null() || ptr as isize == -1 {
            None
        } else {
            Some(ptr as *const u8)
        }
    }

    /// Release a mapping made by [`map_readonly`].
    pub fn unmap(ptr: *const u8, len: usize) {
        // SAFETY: `ptr`/`len` came from a successful map_readonly and
        // are unmapped exactly once (SegmentMap::drop).
        unsafe {
            munmap(ptr as *mut core::ffi::c_void, len);
        }
    }
}

#[derive(Debug)]
enum Backing {
    /// A live `mmap(2)` region (64-bit unix only).
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped { ptr: *const u8, len: usize },
    /// Fallback: the whole file read into memory. Same API, same
    /// semantics, no page-cache sharing.
    Owned(Vec<u8>),
}

/// One sealed segment's bytes, mapped read-only (or owned, on targets
/// and error paths where mapping is unavailable). Immutable for its
/// whole lifetime — see the module docs for the invariant that makes
/// this sound.
#[derive(Debug)]
pub struct SegmentMap {
    backing: Backing,
}

// SAFETY: the backing bytes are immutable and never aliased mutably;
// a raw pointer into a read-only file mapping is as shareable as the
// &[u8] it denotes.
unsafe impl Send for SegmentMap {}
unsafe impl Sync for SegmentMap {}

impl SegmentMap {
    /// Map the file at `path` read-only. Zero-length files (and any
    /// target or syscall that cannot map) come back `Owned`.
    pub fn map(path: &Path) -> Result<SegmentMap> {
        let file =
            File::open(path).with_context(|| format!("mapping segment {}", path.display()))?;
        let len = file.metadata()?.len() as usize;
        #[cfg(all(unix, target_pointer_width = "64"))]
        if len > 0 {
            // (mmap of zero bytes is EINVAL — empty files go owned.)
            if let Some(ptr) = sys::map_readonly(&file, len) {
                return Ok(SegmentMap { backing: Backing::Mapped { ptr, len } });
            }
        }
        let bytes =
            std::fs::read(path).with_context(|| format!("reading segment {}", path.display()))?;
        Ok(SegmentMap { backing: Backing::Owned(bytes) })
    }

    /// The mapped (or owned) file contents.
    pub fn as_bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { ptr, len } => {
                // SAFETY: ptr/len denote a live read-only mapping that
                // outlives this borrow (dropped only in Drop).
                unsafe { std::slice::from_raw_parts(*ptr, *len) }
            }
            Backing::Owned(bytes) => bytes,
        }
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { len, .. } => *len,
            Backing::Owned(bytes) => bytes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `true` when backed by a real `mmap` region (vs the owned-read
    /// fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mapped { .. } => true,
            Backing::Owned(_) => false,
        }
    }
}

impl Drop for SegmentMap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Backing::Mapped { ptr, len } = self.backing {
            sys::unmap(ptr, len);
        }
    }
}

/// A zero-copy `&[f32]` window into a mapped sealed segment: the row
/// payload of one record, reinterpreted in place. Constructible only
/// when the reinterpretation is sound (little-endian target, 4-aligned
/// offset, in-bounds) — [`RowView::new`] returns `None` otherwise and
/// the caller falls back to an owned copy. Holding the `Arc` pins the
/// mapping across compaction (see the module docs on generations).
#[derive(Clone, Debug)]
pub struct RowView {
    map: Arc<SegmentMap>,
    /// Byte offset of the first f32 within the segment.
    off: usize,
    /// Row length in floats.
    len: usize,
}

impl RowView {
    /// `off` is the byte offset of the row's f32 data inside `map`;
    /// `len` counts floats. Returns `None` unless an in-place
    /// `&[f32]` reinterpretation is valid here: rows are stored as
    /// little-endian `f32::to_bits`, so the target must be
    /// little-endian and the start address 4-byte aligned (which the
    /// record layout guarantees — every record length is a multiple of
    /// 4 and segments start with an 8-byte magic — but is re-checked
    /// rather than assumed).
    pub fn new(map: Arc<SegmentMap>, off: usize, len: usize) -> Option<RowView> {
        let end = off.checked_add(len.checked_mul(4)?)?;
        if end > map.len() {
            return None;
        }
        if !cfg!(target_endian = "little") {
            return None;
        }
        if (map.as_bytes().as_ptr() as usize + off) % std::mem::align_of::<f32>() != 0 {
            return None;
        }
        Some(RowView { map, off, len })
    }

    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: new() proved bounds, alignment, and endianness; the
        // bytes are immutable for the mapping's lifetime, and any bit
        // pattern is a valid f32.
        unsafe {
            std::slice::from_raw_parts(
                self.map.as_bytes().as_ptr().add(self.off) as *const f32,
                self.len,
            )
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// One embedding row, either borrowed in place from a mapped sealed
/// segment or owned (active-segment reads, legacy path, and every
/// fallback). The ANN index stores these instead of flattened
/// `Vec<f32>` copies; `owned_bytes` is the "did we actually stop
/// copying?" accounting the `indexed_bytes` stat surfaces.
#[derive(Clone, Debug)]
pub enum RowData {
    View(RowView),
    Owned(Vec<f32>),
}

impl RowData {
    pub fn as_slice(&self) -> &[f32] {
        match self {
            RowData::View(v) => v.as_slice(),
            RowData::Owned(v) => v,
        }
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.as_slice().to_vec()
    }

    pub fn len(&self) -> usize {
        match self {
            RowData::View(v) => v.len(),
            RowData::Owned(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Heap bytes owned by this row (0 for a view).
    pub fn owned_bytes(&self) -> usize {
        match self {
            RowData::View(_) => 0,
            RowData::Owned(v) => 4 * v.len(),
        }
    }
}

impl From<Vec<f32>> for RowData {
    fn from(v: Vec<f32>) -> RowData {
        RowData::Owned(v)
    }
}

/// Decode little-endian f32 bits from raw bytes — the fallback when a
/// view cannot be constructed (big-endian target or a misaligned
/// offset, neither of which occurs with the real record layout).
pub(crate) fn decode_floats(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir()
            .join(format!("graphlet_mmap_{tag}_{}", std::process::id()));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn map_round_trips_file_bytes() {
        let bytes: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let path = temp_file("roundtrip", &bytes);
        let map = SegmentMap::map(&path).unwrap();
        assert_eq!(map.len(), bytes.len());
        assert_eq!(map.as_bytes(), &bytes[..]);
        if cfg!(all(unix, target_pointer_width = "64")) {
            assert!(map.is_mapped(), "64-bit unix must take the real mmap path");
        }
        drop(map);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_maps_as_owned_empty() {
        let path = temp_file("empty", &[]);
        let map = SegmentMap::map(&path).unwrap();
        assert!(map.is_empty());
        assert!(!map.is_mapped(), "zero-length files cannot be mapped");
        assert_eq!(map.as_bytes(), &[] as &[u8]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mapping_survives_unlink_of_the_backing_file() {
        // The generation-safety property compaction relies on: views
        // into a deleted segment stay readable until the Arc drops.
        let bytes = vec![7u8; 4096];
        let path = temp_file("unlink", &bytes);
        let map = SegmentMap::map(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(map.as_bytes(), &bytes[..]);
    }

    #[test]
    fn row_view_reinterprets_le_f32_bits_in_place() {
        let row = [1.5f32, -0.0, f32::NAN, 3.25e-7];
        let mut bytes = vec![0u8; 8]; // 8-byte "magic" keeps the row 4-aligned
        for v in row {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let path = temp_file("rowview", &bytes);
        let map = Arc::new(SegmentMap::map(&path).unwrap());
        let _ = std::fs::remove_file(&path);
        match RowView::new(Arc::clone(&map), 8, row.len()) {
            Some(view) => {
                let got: Vec<u32> = view.as_slice().iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "view must be bitwise the encoded floats");
            }
            // Big-endian targets legitimately refuse; the store then
            // serves owned copies everywhere.
            None => assert!(!cfg!(target_endian = "little")),
        }
    }

    #[test]
    fn row_view_rejects_out_of_bounds_and_misalignment() {
        let path = temp_file("bounds", &[0u8; 64]);
        let map = Arc::new(SegmentMap::map(&path).unwrap());
        let _ = std::fs::remove_file(&path);
        assert!(RowView::new(Arc::clone(&map), 0, 17).is_none(), "68 bytes > 64");
        assert!(RowView::new(Arc::clone(&map), 64, 1).is_none(), "starts past the end");
        assert!(RowView::new(Arc::clone(&map), usize::MAX, 1).is_none(), "offset overflow");
        assert!(RowView::new(Arc::clone(&map), 0, usize::MAX).is_none(), "length overflow");
        if cfg!(target_endian = "little") {
            assert!(RowView::new(Arc::clone(&map), 0, 16).is_some());
            // An mmap region is page-aligned, so offset alignment is
            // offset % 4 here.
            assert!(RowView::new(Arc::clone(&map), 2, 2).is_none(), "misaligned offset");
        }
    }

    #[test]
    fn row_data_accounts_owned_bytes() {
        let owned = RowData::from(vec![1.0f32; 10]);
        assert_eq!(owned.owned_bytes(), 40);
        assert_eq!(owned.len(), 10);
        assert_eq!(owned.to_vec(), vec![1.0f32; 10]);

        let path = temp_file("owned_bytes", &3.5f32.to_bits().to_le_bytes());
        let map = Arc::new(SegmentMap::map(&path).unwrap());
        let _ = std::fs::remove_file(&path);
        if let Some(view) = RowView::new(map, 0, 1) {
            let data = RowData::View(view);
            assert_eq!(data.owned_bytes(), 0, "views own nothing");
            assert_eq!(data.to_vec(), vec![3.5f32]);
        }
    }

    #[test]
    fn decode_floats_matches_from_bits() {
        let vals = [0.0f32, -1.0, f32::INFINITY, 1.25e-12];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let got: Vec<u32> = decode_floats(&bytes).iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
    }
}
