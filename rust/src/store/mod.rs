//! `store`: the persistent tier of the embedding cache — a
//! content-addressed, append-only **segment log** for embedding rows,
//! with sealed segments memory-mapped for zero-copy reads.
//!
//! The paper's economics make embeddings worth keeping: computing one
//! is the expensive part of the graphlet pipeline, and once computed a
//! row is a *pure function* of `(canonical graph hash, config
//! fingerprint, sampling seed)` — the explicit-feature-map view of
//! graph kernels makes rows durable artifacts, not transient request
//! state. This module stores them so a daemon restart serves yesterday's
//! rows **bitwise identical** from disk instead of recomputing them.
//!
//! On-disk layout (see [`codec`] for the byte-exact record format):
//!
//! ```text
//!  <dir>/
//!    seg-00000000.log  ┐ SEALED: immutable after rotation, verified by
//!    seg-00000001.log  ┘ the open scan, mmap'd → zero-copy row views
//!    seg-00000002.log  ◄─ ACTIVE: highest id; appends go here (rotate
//!                         at segment_bytes); reads seek+copy+verify
//!
//!  one segment:
//!    ┌──────────┬────────────┬────────────┬─ ─ ─┬─(torn tail)─┐
//!    │ "GRFSEG1\n" │ record 0 │ record 1  │ ... │ skipped     │
//!    └──────────┴────────────┴────────────┴─ ─ ─┴─────────────┘
//!      8-byte magic            length-prefixed, FNV-checksummed
//!
//!  one record:
//!    [u32 payload_len][u64 graph_hash][u64 config_fp][u64 seed]
//!    [u32 row_len][row_len × f32 bits][u64 FNV-1a(payload)]
//!
//!  segment lifecycle (mmap: true):
//!
//!     appends          rotate            compact
//!    ┌────────┐   seal + mmap   ┌────────┐   rewrite live rows into a
//!    │ ACTIVE │ ──────────────► │ SEALED │ ─► new generation, unlink
//!    └────────┘                 └────────┘   old files; outstanding
//!                                  │ get     RowViews pin the old
//!                                  ▼         mapping (Arc) until the
//!                              &[f32] view   last reader drops it
//! ```
//!
//! Properties the serve tier builds on:
//!
//! - **Append-only writes**: a put is one unbuffered `write_all`; no
//!   in-place mutation, so a crash can only produce a *torn tail*.
//! - **Recovery by scan**: [`EmbeddingStore::open`] rebuilds the whole
//!   in-memory offset index from the segments; torn/corrupt records are
//!   skipped with the `corrupt_skipped` counter (never a panic, never a
//!   failed open) — a checksum failure with intact framing resyncs past
//!   just that record — and the active segment is truncated back to its
//!   last intact record. One store owns a directory at a time (no
//!   cross-process lock; see [`log`]'s module docs).
//! - **Immutable after rotation**: once a segment stops being active it
//!   is never appended to or rewritten in place — compaction writes a
//!   *new* generation. That invariant is what lets [`mmap`] map sealed
//!   segments once and serve [`mmap::RowData::View`]s (`&[f32]`
//!   straight into the page cache) without per-read verification:
//!   sealed records were proven intact by the open scan or written by
//!   this very process. With `mmap`, open seals a recovered tail
//!   segment by rotating once, so *everything* scanned becomes
//!   mappable. Caveat: truncating a mapped file under a live store is
//!   the one way to `SIGBUS` a view — forbidden by the single-writer
//!   contract and impossible from the store's own code; see [`mmap`]'s
//!   module docs.
//! - **Supersede, then compact**: re-putting a key makes the old record
//!   dead; when `dead/(live+dead)` crosses `compact_dead_ratio`,
//!   [`EmbeddingStore::compact`] rewrites live records into a fresh
//!   segment generation (numbered after the old one, so the ascending
//!   recovery scan prefers the rewrite even after a mid-compaction
//!   crash) and deletes the old files. Mappings of the old generation
//!   are released store-side, but any outstanding view (e.g. inside a
//!   live ANN index) holds an `Arc` to its mapping and stays readable —
//!   unlinking a mapped file is safe on unix.
//! - **Bitwise fidelity**: rows are stored as raw `f32` bits; what the
//!   pipeline computed is exactly what a later daemon serves (pinned by
//!   `tests/store.rs` against a fresh `embed_dataset` run, and mmap vs
//!   legacy path by the `tests/mmap.rs` differential battery).
//!
//! The serve layer tiers this store *under* its in-RAM LRU
//! ([`crate::serve::cache::TieredCache`]): L1 misses probe the store
//! (zero-copy for sealed rows — the copy happens only on L1 promotion)
//! and promote hits; inserts write through. The ANN retrieval index
//! ([`crate::ann`]) feeds on [`EmbeddingStore::snapshot_row_data`] — a
//! key-sorted dump of every live row as views-or-copies — taken under
//! a brief `&self` lock at daemon open, after compaction, and when the
//! pending tail overflows; only active-tail rows are copied. No new
//! dependencies — the codec is hand-rolled, checksums share
//! [`crate::util::fnv`], and `mmap(2)`/`munmap(2)` are direct
//! `extern "C"` declarations.

pub mod codec;
pub mod log;
pub mod mmap;

pub use codec::CacheKey;
pub use log::{mmap_default, EmbeddingStore, StoreConfig, StoreStats};
pub use mmap::{RowData, RowView, SegmentMap};
