//! `store`: the persistent tier of the embedding cache — a
//! content-addressed, append-only **segment log** for embedding rows.
//!
//! The paper's economics make embeddings worth keeping: computing one
//! is the expensive part of the graphlet pipeline, and once computed a
//! row is a *pure function* of `(canonical graph hash, config
//! fingerprint, sampling seed)` — the explicit-feature-map view of
//! graph kernels makes rows durable artifacts, not transient request
//! state. This module stores them so a daemon restart serves yesterday's
//! rows **bitwise identical** from disk instead of recomputing them.
//!
//! On-disk layout (see [`codec`] for the byte-exact record format):
//!
//! ```text
//!  <dir>/
//!    seg-00000000.log     ┐ numbered segments, scanned in id order on
//!    seg-00000001.log     │ open; the highest id is the active segment
//!    seg-00000002.log  ◄──┘ (appends go here; rotate at segment_bytes)
//!
//!  one segment:
//!    ┌──────────┬────────────┬────────────┬─ ─ ─┬─(torn tail)─┐
//!    │ "GRFSEG1\n" │ record 0 │ record 1  │ ... │ skipped     │
//!    └──────────┴────────────┴────────────┴─ ─ ─┴─────────────┘
//!      8-byte magic            length-prefixed, FNV-checksummed
//!
//!  one record:
//!    [u32 payload_len][u64 graph_hash][u64 config_fp][u64 seed]
//!    [u32 row_len][row_len × f32 bits][u64 FNV-1a(payload)]
//! ```
//!
//! Properties the serve tier builds on:
//!
//! - **Append-only writes**: a put is one unbuffered `write_all`; no
//!   in-place mutation, so a crash can only produce a *torn tail*.
//! - **Recovery by scan**: [`EmbeddingStore::open`] rebuilds the whole
//!   in-memory offset index from the segments; torn/corrupt records are
//!   skipped with the `corrupt_skipped` counter (never a panic, never a
//!   failed open) — a checksum failure with intact framing resyncs past
//!   just that record — and the active segment is truncated back to its
//!   last intact record. One store owns a directory at a time (no
//!   cross-process lock; see [`log`]'s module docs).
//! - **Supersede, then compact**: re-putting a key makes the old record
//!   dead; when `dead/(live+dead)` crosses `compact_dead_ratio`,
//!   [`EmbeddingStore::compact`] rewrites live records into a fresh
//!   segment generation (numbered after the old one, so the ascending
//!   recovery scan prefers the rewrite even after a mid-compaction
//!   crash) and deletes the old files.
//! - **Bitwise fidelity**: rows are stored as raw `f32` bits; what the
//!   pipeline computed is exactly what a later daemon serves (pinned by
//!   `tests/store.rs` against a fresh `embed_dataset` run).
//!
//! The serve layer tiers this store *under* its in-RAM LRU
//! ([`crate::serve::cache::TieredCache`]): L1 misses probe the store
//! and promote hits; inserts write through. The ANN retrieval index
//! ([`crate::ann`]) feeds on [`EmbeddingStore::snapshot_rows`] — a
//! key-sorted dump of every live row — taken under a brief lock at
//! daemon open, after compaction, and when the pending tail overflows.
//! No new dependencies — the codec is hand-rolled, checksums share
//! [`crate::util::fnv`].

pub mod codec;
pub mod log;

pub use codec::CacheKey;
pub use log::{EmbeddingStore, StoreConfig, StoreStats};
