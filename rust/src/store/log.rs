//! The append-only segment log: rotation, recovery scan, compaction,
//! and the memory-mapped sealed-segment read path. See [`super`] (the
//! module docs) for the on-disk layout diagram; the record codec lives
//! in [`super::codec`] and the mapping layer in [`super::mmap`].
//!
//! Durability model: every [`put`](EmbeddingStore::put) is one
//! unbuffered `write_all` straight to the active segment file, so a
//! record is either fully in the OS page cache or it is the torn tail —
//! there is no user-space write buffer for a crash to eat. The recovery
//! scan ([`open`](EmbeddingStore::open)) walks each segment record by
//! record; a checksum-failed record with intact framing is *resynced
//! past* (one flipped bit loses one row, not a segment), while a torn
//! tail or untrustworthy length prefix stops the segment — both counted
//! in `corrupt_skipped`, never panicking — and the *last* segment is
//! truncated back to its last intact record so future appends start
//! from a clean byte. (`fsync` per record is deliberately not paid: the
//! contract is "crash-tolerant", not "power-loss-proof per row" — a
//! lost tail row is recomputed and rewritten on the next request.)
//!
//! Read model (`mmap: true`, the unix default): only the **active**
//! segment is ever appended to; every other segment is **sealed** —
//! immutable after rotation — and memory-mapped, so a `get` that
//! resolves into a sealed segment returns a zero-copy
//! [`RowData::View`] into the page cache. Sealed records were either
//! verified by the open-time recovery scan or written (and checksummed)
//! by this very process, so the mapped fast path does a structural key
//! check only — re-hashing every read would give up most of the win.
//! To make *recovered* data sealed too, open rotates once when the
//! scanned tail segment holds any records: verified bytes become
//! mappable, appends start in a fresh segment. Active-segment reads
//! (and every read with `mmap: false`) take the legacy
//! seek+read+verify path through a pooled read handle.
//!
//! Single-writer contract: exactly one [`EmbeddingStore`] (one daemon)
//! may own a directory at a time — there is no cross-process lock, and
//! two writers would interleave appends into the same active segment.
//! (A lock file is deliberately absent for now: a stale lock left by a
//! SIGKILLed daemon would block the restart-recovery path this store
//! exists for; a liveness-checked lock is a ROADMAP follow-up.) The
//! mapped read path additionally *requires* this: truncating a mapped
//! file under a live store is the one way to SIGBUS a view (see
//! [`super::mmap`] module docs).

use std::collections::{btree_map, BTreeMap, BTreeSet, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::codec::{
    decode_record, encode_record, read_u64, CacheKey, Decoded, PAYLOAD_HEADER, RECORD_OVERHEAD,
    SEGMENT_MAGIC,
};
use super::mmap::{decode_floats, RowData, RowView, SegmentMap};

/// Default for [`StoreConfig::mmap`]: on for unix targets (where the
/// hand-rolled `mmap(2)` wrapper is real), overridable either way with
/// `GRAPHLET_RF_TEST_MMAP=0|1` — the CI axis that runs every leg down
/// both read paths.
pub fn mmap_default() -> bool {
    match std::env::var("GRAPHLET_RF_TEST_MMAP") {
        Ok(v) if v.trim() == "0" => false,
        Ok(v) if v.trim() == "1" => true,
        _ => cfg!(unix),
    }
}

/// Tunables for one store directory.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Directory holding the `seg-NNNNNNNN.log` files (created on open).
    pub dir: PathBuf,
    /// Rotate the active segment once it would exceed this many bytes
    /// (a single record larger than the threshold still gets written —
    /// into a segment of its own).
    pub segment_bytes: u64,
    /// Compact when `dead_bytes / (live + dead)` exceeds this ratio…
    pub compact_dead_ratio: f64,
    /// …and the log holds at least this many bytes (tiny logs are never
    /// worth rewriting).
    pub compact_min_bytes: u64,
    /// Memory-map sealed segments and serve zero-copy row views out of
    /// them (see the module docs). `false` keeps every read on the
    /// legacy seek+read+verify path.
    pub mmap: bool,
}

impl StoreConfig {
    pub fn new(dir: impl Into<PathBuf>) -> StoreConfig {
        StoreConfig {
            dir: dir.into(),
            segment_bytes: 8 << 20,
            compact_dead_ratio: 0.5,
            compact_min_bytes: 1 << 20,
            mmap: mmap_default(),
        }
    }
}

/// Where one live record sits on disk.
#[derive(Clone, Copy, Debug)]
struct RecordLoc {
    segment: u64,
    offset: u64,
    len: u32,
}

/// Counter/size snapshot for the serve `stats` op.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    /// Segment files currently on disk.
    pub segments: usize,
    /// Live (indexed) records.
    pub records: usize,
    /// Bytes owned by live records.
    pub live_bytes: u64,
    /// Bytes owned by superseded (or corrupt-and-skipped) records —
    /// reclaimed by compaction.
    pub dead_bytes: u64,
    /// Torn/corrupt records skipped — at open (one per abandoned
    /// segment tail) or at read time (a record that fails its checksum
    /// is dropped from the index and recomputed upstream).
    pub corrupt_skipped: u64,
    /// Compaction passes completed since open.
    pub compactions: u64,
    /// Sealed segments currently memory-mapped.
    pub mmap_segments: usize,
    /// Bytes of sealed segment data currently memory-mapped.
    pub mmap_bytes: u64,
    /// Reads served zero-copy out of a mapped sealed segment.
    pub mmap_reads: u64,
}

/// A content-addressed, append-only embedding store over numbered
/// segment files, with an in-memory offset index rebuilt by scanning
/// the segments on open. Not internally synchronized — the serve tier
/// wraps it in a `Mutex` (one store per daemon) — but the *read* path
/// over sealed segments is `&self`, so a snapshot holds that mutex
/// only as long as view construction plus the active-segment tail scan.
pub struct EmbeddingStore {
    cfg: StoreConfig,
    index: HashMap<CacheKey, RecordLoc>,
    /// Lazily opened read handles, one per segment, for the non-mapped
    /// read path (active segment; everything when `mmap: false`).
    /// Behind a `Mutex` so reads are `&self`.
    readers: Mutex<BTreeMap<u64, File>>,
    /// Memory maps of sealed segments, keyed by id. Mutated only by
    /// `&mut self` lifecycle methods (open/rotate/compact); reads
    /// clone out `Arc`s, which keep a generation's pages alive after
    /// compaction unlinks its files.
    maps: BTreeMap<u64, Arc<SegmentMap>>,
    /// Ids of the segment files currently on disk.
    segment_ids: BTreeSet<u64>,
    /// Append handle for the active (highest-id) segment.
    active: File,
    active_id: u64,
    active_len: u64,
    live_bytes: u64,
    dead_bytes: u64,
    /// Atomic so the `&self` read paths (`snapshot_row_data`) can count.
    corrupt_skipped: AtomicU64,
    compactions: u64,
    mmap_reads: AtomicU64,
    scratch: Vec<u8>,
    /// Where `store.append_us` / `store.compact_us` / `store.mmap_*`
    /// record. Defaults to the process-global registry; the serve
    /// daemon swaps in its own instance via
    /// [`set_registry`](Self::set_registry) right after open, so two
    /// in-process daemons never share store metrics.
    registry: Arc<crate::obs::Registry>,
}

fn segment_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("seg-{id:08}.log"))
}

/// Create (or truncate) a segment file and write its magic header.
fn create_segment(dir: &Path, id: u64) -> Result<File> {
    let path = segment_path(dir, id);
    let mut f = OpenOptions::new()
        .create(true)
        .truncate(true)
        .write(true)
        .open(&path)
        .with_context(|| format!("creating segment {}", path.display()))?;
    f.write_all(&SEGMENT_MAGIC)?;
    Ok(f)
}

impl EmbeddingStore {
    /// Open (or initialize) the store at `cfg.dir`: scan every segment
    /// in id order, rebuild the offset index (a later record for the
    /// same key supersedes the earlier one, whose bytes become dead),
    /// and truncate the active segment past its last intact record.
    /// Torn or corrupt data is skipped with a counter — never an error,
    /// never a panic: losing a tail row only costs one recompute.
    /// With `cfg.mmap`, a recovered tail segment holding records is
    /// then sealed by one rotation and every sealed segment is mapped.
    pub fn open(cfg: StoreConfig) -> Result<EmbeddingStore> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating store dir {}", cfg.dir.display()))?;
        let mut ids: Vec<u64> = Vec::new();
        for entry in std::fs::read_dir(&cfg.dir)? {
            let name = entry?.file_name();
            if let Some(id) = name
                .to_str()
                .and_then(|n| n.strip_prefix("seg-"))
                .and_then(|n| n.strip_suffix(".log"))
                .and_then(|n| n.parse::<u64>().ok())
            {
                ids.push(id);
            }
        }
        ids.sort_unstable();

        let mut index: HashMap<CacheKey, RecordLoc> = HashMap::new();
        let mut live_bytes = 0u64;
        let mut dead_bytes = 0u64;
        let mut corrupt_skipped = 0u64;
        for (pos, &id) in ids.iter().enumerate() {
            let path = segment_path(&cfg.dir, id);
            let bytes =
                std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
            let is_last = pos + 1 == ids.len();
            if bytes.len() < SEGMENT_MAGIC.len() || bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
                // Torn or foreign header: nothing in this segment is
                // trustworthy. The last segment is reset so appends
                // start clean; earlier ones are left untouched.
                corrupt_skipped += 1;
                if is_last {
                    create_segment(&cfg.dir, id)?;
                }
                continue;
            }
            let mut at = SEGMENT_MAGIC.len();
            while at < bytes.len() {
                match decode_record(&bytes[at..]) {
                    Decoded::Record { key, row: _, len } => {
                        let loc = RecordLoc { segment: id, offset: at as u64, len: len as u32 };
                        if let Some(old) = index.insert(key, loc) {
                            dead_bytes += u64::from(old.len);
                            live_bytes = live_bytes.saturating_sub(u64::from(old.len));
                        }
                        live_bytes += len as u64;
                        at += len;
                    }
                    Decoded::Corrupt { skip: Some(len), .. } => {
                        // Intact framing, failed verification (e.g. one
                        // flipped bit): resync past exactly this record
                        // so the rest of the segment survives. Its
                        // bytes stay on disk as dead weight until
                        // compaction.
                        corrupt_skipped += 1;
                        dead_bytes += len as u64;
                        at += len;
                    }
                    Decoded::Truncated | Decoded::Corrupt { skip: None, .. } => {
                        // Torn tail or untrustworthy length prefix: one
                        // counted skip, and nothing after it can be
                        // re-framed — the rest of this segment is
                        // unreachable.
                        corrupt_skipped += 1;
                        break;
                    }
                }
            }
            if is_last && at < bytes.len() {
                // Drop the torn tail so the next append starts at a
                // record boundary.
                let f = OpenOptions::new().write(true).open(&path)?;
                f.set_len(at as u64)?;
            }
        }

        // The active segment is the highest id on disk, or a fresh
        // seg-00000000 for an empty directory.
        let (active_id, active) = match ids.last().copied() {
            Some(id) => {
                let f = OpenOptions::new()
                    .append(true)
                    .open(segment_path(&cfg.dir, id))
                    .with_context(|| format!("opening active segment {id}"))?;
                (id, f)
            }
            None => {
                ids.push(0);
                (0, create_segment(&cfg.dir, 0)?)
            }
        };
        let active_len = active.metadata()?.len();
        let mut store = EmbeddingStore {
            cfg,
            index,
            readers: Mutex::new(BTreeMap::new()),
            maps: BTreeMap::new(),
            segment_ids: ids.into_iter().collect(),
            active,
            active_id,
            active_len,
            live_bytes,
            dead_bytes,
            corrupt_skipped: AtomicU64::new(corrupt_skipped),
            compactions: 0,
            mmap_reads: AtomicU64::new(0),
            scratch: Vec::new(),
            registry: crate::obs::global_arc(),
        };
        if store.cfg.mmap {
            if store.active_len > SEGMENT_MAGIC.len() as u64 {
                // Seal the recovered tail: its records are
                // scan-verified, so one rotation makes them mappable
                // and leaves a fresh empty active segment for appends.
                store.rotate()?;
            }
            store.map_sealed_segments()?;
        }
        Ok(store)
    }

    /// Route this store's metrics into an instance-scoped registry (the
    /// owning daemon's) instead of the process-global default.
    pub fn set_registry(&mut self, registry: Arc<crate::obs::Registry>) {
        self.registry = registry;
        if self.cfg.mmap {
            self.publish_mmap_gauges();
        }
    }

    /// Whether this store maps sealed segments (the
    /// [`StoreConfig::mmap`] it was opened with).
    pub fn mmap_enabled(&self) -> bool {
        self.cfg.mmap
    }

    /// Map every sealed (non-active) segment that is not mapped yet.
    fn map_sealed_segments(&mut self) -> Result<()> {
        let missing: Vec<u64> = self
            .segment_ids
            .iter()
            .copied()
            .filter(|&id| id != self.active_id && !self.maps.contains_key(&id))
            .collect();
        for id in missing {
            let map = SegmentMap::map(&segment_path(&self.cfg.dir, id))?;
            self.maps.insert(id, Arc::new(map));
        }
        self.publish_mmap_gauges();
        Ok(())
    }

    fn publish_mmap_gauges(&self) {
        let bytes: u64 = self.maps.values().map(|m| m.len() as u64).sum();
        self.registry.gauge("store.mmap_segments").set(self.maps.len() as u64);
        self.registry.gauge("store.mmap_bytes").set(bytes);
    }

    /// Look up a row by content address, zero-copy when it lives in a
    /// mapped sealed segment. A record that fails verification at read
    /// time is dropped from the index and counted in `corrupt_skipped`
    /// — the caller sees a miss and recomputes.
    pub fn get_row(&mut self, key: &CacheKey) -> Option<RowData> {
        let loc = *self.index.get(key)?;
        match self.read_row(loc, key) {
            Some(row) => Some(row),
            None => {
                self.corrupt_skipped.fetch_add(1, Ordering::Relaxed);
                self.index.remove(key);
                self.live_bytes = self.live_bytes.saturating_sub(u64::from(loc.len));
                self.dead_bytes += u64::from(loc.len);
                None
            }
        }
    }

    /// [`get_row`](Self::get_row) materialized to an owned `Vec` — the
    /// compatibility shape for callers that need ownership anyway.
    pub fn get(&mut self, key: &CacheKey) -> Option<Vec<f32>> {
        self.get_row(key).map(|row| row.to_vec())
    }

    /// Append a row (write-through from the cache tier). Re-putting an
    /// existing key supersedes its old record (the bytes become dead
    /// and are reclaimed by compaction); callers that want append-once
    /// semantics should check [`contains`](Self::contains) first.
    pub fn put(&mut self, key: CacheKey, row: &[f32]) -> Result<()> {
        let t = std::time::Instant::now();
        let loc = self.append_record(&key, row)?;
        if let Some(old) = self.index.insert(key, loc) {
            self.dead_bytes += u64::from(old.len);
            self.live_bytes = self.live_bytes.saturating_sub(u64::from(old.len));
        }
        self.live_bytes += u64::from(loc.len);
        // Recorded before any auto-compaction this put trips, so the
        // append histogram stays an append histogram (compaction has
        // its own in `compact`).
        self.registry.histo("store.append_us").record(t.elapsed());
        self.maybe_compact()
    }

    pub fn contains(&self, key: &CacheKey) -> bool {
        self.index.contains_key(key)
    }

    /// Every live row, **sorted by key**, as [`RowData`] — views for
    /// sealed mapped segments, owned copies only for records still in
    /// the active-segment tail (and for everything with `mmap: false`).
    /// This is the ANN index's feed, and it is `&self`: under the serve
    /// tier's store mutex, a rebuild snapshot now costs view
    /// construction plus the active-tail reads, not a full-copy scan.
    /// The sort is what makes an index build a pure function of the row
    /// *set* (the offset index is an unordered `HashMap`) — the
    /// determinism the differential battery and the restart test pin.
    /// Rows that fail verification are dropped and counted in
    /// `corrupt_skipped`; being `&self`, the index entry itself is
    /// repaired later by the next [`get_row`](Self::get_row).
    pub fn snapshot_row_data(&self) -> Vec<(CacheKey, RowData)> {
        let mut entries: Vec<(CacheKey, RecordLoc)> =
            self.index.iter().map(|(k, &l)| (*k, l)).collect();
        entries.sort_unstable_by_key(|&(k, _)| k);
        let mut out = Vec::with_capacity(entries.len());
        for (key, loc) in entries {
            match self.read_row(loc, &key) {
                Some(row) => out.push((key, row)),
                None => {
                    self.corrupt_skipped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        out
    }

    /// [`snapshot_row_data`](Self::snapshot_row_data) materialized to
    /// owned rows — the legacy shape.
    pub fn snapshot_rows(&self) -> Vec<(CacheKey, Vec<f32>)> {
        self.snapshot_row_data().into_iter().map(|(k, r)| (k, r.to_vec())).collect()
    }

    /// Live (indexed) record count.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            segments: self.segment_ids.len(),
            records: self.index.len(),
            live_bytes: self.live_bytes,
            dead_bytes: self.dead_bytes,
            corrupt_skipped: self.corrupt_skipped.load(Ordering::Relaxed),
            compactions: self.compactions,
            mmap_segments: self.maps.len(),
            mmap_bytes: self.maps.values().map(|m| m.len() as u64).sum(),
            mmap_reads: self.mmap_reads.load(Ordering::Relaxed),
        }
    }

    /// Rewrite every live record into fresh segments (numbered after
    /// the current active, so a crash mid-compaction leaves a directory
    /// where the ascending-id recovery scan still prefers the rewrite),
    /// then delete the old generation. Reclaims all dead bytes. The old
    /// generation's *mappings* are merely released here: any
    /// outstanding [`RowData::View`] (e.g. inside a live ANN index)
    /// holds its own `Arc` and keeps reading valid pages until dropped
    /// — that is what makes a rebuild's generation swap atomic for
    /// readers.
    pub fn compact(&mut self) -> Result<()> {
        let t = std::time::Instant::now();
        let mut entries: Vec<(CacheKey, RecordLoc)> =
            self.index.iter().map(|(k, &l)| (*k, l)).collect();
        // (segment, offset) order: sequential reads, deterministic
        // rewrite layout.
        entries.sort_unstable_by_key(|&(_, l)| (l.segment, l.offset));
        let old_ids: Vec<u64> = self.segment_ids.iter().copied().collect();
        self.rotate()?;
        let mut new_index = HashMap::with_capacity(entries.len());
        let mut new_live = 0u64;
        for (key, loc) in entries {
            // Full read+verify (not the mapped fast path): compaction
            // is the one chance to re-prove every surviving byte.
            let row = match self.read_at(loc) {
                Ok((k, row)) if k == key => row,
                // A record that went bad between index build and
                // rewrite: skip it, like any other corrupt read.
                _ => {
                    self.corrupt_skipped.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            };
            let new_loc = self.append_record(&key, &row)?;
            new_live += u64::from(new_loc.len);
            new_index.insert(key, new_loc);
        }
        self.index = new_index;
        self.live_bytes = new_live;
        self.dead_bytes = 0;
        {
            let mut readers = self.readers.lock().expect("store reader lock");
            for id in &old_ids {
                readers.remove(id);
            }
        }
        for id in old_ids {
            self.maps.remove(&id);
            self.segment_ids.remove(&id);
            let _ = std::fs::remove_file(segment_path(&self.cfg.dir, id));
        }
        if self.cfg.mmap {
            self.publish_mmap_gauges();
        }
        self.compactions += 1;
        self.registry.histo("store.compact_us").record(t.elapsed());
        Ok(())
    }

    fn maybe_compact(&mut self) -> Result<()> {
        let total = self.live_bytes + self.dead_bytes;
        if total >= self.cfg.compact_min_bytes
            && self.dead_bytes as f64 > self.cfg.compact_dead_ratio * total as f64
        {
            self.compact()?;
        }
        Ok(())
    }

    /// Encode and append one record to the active segment, rotating
    /// first when the segment is at its size threshold. No index or
    /// byte accounting — [`put`](Self::put) and
    /// [`compact`](Self::compact) layer their own on top.
    fn append_record(&mut self, key: &CacheKey, row: &[f32]) -> Result<RecordLoc> {
        let mut buf = std::mem::take(&mut self.scratch);
        buf.clear();
        encode_record(key, row, &mut buf);
        if self.active_len > SEGMENT_MAGIC.len() as u64
            && self.active_len + buf.len() as u64 > self.cfg.segment_bytes
        {
            self.rotate()?;
        }
        let wrote = self.active.write_all(&buf);
        self.scratch = buf;
        if let Err(e) = wrote {
            // A partial append is a torn tail mid-segment; rotate so
            // later records land in a clean segment (the recovery scan
            // would otherwise stop at the tear and lose them).
            let _ = self.rotate();
            return Err(anyhow::Error::from(e).context("appending embedding record"));
        }
        let loc = RecordLoc {
            segment: self.active_id,
            offset: self.active_len,
            len: self.scratch.len() as u32,
        };
        self.active_len += self.scratch.len() as u64;
        Ok(loc)
    }

    /// Seal the active segment and start a fresh one. With `cfg.mmap`
    /// the just-sealed segment is mapped here — from this point on it
    /// is immutable and its rows are served zero-copy.
    fn rotate(&mut self) -> Result<()> {
        let sealed_id = self.active_id;
        let id = self.active_id + 1;
        self.active = create_segment(&self.cfg.dir, id)?;
        self.active_id = id;
        self.active_len = SEGMENT_MAGIC.len() as u64;
        self.segment_ids.insert(id);
        if self.cfg.mmap && self.segment_ids.contains(&sealed_id) {
            let map = SegmentMap::map(&segment_path(&self.cfg.dir, sealed_id))?;
            self.maps.insert(sealed_id, Arc::new(map));
            self.publish_mmap_gauges();
        }
        Ok(())
    }

    /// Resolve `loc` to its row. Mapped sealed segments serve a
    /// zero-copy view after a structural key check (their records are
    /// already verified — see the module docs); everything else takes
    /// the read+decode+verify file path. `None` means "don't trust
    /// this record"; counting/repair policy belongs to the caller.
    fn read_row(&self, loc: RecordLoc, key: &CacheKey) -> Option<RowData> {
        if let Some(map) = self.maps.get(&loc.segment) {
            return self.read_mapped(map, loc, key);
        }
        match self.read_at(loc) {
            Ok((k, row)) if k == *key => Some(RowData::Owned(row)),
            _ => None,
        }
    }

    /// The zero-copy fast path: bounds-check the location against the
    /// mapping (never trusting `loc` enough to fault), confirm the
    /// stored key, and hand out a view of the f32 payload in place.
    fn read_mapped(&self, map: &Arc<SegmentMap>, loc: RecordLoc, key: &CacheKey) -> Option<RowData> {
        let bytes = map.as_bytes();
        let start = usize::try_from(loc.offset).ok()?;
        let len = loc.len as usize;
        if len < RECORD_OVERHEAD + PAYLOAD_HEADER || start.checked_add(len)? > bytes.len() {
            return None;
        }
        let payload = &bytes[start + 4..start + len - 8];
        let stored = CacheKey {
            graph_hash: read_u64(&payload[0..8]),
            config_fp: read_u64(&payload[8..16]),
            seed: read_u64(&payload[16..24]),
        };
        if stored != *key {
            return None;
        }
        let row_len = (len - RECORD_OVERHEAD - PAYLOAD_HEADER) / 4;
        let row_off = start + 4 + PAYLOAD_HEADER;
        self.mmap_reads.fetch_add(1, Ordering::Relaxed);
        self.registry.counter("store.mmap_reads").inc();
        Some(match RowView::new(Arc::clone(map), row_off, row_len) {
            Some(view) => RowData::View(view),
            // Misaligned or big-endian: reinterpretation is unsound,
            // decode an owned copy instead (never hit with the real
            // record layout on little-endian targets).
            None => RowData::Owned(decode_floats(&bytes[row_off..row_off + 4 * row_len])),
        })
    }

    /// Read + verify the record at `loc` through this segment's (lazily
    /// opened, pooled) read handle.
    fn read_at(&self, loc: RecordLoc) -> Result<(CacheKey, Vec<f32>)> {
        let mut readers = self.readers.lock().expect("store reader lock");
        let file = match readers.entry(loc.segment) {
            btree_map::Entry::Occupied(e) => e.into_mut(),
            btree_map::Entry::Vacant(e) => {
                let path = segment_path(&self.cfg.dir, loc.segment);
                e.insert(
                    File::open(&path)
                        .with_context(|| format!("opening segment {}", path.display()))?,
                )
            }
        };
        file.seek(SeekFrom::Start(loc.offset))?;
        let mut buf = vec![0u8; loc.len as usize];
        file.read_exact(&mut buf)?;
        match decode_record(&buf) {
            Decoded::Record { key, row, .. } => Ok((key, row)),
            Decoded::Truncated => bail!("record truncated on disk"),
            Decoded::Corrupt { reason, .. } => bail!("record corrupt on disk: {reason}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::codec::record_len;

    fn key(n: u64) -> CacheKey {
        CacheKey { graph_hash: n, config_fp: 0xC0FFEE, seed: n ^ 0xA5 }
    }

    fn row(n: u64, len: usize) -> Vec<f32> {
        (0..len).map(|i| (n as f32) * 0.25 + (i as f32) * 1.5e-3).collect()
    }

    fn temp_store(tag: &str) -> StoreConfig {
        let dir = std::env::temp_dir()
            .join(format!("graphlet_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        StoreConfig::new(dir)
    }

    fn cleanup(cfg: &StoreConfig) {
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn put_get_roundtrip_and_reopen_rebuild_index() {
        let cfg = temp_store("roundtrip");
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            assert!(s.is_empty());
            for n in 0..10u64 {
                s.put(key(n), &row(n, 16)).unwrap();
            }
            assert_eq!(s.len(), 10);
            for n in 0..10u64 {
                let got = s.get(&key(n)).unwrap();
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    row(n, 16).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "row {n} must round-trip bitwise"
                );
            }
            assert!(s.get(&key(99)).is_none());
            let st = s.stats();
            assert_eq!((st.records, st.segments, st.dead_bytes, st.corrupt_skipped), (10, 1, 0, 0));
            assert_eq!(st.live_bytes, 10 * record_len(16) as u64);
        }
        // Reopen: the index is rebuilt purely from the segment scan.
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        assert_eq!(s.len(), 10);
        for n in 0..10u64 {
            assert_eq!(s.get(&key(n)).unwrap(), row(n, 16), "row {n} lost across reopen");
        }
        assert_eq!(s.stats().corrupt_skipped, 0);
        cleanup(&cfg);
    }

    #[test]
    fn reopen_with_mmap_seals_recovered_rows_and_serves_views() {
        let mut cfg = temp_store("sealviews");
        cfg.mmap = true;
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            for n in 0..8u64 {
                s.put(key(n), &row(n, 16)).unwrap();
            }
            // Rows written this session sit in the active segment:
            // reads come back owned, no mmap reads yet.
            assert_eq!(s.stats().mmap_reads, 0);
            let snap = s.snapshot_row_data();
            assert!(snap.iter().all(|(_, r)| matches!(r, RowData::Owned(_))));
        }
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        let st = s.stats();
        assert_eq!(st.records, 8);
        assert_eq!(
            st.mmap_segments, 1,
            "open must seal + map the recovered segment: {st:?}"
        );
        assert!(st.mmap_bytes > SEGMENT_MAGIC.len() as u64);
        for n in 0..8u64 {
            let got = s.get_row(&key(n)).unwrap();
            if cfg!(all(unix, target_endian = "little", target_pointer_width = "64")) {
                assert!(
                    matches!(got, RowData::View(_)),
                    "sealed row {n} must be served zero-copy"
                );
            }
            assert_eq!(got.to_vec(), row(n, 16), "sealed row {n} must be bitwise");
        }
        assert_eq!(s.stats().mmap_reads, 8);
        let snap = s.snapshot_row_data();
        let owned: usize = snap.iter().map(|(_, r)| r.owned_bytes()).sum();
        if cfg!(all(unix, target_endian = "little", target_pointer_width = "64")) {
            assert_eq!(owned, 0, "a fully sealed store snapshots without copying");
        }
        cleanup(&cfg);
    }

    #[test]
    fn views_outlive_compaction_of_their_segment() {
        let mut cfg = temp_store("genpin");
        cfg.mmap = true;
        cfg.compact_min_bytes = u64::MAX; // manual compaction only
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            for n in 0..5u64 {
                s.put(key(n), &row(n, 8)).unwrap();
            }
        }
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        let snap = s.snapshot_row_data(); // views into the sealed generation
        s.compact().unwrap(); // unlinks the files those views point into
        for (k, r) in &snap {
            assert_eq!(
                r.to_vec(),
                row(k.graph_hash, 8),
                "view into a compacted-away segment must stay readable"
            );
        }
        // And the store itself serves the new generation correctly.
        for n in 0..5u64 {
            assert_eq!(s.get(&key(n)).unwrap(), row(n, 8));
        }
        cleanup(&cfg);
    }

    #[test]
    fn mmap_disabled_never_maps_or_counts() {
        let mut cfg = temp_store("nommap");
        cfg.mmap = false;
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            for n in 0..6u64 {
                s.put(key(n), &row(n, 8)).unwrap();
            }
        }
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        let st = s.stats();
        assert_eq!((st.mmap_segments, st.mmap_bytes, st.mmap_reads), (0, 0, 0));
        for n in 0..6u64 {
            let got = s.get_row(&key(n)).unwrap();
            assert!(matches!(got, RowData::Owned(_)), "legacy path must copy");
            assert_eq!(got.to_vec(), row(n, 8));
        }
        assert_eq!(s.stats().mmap_reads, 0);
        cleanup(&cfg);
    }

    #[test]
    fn torn_final_record_is_dropped_not_fatal() {
        let cfg = temp_store("torn");
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            for n in 0..3u64 {
                s.put(key(n), &row(n, 8)).unwrap();
            }
        }
        // Tear the final record mid-checksum, as a crash would.
        let path = segment_path(&cfg.dir, 0);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);

        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        let st = s.stats();
        assert_eq!(st.corrupt_skipped, 1, "the torn tail must be counted");
        assert_eq!(st.records, 2, "only the torn record is lost");
        assert!(s.get(&key(2)).is_none(), "the torn record must read as a miss");
        assert_eq!(s.get(&key(0)).unwrap(), row(0, 8));
        assert_eq!(s.get(&key(1)).unwrap(), row(1, 8));
        // The tail was truncated: a fresh put lands cleanly and
        // survives another reopen.
        s.put(key(2), &row(2, 8)).unwrap();
        drop(s);
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        assert_eq!(s.stats().corrupt_skipped, 0, "truncation removed the torn bytes");
        assert_eq!(s.get(&key(2)).unwrap(), row(2, 8));
        cleanup(&cfg);
    }

    #[test]
    fn mid_segment_bit_flip_loses_exactly_one_record() {
        let cfg = temp_store("midcorrupt");
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            for n in 0..6u64 {
                s.put(key(n), &row(n, 8)).unwrap();
            }
            assert_eq!(s.stats().segments, 1, "one big segment holds every record");
        }
        // Flip a byte inside the SECOND record of six: the framing is
        // intact, so the recovery scan must resync past exactly that
        // record — one flipped bit costs one row, not the segment.
        let path = segment_path(&cfg.dir, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let at = SEGMENT_MAGIC.len() + record_len(8) + 20;
        bytes[at] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();

        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        let st = s.stats();
        assert_eq!(st.corrupt_skipped, 1);
        assert_eq!(st.records, 5);
        assert_eq!(st.dead_bytes, record_len(8) as u64, "the skipped bytes become dead weight");
        assert_eq!(s.get(&key(0)).unwrap(), row(0, 8), "record before the flip survives");
        assert!(s.get(&key(1)).is_none(), "the flipped record is lost");
        for n in 2..6u64 {
            assert_eq!(
                s.get(&key(n)).unwrap(),
                row(n, 8),
                "records after the flip in the same segment must survive"
            );
        }
        cleanup(&cfg);
    }

    #[test]
    fn rotation_splits_segments_and_all_rows_stay_readable() {
        let mut cfg = temp_store("rotate");
        cfg.segment_bytes = 3 * record_len(4) as u64; // ~3 records per segment
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        for n in 0..20u64 {
            s.put(key(n), &row(n, 4)).unwrap();
        }
        let st = s.stats();
        assert!(st.segments >= 6, "20 records at ~3/segment, got {}", st.segments);
        for n in 0..20u64 {
            assert_eq!(s.get(&key(n)).unwrap(), row(n, 4));
        }
        drop(s);
        let s2 = EmbeddingStore::open(cfg.clone()).unwrap();
        // An mmap reopen seals the recovered tail segment, adding
        // exactly one fresh (empty) active segment; the legacy path
        // reopens in place.
        let expect = st.segments + usize::from(s2.mmap_enabled());
        let mut s = s2;
        assert_eq!(s.stats().segments, expect, "reopen must see the same data segments");
        for n in 0..20u64 {
            assert_eq!(s.get(&key(n)).unwrap(), row(n, 4));
        }
        cleanup(&cfg);
    }

    #[test]
    fn duplicate_puts_count_dead_bytes_and_compaction_reclaims_them() {
        let mut cfg = temp_store("compact");
        cfg.segment_bytes = 4 * record_len(8) as u64;
        cfg.compact_min_bytes = u64::MAX; // manual compaction only
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        for n in 0..4u64 {
            s.put(key(n), &row(n, 8)).unwrap();
        }
        // Rewrite key 0 five times: five superseded records.
        for gen in 0..5u64 {
            s.put(key(0), &row(100 + gen, 8)).unwrap();
        }
        let st = s.stats();
        assert_eq!(st.records, 4);
        assert_eq!(st.dead_bytes, 5 * record_len(8) as u64);
        let segments_before = st.segments;
        assert!(segments_before >= 2);

        s.compact().unwrap();
        let st = s.stats();
        assert_eq!(st.dead_bytes, 0, "compaction reclaims every dead byte");
        assert_eq!(st.records, 4);
        assert_eq!(st.live_bytes, 4 * record_len(8) as u64);
        assert_eq!(st.compactions, 1);
        // Liveness: every key still reads back the LATEST value.
        assert_eq!(s.get(&key(0)).unwrap(), row(104, 8));
        for n in 1..4u64 {
            assert_eq!(s.get(&key(n)).unwrap(), row(n, 8));
        }
        // The old generation's files are actually gone from disk, and
        // no stale mapping lingers for a deleted segment.
        let on_disk = std::fs::read_dir(&cfg.dir).unwrap().count();
        assert_eq!(on_disk, s.stats().segments, "deleted segments must not linger");
        assert!(on_disk < segments_before + 2);
        assert!(s.stats().mmap_segments < on_disk, "the active segment is never mapped");

        // And the compacted layout survives a reopen.
        drop(s);
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        assert_eq!(s.len(), 4);
        assert_eq!(s.get(&key(0)).unwrap(), row(104, 8));
        assert_eq!(s.stats().dead_bytes, 0);
        cleanup(&cfg);
    }

    #[test]
    fn compaction_triggers_automatically_past_the_dead_ratio() {
        let mut cfg = temp_store("autocompact");
        cfg.compact_min_bytes = record_len(8) as u64; // tiny log may compact
        cfg.compact_dead_ratio = 0.5;
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        s.put(key(1), &row(1, 8)).unwrap();
        // Keep superseding the same key: once dead > live the put path
        // must compact on its own.
        for gen in 0..4u64 {
            s.put(key(1), &row(10 + gen, 8)).unwrap();
        }
        let st = s.stats();
        assert!(st.compactions >= 1, "dead ratio crossing must trigger compaction");
        assert!(
            st.dead_bytes as f64 <= 0.5 * (st.live_bytes + st.dead_bytes) as f64,
            "post-compaction dead ratio must be back under the bound: {st:?}"
        );
        assert_eq!(s.get(&key(1)).unwrap(), row(13, 8), "latest value must win");
        cleanup(&cfg);
    }

    #[test]
    fn empty_directory_opens_and_missing_keys_miss() {
        let cfg = temp_store("empty");
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        assert!(s.is_empty());
        assert!(s.get(&key(0)).is_none());
        let st = s.stats();
        assert_eq!((st.segments, st.records, st.live_bytes), (1, 0, 0));
        assert_eq!(st.mmap_segments, 0, "an empty store has nothing sealed to map");
        cleanup(&cfg);
    }

    #[test]
    fn snapshot_rows_is_key_sorted_and_complete() {
        let cfg = temp_store("snapshot");
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        // Insert in a scrambled key order; the snapshot must come back
        // sorted regardless.
        for n in [5u64, 1, 9, 3, 7, 0] {
            s.put(key(n), &row(n, 8)).unwrap();
        }
        let snap = s.snapshot_rows();
        assert_eq!(snap.len(), 6);
        let keys: Vec<CacheKey> = snap.iter().map(|(k, _)| *k).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "snapshot must be key-sorted");
        for (k, r) in &snap {
            let n = k.graph_hash;
            assert_eq!(
                r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                row(n, 8).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "snapshot row {n} must be bitwise"
            );
        }
        cleanup(&cfg);
    }

    #[test]
    fn torn_header_on_last_segment_resets_it() {
        let cfg = temp_store("tornheader");
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            s.put(key(1), &row(1, 4)).unwrap();
        }
        // Crash while creating the next segment: 3 bytes of magic only.
        std::fs::write(segment_path(&cfg.dir, 1), b"GRF").unwrap();
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        assert_eq!(s.stats().corrupt_skipped, 1);
        assert_eq!(s.get(&key(1)).unwrap(), row(1, 4), "earlier segment unaffected");
        // The reset segment accepts appends and survives reopen.
        s.put(key(2), &row(2, 4)).unwrap();
        drop(s);
        let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
        assert_eq!(s.get(&key(2)).unwrap(), row(2, 4));
        cleanup(&cfg);
    }
}
