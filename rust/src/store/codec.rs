//! The hand-rolled binary codec for embedding-store records.
//!
//! One record carries one embedding row plus its content address
//! ([`CacheKey`]), length-prefixed and checksummed so a reader can walk
//! a segment without any external index and can *prove* each record
//! intact before trusting it:
//!
//! ```text
//!  offset  size  field
//!  ──────  ────  ─────────────────────────────────────────────
//!       0     4  payload_len (u32 LE) — bytes of the payload
//!       4     8  key.graph_hash (u64 LE)  ┐
//!      12     8  key.config_fp  (u64 LE)  │
//!      20     8  key.seed       (u64 LE)  │ payload
//!      28     4  row_len        (u32 LE)  │ (payload_len bytes)
//!      32  4·row_len  row f32 bits (LE)   ┘
//!    32+4·row_len  8  FNV-1a of the payload bytes (u64 LE)
//! ```
//!
//! Rows are written as raw `f32::to_bits` and read back with
//! `f32::from_bits`, so a round-trip is **bitwise** — the store serves
//! exactly the floats the pipeline computed, NaN payloads included.
//! The checksum is the same FNV-1a mixing as [`crate::util::fnv`] (one
//! definition crate-wide), covering the payload only: the length prefix
//! is validated structurally (bounds + row_len consistency) instead.
//!
//! Decoding distinguishes [`Decoded::Truncated`] (fewer bytes than the
//! framing promises — the torn tail a crash leaves behind) from
//! [`Decoded::Corrupt`] (framing present but inconsistent, or a
//! checksum mismatch). Both are recoverable conditions for the segment
//! scanner, never panics.

use crate::util::fnv;

/// The content address of one embedding row: with `(canonical graph
/// hash, config fingerprint, per-job seed)` fixed, an embedding is a
/// pure function of its inputs — which is what makes rows durable
/// artifacts worth persisting. Defined here (the on-disk key) and
/// re-exported by `serve::cache` (the in-RAM key); both tiers address
/// rows identically.
/// `Ord` is lexicographic over `(graph_hash, config_fp, seed)`: the ANN
/// index sorts snapshots by key so index builds are deterministic even
/// though the store's in-RAM offset index is an (unordered) `HashMap`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    pub graph_hash: u64,
    pub config_fp: u64,
    pub seed: u64,
}

impl CacheKey {
    /// Wire encoding for the `nearest` reply: the protocol's JSON
    /// numbers are f64-backed (exact only below 2^53), so full-width
    /// u64 key fields travel as a colon-separated hex triple instead.
    pub fn to_hex(&self) -> String {
        format!("{:016x}:{:016x}:{:016x}", self.graph_hash, self.config_fp, self.seed)
    }

    /// Inverse of [`CacheKey::to_hex`]; `None` on any malformed input.
    pub fn from_hex(s: &str) -> Option<CacheKey> {
        let mut parts = s.split(':');
        let graph_hash = u64::from_str_radix(parts.next()?, 16).ok()?;
        let config_fp = u64::from_str_radix(parts.next()?, 16).ok()?;
        let seed = u64::from_str_radix(parts.next()?, 16).ok()?;
        if parts.next().is_some() {
            return None;
        }
        Some(CacheKey { graph_hash, config_fp, seed })
    }
}

/// Every segment file starts with these 8 bytes (name + format version;
/// bump the digit on incompatible codec changes).
pub const SEGMENT_MAGIC: [u8; 8] = *b"GRFSEG1\n";

/// Payload bytes ahead of the row data: three u64 key fields + u32 row
/// length.
pub const PAYLOAD_HEADER: usize = 28;

/// Framing bytes around the payload: u32 length prefix + u64 checksum.
pub const RECORD_OVERHEAD: usize = 12;

/// Sanity bound on `row_len` (16M floats = 64 MiB rows): a length
/// beyond this is treated as corruption, so a scrambled length prefix
/// cannot make the scanner attempt a huge allocation.
pub const MAX_ROW_LEN: usize = 1 << 24;

/// Total encoded size of a record carrying `row_len` floats.
pub fn record_len(row_len: usize) -> usize {
    RECORD_OVERHEAD + PAYLOAD_HEADER + 4 * row_len
}

/// Append one encoded record to `out`.
pub fn encode_record(key: &CacheKey, row: &[f32], out: &mut Vec<u8>) {
    let payload_len = PAYLOAD_HEADER + 4 * row.len();
    out.reserve(RECORD_OVERHEAD + payload_len);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    let payload_start = out.len();
    out.extend_from_slice(&key.graph_hash.to_le_bytes());
    out.extend_from_slice(&key.config_fp.to_le_bytes());
    out.extend_from_slice(&key.seed.to_le_bytes());
    out.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let sum = fnv::mix_bytes(fnv::OFFSET, &out[payload_start..]);
    out.extend_from_slice(&sum.to_le_bytes());
}

/// Outcome of decoding the record at the front of `buf`.
#[derive(Debug)]
pub enum Decoded {
    /// A verified record; `len` is the encoded size consumed.
    Record { key: CacheKey, row: Vec<f32>, len: usize },
    /// The framing promises more bytes than `buf` holds — the torn tail
    /// an interrupted append leaves behind.
    Truncated,
    /// Framing present but inconsistent, or the checksum failed. When
    /// the framing itself was plausible, `skip` carries the record's
    /// encoded length so a scanner can resync past *just* the damaged
    /// record (one flipped bit must not cost the rest of the segment);
    /// `skip: None` means the length prefix is untrustworthy and
    /// nothing after it can be re-framed.
    Corrupt { reason: &'static str, skip: Option<usize> },
}

fn read_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

pub(crate) fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Decode (and verify) the record at the front of `buf`. Callers scan a
/// segment by repeatedly decoding and advancing by the returned `len`;
/// an empty `buf` is end-of-segment and should not reach here.
pub fn decode_record(buf: &[u8]) -> Decoded {
    if buf.len() < 4 {
        return Decoded::Truncated;
    }
    let payload_len = read_u32(buf) as usize;
    if !(PAYLOAD_HEADER..=PAYLOAD_HEADER + 4 * MAX_ROW_LEN).contains(&payload_len) {
        return Decoded::Corrupt { reason: "payload length out of bounds", skip: None };
    }
    let total = RECORD_OVERHEAD + payload_len;
    if buf.len() < total {
        return Decoded::Truncated;
    }
    let payload = &buf[4..4 + payload_len];
    let want_sum = read_u64(&buf[4 + payload_len..total]);
    if fnv::mix_bytes(fnv::OFFSET, payload) != want_sum {
        return Decoded::Corrupt { reason: "checksum mismatch", skip: Some(total) };
    }
    let row_len = read_u32(&payload[24..28]) as usize;
    if payload_len != PAYLOAD_HEADER + 4 * row_len {
        return Decoded::Corrupt {
            reason: "row length disagrees with payload length",
            skip: Some(total),
        };
    }
    let key = CacheKey {
        graph_hash: read_u64(&payload[0..8]),
        config_fp: read_u64(&payload[8..16]),
        seed: read_u64(&payload[16..24]),
    };
    let mut row = Vec::with_capacity(row_len);
    for chunk in payload[PAYLOAD_HEADER..].chunks_exact(4) {
        row.push(f32::from_bits(read_u32(chunk)));
    }
    Decoded::Record { key, row, len: total }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: u64) -> CacheKey {
        CacheKey { graph_hash: n, config_fp: n ^ 0xBEEF, seed: n.wrapping_mul(31) }
    }

    #[test]
    fn roundtrip_is_bitwise_including_odd_floats() {
        let row = vec![1.0f32, -0.0, f32::MIN_POSITIVE, f32::NAN, 3.25e-7, f32::INFINITY];
        let mut buf = Vec::new();
        encode_record(&key(7), &row, &mut buf);
        assert_eq!(buf.len(), record_len(row.len()));
        match decode_record(&buf) {
            Decoded::Record { key: k, row: back, len } => {
                assert_eq!(k, key(7));
                assert_eq!(len, buf.len());
                assert_eq!(back.len(), row.len());
                for (a, b) in back.iter().zip(&row) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bitwise drift");
                }
            }
            other => panic!("decode failed: {other:?}"),
        }
    }

    #[test]
    fn consecutive_records_scan() {
        let mut buf = Vec::new();
        encode_record(&key(1), &[1.0, 2.0], &mut buf);
        encode_record(&key(2), &[], &mut buf);
        encode_record(&key(3), &[9.5; 17], &mut buf);
        let mut at = 0usize;
        let mut seen = Vec::new();
        while at < buf.len() {
            match decode_record(&buf[at..]) {
                Decoded::Record { key, len, .. } => {
                    seen.push(key.graph_hash);
                    at += len;
                }
                other => panic!("scan broke at {at}: {other:?}"),
            }
        }
        assert_eq!(seen, [1, 2, 3]);
        assert_eq!(at, buf.len());
    }

    #[test]
    fn truncation_at_every_boundary_is_truncated_not_panic() {
        let mut buf = Vec::new();
        encode_record(&key(4), &[1.0, 2.0, 3.0], &mut buf);
        for cut in 1..buf.len() {
            match decode_record(&buf[..cut]) {
                Decoded::Truncated => {}
                Decoded::Corrupt { .. } => {
                    panic!("clean prefix of len {cut} must read as truncated, not corrupt")
                }
                Decoded::Record { .. } => panic!("prefix of len {cut} decoded as a full record"),
            }
        }
    }

    #[test]
    fn bit_flips_fail_the_checksum_and_carry_a_resync_hint() {
        let mut clean = Vec::new();
        encode_record(&key(5), &[0.5, -0.5, 42.0], &mut clean);
        // Flip one bit in every payload byte position in turn. The
        // framing stays intact, so every flip must be skippable: the
        // hint lets a scanner lose exactly one record, not a segment.
        for at in 4..4 + PAYLOAD_HEADER + 12 {
            let mut buf = clean.clone();
            buf[at] ^= 0x40;
            match decode_record(&buf) {
                Decoded::Corrupt { skip: Some(n), .. } => assert_eq!(n, clean.len()),
                other => panic!("flip at byte {at} not caught: {other:?}"),
            }
        }
    }

    #[test]
    fn absurd_length_prefix_is_corrupt_without_allocating_or_resyncing() {
        let mut buf = Vec::new();
        encode_record(&key(6), &[1.0], &mut buf);
        buf[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        match decode_record(&buf) {
            Decoded::Corrupt { reason, skip } => {
                assert!(reason.contains("length"), "{reason}");
                assert!(skip.is_none(), "an untrusted length must not offer a resync hint");
            }
            other => panic!("{other:?}"),
        }
        // Too-small lengths (below the fixed payload header) too.
        buf[0..4].copy_from_slice(&3u32.to_le_bytes());
        assert!(matches!(decode_record(&buf), Decoded::Corrupt { skip: None, .. }));
    }

    #[test]
    fn cache_key_hex_roundtrips_full_width_u64s() {
        let keys = [
            CacheKey { graph_hash: 0, config_fp: 0, seed: 0 },
            CacheKey { graph_hash: u64::MAX, config_fp: 1 << 63, seed: (1 << 53) + 1 },
            key(123),
        ];
        for k in keys {
            let hex = k.to_hex();
            assert_eq!(hex.len(), 16 * 3 + 2);
            assert_eq!(CacheKey::from_hex(&hex), Some(k));
        }
        let long = "f".repeat(17);
        for bad in ["", "12:34", "zz:0:0", "0:0:0:0", "0:0:", long.as_str()] {
            assert_eq!(CacheKey::from_hex(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn cache_key_order_is_lexicographic_over_fields() {
        let a = CacheKey { graph_hash: 1, config_fp: 9, seed: 9 };
        let b = CacheKey { graph_hash: 2, config_fp: 0, seed: 0 };
        let c = CacheKey { graph_hash: 2, config_fp: 0, seed: 1 };
        assert!(a < b && b < c);
    }

    #[test]
    fn empty_row_roundtrips() {
        let mut buf = Vec::new();
        encode_record(&key(8), &[], &mut buf);
        assert_eq!(buf.len(), RECORD_OVERHEAD + PAYLOAD_HEADER);
        match decode_record(&buf) {
            Decoded::Record { row, .. } => assert!(row.is_empty()),
            other => panic!("{other:?}"),
        }
    }
}
