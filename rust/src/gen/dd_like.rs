//! D&D-like synthetic protein graphs (substitution for the real D&D
//! dataset, DESIGN.md §2).
//!
//! D&D (Dobson & Doig 2003) contains 1178 protein structures labelled
//! enzyme / non-enzyme; graphs are amino acids linked by spatial
//! proximity: locally dense, small-world, mean |V| ~ 284, mean degree ~ 5.
//!
//! We emulate that with a ring-lattice + rewiring construction
//! (Watts-Strogatz-like) whose *local clustering* differs by class:
//! enzymes (class 1) keep more of the lattice's triangles, non-enzymes
//! (class 0) are rewired more aggressively. Mean degree is identical
//! across classes, so — exactly like the paper's SBM protocol — the
//! classes are only separable through subgraph *structure*, which is the
//! code path Fig. 3 (left) exercises (k = 7, s = 4000, RW sampling).

use crate::data::Dataset;
use crate::graph::{AnyGraph, CsrGraph};
use crate::util::Rng;

/// Configuration (defaults sized after published D&D statistics, scaled
/// down ~2x in node count to keep laptop runtimes reasonable).
#[derive(Clone, Debug)]
pub struct DdLikeConfig {
    /// Minimum / maximum nodes per graph (log-uniform-ish sampling).
    pub v_min: usize,
    pub v_max: usize,
    /// Half-degree of the ring lattice (degree = 2 * lattice_k).
    pub lattice_k: usize,
    /// Rewiring probability per class: [class0, class1].
    pub rewire: [f64; 2],
    /// Graphs per class.
    pub per_class: usize,
}

impl Default for DdLikeConfig {
    fn default() -> Self {
        DdLikeConfig {
            v_min: 60,
            v_max: 300,
            lattice_k: 3, // mean degree 6 ~ D&D's ~5
            // Close enough that classification is non-trivial (paper's
            // D&D protocol sits near ~75% accuracy, not 100%).
            rewire: [0.30, 0.16],
            per_class: 300, // 600 total ~ D&D's 1178 at half scale
        }
    }
}

impl DdLikeConfig {
    /// Sample the node count for one graph: mixture favouring mid sizes,
    /// mimicking D&D's right-skewed size distribution.
    fn sample_v(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        let span = (self.v_max - self.v_min) as f64;
        // Squaring skews towards the small end (right-skewed sizes).
        self.v_min + (u * u * span) as usize
    }

    /// One Watts-Strogatz-like graph with class-dependent rewiring.
    pub fn sample_graph(&self, class: u8, rng: &mut Rng) -> AnyGraph {
        let v = self.sample_v(rng);
        let beta = self.rewire[class as usize];
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(v * self.lattice_k);
        for u in 0..v {
            for d in 1..=self.lattice_k {
                let w = (u + d) % v;
                if rng.bool(beta) {
                    // Rewire: keep u, pick a uniform random other endpoint.
                    let mut t = rng.usize(v);
                    while t == u {
                        t = rng.usize(v);
                    }
                    edges.push((u, t));
                } else {
                    edges.push((u, w));
                }
            }
        }
        AnyGraph::Csr(CsrGraph::from_edges(v, &edges))
    }

    pub fn generate(&self, rng: &mut Rng) -> Dataset {
        let mut graphs = Vec::with_capacity(2 * self.per_class);
        let mut labels = Vec::with_capacity(2 * self.per_class);
        for i in 0..(2 * self.per_class) {
            let class = (i % 2) as u8;
            graphs.push(self.sample_graph(class, rng));
            labels.push(class);
        }
        Dataset::new("dd_like", graphs, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_within_bounds() {
        let cfg = DdLikeConfig { per_class: 20, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(1));
        for g in &ds.graphs {
            assert!(g.v() >= cfg.v_min && g.v() <= cfg.v_max);
        }
    }

    #[test]
    fn mean_degree_close_across_classes() {
        let cfg = DdLikeConfig { per_class: 40, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(2));
        let mean = |class: u8| {
            let xs: Vec<f64> = ds
                .graphs
                .iter()
                .zip(&ds.labels)
                .filter(|(_, &l)| l == class)
                .map(|(g, _)| g.mean_degree())
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let (m0, m1) = (mean(0), mean(1));
        assert!((m0 - m1).abs() < 0.4, "degree leak: {m0} vs {m1}");
        assert!(m0 > 4.0 && m0 < 7.0, "{m0}");
    }

    #[test]
    fn classes_differ_in_triangle_density() {
        // The whole point of the substitution: class structure must be
        // detectable via small-subgraph statistics.
        let cfg = DdLikeConfig { per_class: 25, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(3));
        let tri_rate = |class: u8| {
            let mut rng = Rng::new(42);
            let mut hits = 0usize;
            let mut total = 0usize;
            for (g, _) in ds.graphs.iter().zip(&ds.labels).filter(|(_, &l)| l == class) {
                for _ in 0..300 {
                    let u = rng.usize(g.v());
                    let ns = g.neighbors(u);
                    if ns.len() < 2 {
                        continue;
                    }
                    let a = *rng.choose(&ns);
                    let b = *rng.choose(&ns);
                    if a != b {
                        total += 1;
                        hits += g.has_edge(a, b) as usize;
                    }
                }
            }
            hits as f64 / total.max(1) as f64
        };
        let (t0, t1) = (tri_rate(0), tri_rate(1));
        assert!(t1 > t0 + 0.1, "clustering not separated: {t0} vs {t1}");
    }
}
