//! Reddit-Binary-like synthetic thread graphs (substitution for the real
//! Reddit-Binary dataset, DESIGN.md §2).
//!
//! Reddit-Binary (Yanardag & Vishwanathan 2015) models discussion threads:
//! nodes are users, edges are replies; the task is discriminating
//! Q&A-style subreddits (a few experts answer many askers — star-heavy
//! graphs) from discussion-style subreddits (long back-and-forth chains —
//! deeper trees). We emulate both with preferential attachment whose
//! exponent controls hub formation:
//!
//!   class 1 (Q&A)        : attach ~ deg^1.4  -> a few dominant hubs
//!   class 0 (discussion) : attach ~ deg^0.4  -> chain-ier, flatter trees
//!
//! plus a small number of extra random reply edges. Sizes are uniform in
//! [v_min, v_max] for both classes; mean degree is ~2 (trees + extras) in
//! both, so classes again differ only in structure.

use crate::data::Dataset;
use crate::graph::{AnyGraph, CsrGraph};
use crate::util::Rng;

/// Configuration for the Reddit-like generator.
#[derive(Clone, Debug)]
pub struct RedditLikeConfig {
    pub v_min: usize,
    pub v_max: usize,
    /// Preferential-attachment exponent per class: [class0, class1].
    pub pa_exponent: [f64; 2],
    /// Extra random edges as a fraction of v.
    pub extra_edge_frac: f64,
    /// Graphs per class.
    pub per_class: usize,
}

impl Default for RedditLikeConfig {
    fn default() -> Self {
        RedditLikeConfig {
            v_min: 50,
            v_max: 300,
            // Close enough that accuracy lands off the ceiling (the real
            // Reddit-Binary sits near ~78-90% for these methods).
            pa_exponent: [0.8, 1.3],
            extra_edge_frac: 0.05,
            per_class: 400, // 800 total ~ Reddit-Binary's 2000, scaled
        }
    }
}

impl RedditLikeConfig {
    /// One preferential-attachment tree with exponent alpha + extra edges.
    pub fn sample_graph(&self, class: u8, rng: &mut Rng) -> AnyGraph {
        let v = self.v_min + rng.usize(self.v_max - self.v_min + 1);
        let alpha = self.pa_exponent[class as usize];
        let mut degrees = vec![0u32; v];
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(v + v / 10);
        // Node t attaches to one previous node with prob ~ (deg + 1)^alpha.
        // Linear scan with cumulative weights: v <= ~300 keeps this cheap.
        let mut weights = vec![0.0f64; v];
        for t in 1..v {
            let mut total = 0.0;
            for i in 0..t {
                let w = ((degrees[i] + 1) as f64).powf(alpha);
                weights[i] = w;
                total += w;
            }
            let mut pick = rng.f64() * total;
            let mut target = t - 1;
            for i in 0..t {
                pick -= weights[i];
                if pick <= 0.0 {
                    target = i;
                    break;
                }
            }
            edges.push((t, target));
            degrees[t] += 1;
            degrees[target] += 1;
        }
        // Extra reply edges between random existing users.
        let extras = ((v as f64) * self.extra_edge_frac) as usize;
        for _ in 0..extras {
            let a = rng.usize(v);
            let b = rng.usize(v);
            if a != b {
                edges.push((a, b));
            }
        }
        AnyGraph::Csr(CsrGraph::from_edges(v, &edges))
    }

    pub fn generate(&self, rng: &mut Rng) -> Dataset {
        let mut graphs = Vec::with_capacity(2 * self.per_class);
        let mut labels = Vec::with_capacity(2 * self.per_class);
        for i in 0..(2 * self.per_class) {
            let class = (i % 2) as u8;
            graphs.push(self.sample_graph(class, rng));
            labels.push(class);
        }
        Dataset::new("reddit_like", graphs, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graphs_are_connected_trees_plus_extras() {
        let cfg = RedditLikeConfig { per_class: 10, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(1));
        for g in &ds.graphs {
            // Tree has v-1 edges; extras can only add (duplicates drop).
            assert!(g.num_edges() >= g.v() - 1);
            assert!(g.num_edges() <= g.v() - 1 + g.v() / 10);
        }
    }

    #[test]
    fn qa_class_has_bigger_hubs() {
        let cfg = RedditLikeConfig { per_class: 30, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(2));
        let max_deg_frac = |class: u8| {
            let xs: Vec<f64> = ds
                .graphs
                .iter()
                .zip(&ds.labels)
                .filter(|(_, &l)| l == class)
                .map(|(g, _)| {
                    let md = (0..g.v()).map(|u| g.degree(u)).max().unwrap();
                    md as f64 / g.v() as f64
                })
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let (h0, h1) = (max_deg_frac(0), max_deg_frac(1));
        assert!(h1 > h0 * 1.5, "hub separation failed: {h0} vs {h1}");
    }

    #[test]
    fn sizes_in_range_and_balanced() {
        let cfg = RedditLikeConfig { per_class: 15, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(3));
        assert_eq!(ds.len(), 30);
        for g in &ds.graphs {
            assert!(g.v() >= cfg.v_min && g.v() <= cfg.v_max);
        }
        assert_eq!(ds.labels.iter().filter(|&&l| l == 1).count(), 15);
    }
}
