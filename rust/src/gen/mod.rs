//! Synthetic dataset generators.
//!
//! - [`sbm`]: the paper's controlled setting (§4.1): stochastic block
//!   model graphs, 60 nodes, 6 communities, equal expected degree across
//!   classes, inter-class similarity parameter `r`.
//! - [`dd_like`] / [`reddit_like`]: structure-matched substitutes for the
//!   D&D and Reddit-Binary datasets (DESIGN.md §2 documents the
//!   substitution; real data drops in through `data::tu`).

pub mod dd_like;
pub mod reddit_like;
pub mod sbm;

pub use dd_like::DdLikeConfig;
pub use reddit_like::RedditLikeConfig;
pub use sbm::SbmConfig;
