//! Stochastic block model generator (paper §4.1).
//!
//! Each graph: `v = 60` nodes split equally into 6 communities. Two
//! classes {0, 1}; class `c` has edge probability `p_in(c)` within a
//! community and `p_out(c)` across. The pairs are chosen so both classes
//! have the same expected degree (10), removing average degree as a
//! trivial discriminant. One degree of freedom remains: `p_in(1)` is
//! fixed at 0.3 and `r = p_in(1) / p_in(0)` controls class similarity —
//! `r -> 1` makes the classes indistinguishable.

use crate::data::Dataset;
use crate::graph::{AnyGraph, DenseGraph};
use crate::util::Rng;

/// Configuration for one SBM dataset (defaults match the paper).
#[derive(Clone, Debug)]
pub struct SbmConfig {
    /// Nodes per graph.
    pub v: usize,
    /// Number of communities (must divide `v`).
    pub communities: usize,
    /// Expected node degree in both classes.
    pub expected_degree: f64,
    /// Within-community edge probability of class 1.
    pub p_in_1: f64,
    /// Inter-class similarity: `r = p_in(1) / p_in(0)`.
    pub r: f64,
    /// Graphs per class.
    pub per_class: usize,
}

impl Default for SbmConfig {
    fn default() -> Self {
        SbmConfig {
            v: 60,
            communities: 6,
            expected_degree: 10.0,
            p_in_1: 0.3,
            r: 1.1,
            per_class: 150,
        }
    }
}

impl SbmConfig {
    /// (p_in, p_out) for class `c`, solving
    /// `(v/comm - 1) * p_in + (v - v/comm) * p_out = expected_degree`.
    pub fn edge_probs(&self, class: u8) -> (f64, f64) {
        let p_in = match class {
            1 => self.p_in_1,
            0 => self.p_in_1 / self.r,
            _ => panic!("binary classes only"),
        };
        let c = self.v / self.communities;
        let within = (c - 1) as f64;
        let across = (self.v - c) as f64;
        let p_out = (self.expected_degree - within * p_in) / across;
        assert!(
            (0.0..=1.0).contains(&p_out),
            "infeasible SBM: p_in={p_in} gives p_out={p_out}"
        );
        (p_in, p_out)
    }

    /// Sample one graph of the given class.
    pub fn sample_graph(&self, class: u8, rng: &mut Rng) -> AnyGraph {
        let (p_in, p_out) = self.edge_probs(class);
        let c = self.v / self.communities;
        let mut g = DenseGraph::new(self.v);
        for a in 0..self.v {
            for b in (a + 1)..self.v {
                let p = if a / c == b / c { p_in } else { p_out };
                if rng.bool(p) {
                    g.add_edge(a, b);
                }
            }
        }
        AnyGraph::Dense(g)
    }

    /// Generate the full labelled dataset (balanced, interleaved labels).
    pub fn generate(&self, rng: &mut Rng) -> Dataset {
        assert_eq!(self.v % self.communities, 0, "communities must divide v");
        let mut graphs = Vec::with_capacity(2 * self.per_class);
        let mut labels = Vec::with_capacity(2 * self.per_class);
        for i in 0..(2 * self.per_class) {
            let class = (i % 2) as u8;
            graphs.push(self.sample_graph(class, rng));
            labels.push(class);
        }
        Dataset::new(format!("sbm_r{:.3}", self.r), graphs, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_share_expected_degree() {
        let cfg = SbmConfig::default();
        for class in [0u8, 1] {
            let (p_in, p_out) = cfg.edge_probs(class);
            let c = cfg.v / cfg.communities;
            let deg = (c - 1) as f64 * p_in + (cfg.v - c) as f64 * p_out;
            assert!((deg - cfg.expected_degree).abs() < 1e-9, "class {class}");
        }
    }

    #[test]
    fn r_controls_similarity() {
        let mut cfg = SbmConfig::default();
        cfg.r = 1.0;
        let (pi0, po0) = cfg.edge_probs(0);
        let (pi1, po1) = cfg.edge_probs(1);
        assert!((pi0 - pi1).abs() < 1e-12 && (po0 - po1).abs() < 1e-12);
        cfg.r = 2.0;
        let (pi0, _) = cfg.edge_probs(0);
        assert!((pi0 - 0.15).abs() < 1e-12);
    }

    #[test]
    fn empirical_degree_matches() {
        let cfg = SbmConfig { per_class: 6, ..Default::default() };
        let mut rng = Rng::new(1);
        let ds = cfg.generate(&mut rng);
        for class in [0u8, 1] {
            let degs: Vec<f64> = ds
                .graphs
                .iter()
                .zip(&ds.labels)
                .filter(|(_, &l)| l == class)
                .map(|(g, _)| g.mean_degree())
                .collect();
            let mean = degs.iter().sum::<f64>() / degs.len() as f64;
            assert!((mean - 10.0).abs() < 1.2, "class {class}: {mean}");
        }
    }

    #[test]
    fn dataset_is_balanced_and_sized() {
        let cfg = SbmConfig { per_class: 10, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(2));
        assert_eq!(ds.len(), 20);
        assert_eq!(ds.labels.iter().filter(|&&l| l == 0).count(), 10);
        assert!(ds.graphs.iter().all(|g| g.v() == 60));
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SbmConfig { per_class: 3, ..Default::default() };
        let a = cfg.generate(&mut Rng::new(7));
        let b = cfg.generate(&mut Rng::new(7));
        for (ga, gb) in a.graphs.iter().zip(&b.graphs) {
            assert_eq!(ga.num_edges(), gb.num_edges());
        }
    }
}
