//! The classical graphlet kernel baseline (`GSA-phi_match`).
//!
//! Computes the sampled k-spectrum of each graph (eq. 2): a histogram
//! over isomorphism classes of s subgraphs drawn from `S_k(G)`, folded
//! via the canonical-form registry. The kernel between graphs is the dot
//! product of spectra; classification uses the same linear-classifier
//! tail as GSA-phi so comparisons isolate the feature map.

use crate::data::Dataset;
use crate::graph::AnyGraph;
use crate::iso::GraphletRegistry;
use crate::sample::GraphletSampler;
use crate::util::Rng;

/// Sampled k-spectrum of one graph: sparse counts over registry classes.
pub fn k_spectrum(
    g: &AnyGraph,
    k: usize,
    s: usize,
    sampler: &dyn GraphletSampler,
    reg: &mut GraphletRegistry,
    rng: &mut Rng,
) -> Vec<(u32, f32)> {
    let mut counts: std::collections::HashMap<u32, u32> = Default::default();
    let mut scratch = Vec::with_capacity(k);
    for _ in 0..s {
        let gl = sampler.sample(g, k, rng, &mut scratch);
        *counts.entry(reg.classify(&gl)).or_insert(0) += 1;
    }
    let mut out: Vec<(u32, f32)> = counts
        .into_iter()
        .map(|(idx, c)| (idx, c as f32 / s as f32))
        .collect();
    out.sort_unstable_by_key(|&(idx, _)| idx);
    out
}

/// All spectra of a dataset, densified to the final registry size.
/// Returns (row-major embeddings (n, dim), dim).
pub fn dataset_spectra(
    ds: &Dataset,
    k: usize,
    s: usize,
    sampler: &dyn GraphletSampler,
    rng: &mut Rng,
) -> (Vec<f32>, usize) {
    let mut reg = GraphletRegistry::new();
    let sparse: Vec<Vec<(u32, f32)>> = ds
        .graphs
        .iter()
        .map(|g| k_spectrum(g, k, s, sampler, &mut reg, rng))
        .collect();
    let dim = reg.len().max(1);
    let mut dense = vec![0.0f32; ds.len() * dim];
    for (row, spec) in sparse.iter().enumerate() {
        for &(idx, v) in spec {
            dense[row * dim + idx as usize] = v;
        }
    }
    (dense, dim)
}

/// Graphlet-kernel Gram matrix (dot products of spectra) — the object the
/// original method feeds to a kernel SVM. Provided for completeness and
/// for tests; the classification path uses the explicit spectra.
pub fn gram(spectra: &[f32], n: usize, dim: usize) -> Vec<f64> {
    let mut g = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i..n {
            let mut acc = 0.0f64;
            let (a, b) = (&spectra[i * dim..(i + 1) * dim], &spectra[j * dim..(j + 1) * dim]);
            for (x, y) in a.iter().zip(b) {
                acc += (*x as f64) * (*y as f64);
            }
            g[i * n + j] = acc;
            g[j * n + i] = acc;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SbmConfig;
    use crate::graph::{CsrGraph, DenseGraph};
    use crate::sample::{RwSampler, UniformSampler};

    fn triangle_graph() -> AnyGraph {
        let mut g = DenseGraph::new(3);
        g.add_edge(0, 1);
        g.add_edge(1, 2);
        g.add_edge(0, 2);
        AnyGraph::Dense(g)
    }

    #[test]
    fn spectrum_sums_to_one() {
        let g = triangle_graph();
        let mut reg = GraphletRegistry::new();
        let mut rng = Rng::new(1);
        let spec = k_spectrum(&g, 3, 500, &UniformSampler, &mut reg, &mut rng);
        let total: f32 = spec.iter().map(|&(_, v)| v).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn triangle_spectrum_is_pure() {
        // K3's only 3-subgraph is the triangle itself.
        let g = triangle_graph();
        let mut reg = GraphletRegistry::new();
        let mut rng = Rng::new(2);
        let spec = k_spectrum(&g, 3, 100, &UniformSampler, &mut reg, &mut rng);
        assert_eq!(spec.len(), 1);
        assert_eq!(spec[0].1, 1.0);
    }

    #[test]
    fn ring_vs_clique_spectra_differ() {
        let ring: Vec<(usize, usize)> = (0..12).map(|i| (i, (i + 1) % 12)).collect();
        let ring = AnyGraph::Csr(CsrGraph::from_edges(12, &ring));
        let mut clique = DenseGraph::new(12);
        for a in 0..12 {
            for b in (a + 1)..12 {
                clique.add_edge(a, b);
            }
        }
        let ds = Dataset::new(
            "rc",
            vec![ring, AnyGraph::Dense(clique)],
            vec![0, 1],
        );
        let mut rng = Rng::new(3);
        let (spectra, dim) = dataset_spectra(&ds, 4, 400, &RwSampler::default(), &mut rng);
        let a = &spectra[..dim];
        let b = &spectra[dim..];
        let dist: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(dist > 0.5, "spectra too close: {dist}");
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let cfg = SbmConfig { per_class: 3, ..Default::default() };
        let ds = cfg.generate(&mut Rng::new(4));
        let mut rng = Rng::new(5);
        let (spectra, dim) = dataset_spectra(&ds, 3, 200, &UniformSampler, &mut rng);
        let g = gram(&spectra, ds.len(), dim);
        let n = ds.len();
        for i in 0..n {
            assert!(g[i * n + i] > 0.0);
            for j in 0..n {
                assert_eq!(g[i * n + j], g[j * n + i]);
                // Cauchy-Schwarz.
                assert!(
                    g[i * n + j] * g[i * n + j] <= g[i * n + i] * g[j * n + j] * (1.0 + 1e-9)
                );
            }
        }
    }

    /// Density-separable classes (ER p=0.08 vs p=0.25): the k-spectra
    /// must separate them cleanly. (The paper's equal-degree SBM is
    /// deliberately HARD for phi_match — per-realization histogram noise
    /// rivals the class signal, which is exactly why GSA-phi_OPU beats
    /// the graphlet kernel in Fig 1 right — so machinery tests use a
    /// strongly-separable task instead.)
    fn density_dataset(per_class: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut graphs = Vec::new();
        let mut labels = Vec::new();
        for i in 0..2 * per_class {
            let class = (i % 2) as u8;
            let p = if class == 0 { 0.08 } else { 0.25 };
            let mut g = DenseGraph::new(40);
            for a in 0..40 {
                for b in (a + 1)..40 {
                    if rng.bool(p) {
                        g.add_edge(a, b);
                    }
                }
            }
            graphs.push(AnyGraph::Dense(g));
            labels.push(class);
        }
        Dataset::new("density", graphs, labels)
    }

    #[test]
    fn spectra_discriminate_density_classes() {
        let ds = density_dataset(6, 6);
        let mut rng = Rng::new(7);
        let (spectra, dim) = dataset_spectra(&ds, 4, 1500, &RwSampler::default(), &mut rng);
        let dist = |i: usize, j: usize| -> f32 {
            (0..dim)
                .map(|c| {
                    let d = spectra[i * dim + c] - spectra[j * dim + c];
                    d * d
                })
                .sum()
        };
        let (mut within, mut across, mut nw, mut na) = (0.0f32, 0.0f32, 0, 0);
        for i in 0..ds.len() {
            for j in (i + 1)..ds.len() {
                if ds.labels[i] == ds.labels[j] {
                    within += dist(i, j);
                    nw += 1;
                } else {
                    across += dist(i, j);
                    na += 1;
                }
            }
        }
        let (within, across) = (within / nw as f32, across / na as f32);
        assert!(across > within * 1.5, "within={within} across={across}");
    }
}
