//! Labelled graph datasets: container, splits, and the TU-format parser.
//!
//! The TU parser reads the standard benchmark layout (Morris et al. 2020)
//! so the real D&D / REDDIT-BINARY data can be dropped in when available;
//! the synthetic substitutes in [`crate::gen`] produce the same `Dataset`
//! type, so everything downstream is agnostic.
//!
//! Expected on-disk layout for `--data-dir DIR` (quickstart and fig3):
//! one directory per dataset, holding the unzipped TU files named after
//! the dataset — for D&D (`--dataset dd`, files `DD_*`) and
//! REDDIT-BINARY (`--dataset reddit`, files `REDDIT-BINARY_*`; the
//! short CLI names map onto the archive prefixes via [`tu_name`], and a
//! verbatim TU prefix like `--dataset PROTEINS` also works; archives
//! from <https://chrsmrrs.github.io/datasets/>):
//!
//! ```text
//!  DIR/
//!    DD_A.txt                 edge list, "a, b" per line, 1-based
//!                             global node ids, both directions listed
//!    DD_graph_indicator.txt   line n = graph id (1-based) of node n;
//!                             node blocks contiguous per graph
//!    DD_graph_labels.txt      line g = class label of graph g (any two
//!                             distinct integers; normalized to {0,1})
//! ```
//!
//! Optional TU files (`*_node_labels.txt`, `*_edge_labels.txt`,
//! `*_graph_attributes.txt`, …) are ignored: the graphlet pipeline is
//! structure-only. Malformed input fails with a contextual `Err` (see
//! [`load_tu_dataset`]), so a bad drop-in is a readable CLI error, not
//! a panic.

use std::io::BufRead;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::{AnyGraph, CsrGraph};
use crate::util::Rng;

/// A labelled graph-classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graphs: Vec<AnyGraph>,
    /// Binary class labels (0 / 1).
    pub labels: Vec<u8>,
}

/// Index-based train/test split of a [`Dataset`].
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Vec<usize>,
    pub test: Vec<usize>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, graphs: Vec<AnyGraph>, labels: Vec<u8>) -> Self {
        assert_eq!(graphs.len(), labels.len());
        Dataset { name: name.into(), graphs, labels }
    }

    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }

    /// Stratified shuffled split: `train_frac` of each class to train.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> Split {
        let mut by_class: [Vec<usize>; 2] = [Vec::new(), Vec::new()];
        for (i, &l) in self.labels.iter().enumerate() {
            by_class[l as usize].push(i);
        }
        let mut train = Vec::new();
        let mut test = Vec::new();
        for idxs in by_class.iter_mut() {
            rng.shuffle(idxs);
            let n_train = (idxs.len() as f64 * train_frac).round() as usize;
            train.extend_from_slice(&idxs[..n_train]);
            test.extend_from_slice(&idxs[n_train..]);
        }
        rng.shuffle(&mut train);
        rng.shuffle(&mut test);
        Split { train, test }
    }

    /// Summary line for logs: size, class balance, mean |V| and degree.
    pub fn summary(&self) -> String {
        let n1 = self.labels.iter().filter(|&&l| l == 1).count();
        let mean_v =
            self.graphs.iter().map(|g| g.v() as f64).sum::<f64>() / self.len().max(1) as f64;
        let mean_deg =
            self.graphs.iter().map(|g| g.mean_degree()).sum::<f64>() / self.len().max(1) as f64;
        format!(
            "{}: n={} (class1: {}), mean|V|={:.1}, mean deg={:.2}",
            self.name,
            self.len(),
            n1,
            mean_v,
            mean_deg
        )
    }
}

/// Map the CLI's short dataset names onto the canonical TU archive
/// prefixes (`--dataset dd` → files `DD_*.txt`, `--dataset reddit` →
/// `REDDIT-BINARY_*.txt`), so the same `--dataset` value selects the
/// synthetic substitute *and* the real drop-in under `--data-dir`. Any
/// other name is taken to already be a TU prefix and passes through.
pub fn tu_name(name: &str) -> &str {
    match name {
        "dd" => "DD",
        "reddit" => "REDDIT-BINARY",
        other => other,
    }
}

/// Parse a TU-format dataset directory: `<name>_A.txt` (edge list,
/// 1-based node ids), `<name>_graph_indicator.txt` (node -> graph id),
/// `<name>_graph_labels.txt` (graph -> class). Binary labels are
/// normalized to {0, 1} by mapping the smallest label to 0.
///
/// Malformed input — non-numeric lines, 0-based ids (the format is
/// 1-based), label/graph count mismatches, out-of-range edge
/// endpoints, graph ids with no nodes — returns an `Err` with context
/// naming the offending file/line; it never panics, so a bad dataset
/// drop-in fails the CLI gracefully.
pub fn load_tu_dataset(dir: &Path, name: &str) -> Result<Dataset> {
    let read_lines = |suffix: &str| -> Result<Vec<String>> {
        let path = dir.join(format!("{name}_{suffix}.txt"));
        let f = std::fs::File::open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        Ok(std::io::BufReader::new(f)
            .lines()
            .collect::<std::io::Result<Vec<_>>>()?)
    };

    let indicator: Vec<usize> = read_lines("graph_indicator")?
        .iter()
        .filter(|l| !l.trim().is_empty())
        .enumerate()
        .map(|(i, l)| {
            l.trim()
                .parse::<usize>()
                .with_context(|| format!("graph_indicator line {}: {:?}", i + 1, l.trim()))
        })
        .collect::<Result<_>>()?;
    if indicator.is_empty() {
        bail!("empty graph_indicator");
    }
    let n_nodes = indicator.len();
    let n_graphs = *indicator.iter().max().unwrap();
    if indicator.contains(&0) {
        bail!("graph_indicator contains graph id 0: TU graph ids are 1-based");
    }
    if n_graphs > n_nodes {
        bail!("graph_indicator names graph {n_graphs} but the file has only {n_nodes} nodes");
    }
    // TU node blocks are contiguous per graph (the format lists each
    // graph's nodes consecutively). An interleaved indicator would make
    // the per-graph (first_node, count) ranges below silently wrong —
    // edges would map to bogus local indices — so reject it up front.
    if indicator.windows(2).any(|w| w[1] < w[0]) {
        bail!("graph_indicator is not sorted: TU node blocks must be contiguous per graph");
    }

    let raw_labels: Vec<i64> = read_lines("graph_labels")?
        .iter()
        .filter(|l| !l.trim().is_empty())
        .enumerate()
        .map(|(i, l)| {
            l.trim()
                .parse::<i64>()
                .with_context(|| format!("graph_labels line {}: {:?}", i + 1, l.trim()))
        })
        .collect::<Result<_>>()?;
    if raw_labels.len() != n_graphs {
        bail!("label count {} != graph count {}", raw_labels.len(), n_graphs);
    }
    // Normalize arbitrary binary label values (e.g. {-1, 1} or {1, 2})
    // to {0, 1} by rank.
    let mut distinct: Vec<i64> = raw_labels.to_vec();
    distinct.sort_unstable();
    distinct.dedup();
    if distinct.len() > 2 {
        bail!("only binary labels supported, saw {} classes", distinct.len());
    }
    let labels: Vec<u8> = raw_labels
        .iter()
        .map(|l| distinct.binary_search(l).unwrap() as u8)
        .collect();

    // Per-graph node ranges (TU node ids are 1-based and contiguous).
    let mut node_graph = vec![0usize; n_nodes];
    let mut first_node = vec![usize::MAX; n_graphs];
    let mut node_counts = vec![0usize; n_graphs];
    for (node, &gid) in indicator.iter().enumerate() {
        let g = gid - 1;
        node_graph[node] = g;
        first_node[g] = first_node[g].min(node);
        node_counts[g] += 1;
    }
    // Every graph id in 1..=n_graphs must own at least one node: an
    // empty-graph row has no node range, cannot carry edges, and makes
    // the label column ambiguous — reject rather than fabricate a
    // 0-node graph.
    for (g, &count) in node_counts.iter().enumerate() {
        if count == 0 {
            bail!("graph {} has no nodes in graph_indicator", g + 1);
        }
    }

    let mut edge_lists: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n_graphs];
    for line in read_lines("A")? {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (a, b) = line
            .split_once(',')
            .with_context(|| format!("bad edge line {line:?}"))?;
        let a: usize = a
            .trim()
            .parse()
            .with_context(|| format!("edge endpoint in line {line:?}"))?;
        let b: usize = b
            .trim()
            .parse()
            .with_context(|| format!("edge endpoint in line {line:?}"))?;
        if a == 0 || b == 0 {
            bail!("edge line {line:?} uses node id 0: TU node ids are 1-based (0-based input?)");
        }
        if a > n_nodes || b > n_nodes {
            bail!("edge line {line:?} references node beyond the {n_nodes} in graph_indicator");
        }
        let (a, b) = (a - 1, b - 1);
        let g = node_graph[a];
        if node_graph[b] != g {
            bail!("edge {a}-{b} crosses graphs");
        }
        edge_lists[g].push((a - first_node[g], b - first_node[g]));
    }

    let graphs: Vec<AnyGraph> = edge_lists
        .iter()
        .zip(&node_counts)
        .map(|(edges, &v)| AnyGraph::Csr(CsrGraph::from_edges(v, edges)))
        .collect();

    Ok(Dataset::new(name.to_string(), graphs, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DenseGraph;

    fn tiny_dataset(n: usize) -> Dataset {
        let graphs: Vec<AnyGraph> = (0..n)
            .map(|i| {
                let mut g = DenseGraph::new(4);
                g.add_edge(0, 1);
                if i % 2 == 1 {
                    g.add_edge(2, 3);
                }
                AnyGraph::Dense(g)
            })
            .collect();
        let labels = (0..n).map(|i| (i % 2) as u8).collect();
        Dataset::new("tiny", graphs, labels)
    }

    #[test]
    fn split_is_stratified_partition() {
        let ds = tiny_dataset(40);
        let mut rng = Rng::new(1);
        let split = ds.split(0.8, &mut rng);
        assert_eq!(split.train.len(), 32);
        assert_eq!(split.test.len(), 8);
        let mut all: Vec<usize> = split.train.iter().chain(&split.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<_>>());
        // Stratified: half of each side is class 1.
        let c1 = split.train.iter().filter(|&&i| ds.labels[i] == 1).count();
        assert_eq!(c1, 16);
    }

    #[test]
    fn tu_parser_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tu_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Two graphs: a triangle (nodes 1..3) and an edge (nodes 4..5).
        std::fs::write(
            dir.join("toy_A.txt"),
            "1, 2\n2, 1\n2, 3\n3, 2\n1, 3\n3, 1\n4, 5\n5, 4\n",
        )
        .unwrap();
        std::fs::write(dir.join("toy_graph_indicator.txt"), "1\n1\n1\n2\n2\n").unwrap();
        std::fs::write(dir.join("toy_graph_labels.txt"), "-1\n1\n").unwrap();
        let ds = load_tu_dataset(&dir, "toy").unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.labels, vec![0, 1]);
        assert_eq!(ds.graphs[0].v(), 3);
        assert_eq!(ds.graphs[0].num_edges(), 3);
        assert_eq!(ds.graphs[1].v(), 2);
        assert_eq!(ds.graphs[1].num_edges(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tu_parser_rejects_cross_graph_edges() {
        let dir = std::env::temp_dir().join(format!("tu_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad_A.txt"), "1, 3\n").unwrap();
        std::fs::write(dir.join("bad_graph_indicator.txt"), "1\n1\n2\n").unwrap();
        std::fs::write(dir.join("bad_graph_labels.txt"), "0\n1\n").unwrap();
        assert!(load_tu_dataset(&dir, "bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Write a TU triplet into a fresh temp dir, parse it, return the
    /// error string (the malformed-input tests all expect `Err`).
    fn tu_error(tag: &str, a: &str, indicator: &str, labels: &str) -> String {
        let dir = std::env::temp_dir().join(format!("tu_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t_A.txt"), a).unwrap();
        std::fs::write(dir.join("t_graph_indicator.txt"), indicator).unwrap();
        std::fs::write(dir.join("t_graph_labels.txt"), labels).unwrap();
        let err = match load_tu_dataset(&dir, "t") {
            Ok(_) => panic!("malformed TU input {tag:?} parsed successfully"),
            // Render the whole context chain so asserts can match any
            // level of it.
            Err(e) => format!("{e:#}"),
        };
        std::fs::remove_dir_all(&dir).ok();
        err
    }

    #[test]
    fn tu_parser_rejects_non_numeric_indicator() {
        let err = tu_error("nonnum", "1, 2\n2, 1\n", "1\nbanana\n", "0\n");
        assert!(err.contains("graph_indicator"), "{err}");
        assert!(err.contains("banana"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_zero_based_graph_ids() {
        // A 0 graph id means the file is 0-based; subtracting 1 must
        // not underflow-panic.
        let err = tu_error("gid0", "1, 2\n2, 1\n", "0\n0\n1\n", "0\n1\n");
        assert!(err.contains("1-based"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_label_count_mismatch() {
        let err = tu_error("labels", "1, 2\n2, 1\n", "1\n1\n2\n2\n", "0\n1\n1\n");
        assert!(err.contains("label count 3 != graph count 2"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_zero_based_edges() {
        // 0-based edge endpoints: must be a contextual error, not an
        // index underflow/out-of-bounds panic.
        let err = tu_error("edge0", "0, 1\n", "1\n1\n", "0\n");
        assert!(err.contains("1-based"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_edge_beyond_node_count() {
        let err = tu_error("edgebig", "1, 99\n", "1\n1\n", "0\n");
        assert!(err.contains("beyond"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_non_numeric_edges_and_labels() {
        let err = tu_error("edgetxt", "1, two\n", "1\n1\n", "0\n");
        assert!(err.contains("edge endpoint"), "{err}");
        let err = tu_error("edgecomma", "1 2\n", "1\n1\n", "0\n");
        assert!(err.contains("bad edge line"), "{err}");
        let err = tu_error("labeltxt", "1, 2\n2, 1\n", "1\n1\n", "x\n");
        assert!(err.contains("graph_labels"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_empty_graph_rows() {
        // Graph 2 is named by graph_labels/indicator max (graph 3) but
        // owns no nodes: an empty-graph row must be an error, not a
        // fabricated 0-node graph.
        let err = tu_error("gap", "1, 2\n2, 1\n", "1\n1\n3\n", "0\n1\n1\n");
        assert!(err.contains("graph 2 has no nodes"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_interleaved_graph_blocks() {
        // Graph 1 owns nodes 1 and 3 with graph 2's node between them:
        // the per-graph contiguous ranges would be wrong, so this must
        // be an Err — not an Ok with silently mis-mapped edges.
        let err = tu_error("interleave", "1, 3\n3, 1\n", "1\n2\n1\n", "0\n1\n");
        assert!(err.contains("contiguous"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_indicator_graph_id_beyond_node_count() {
        // A wild graph id (e.g. a stray huge number) must error before
        // any per-graph allocation happens.
        let err = tu_error("wildgid", "1, 2\n2, 1\n", "1\n999999\n", "0\n1\n");
        assert!(err.contains("only"), "{err}");
    }

    #[test]
    fn tu_parser_rejects_missing_file() {
        let dir = std::env::temp_dir().join(format!("tu_missing_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = format!("{:#}", load_tu_dataset(&dir, "ghost").unwrap_err());
        assert!(err.contains("opening"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The CLI's `--dataset dd|reddit` names must reach the parser as
    /// the real archives' file prefixes; true TU prefixes pass through.
    #[test]
    fn tu_name_maps_cli_names_to_archive_prefixes() {
        assert_eq!(tu_name("dd"), "DD");
        assert_eq!(tu_name("reddit"), "REDDIT-BINARY");
        assert_eq!(tu_name("DD"), "DD");
        assert_eq!(tu_name("REDDIT-BINARY"), "REDDIT-BINARY");
        assert_eq!(tu_name("PROTEINS"), "PROTEINS");
    }

    #[test]
    fn summary_contains_counts() {
        let ds = tiny_dataset(10);
        let s = ds.summary();
        assert!(s.contains("n=10"), "{s}");
        assert!(s.contains("class1: 5"), "{s}");
    }
}
