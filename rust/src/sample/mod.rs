//! Graphlet samplers: the `S_k(G)` distributions of the paper (§2.2).
//!
//! A sampler draws a size-k node subset from a host graph and returns the
//! induced [`Graphlet`]. Two strategies from the paper:
//!
//! - [`UniformSampler`] (`S^unif`): k nodes uniformly without replacement.
//!   Its expectation over `phi_match` IS the classical graphlet kernel
//!   k-spectrum (eq. 1). On sparse graphs most draws are nearly empty
//!   graphlets, which is why…
//! - [`RwSampler`]: a random walk collects k distinct nodes (restarting on
//!   dead ends), biasing towards *connected* subgraphs — the better
//!   performing sampler in Fig. 1 (right).

use crate::graph::{AnyGraph, Graphlet};
use crate::util::Rng;

/// A subgraph sampling process `S_k(G)`.
pub trait GraphletSampler {
    /// Draw one induced size-k subgraph. `scratch` avoids re-allocating
    /// the node buffer in the hot loop.
    fn sample(&self, g: &AnyGraph, k: usize, rng: &mut Rng, scratch: &mut Vec<usize>) -> Graphlet;

    /// Human-readable name (logs, manifests, result files).
    fn name(&self) -> &'static str;
}

/// Uniform k-subset sampling (the classical graphlet-kernel sampler).
#[derive(Clone, Copy, Debug, Default)]
pub struct UniformSampler;

impl GraphletSampler for UniformSampler {
    fn sample(&self, g: &AnyGraph, k: usize, rng: &mut Rng, scratch: &mut Vec<usize>) -> Graphlet {
        debug_assert!(k <= g.v(), "k={k} > v={}", g.v());
        rng.sample_distinct(g.v(), k, scratch);
        // Sorted node-id order: a deterministic, id-consistent node order
        // gives non-permutation-invariant feature maps (phi_Gs, phi_OPU)
        // a stable frame — without it every sample is an arbitrary
        // relabelling and the maps lose most class signal. phi_match is
        // unaffected (it canonicalizes anyway).
        scratch.sort_unstable();
        g.induced_graphlet(scratch)
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Random-walk sampling: walk from a uniform start node, collecting
/// distinct visited nodes until k are found. Dead ends (or slow mixing)
/// trigger a jump to a fresh uniform node, so the sampler terminates on
/// any graph, including disconnected ones.
#[derive(Clone, Copy, Debug)]
pub struct RwSampler {
    /// Walk steps allowed per collected node before jumping ( * k total).
    pub patience: usize,
}

impl Default for RwSampler {
    fn default() -> Self {
        RwSampler { patience: 16 }
    }
}

impl GraphletSampler for RwSampler {
    fn sample(&self, g: &AnyGraph, k: usize, rng: &mut Rng, scratch: &mut Vec<usize>) -> Graphlet {
        debug_assert!(k <= g.v());
        scratch.clear();
        let mut cur = rng.usize(g.v());
        scratch.push(cur);
        let mut budget = self.patience * k;
        while scratch.len() < k {
            let deg = g.degree(cur);
            if deg == 0 || budget == 0 {
                // Jump: uniform fresh node not yet collected.
                loop {
                    cur = rng.usize(g.v());
                    if !scratch.contains(&cur) {
                        break;
                    }
                }
                scratch.push(cur);
                budget = self.patience * k;
                continue;
            }
            budget -= 1;
            cur = g.nth_neighbor(cur, rng.usize(deg));
            if !scratch.contains(&cur) {
                scratch.push(cur);
            }
        }
        // Same sorted-frame convention as UniformSampler: the walk decides
        // WHICH nodes are sampled (connected subgraphs), sorted ids decide
        // the adjacency ordering the feature maps see.
        scratch.sort_unstable();
        g.induced_graphlet(scratch)
    }

    fn name(&self) -> &'static str {
        "rw"
    }
}

/// Sampler selection by name (CLI / config layer).
pub fn sampler_by_name(name: &str) -> Box<dyn GraphletSampler + Send + Sync> {
    match name {
        "uniform" => Box::new(UniformSampler),
        "rw" => Box::new(RwSampler::default()),
        other => panic!("unknown sampler {other:?} (expected uniform|rw)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CsrGraph, DenseGraph};
    use crate::util::check;

    fn ring(v: usize) -> AnyGraph {
        let edges: Vec<(usize, usize)> = (0..v).map(|i| (i, (i + 1) % v)).collect();
        AnyGraph::Csr(CsrGraph::from_edges(v, &edges))
    }

    fn dense_er(v: usize, p: f64, seed: u64) -> AnyGraph {
        let mut rng = Rng::new(seed);
        let mut g = DenseGraph::new(v);
        for a in 0..v {
            for b in (a + 1)..v {
                if rng.bool(p) {
                    g.add_edge(a, b);
                }
            }
        }
        AnyGraph::Dense(g)
    }

    #[test]
    fn uniform_sampler_induces_consistent_graphlets() {
        check::check("uniform-induce", 0xC1, 100, |rng| {
            let g = dense_er(30, 0.3, rng.next_u64());
            let k = 3 + rng.usize(5);
            let mut scratch = Vec::new();
            let gl = UniformSampler.sample(&g, k, rng, &mut scratch);
            assert_eq!(gl.k(), k);
            assert_eq!(scratch.len(), k);
            for i in 0..k {
                for j in (i + 1)..k {
                    assert_eq!(gl.has_edge(i, j), g.has_edge(scratch[i], scratch[j]));
                }
            }
        });
    }

    #[test]
    fn uniform_sampler_unbiased_on_edge_count() {
        // On ER(p), expected edges of a k-graphlet = C(k,2) * p.
        let g = dense_er(40, 0.25, 7);
        // Measure actual density first (the realized graph, not p).
        let dens = g.num_edges() as f64 / (40.0 * 39.0 / 2.0);
        let mut rng = Rng::new(8);
        let mut scratch = Vec::new();
        let k = 5;
        let trials = 20_000;
        let mean_edges: f64 = (0..trials)
            .map(|_| UniformSampler.sample(&g, k, &mut rng, &mut scratch).num_edges() as f64)
            .sum::<f64>()
            / trials as f64;
        let expect = (k * (k - 1) / 2) as f64 * dens;
        assert!((mean_edges - expect).abs() < 0.1, "{mean_edges} vs {expect}");
    }

    #[test]
    fn rw_sampler_prefers_connected_subgraphs() {
        let g = ring(60);
        let mut rng = Rng::new(3);
        let mut scratch = Vec::new();
        let k = 4;
        let trials = 2_000;
        let conn_rw = (0..trials)
            .filter(|_| RwSampler::default().sample(&g, k, &mut rng, &mut scratch).is_connected())
            .count() as f64
            / trials as f64;
        let conn_unif = (0..trials)
            .filter(|_| UniformSampler.sample(&g, k, &mut rng, &mut scratch).is_connected())
            .count() as f64
            / trials as f64;
        // On a sparse ring, uniform almost never draws connected 4-sets.
        assert!(conn_rw > 0.9, "rw connectivity {conn_rw}");
        assert!(conn_unif < 0.05, "uniform connectivity {conn_unif}");
    }

    #[test]
    fn rw_sampler_terminates_on_disconnected_graphs() {
        // Two components + isolated nodes; the jump logic must kick in.
        let edges = vec![(0, 1), (1, 2), (3, 4)];
        let g = AnyGraph::Csr(CsrGraph::from_edges(8, &edges));
        let mut rng = Rng::new(4);
        let mut scratch = Vec::new();
        for _ in 0..200 {
            let gl = RwSampler::default().sample(&g, 5, &mut rng, &mut scratch);
            assert_eq!(gl.k(), 5);
            let mut sorted = scratch.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "distinct nodes");
        }
    }

    #[test]
    fn rw_sampler_covers_whole_graph() {
        let g = ring(20);
        let mut rng = Rng::new(5);
        let mut scratch = Vec::new();
        let mut seen = vec![false; 20];
        for _ in 0..2_000 {
            RwSampler::default().sample(&g, 3, &mut rng, &mut scratch);
            for &n in scratch.iter() {
                seen[n] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all nodes reachable by sampling");
    }

    #[test]
    fn samplers_deterministic_under_fixed_seed() {
        // Same seed -> identical graphlet stream, for both strategies.
        // This is the invariant the pipeline's per-graph seeding (and
        // therefore its bitwise shard/worker independence) rests on.
        let g = dense_er(40, 0.2, 17);
        for name in ["uniform", "rw"] {
            let sampler = sampler_by_name(name);
            let mut rng_a = Rng::new(0xDECADE);
            let mut rng_b = Rng::new(0xDECADE);
            let mut scratch_a = Vec::new();
            let mut scratch_b = Vec::new();
            for i in 0..200 {
                let ga = sampler.sample(&g, 5, &mut rng_a, &mut scratch_a);
                let gb = sampler.sample(&g, 5, &mut rng_b, &mut scratch_b);
                assert_eq!(ga, gb, "{name} diverged at draw {i}");
                assert_eq!(scratch_a, scratch_b, "{name} node sets diverged at draw {i}");
            }
            // And a different seed must give a different stream.
            let mut rng_c = Rng::new(0xDEC0DE);
            let mut scratch_c = Vec::new();
            let diverged = (0..50).any(|_| {
                let gc = sampler.sample(&g, 5, &mut rng_c, &mut scratch_c);
                let ga = sampler.sample(&g, 5, &mut rng_a, &mut scratch_a);
                gc != ga
            });
            assert!(diverged, "{name}: different seeds produced identical streams");
        }
    }

    #[test]
    fn samplers_handle_k_equals_v() {
        // k == v is the boundary the samplers advertise (`k <= v`): both
        // must return the full graph as the induced graphlet.
        let g = dense_er(7, 0.35, 5);
        for name in ["uniform", "rw"] {
            let sampler = sampler_by_name(name);
            let mut rng = Rng::new(3);
            let mut scratch = Vec::new();
            for _ in 0..50 {
                let gl = sampler.sample(&g, 7, &mut rng, &mut scratch);
                assert_eq!(gl.k(), 7);
                let mut nodes = scratch.clone();
                nodes.sort_unstable();
                nodes.dedup();
                assert_eq!(nodes, (0..7).collect::<Vec<_>>(), "{name} must use every node");
                assert_eq!(gl.num_edges() as usize, g.num_edges(), "{name}");
            }
        }
    }

    #[test]
    fn samplers_handle_k_equals_one() {
        let g = ring(9);
        for name in ["uniform", "rw"] {
            let sampler = sampler_by_name(name);
            let mut rng = Rng::new(4);
            let mut scratch = Vec::new();
            let gl = sampler.sample(&g, 1, &mut rng, &mut scratch);
            assert_eq!(gl.k(), 1);
            assert_eq!(gl.num_edges(), 0);
            assert_eq!(scratch.len(), 1);
        }
    }

    #[test]
    fn rw_beats_uniform_connectivity_on_sparse_sbm() {
        // Fig 1 (right)'s motivation, on the paper's own generator: at
        // low expected degree a uniform k-subset of an SBM graph is
        // almost never connected, while the random walk's draws mostly
        // are — that connectivity bias is why RW sampling wins.
        let cfg = crate::gen::SbmConfig {
            expected_degree: 3.0,
            p_in_1: 0.2,
            per_class: 1,
            ..Default::default()
        };
        let g = cfg.sample_graph(1, &mut Rng::new(9));
        let mut rng = Rng::new(10);
        let mut scratch = Vec::new();
        let (k, trials) = (5usize, 2_000);
        let conn_rw = (0..trials)
            .filter(|_| RwSampler::default().sample(&g, k, &mut rng, &mut scratch).is_connected())
            .count() as f64
            / trials as f64;
        let conn_unif = (0..trials)
            .filter(|_| UniformSampler.sample(&g, k, &mut rng, &mut scratch).is_connected())
            .count() as f64
            / trials as f64;
        assert!(
            conn_rw > conn_unif + 0.3,
            "rw connectivity bias too weak on sparse SBM: rw={conn_rw} vs uniform={conn_unif}"
        );
        assert!(conn_unif < 0.35, "uniform unexpectedly connected: {conn_unif}");
    }

    #[test]
    fn sampler_by_name_resolves() {
        assert_eq!(sampler_by_name("uniform").name(), "uniform");
        assert_eq!(sampler_by_name("rw").name(), "rw");
    }

    #[test]
    #[should_panic(expected = "unknown sampler")]
    fn sampler_by_name_rejects_unknown() {
        sampler_by_name("bogus");
    }
}
