//! Linear classification on graph embeddings.
//!
//! GSA-phi ends with "train a linear classifier on the vector dataset"
//! (Alg. 1, line 9). We provide the two standard choices — a linear SVM
//! trained with Pegasos-style SGD on the hinge loss, and logistic
//! regression — plus feature standardization and the evaluation protocol
//! (stratified split, multi-restart accuracy).

use crate::util::Rng;

/// Feature standardizer (per-dimension mean / std from the training set).
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Standardizer {
    /// Fit on row-major `x` of shape (n, d).
    pub fn fit(x: &[f32], n: usize, d: usize) -> Self {
        assert_eq!(x.len(), n * d);
        let mut mean = vec![0.0f32; d];
        for r in 0..n {
            for c in 0..d {
                mean[c] += x[r * d + c];
            }
        }
        for m in mean.iter_mut() {
            *m /= n.max(1) as f32;
        }
        let mut var = vec![0.0f32; d];
        for r in 0..n {
            for c in 0..d {
                let v = x[r * d + c] - mean[c];
                var[c] += v * v;
            }
        }
        let std = var
            .iter()
            .map(|&v| (v / n.max(1) as f32).sqrt().max(1e-6))
            .collect();
        Standardizer { mean, std }
    }

    pub fn apply(&self, x: &mut [f32]) {
        let d = self.mean.len();
        for row in x.chunks_exact_mut(d) {
            for c in 0..d {
                row[c] = (row[c] - self.mean[c]) / self.std[c];
            }
        }
    }
}

/// Which linear model to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Model {
    /// Hinge loss + L2 (Pegasos SGD).
    Svm,
    /// Logistic loss + L2 (SGD).
    Logistic,
}

/// Training hyperparameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: Model,
    /// L2 regularization strength (Pegasos lambda).
    pub lambda: f32,
    pub epochs: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { model: Model::Svm, lambda: 1e-2, epochs: 100, seed: 0 }
    }
}

/// A trained linear classifier: sign(w . x + b).
#[derive(Clone, Debug)]
pub struct LinearClassifier {
    pub w: Vec<f32>,
    pub b: f32,
}

impl LinearClassifier {
    /// Train on row-major `x` (n, d) with labels in {0, 1}.
    ///
    /// The bias is folded into the weight vector as a constant feature,
    /// so it shares the L2 regularizer — plain Pegasos with an
    /// unregularized bias takes `eta = 1/(lambda t)`-sized jolts that
    /// never anneal within a realistic epoch budget and drowns small
    /// class signals (observed at chance level on SBM embeddings).
    pub fn train(x: &[f32], labels: &[u8], d: usize, cfg: &TrainConfig) -> Self {
        let n = labels.len();
        assert_eq!(x.len(), n * d);
        assert!(n > 0);
        // w has d + 1 entries; the last pairs with the implicit 1 input.
        //
        // Perf (EXPERIMENTS.md §Perf): the L2 shrink is kept as a scalar
        // factor `scale` (w_true = scale * v), so each step is one dot +
        // (on margin violation) one axpy instead of an O(d) rescale of
        // the whole vector — ~2.5x faster at m = 5000.
        let mut v = vec![0.0f32; d + 1];
        let mut scale = 1.0f32;
        let mut rng = Rng::new(cfg.seed);
        let mut order: Vec<usize> = (0..n).collect();
        let mut t: u64 = 1;
        for _epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let y = if labels[i] == 1 { 1.0f32 } else { -1.0 };
                let xi = &x[i * d..(i + 1) * d];
                let score = scale * (dot(&v[..d], xi) + v[d]);
                let eta = 1.0 / (cfg.lambda * t as f32);
                let shrink = (1.0 - eta * cfg.lambda).max(1e-12);
                let update = match cfg.model {
                    // Pegasos: w <- shrink*w + eta*y*(x,1) on margin < 1.
                    Model::Svm => (y * score < 1.0).then_some(eta * y),
                    Model::Logistic => {
                        let g = -y / (1.0 + (y * score).exp());
                        Some(-eta * g)
                    }
                };
                scale *= shrink;
                if let Some(a) = update {
                    // w += a*(x,1)  =>  v += (a/scale)*(x,1)
                    let a = a / scale;
                    axpy(&mut v[..d], a, xi);
                    v[d] += a;
                }
                // Renormalize occasionally to keep scale/v well-ranged.
                if scale < 1e-6 {
                    for w in v.iter_mut() {
                        *w *= scale;
                    }
                    scale = 1.0;
                }
                t += 1;
            }
        }
        for w in v.iter_mut() {
            *w *= scale;
        }
        let b = v.pop().unwrap();
        LinearClassifier { w: v, b }
    }

    pub fn decision(&self, x: &[f32]) -> f32 {
        dot(&self.w, x) + self.b
    }

    pub fn predict(&self, x: &[f32]) -> u8 {
        (self.decision(x) > 0.0) as u8
    }

    /// Accuracy over row-major `x` (n, d).
    pub fn accuracy(&self, x: &[f32], labels: &[u8]) -> f64 {
        let d = self.w.len();
        let correct = labels
            .iter()
            .enumerate()
            .filter(|(i, &l)| self.predict(&x[i * d..(i + 1) * d]) == l)
            .count();
        correct as f64 / labels.len().max(1) as f64
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled accumulation: measurably faster than naive on the
    // m = 5000 embeddings this sees in the pipeline hot path.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

#[inline]
fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Full train/evaluate pass: standardize on train, fit, report test
/// accuracy. This is the tail of every GSA-phi experiment.
pub fn train_and_eval(
    embeddings: &[f32],
    labels: &[u8],
    d: usize,
    train_idx: &[usize],
    test_idx: &[usize],
    cfg: &TrainConfig,
) -> f64 {
    let gather = |idx: &[usize]| -> (Vec<f32>, Vec<u8>) {
        let mut x = Vec::with_capacity(idx.len() * d);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(&embeddings[i * d..(i + 1) * d]);
            y.push(labels[i]);
        }
        (x, y)
    };
    let (mut x_train, y_train) = gather(train_idx);
    let (mut x_test, y_test) = gather(test_idx);
    let std = Standardizer::fit(&x_train, y_train.len(), d);
    std.apply(&mut x_train);
    std.apply(&mut x_test);
    let clf = LinearClassifier::train(&x_train, &y_train, d, cfg);
    clf.accuracy(&x_test, &y_test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;

    /// Gaussian blobs at +/- mu in d dims.
    fn blobs(n: usize, d: usize, mu: f32, rng: &mut Rng) -> (Vec<f32>, Vec<u8>) {
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0u8; n];
        for i in 0..n {
            let label = (i % 2) as u8;
            y[i] = label;
            let center = if label == 1 { mu } else { -mu };
            for c in 0..d {
                x[i * d + c] = center + rng.gaussian_f32();
            }
        }
        (x, y)
    }

    #[test]
    fn svm_separates_blobs() {
        let mut rng = Rng::new(1);
        let (x, y) = blobs(200, 8, 2.0, &mut rng);
        let clf = LinearClassifier::train(&x, &y, 8, &TrainConfig::default());
        assert!(clf.accuracy(&x, &y) > 0.97);
    }

    #[test]
    fn logistic_separates_blobs() {
        let mut rng = Rng::new(2);
        let (x, y) = blobs(200, 8, 2.0, &mut rng);
        let cfg = TrainConfig { model: Model::Logistic, ..Default::default() };
        let clf = LinearClassifier::train(&x, &y, 8, &cfg);
        assert!(clf.accuracy(&x, &y) > 0.97);
    }

    #[test]
    fn chance_level_on_unseparable_data() {
        check::check("chance-level", 0xF1, 5, |rng| {
            let (x, y) = blobs(300, 6, 0.0, rng); // identical classes
            let clf = LinearClassifier::train(&x, &y, 6, &TrainConfig::default());
            let acc = clf.accuracy(&x, &y);
            assert!(acc < 0.68, "acc={acc} should be near chance");
        });
    }

    #[test]
    fn standardizer_zero_mean_unit_var() {
        let mut rng = Rng::new(3);
        let (n, d) = (500, 4);
        let mut x = vec![0.0f32; n * d];
        for (i, v) in x.iter_mut().enumerate() {
            *v = rng.gaussian_f32() * (i % d + 1) as f32 + 5.0;
        }
        let std = Standardizer::fit(&x, n, d);
        std.apply(&mut x);
        let refit = Standardizer::fit(&x, n, d);
        for c in 0..d {
            assert!(refit.mean[c].abs() < 1e-4);
            assert!((refit.std[c] - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn standardizer_handles_constant_features() {
        let x = vec![3.0f32; 10 * 2];
        let std = Standardizer::fit(&x, 10, 2);
        let mut y = x.clone();
        std.apply(&mut y);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn train_and_eval_protocol() {
        let mut rng = Rng::new(4);
        let (x, y) = blobs(100, 5, 1.5, &mut rng);
        let train: Vec<usize> = (0..80).collect();
        let test: Vec<usize> = (80..100).collect();
        let acc = train_and_eval(&x, &y, 5, &train, &test, &TrainConfig::default());
        assert!(acc > 0.85, "acc={acc}");
    }

    #[test]
    fn dot_matches_naive() {
        check::check("dot", 0xF2, 50, |rng| {
            let n = 1 + rng.usize(100);
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_gaussian(&mut a, 1.0);
            rng.fill_gaussian(&mut b, 1.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3);
        });
    }
}
