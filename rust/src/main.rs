//! graphlet-rf CLI: the L3 coordinator entrypoint.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §5):
//!
//! ```text
//! graphlet-rf quickstart                end-to-end smoke run (see examples/)
//! graphlet-rf fig1-left  [--scale full] Fig 1 left  (uniform sampling sweeps)
//! graphlet-rf fig1-right [--scale full] Fig 1 right (RW vs match vs GIN)
//! graphlet-rf fig2-left  [--scale full] Fig 2 left  (feature-map comparison)
//! graphlet-rf fig2-right                Fig 2 right + Table 1 (timing vs k)
//! graphlet-rf fig3 --dataset dd|reddit  Fig 3 (real-data protocol)
//! graphlet-rf thm1                      Theorem 1 concentration check
//! graphlet-rf gnn                       GIN baseline training run
//! graphlet-rf info                      platform + artifact inventory
//! graphlet-rf serve --port N            persistent embedding daemon
//! graphlet-rf serve-bench --addr A      loopback load generator (p50/p99)
//! ```
//!
//! Common flags: `--seed N`, `--engine pjrt|cpu|cpu-inline|cpu-sorf`,
//! `--shards N`, `--workers N`, `--fwht-threads N`, `--artifacts DIR`,
//! `--out DIR`, `--scale quick|full`. The `cpu-sorf` engine swaps the
//! dense random projection for structured SORF features (batch-major
//! FWHT `HD` panels, see `graphlet_rf::fastrf`) on every feature
//! shard; `--fwht-threads` gives each shard a panel-worker budget
//! (default 1 — shard-level parallelism owns the cores).
//!
//! Serve path (one warm pipeline + a two-level cache behind a TCP
//! line-JSON protocol; see `graphlet_rf::serve` for the full diagram):
//!
//! ```text
//! clients ──TCP──► per-conn reader ──┬─ L1 RAM hit ──► per-conn writer
//!                                    ├─ L2 store hit (--store-dir,
//!                                    │   promoted to L1) ──► writer
//!                                    └─ miss: GraphJob ──► shared
//!                  StreamingPipeline (workers ► shards) ──► Completed
//!                                    └─ write-through L2+L1 ──► writer
//! ```
//!
//! With `--store-dir DIR` the daemon persists every computed row to an
//! append-only segment log (`graphlet_rf::store`); a restarted daemon
//! reopens the log and serves yesterday's rows bitwise identical with
//! zero recomputes.
//!
//! Unknown subcommands print the usage text to **stderr** and exit
//! nonzero; `graphlet-rf help` (or no arguments) prints it to stdout
//! and exits 0.

use anyhow::Result;
use graphlet_rf::coordinator::{EngineMode, GsaConfig};
use graphlet_rf::experiments::{figures, thm1, timing, ExpContext, Scale};
use graphlet_rf::features::Variant;
use graphlet_rf::gen::SbmConfig;
use graphlet_rf::gnn::{GinConfig, GinModel};
use graphlet_rf::runtime::{artifacts_dir, Engine};
use graphlet_rf::util::{Args, Rng};

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let seed: u64 = args.parse_or("seed", 0u64);
    let scale = Scale::parse(args.str_or("scale", "quick"));

    // Engine setup: PJRT when artifacts exist (or --engine pjrt forces it).
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let engine_flag = args.get("engine").map(EngineMode::parse).transpose()?;
    let engine = match engine_flag {
        Some(EngineMode::Cpu) | Some(EngineMode::CpuInline) | Some(EngineMode::CpuSorf) => None,
        _ => match Engine::new(&dir) {
            Ok(e) => {
                eprintln!("PJRT engine up: platform={}, artifacts={}", e.platform(), dir.display());
                Some(e)
            }
            Err(err) => {
                if engine_flag == Some(EngineMode::Pjrt) {
                    return Err(err.context("--engine pjrt requested but engine setup failed"));
                }
                eprintln!("no PJRT artifacts ({err}); falling back to CPU feature maps");
                None
            }
        },
    };
    let out_dir = std::path::PathBuf::from(args.str_or("out", "results"));
    let mut ctx = ExpContext::new(engine, out_dir);
    if let Some(mode) = engine_flag {
        ctx.engine_mode = Some(mode);
    }

    match cmd {
        "quickstart" => quickstart(&ctx, &args, seed)?,
        "fig1-left" => {
            figures::fig1_left(&ctx, &scale, seed)?;
        }
        "fig1-right" => {
            figures::fig1_right(&ctx, &scale, seed)?;
        }
        "fig2-left" => {
            figures::fig2_left(&ctx, &scale, seed)?;
        }
        "fig2-right" => {
            let ks = args.parse_list("ks", &[3usize, 4, 5, 6, 7, 8]);
            let m = args.parse_or("m", 5000usize);
            let pool = args.parse_or("pool", 512usize);
            timing::fig2_right(&ctx, &ks, m, pool)?;
        }
        "fig3" => {
            let dataset = args.str_or("dataset", "dd").to_string();
            // --data-dir is the canonical real-data flag (--tu-dir kept
            // as an alias): point it at a TU-format directory holding
            // <dataset>_A.txt etc. (see rust/src/data/mod.rs for the
            // layout) to run the fig3 protocol on D&D / REDDIT-BINARY
            // instead of the synthetic substitutes.
            let tu_dir = args
                .get("data-dir")
                .or_else(|| args.get("tu-dir"))
                .map(std::path::Path::new);
            figures::fig3(&ctx, &scale, &dataset, tu_dir, seed)?;
        }
        "thm1" => {
            thm1::run(&ctx, seed)?;
        }
        "gnn" => gnn_cmd(&ctx, &args, seed)?,
        "info" => info(&ctx)?,
        "serve" => serve_cmd(&ctx, &args, seed)?,
        "serve-bench" => serve_bench_cmd(&ctx, &args, seed)?,
        "help" => println!("{HELP}"),
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    }
    Ok(())
}

const HELP: &str = "graphlet-rf — Fast Graph Kernel with Optical Random Features

USAGE: graphlet-rf <quickstart|fig1-left|fig1-right|fig2-left|fig2-right|fig3|thm1|gnn|info|serve|serve-bench>
             [--scale quick|mid|full] [--seed N]
             [--engine pjrt|cpu|cpu-inline|cpu-sorf]
             [--shards N] [--workers N] [--fwht-threads N]
             [--variant opu|gauss|gauss-eig]
             [--artifacts DIR] [--out DIR] [--dataset dd|reddit]
             [--data-dir DIR] [--tu-dir DIR]
             [--store-dir DIR] [--cache-policy lru|cost-aware]
             [--ann-probe F] [--ann-min-brute N] [--slow-ms N]
             [--profile-hz N] [--http-port N]

--shards N runs N parallel feature-engine shards (jobs round-robin over
shards); embeddings are bitwise identical for every shard/worker count.

--engine cpu-sorf replaces the dense random projection with structured
SORF features: HD-product blocks computed by a batch-major fast
Walsh-Hadamard transform in O(p log p) per block instead of O(d*m) —
the software analogue of the paper's constant-time optical transform.
Deterministic per seed; a different random-feature family than cpu, so
embeddings differ numerically but match statistically.

--fwht-threads N gives each cpu-sorf shard N panel workers: independent
HD blocks (and, for single-block maps, panel rows) split across scoped
threads. Default 1, so shard-level parallelism owns the cores; another
pure scheduling knob — embeddings never move a bit.

serve       long-running embedding daemon: line-delimited JSON over TCP,
            one persistent pipeline, cross-request batching, two-level
            embedding cache. Flags: --port N (default 7878),
            --addr HOST:PORT, --cache-cap N,
            --cache-policy lru|cost-aware (L1 eviction; cost-aware
            weighs victims by row size x recompute cost),
            --store-dir DIR (persistent L2 segment log — rows survive
            daemon restarts and are served bitwise identical from disk),
            --store-mmap true|false (memory-map sealed segments so L2
            reads and ANN index rows are zero-copy views into the page
            cache; default true on unix, or the GRAPHLET_RF_TEST_MMAP
            env override),
            --max-nodes N, --max-edges N, plus the usual embedding
            flags (--k --s --m --variant --shards --workers).
            With a store the daemon also answers the nearest op (k-NN
            retrieval over every stored embedding through an IVFFlat
            index, exact L2 distances): --ann-probe F sets the default
            fraction of inverted lists scanned per query (0 < F <= 1;
            1.0 = exhaustive/exact), --ann-min-brute N brute-forces
            below N indexed rows.
            Observability: the metrics op returns every latency
            histogram (log2 buckets + p50/p90/p99) and the trace op the
            last N per-request stage spans; --slow-ms N additionally
            captures any request slower than N ms and logs it as one
            JSON line to stderr (0 = every request; default off).
            --profile-hz N sets the always-on sampling profiler's rate
            (default 19 Hz, 0 = off): every registered daemon thread
            publishes its current stage and the sampler attributes
            per-thread CPU time to (role, stage) pairs — read it via
            the profile op, and observe it never moves an embedding
            bit. --http-port N opens a GET-only HTTP sidecar on
            127.0.0.1:N (0 = ephemeral) serving /metrics (Prometheus
            text format v0.0.4, this daemon's registry only), /healthz,
            /readyz, /profile (collapsed-stack flame text; ?seconds=N
            profiles a window), and /debug/threads; without the flag no
            HTTP socket is opened.
serve-bench loopback load generator: --addr HOST:PORT (default
            127.0.0.1:7878), --clients C, --requests N per client;
            reports labeled cold/warm_l1 passes (throughput, p50/p99,
            daemon-verified recompute counts) plus one JSON result
            line. With --store-dir DIR it instead hosts the daemon
            itself and adds two restart passes — kill the daemon, then
            reopen the store once with --store-mmap false (warm_l2, the
            legacy read+copy path) and once with it true (warm_l2_mmap,
            zero-copy page-cache views) — measuring zero-recompute
            throughput and ns/row for both read paths (self-checked:
            any recompute or full miss fails the run; the mmap pass
            also requires store.mmap_reads == requests and a zero-owned
            ANN index) — plus nearest_p10/p50/p100 retrieval passes
            (k-NN queries at probe factors 0.1/0.5/1.0 over the
            persisted corpus, with the index build cost reported as
            ann_build_ms).

fig3 --data-dir DIR loads the real TU-format dataset (e.g. D&D,
REDDIT-BINARY; see rust/src/data/mod.rs for the expected file layout)
instead of the synthetic substitute; quickstart accepts the same flag.

Run `make artifacts` first to build the AOT XLA artifacts (PJRT engine);
without them the CPU fallback engine is used automatically.";

/// End-to-end smoke run: SBM dataset -> RW sampling -> OPU features
/// (PJRT if available) -> SVM -> accuracy + throughput.
fn quickstart(ctx: &ExpContext, args: &Args, seed: u64) -> Result<()> {
    use graphlet_rf::classify::{train_and_eval, TrainConfig};
    use graphlet_rf::coordinator::embed_dataset;

    let r = args.parse_or("r", 1.2f64);
    let per_class = args.parse_or("per-class", 60usize);
    let cfg = gsa_from_args(ctx, args, seed)?;
    // End-to-end on real data: --data-dir DIR loads the TU-format
    // dataset named by --dataset (e.g. DD, REDDIT-BINARY; layout
    // documented in rust/src/data/mod.rs) through the hardened parser
    // instead of generating a synthetic SBM set.
    let ds = match args.get("data-dir") {
        Some(dir) => {
            let name = graphlet_rf::data::tu_name(args.str_or("dataset", "dd"));
            println!("loading TU dataset {name} from {dir}");
            graphlet_rf::data::load_tu_dataset(std::path::Path::new(dir), name)?
        }
        None => {
            println!("generating SBM dataset: r={r}, {} graphs", 2 * per_class);
            SbmConfig { r, per_class, ..Default::default() }.generate(&mut Rng::new(seed))
        }
    };
    println!("{}", ds.summary());
    println!(
        "embedding: k={} s={} m={} variant={} sampler={} engine={:?} shards={} workers={}",
        cfg.k,
        cfg.s,
        cfg.m,
        cfg.variant.name(),
        cfg.sampler,
        cfg.engine,
        cfg.shards,
        cfg.workers
    );
    let (emb, metrics) = embed_dataset(&ds, &cfg, ctx.engine.as_ref())?;
    println!("pipeline: {}", metrics.report());
    let mut rng = Rng::new(seed ^ 0xACC);
    let split = ds.split(0.8, &mut rng);
    let acc = train_and_eval(
        &emb,
        &ds.labels,
        cfg.m,
        &split.train,
        &split.test,
        &TrainConfig::default(),
    );
    println!("test accuracy: {acc:.3}");
    Ok(())
}

/// Shared GsaConfig construction for the serve subcommand (the serving
/// analogue of quickstart's flag handling).
fn gsa_from_args(ctx: &ExpContext, args: &Args, seed: u64) -> Result<GsaConfig> {
    let shards = args
        .try_parse::<usize>("shards")
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or(1)
        .max(1);
    let mut cfg = GsaConfig {
        k: args.parse_or("k", 6usize),
        s: args.parse_or("s", 1000usize),
        m: args.parse_or("m", 5000usize),
        variant: Variant::parse(args.str_or("variant", "opu"))?,
        batch: args.parse_or("batch", 256usize),
        shards,
        engine: ctx.mode(),
        seed,
        ..Default::default()
    };
    if let Some(workers) = args.try_parse::<usize>("workers").map_err(|e| anyhow::anyhow!(e))? {
        cfg.workers = workers.max(1);
    }
    if let Some(t) = args.try_parse::<usize>("fwht-threads").map_err(|e| anyhow::anyhow!(e))? {
        cfg.fwht_threads = t.max(1);
    }
    if cfg.variant == Variant::Match {
        anyhow::bail!(
            "this command embeds with dense feature maps; use --variant opu|gauss|gauss-eig \
             (phi_match is the fig1-right / fig2-right baseline)"
        );
    }
    Ok(cfg)
}

/// Serve-layer configuration shared by `serve` and the self-hosted
/// `serve-bench` restart mode.
fn serve_cfg_from_args(
    ctx: &ExpContext,
    args: &Args,
    seed: u64,
) -> Result<graphlet_rf::serve::ServeConfig> {
    use graphlet_rf::serve::{EvictPolicy, ServeConfig};

    let gsa = gsa_from_args(ctx, args, seed)?;
    let defaults = ServeConfig::default();
    Ok(ServeConfig {
        gsa,
        max_nodes: args.parse_or("max-nodes", defaults.max_nodes),
        max_edges: args.parse_or("max-edges", defaults.max_edges),
        cache_capacity: args.parse_or("cache-cap", defaults.cache_capacity),
        cache_policy: match args.get("cache-policy") {
            Some(name) => EvictPolicy::parse(name)?,
            None => defaults.cache_policy,
        },
        store_dir: args.get("store-dir").map(std::path::PathBuf::from),
        store_mmap: args.parse_or("store-mmap", defaults.store_mmap),
        ann_probe: args.parse_or("ann-probe", defaults.ann_probe),
        ann_min_brute: args.parse_or("ann-min-brute", defaults.ann_min_brute),
        slow_ms: args.parse_or("slow-ms", defaults.slow_ms),
        profile_hz: args.parse_or("profile-hz", defaults.profile_hz),
        http_port: args.try_parse::<u16>("http-port").map_err(|e| anyhow::anyhow!(e))?,
        ..defaults
    })
}

/// `graphlet-rf serve`: bind the daemon and block in the accept loop.
fn serve_cmd(ctx: &ExpContext, args: &Args, seed: u64) -> Result<()> {
    use graphlet_rf::serve::Server;

    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => format!("127.0.0.1:{}", args.parse_or("port", 7878u16)),
    };
    let cfg = serve_cfg_from_args(ctx, args, seed)?;
    println!(
        "serve: k={} s={} m={} variant={} engine={} shards={} workers={} fwht_threads={} \
         cache_cap={} cache_policy={} store={} store_mmap={} slow_ms={} profile_hz={}",
        cfg.gsa.k,
        cfg.gsa.s,
        cfg.gsa.m,
        cfg.gsa.variant.name(),
        cfg.gsa.engine.name(),
        cfg.gsa.shards,
        cfg.gsa.workers,
        cfg.gsa.fwht_threads,
        cfg.cache_capacity,
        cfg.cache_policy.name(),
        cfg.store_dir
            .as_ref()
            .map_or("none (RAM-only cache)".to_string(), |d| d.display().to_string()),
        cfg.store_mmap,
        if cfg.slow_ms == u64::MAX { "off".to_string() } else { cfg.slow_ms.to_string() },
        if cfg.profile_hz == 0 { "off".to_string() } else { cfg.profile_hz.to_string() },
    );
    if cfg.store_dir.is_some() {
        println!(
            "serve: nearest op enabled (ann_probe={} ann_min_brute={})",
            cfg.ann_probe, cfg.ann_min_brute
        );
    }
    let server = Server::bind(&addr, cfg, ctx.engine.as_ref())?;
    println!(
        "serving on {} (config_fp={:016x}; line-delimited JSON; send {{\"op\":\"shutdown\"}} \
         to stop)",
        server.local_addr(),
        server.config_fp(),
    );
    if let Some(http) = server.http_addr() {
        println!(
            "serve: http sidecar on http://{http} \
             (/metrics /healthz /readyz /profile /debug/threads)"
        );
    }
    server.run()
}

/// `graphlet-rf serve-bench`: drive a daemon over loopback and print
/// labeled pass reports (throughput + latency percentiles) plus one
/// machine-readable JSON line. With `--store-dir` the daemons are
/// hosted in-process and restart-warm passes (`warm_l2` with mmap off,
/// `warm_l2_mmap` with it on) measure zero-recompute serving off the
/// reopened segment log through both read paths.
fn serve_bench_cmd(ctx: &ExpContext, args: &Args, seed: u64) -> Result<()> {
    let clients = args.parse_or("clients", 4usize).max(1);
    let per_client = args.parse_or("requests", 32usize).max(1);
    let run = match args.get("store-dir") {
        Some(dir) => {
            println!(
                "serve-bench (restart mode): store={dir}, {clients} clients x {per_client} \
                 requests, seed {seed}"
            );
            let cfg = serve_cfg_from_args(ctx, args, seed)?;
            graphlet_rf::serve::run_restart_bench(
                &cfg,
                clients,
                per_client,
                seed,
                ctx.engine.as_ref(),
            )?
        }
        None => {
            let addr = args.str_or("addr", "127.0.0.1:7878").to_string();
            println!(
                "serve-bench: {addr}, {clients} clients x {per_client} requests, seed {seed}"
            );
            let run = graphlet_rf::serve::run_bench(&addr, clients, per_client, seed)?;
            if args.flag("shutdown") {
                graphlet_rf::serve::send_shutdown(&addr)?;
                println!("sent shutdown to {addr}");
            }
            run
        }
    };
    for (label, report) in &run.passes {
        println!("{label}: {}", report.line());
    }
    if let Some((legacy, mmap)) = run.l2_read_ns_per_row {
        println!(
            "l2 read path: warm_l2={legacy:.0} ns/row (read+copy) vs \
             warm_l2_mmap={mmap:.0} ns/row (zero-copy view)"
        );
    }
    println!("{}", run.json());
    Ok(())
}

fn gnn_cmd(ctx: &ExpContext, args: &Args, seed: u64) -> Result<()> {
    let engine = ctx
        .engine
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("gnn requires PJRT artifacts (run `make artifacts`)"))?;
    let r = args.parse_or("r", 1.2f64);
    let per_class = args.parse_or("per-class", 100usize);
    let steps = args.parse_or("steps", 300usize);
    let ds = SbmConfig { r, per_class, ..Default::default() }.generate(&mut Rng::new(seed));
    println!("{}", ds.summary());
    let split = ds.split(0.8, &mut Rng::new(seed ^ 0xACC));
    let cfg = GinConfig { steps, seed, ..Default::default() };
    let (acc, curve) = GinModel::train_and_eval(engine, &ds, &split, &cfg)?;
    for (step, loss) in &curve {
        println!("step {step}: loss {loss:.4}");
    }
    println!("GIN test accuracy: {acc:.3}");
    Ok(())
}

fn info(ctx: &ExpContext) -> Result<()> {
    match &ctx.engine {
        Some(engine) => {
            println!("platform: {}", engine.platform());
            let manifest = engine.manifest();
            println!("artifacts: {}", manifest.artifacts.len());
            let mut by_kind: std::collections::BTreeMap<&str, usize> = Default::default();
            for a in manifest.artifacts.values() {
                *by_kind.entry(a.kind.as_str()).or_default() += 1;
            }
            for (kind, n) in by_kind {
                println!("  {kind}: {n}");
            }
        }
        None => println!("no PJRT engine (artifacts missing) — CPU fallback active"),
    }
    Ok(())
}
