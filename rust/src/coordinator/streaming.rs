//! The persistent streaming pipeline: sampler workers + feature shards
//! that outlive any one dataset.
//!
//! ```text
//!   GraphJob (graph, seed, tag, done, trace) ──► bounded job queue
//!                                              │ (admission control:
//!                                              │  try_submit → Overloaded)
//!                    sampler workers ◄─────────┘
//!                    (std::thread x W, shared queue)
//!                         │ sample s subgraphs per job, pack rows into
//!                         │ per-shard cross-REQUEST batches of B rows;
//!                         │ partial batches flush when the queue idles
//!                         ▼
//!            per-shard bounded channels (job ticket → shard ticket mod N)
//!                         │
//!                         ▼
//!       N feature shards (own RfExecutor/CpuFeatureMap/SorfMap each)
//!                         │ scatter rows into per-job accumulators;
//!                         │ a job completes when its s rows arrived
//!                         ▼
//!              Completed { tag, row } ──► the job's own `done` channel
//! ```
//!
//! Invariants carried over from the batch pipeline (and pinned by its
//! tests, which now run through this core via [`embed_dataset`]):
//!
//! - **Determinism**: every job owns a seeded RNG stream; one worker
//!   samples the whole job in order, and its rows reach exactly one
//!   shard in FIFO order, so each job's accumulator sees its rows in
//!   sample order. Embeddings are bitwise identical for every worker
//!   count, shard count, and batching/flush schedule.
//! - **Cross-request batching**: workers keep one open batch per shard
//!   shared across *all* jobs they process, so rows from concurrent
//!   requests pack into full compiled-size batches. A worker flushes its
//!   partial batches only when the job queue momentarily idles — full
//!   batches under load, low latency when drained.
//! - **Backpressure**: the job queue and per-shard channels are bounded;
//!   [`StreamingPipeline::try_submit`] surfaces a full queue to callers
//!   (the serve layer's admission control) instead of blocking. Both
//!   bounds are observable before they bite:
//!   [`queue_depth`](StreamingPipeline::queue_depth) (admitted jobs not
//!   yet claimed by a worker) and
//!   [`shard_occupancy`](StreamingPipeline::shard_occupancy) (messages
//!   in flight to each shard) feed the serve `stats` op.
//! - **Observability is observation-only**: the [`crate::obs`] wiring
//!   (queue-wait / batch-wait / projection histograms, per-job
//!   [`TraceCtx`] stage stamps, and the sampling profiler's per-thread
//!   stage slots — workers register as role `worker`, shards as `shard`)
//!   reads clocks and atomics but never an RNG or a row, so embeddings
//!   are bitwise identical with tracing or profiling on or off — pinned
//!   by `tests/obs.rs`.
//!
//! [`embed_dataset`]: super::pipeline::embed_dataset

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Result};

use super::metrics::PipelineMetrics;
use super::pipeline::{EngineMode, GsaConfig};
use crate::fastrf::{SorfMap, SorfParams};
use crate::features::{CpuFeatureMap, RfParams};
use crate::graph::AnyGraph;
use crate::obs::{self, TraceCtx};
use crate::runtime::{Engine, Manifest, RfExecutor};
use crate::sample::sampler_by_name;
use crate::util::{Rng, Timer};

/// One graph to embed through the persistent pipeline.
pub struct GraphJob {
    /// The graph (shared so jobs stay cheap to move between threads).
    pub graph: Arc<AnyGraph>,
    /// Seed of this job's private sampling RNG stream; with the same
    /// seed/config a job's embedding is a pure function of the graph.
    pub seed: u64,
    /// Caller-defined correlation id, echoed back in [`Completed`].
    pub tag: u64,
    /// Where the finished embedding is delivered.
    pub done: Sender<Completed>,
    /// Optional span handle: workers and shards stamp the stages this
    /// job crosses (queue wait, projection). Pure observation — `None`
    /// and `Some` produce bitwise-identical embeddings.
    pub trace: Option<TraceCtx>,
}

/// A finished (or failed) job, delivered on the job's `done` channel.
pub struct Completed {
    /// The submitting caller's correlation id.
    pub tag: u64,
    /// The (m,) embedding: mean feature vector over the job's s samples.
    /// Empty when `error` is set.
    pub row: Vec<f32>,
    /// Samples that contributed to `row`.
    pub samples: usize,
    /// Per-job failure (executor error, graph too small, …); the
    /// pipeline itself keeps running.
    pub error: Option<String>,
}

/// Outcome of a non-blocking submit (the admission-control path).
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitOutcome {
    Accepted,
    /// The bounded job queue is full; the job was dropped — callers
    /// should surface an overload error to the requester.
    Overloaded,
}

/// Per-job bookkeeping shared between the worker that samples it and the
/// shard that accumulates it.
struct JobState {
    ticket: u64,
    tag: u64,
    done: Sender<Completed>,
    trace: Option<TraceCtx>,
}

impl JobState {
    fn fail(&self, msg: String) {
        let _ = self.done.send(Completed {
            tag: self.tag,
            row: Vec::new(),
            samples: 0,
            error: Some(msg),
        });
    }
}

/// Internal job as routed to workers (shard chosen at submit time).
struct Job {
    graph: Arc<AnyGraph>,
    seed: u64,
    shard: usize,
    state: Arc<JobState>,
    /// When the job entered the queue — the worker that claims it
    /// records the difference as `pipeline.queue_wait_us`.
    queued: Instant,
}

/// A batch in flight: row-major input rows + the (job, rows) segments
/// they belong to. All segments of one batch target the same shard.
struct Batch {
    data: Vec<f32>,
    segments: Vec<(Arc<JobState>, usize)>,
    rows: usize,
    /// Sampler busy-time attributed to this batch (metrics).
    sample_secs: f64,
    /// When the batch was handed to the shard channel — the shard
    /// records the difference as `shard.batch_wait_us`.
    sent_at: Instant,
}

/// Message from CpuInline workers: a finished per-job feature sum.
struct JobSum {
    state: Arc<JobState>,
    sum: Vec<f32>,
    samples: usize,
    sample_secs: f64,
    sent_at: Instant,
}

enum Msg {
    Batch(Batch),
    Sum(JobSum),
}

/// One open cross-request batch a worker is filling for one shard.
struct Packer {
    data: Vec<f32>,
    rows: usize,
    segments: Vec<(Arc<JobState>, usize)>,
    sample_secs: f64,
}

impl Packer {
    fn new(batch: usize, d: usize) -> Packer {
        Packer { data: vec![0.0f32; batch * d], rows: 0, segments: Vec::new(), sample_secs: 0.0 }
    }
}

/// Spec from which a spawned shard thread rebuilds its own PJRT engine
/// (PJRT handles are not Sync, so each shard owns one).
type PjrtSpawn = (PathBuf, Manifest, String);

/// One shard's channel endpoint plus its live occupancy gauge: messages
/// sent to the shard but not yet drained by its loop. The gauge is the
/// serve `stats` backpressure signal — sustained non-zero occupancy
/// means the feature engines, not the samplers, are the bottleneck. A
/// sender blocked on a full channel has already bumped the gauge, so
/// occupancy can transiently exceed the channel capacity — exactly when
/// overload is worth seeing.
#[derive(Clone)]
struct ShardTx {
    tx: SyncSender<Msg>,
    occupancy: Arc<AtomicUsize>,
}

impl ShardTx {
    fn send(&self, msg: Msg) {
        self.occupancy.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(msg).is_err() {
            // Receiver gone (teardown): roll the gauge back.
            self.occupancy.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// The one shared random-parameter draw, in whichever family the
/// engine mode uses: dense Gaussian matrices for `pjrt`/`cpu`/
/// `cpu-inline`, structured SORF diagonals for `cpu-sorf`. Every
/// worker and shard clones the same `Arc`, so shard count never
/// changes the math — the same invariant the dense path pins.
#[derive(Clone)]
enum ParamSet {
    Dense(Arc<RfParams>),
    Sorf(Arc<SorfParams>),
}

/// The bounded multi-producer multi-consumer job queue feeding the
/// sampler workers.
///
/// Hand-rolled on Mutex + Condvar rather than `mpsc` because workers
/// need two properties a shared `Mutex<Receiver>` cannot give:
/// 1. a waiting worker must NOT hold the queue lock (with `recv` under
///    a mutex, one blocked worker would pin every other worker — and
///    their unflushed batches — behind the lock);
/// 2. a worker must run its partial-batch flush *between* "queue looks
///    empty" and "go to sleep", with the lock released, so in-flight
///    jobs whose rows it still holds can complete.
struct JobQueue {
    inner: Mutex<JobQueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct JobQueueInner {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Outcome of a non-blocking push.
enum TryPush {
    Pushed,
    Full,
    Closed,
}

impl JobQueue {
    fn new(cap: usize) -> JobQueue {
        JobQueue {
            inner: Mutex::new(JobQueueInner { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; `false` if the queue is closed.
    fn push(&self, job: Job) -> bool {
        let mut g = self.inner.lock().expect("job queue lock");
        loop {
            if g.closed {
                return false;
            }
            if g.jobs.len() < self.cap {
                g.jobs.push_back(job);
                self.not_empty.notify_one();
                return true;
            }
            g = self.not_full.wait(g).expect("job queue lock");
        }
    }

    fn try_push(&self, job: Job) -> TryPush {
        let mut g = self.inner.lock().expect("job queue lock");
        if g.closed {
            TryPush::Closed
        } else if g.jobs.len() >= self.cap {
            TryPush::Full
        } else {
            g.jobs.push_back(job);
            self.not_empty.notify_one();
            TryPush::Pushed
        }
    }

    /// Jobs admitted but not yet claimed by a worker (the backpressure
    /// depth gauge the serve `stats` op reports).
    fn len(&self) -> usize {
        self.inner.lock().expect("job queue lock").jobs.len()
    }

    /// Blocking pop; `None` once the queue is closed and drained.
    /// `before_wait` runs — with the lock released — every time the
    /// queue turns out to be empty, before this worker goes to sleep:
    /// that is the partial-batch flush hook.
    fn pop<F: FnMut()>(&self, mut before_wait: F) -> Option<Job> {
        let mut g = self.inner.lock().expect("job queue lock");
        loop {
            if let Some(j) = g.jobs.pop_front() {
                self.not_full.notify_one();
                return Some(j);
            }
            if g.closed {
                return None;
            }
            drop(g);
            before_wait();
            g = self.inner.lock().expect("job queue lock");
            // Re-check under the lock: a job may have landed while we
            // flushed; only wait when the queue is still empty (the
            // condvar atomically releases the lock).
            if g.jobs.is_empty() && !g.closed {
                g = self.not_empty.wait(g).expect("job queue lock");
            }
        }
    }

    fn close(&self) {
        let mut g = self.inner.lock().expect("job queue lock");
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// A long-running embedding pipeline: W sampler workers and N feature
/// shards built once, fed by [`submit`](StreamingPipeline::submit) /
/// [`try_submit`](StreamingPipeline::try_submit), torn down by
/// [`shutdown`](StreamingPipeline::shutdown) (or by dropping it — the
/// threads then drain and exit on their own).
pub struct StreamingPipeline {
    jobs: Arc<JobQueue>,
    workers: Vec<JoinHandle<()>>,
    shard_handles: Vec<JoinHandle<PipelineMetrics>>,
    /// Live per-shard metric snapshots, refreshed by the shard threads.
    shard_slots: Vec<Arc<Mutex<PipelineMetrics>>>,
    /// Live per-shard channel occupancy gauges (see [`ShardTx`]).
    shard_occupancy: Vec<Arc<AtomicUsize>>,
    next_ticket: AtomicU64,
    cfg: GsaConfig,
    /// RNG state positioned right after the parameter draw — exactly
    /// where the per-graph seed stream historically started, so
    /// [`graph_seeds`](Self::graph_seeds) reproduces `embed_dataset`'s
    /// seeding bit for bit.
    seed_rng: Rng,
}

impl StreamingPipeline {
    /// Build the persistent pipeline: draw the shared feature parameters
    /// (one draw per pipeline — the paper's W is fixed, it is the same
    /// "device"), then spawn `cfg.workers` sampler workers and
    /// `cfg.shards` feature shards. `engine` must be Some for
    /// [`EngineMode::Pjrt`]; it serves as the template (artifacts dir +
    /// parsed manifest) from which each shard builds its own engine.
    ///
    /// PJRT note: every shard — including `shards == 1` — constructs its
    /// own engine inside its thread (PJRT handles are neither Send nor
    /// Sync, and shard threads outlive the caller), so a caller holding
    /// a borrowed engine pays one extra engine construction per
    /// *pipeline* (not per job). Long-lived pipelines (serve) amortize
    /// it to zero; `embed_dataset` pays it once per call.
    pub fn new(cfg: &GsaConfig, engine: Option<&Engine>) -> Result<StreamingPipeline> {
        StreamingPipeline::with_registry(cfg, engine, obs::global_arc())
    }

    /// Like [`new`](Self::new), but every worker/shard histogram
    /// (`pipeline.queue_wait_us`, `shard.batch_wait_us`,
    /// `shard.projection_us`) records into the given instance-scoped
    /// registry — the serve daemon passes its own, so two in-process
    /// daemons never share pipeline metrics. [`new`](Self::new) is the
    /// batch-CLI path and records into [`obs::global`].
    pub fn with_registry(
        cfg: &GsaConfig,
        engine: Option<&Engine>,
        registry: Arc<obs::Registry>,
    ) -> Result<StreamingPipeline> {
        let mut cfg = cfg.clone();
        cfg.shards = cfg.shards.max(1);
        cfg.workers = cfg.workers.max(1);
        cfg.queue_cap = cfg.queue_cap.max(1);
        cfg.fwht_threads = cfg.fwht_threads.max(1);
        // Degenerate values would hang jobs (s = 0 never completes, a
        // 0-row batch never fills) or panic a shared worker thread
        // (graphlet size out of the u32-mask range) — reject up front.
        anyhow::ensure!(
            (1..=crate::graph::MAX_K).contains(&cfg.k),
            "graphlet size k={} out of range 1..={}",
            cfg.k,
            crate::graph::MAX_K
        );
        anyhow::ensure!(cfg.s >= 1, "samples per graph must be >= 1");
        anyhow::ensure!(cfg.m >= 1, "feature count m must be >= 1");
        anyhow::ensure!(cfg.batch >= 1, "batch size must be >= 1");
        let d = cfg.input_dim();

        let mut seed_rng = Rng::new(cfg.seed);
        // One draw per pipeline, in the engine's parameter family. The
        // per-graph seed stream starts right after this draw either
        // way; `cpu-sorf` embeddings are a different (structured)
        // random-feature family, so they differ numerically from the
        // dense engines but are equally deterministic per seed.
        let params = match cfg.engine {
            EngineMode::CpuSorf => ParamSet::Sorf(Arc::new(SorfParams::generate(
                cfg.variant,
                d,
                cfg.m,
                cfg.sigma,
                &mut seed_rng,
            ))),
            _ => ParamSet::Dense(Arc::new(RfParams::generate(
                cfg.variant,
                d,
                cfg.m,
                cfg.sigma,
                &mut seed_rng,
            ))),
        };

        if cfg.engine == EngineMode::Pjrt && engine.is_none() {
            bail!("PJRT mode requires an Engine");
        }
        let pjrt_spawn: Option<PjrtSpawn> = match cfg.engine {
            EngineMode::Pjrt => {
                let e = engine.unwrap();
                Some((e.dir().to_path_buf(), e.manifest().clone(), cfg.impl_.clone()))
            }
            _ => None,
        };

        // ---- feature shards -------------------------------------------
        let mut txs: Vec<ShardTx> = Vec::with_capacity(cfg.shards);
        let mut shard_handles = Vec::with_capacity(cfg.shards);
        let mut shard_slots = Vec::with_capacity(cfg.shards);
        let mut shard_occupancy = Vec::with_capacity(cfg.shards);
        for q in 0..cfg.shards {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap);
            let slot = Arc::new(Mutex::new(PipelineMetrics::default()));
            let occupancy = Arc::new(AtomicUsize::new(0));
            let spawn_spec = pjrt_spawn.clone();
            let params = params.clone();
            let cfg_cl = cfg.clone();
            let slot_cl = slot.clone();
            let occ_cl = occupancy.clone();
            let reg_cl = registry.clone();
            shard_handles.push(std::thread::spawn(move || {
                shard_loop(rx, spawn_spec, &params, &cfg_cl, &slot_cl, &occ_cl, &reg_cl, q)
            }));
            txs.push(ShardTx { tx, occupancy: occupancy.clone() });
            shard_slots.push(slot);
            shard_occupancy.push(occupancy);
        }

        // ---- sampler workers ------------------------------------------
        // The job queue bounds admitted-but-unsampled work; together with
        // the per-shard channels it caps pipeline memory.
        let jobs = Arc::new(JobQueue::new(cfg.queue_cap * cfg.workers));
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let queue = jobs.clone();
            let txs = txs.clone();
            let params = params.clone();
            let cfg_cl = cfg.clone();
            let reg_cl = registry.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&queue, &txs, &params, &cfg_cl, &reg_cl, w)
            }));
        }
        // `txs` originals drop here: shard channels close exactly when the
        // last worker exits.

        Ok(StreamingPipeline {
            jobs,
            workers,
            shard_handles,
            shard_slots,
            shard_occupancy,
            next_ticket: AtomicU64::new(0),
            cfg,
            seed_rng,
        })
    }

    /// Jobs admitted to the bounded queue but not yet claimed by a
    /// sampler worker. Non-zero depth means the workers are saturated —
    /// the observable precursor of [`SubmitOutcome::Overloaded`], which
    /// only fires once the depth hits the queue capacity.
    pub fn queue_depth(&self) -> usize {
        self.jobs.len()
    }

    /// Per-shard feature-channel occupancy: batches/sums sent to each
    /// shard and not yet drained by its loop (indexed by shard id).
    /// Sustained non-zero values mean the feature engines, not the
    /// samplers, are the bottleneck.
    pub fn shard_occupancy(&self) -> Vec<usize> {
        self.shard_occupancy.iter().map(|o| o.load(Ordering::Relaxed)).collect()
    }

    /// The pipeline's (normalized) configuration.
    pub fn cfg(&self) -> &GsaConfig {
        &self.cfg
    }

    /// The first `n` seeds of the pipeline's per-graph seed stream —
    /// identical to what `embed_dataset` assigns graphs `0..n` for the
    /// same `cfg.seed`.
    pub fn graph_seeds(&self, n: usize) -> Vec<u64> {
        self.seed_rng.clone().seed_stream(n)
    }

    /// Seed of stream position `index` (O(index); request paths use
    /// small indices).
    pub fn graph_seed(&self, index: usize) -> u64 {
        let mut rng = self.seed_rng.clone();
        let mut seed = 0u64;
        for _ in 0..=index {
            seed = rng.next_u64();
        }
        seed
    }

    fn make_job(&self, job: GraphJob) -> Job {
        let ticket = self.next_ticket.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = &job.trace {
            t.stamp("admission");
        }
        Job {
            graph: job.graph,
            seed: job.seed,
            shard: (ticket % self.cfg.shards as u64) as usize,
            state: Arc::new(JobState {
                ticket,
                tag: job.tag,
                done: job.done,
                trace: job.trace,
            }),
            queued: Instant::now(),
        }
    }

    /// Blocking submit: waits while the job queue is full. Errors only
    /// if the pipeline has shut down.
    pub fn submit(&self, job: GraphJob) -> Result<()> {
        let j = self.make_job(job);
        if self.jobs.push(j) {
            Ok(())
        } else {
            bail!("pipeline is shut down")
        }
    }

    /// Non-blocking submit for the serve path: a full queue is reported
    /// as [`SubmitOutcome::Overloaded`] (the job is dropped) instead of
    /// blocking the acceptor.
    pub fn try_submit(&self, job: GraphJob) -> Result<SubmitOutcome> {
        let j = self.make_job(job);
        match self.jobs.try_push(j) {
            TryPush::Pushed => Ok(SubmitOutcome::Accepted),
            TryPush::Full => Ok(SubmitOutcome::Overloaded),
            TryPush::Closed => bail!("pipeline is shut down"),
        }
    }

    /// Live metrics: the merge of every shard's latest snapshot (the
    /// serve `stats` op). Totals lag the hot path by at most one batch.
    pub fn metrics_snapshot(&self) -> PipelineMetrics {
        let mut total = PipelineMetrics { shards: self.cfg.shards, ..Default::default() };
        for slot in &self.shard_slots {
            let snap = slot.lock().map(|g| g.clone()).unwrap_or_default();
            total.merge_shard(snap);
        }
        total
    }

    /// Close the job queue, join every worker and shard, and return the
    /// merged run metrics.
    pub fn shutdown(mut self) -> Result<PipelineMetrics> {
        self.jobs.close();
        for w in self.workers.drain(..) {
            w.join().map_err(|_| anyhow::anyhow!("sampler worker panicked"))?;
        }
        let mut total = PipelineMetrics { shards: self.cfg.shards, ..Default::default() };
        for (q, h) in self.shard_handles.drain(..).enumerate() {
            let m = h.join().map_err(|_| anyhow::anyhow!("feature shard {q} panicked"))?;
            total.merge_shard(m);
        }
        Ok(total)
    }
}

impl Drop for StreamingPipeline {
    fn drop(&mut self) {
        // Dropping without `shutdown` (e.g. the serve daemon exiting):
        // close the queue so workers and shards drain and exit on their
        // own instead of waiting for jobs that will never come.
        self.jobs.close();
    }
}

/// Send every open partial batch and reset the packers for reuse.
fn flush_packers(packers: &mut [Packer], txs: &[ShardTx], batch: usize, d: usize) {
    for (q, p) in packers.iter_mut().enumerate() {
        if p.rows == 0 {
            continue;
        }
        let mut data = std::mem::replace(&mut p.data, vec![0.0f32; batch * d]);
        data.truncate(p.rows * d);
        let msg = Batch {
            data,
            segments: std::mem::take(&mut p.segments),
            rows: p.rows,
            sample_secs: std::mem::take(&mut p.sample_secs),
            sent_at: Instant::now(),
        };
        p.rows = 0;
        txs[q].send(Msg::Batch(msg));
    }
}

/// Sampler worker: pull jobs off the shared queue, sample each job's s
/// subgraphs in seed order, and pack rows into per-shard cross-request
/// batches. Partial batches flush when the queue idles, so a lone
/// request is never stranded behind an unfilled batch.
fn worker_loop(
    queue: &JobQueue,
    txs: &[ShardTx],
    params: &ParamSet,
    cfg: &GsaConfig,
    registry: &obs::Registry,
    worker_idx: usize,
) {
    // Register with the sampling profiler: "queue_wait" while blocked on
    // the job queue, "sample" while sampling subgraphs, "projection"
    // during inline feature maps. Stage publication is two atomic ops —
    // observation-only, never touches an RNG or a row.
    let prof = registry.threads().register("worker", worker_idx);
    let sampler = sampler_by_name(&cfg.sampler);
    let h_queue_wait = registry.histo("pipeline.queue_wait_us");
    // Inline mode projects on the worker thread, so the projection
    // histogram is recorded here; batch modes record it in shard_loop.
    let h_projection = registry.histo("shard.projection_us");
    let inline_map = match (cfg.engine, params) {
        (EngineMode::CpuInline, ParamSet::Dense(p)) => Some(CpuFeatureMap::new((**p).clone())),
        _ => None,
    };
    let d = cfg.input_dim();
    let shards = cfg.shards;
    let mut scratch: Vec<usize> = Vec::with_capacity(cfg.k);
    // One open batch per shard (batch mode only).
    let mut packers: Vec<Packer> = match inline_map {
        None => (0..shards).map(|_| Packer::new(cfg.batch, d)).collect(),
        Some(_) => Vec::new(),
    };
    // Inline-mode scratch: inputs + feature rows for one chunk.
    let (mut inline_x, mut inline_feat) = match inline_map {
        Some(_) => (vec![0.0f32; cfg.batch * d], vec![0.0f32; cfg.batch * cfg.m]),
        None => (Vec::new(), Vec::new()),
    };
    loop {
        // Take the next job; whenever the queue turns out to be empty,
        // `pop` runs the flush hook (lock released) before sleeping, so
        // in-flight requests complete instead of waiting on future
        // traffic — and a sleeping worker never pins the queue lock.
        prof.set_stage("queue_wait");
        let job = queue.pop(|| flush_packers(&mut packers, txs, cfg.batch, d));
        let Some(job) = job else { break };
        prof.set_stage("sample");
        h_queue_wait.record(job.queued.elapsed());
        if let Some(tr) = &job.state.trace {
            tr.stamp("queue_wait");
        }

        let g = &*job.graph;
        if cfg.k > g.v() {
            // Guard here as well as in the serve layer: a too-small graph
            // must fail its own request, never a shared worker thread.
            job.state.fail(format!(
                "graph has {} nodes but graphlet size k={} requires at least k",
                g.v(),
                cfg.k
            ));
            continue;
        }
        let q = job.shard;
        let mut rng = Rng::new(job.seed);
        let mut t = Timer::start();
        match &inline_map {
            Some(map) => {
                // Compute features locally; ship only the sum.
                let mut sum = vec![0.0f32; cfg.m];
                let mut done = 0usize;
                while done < cfg.s {
                    let chunk = (cfg.s - done).min(cfg.batch);
                    for r in 0..chunk {
                        let gl = sampler.sample(g, cfg.k, &mut rng, &mut scratch);
                        cfg.variant.write_input(&gl, &mut inline_x[r * d..(r + 1) * d]);
                    }
                    let proj = Instant::now();
                    prof.set_stage("projection");
                    map.map_batch(&inline_x[..chunk * d], chunk, &mut inline_feat[..chunk * cfg.m]);
                    prof.set_stage("sample");
                    h_projection.record(proj.elapsed());
                    for r in 0..chunk {
                        for (acc, &v) in
                            sum.iter_mut().zip(&inline_feat[r * cfg.m..(r + 1) * cfg.m])
                        {
                            *acc += v;
                        }
                    }
                    done += chunk;
                }
                if let Some(tr) = &job.state.trace {
                    tr.stamp("projection");
                }
                let msg = JobSum {
                    state: job.state.clone(),
                    sum,
                    samples: cfg.s,
                    sample_secs: t.elapsed_secs(),
                    sent_at: Instant::now(),
                };
                txs[q].send(Msg::Sum(msg));
            }
            None => {
                // Fill this shard's cross-request batch.
                let mut remaining = cfg.s;
                while remaining > 0 {
                    let p = &mut packers[q];
                    let take = remaining.min(cfg.batch - p.rows);
                    for r in 0..take {
                        let gl = sampler.sample(g, cfg.k, &mut rng, &mut scratch);
                        let row = p.rows + r;
                        cfg.variant.write_input(&gl, &mut p.data[row * d..(row + 1) * d]);
                    }
                    p.segments.push((job.state.clone(), take));
                    p.rows += take;
                    remaining -= take;
                    if p.rows == cfg.batch {
                        p.sample_secs += t.elapsed_secs();
                        let msg = Batch {
                            data: std::mem::replace(&mut p.data, vec![0.0f32; cfg.batch * d]),
                            segments: std::mem::take(&mut p.segments),
                            rows: cfg.batch,
                            sample_secs: std::mem::take(&mut p.sample_secs),
                            sent_at: Instant::now(),
                        };
                        p.rows = 0;
                        txs[q].send(Msg::Batch(msg));
                        t = Timer::start();
                    }
                }
                packers[q].sample_secs += t.elapsed_secs();
            }
        }
    }
    // Queue closed: flush whatever is still open before exiting.
    flush_packers(&mut packers, txs, cfg.batch, d);
}

/// This shard's executor, built inside the shard thread (PJRT handles
/// are neither Send nor Sync).
enum ShardExec {
    Pjrt { engine: Box<Engine>, exec: RfExecutor },
    Cpu(CpuFeatureMap),
    /// Structured SORF features (`cpu-sorf`): same batch contract as
    /// the dense CPU map, `O(p log p)` projection per block.
    Sorf(SorfMap),
    /// CpuInline: workers computed the features; only sums arrive here.
    Inline,
}

fn build_exec(
    spawn_spec: Option<PjrtSpawn>,
    params: &ParamSet,
    cfg: &GsaConfig,
) -> Result<ShardExec> {
    match cfg.engine {
        EngineMode::Pjrt => {
            let ParamSet::Dense(params) = params else {
                bail!("pjrt engine requires dense parameters");
            };
            let (dir, manifest, impl_) = spawn_spec.expect("pjrt spawn spec");
            let engine = Box::new(Engine::with_manifest(&dir, manifest)?);
            let exec = RfExecutor::new(&engine, &impl_, params, cfg.batch)?;
            Ok(ShardExec::Pjrt { engine, exec })
        }
        EngineMode::Cpu => {
            let ParamSet::Dense(params) = params else {
                bail!("cpu engine requires dense parameters");
            };
            Ok(ShardExec::Cpu(CpuFeatureMap::new((**params).clone())))
        }
        EngineMode::CpuSorf => {
            let ParamSet::Sorf(params) = params else {
                bail!("cpu-sorf engine requires structured parameters");
            };
            Ok(ShardExec::Sorf(SorfMap::new((**params).clone())))
        }
        EngineMode::CpuInline => Ok(ShardExec::Inline),
    }
}

/// Per-job accumulator living in exactly one shard.
struct Accum {
    sum: Vec<f32>,
    count: usize,
}

fn publish(slot: &Mutex<PipelineMetrics>, metrics: &PipelineMetrics) {
    if let Ok(mut g) = slot.lock() {
        *g = metrics.clone();
    }
}

/// Drain one shard's channel: execute batches on this shard's executor,
/// scatter rows into per-job accumulators (arrival order == sample
/// order, the determinism invariant), and deliver each job's mean row on
/// its `done` channel the moment its s-th sample lands.
#[allow(clippy::too_many_arguments)]
fn shard_loop(
    rx: Receiver<Msg>,
    spawn_spec: Option<PjrtSpawn>,
    params: &ParamSet,
    cfg: &GsaConfig,
    slot: &Mutex<PipelineMetrics>,
    occupancy: &AtomicUsize,
    registry: &obs::Registry,
    shard_idx: usize,
) -> PipelineMetrics {
    // Register with the sampling profiler under the "shard" role — the
    // role whose per-thread busy fraction feeds the `shard.busy_permille`
    // gauges and serve-bench's per-pass CPU attribution. "batch_wait"
    // while blocked on the channel, "projection" while executing.
    let prof = registry.threads().register("shard", shard_idx);
    prof.set_stage("batch_wait");
    let exec = match build_exec(spawn_spec, params, cfg) {
        Ok(exec) => exec,
        Err(e) => {
            // Setup failed (e.g. PJRT engine build): fail every job that
            // reaches this shard instead of hanging its requesters. A
            // job's rows total exactly cfg.s, so tracking seen rows lets
            // the book-keeping drop each ticket once it drained — the
            // map stays bounded by in-flight jobs even if the daemon
            // keeps serving errors for days.
            let msg = format!("feature shard setup failed: {e}");
            let mut seen_rows: HashMap<u64, usize> = HashMap::new();
            for m in rx {
                occupancy.fetch_sub(1, Ordering::Relaxed);
                match m {
                    // A Sum is the job's entire payload: fail and forget.
                    Msg::Sum(s) => s.state.fail(msg.clone()),
                    Msg::Batch(b) => {
                        for (state, rows) in b.segments {
                            let seen = seen_rows.entry(state.ticket).or_insert(0);
                            if *seen == 0 {
                                state.fail(msg.clone());
                            }
                            *seen += rows;
                            if *seen >= cfg.s {
                                seen_rows.remove(&state.ticket);
                            }
                        }
                    }
                }
            }
            return PipelineMetrics::default();
        }
    };

    let m = cfg.m;
    let inv = 1.0 / cfg.s as f32;
    let h_batch_wait = registry.histo("shard.batch_wait_us");
    let h_projection = registry.histo("shard.projection_us");
    let mut metrics = PipelineMetrics::default();
    let mut accums: HashMap<u64, Accum> = HashMap::new();
    // Tickets whose batch failed mid-run -> rows seen so far. Later
    // segments are skipped (still counted), and the entry is dropped
    // once all cfg.s rows drained, so the map stays bounded by
    // in-flight jobs in a long-lived pipeline.
    let mut failed: HashMap<u64, usize> = HashMap::new();
    let mut cpu_out = vec![0.0f32; cfg.batch * m];
    for msg in rx {
        occupancy.fetch_sub(1, Ordering::Relaxed);
        prof.set_stage("projection");
        match msg {
            Msg::Sum(js) => {
                h_batch_wait.record(js.sent_at.elapsed());
                metrics.samples += js.samples;
                metrics.sample_secs += js.sample_secs;
                metrics.batches += 1;
                metrics.graphs += 1;
                // Publish BEFORE delivering: once the Completed is
                // visible to a client, a stats snapshot must already
                // account for it.
                publish(slot, &metrics);
                let mut row = js.sum;
                for v in &mut row {
                    *v *= inv;
                }
                let _ = js.state.done.send(Completed {
                    tag: js.state.tag,
                    row,
                    samples: js.samples,
                    error: None,
                });
            }
            Msg::Batch(b) => {
                h_batch_wait.record(b.sent_at.elapsed());
                let t = Timer::start();
                let mut exec_err: Option<String> = None;
                match &exec {
                    ShardExec::Pjrt { engine, exec } => {
                        metrics.padded_rows += cfg.batch - b.rows.min(cfg.batch);
                        match exec.map(engine, &b.data, b.rows) {
                            Ok(y) => cpu_out = y,
                            Err(e) => exec_err = Some(e.to_string()),
                        }
                    }
                    ShardExec::Cpu(map) => {
                        cpu_out.resize(b.rows * m, 0.0);
                        map.map_batch(&b.data, b.rows, &mut cpu_out[..b.rows * m]);
                    }
                    ShardExec::Sorf(map) => {
                        cpu_out.resize(b.rows * m, 0.0);
                        // Batch-major panel execution with this shard's
                        // --fwht-threads budget (1 = serial panels).
                        map.map_batch_threads(
                            &b.data,
                            b.rows,
                            &mut cpu_out[..b.rows * m],
                            cfg.fwht_threads,
                        );
                    }
                    ShardExec::Inline => unreachable!("batch message in inline mode"),
                }
                if let Some(e) = exec_err {
                    for (state, rows) in &b.segments {
                        match failed.get_mut(&state.ticket) {
                            Some(seen) => *seen += rows,
                            None => {
                                // First failure for this job: count any
                                // rows already accumulated plus this
                                // segment's, then notify the requester.
                                let prior =
                                    accums.remove(&state.ticket).map_or(0, |a| a.count);
                                failed.insert(state.ticket, prior + rows);
                                state.fail(format!("feature execution failed: {e}"));
                            }
                        }
                        if failed.get(&state.ticket).is_some_and(|&seen| seen >= cfg.s) {
                            failed.remove(&state.ticket);
                        }
                    }
                    publish(slot, &metrics);
                    continue;
                }
                let dt = t.elapsed_secs();
                h_projection.record_us((dt * 1e6) as u64);
                metrics.feature_secs += dt;
                metrics.batch_latency.record(dt);
                metrics.batches += 1;
                metrics.samples += b.rows;
                metrics.sample_secs += b.sample_secs;
                // Scatter rows into per-job accumulators (sample order
                // within each job — the determinism invariant).
                let mut row0 = 0usize;
                for (state, rows) in &b.segments {
                    if let Some(tr) = &state.trace {
                        tr.stamp("projection");
                    }
                    if let Some(seen) = failed.get_mut(&state.ticket) {
                        *seen += rows;
                        if *seen >= cfg.s {
                            failed.remove(&state.ticket);
                        }
                        row0 += rows;
                        continue;
                    }
                    let acc = accums
                        .entry(state.ticket)
                        .or_insert_with(|| Accum { sum: vec![0.0f32; m], count: 0 });
                    for r in row0..row0 + rows {
                        let frow = &cpu_out[r * m..(r + 1) * m];
                        for (a, &v) in acc.sum.iter_mut().zip(frow) {
                            *a += v;
                        }
                    }
                    acc.count += rows;
                    row0 += rows;
                    if acc.count >= cfg.s {
                        let mut done = accums.remove(&state.ticket).expect("accumulator");
                        for v in &mut done.sum {
                            *v *= inv;
                        }
                        metrics.graphs += 1;
                        // Publish BEFORE delivering (stats must never
                        // lag a reply a client already holds).
                        publish(slot, &metrics);
                        let _ = state.done.send(Completed {
                            tag: state.tag,
                            row: done.sum,
                            samples: done.count,
                            error: None,
                        });
                    }
                }
                publish(slot, &metrics);
            }
        }
        prof.set_stage("batch_wait");
    }
    publish(slot, &metrics);
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SbmConfig;
    use crate::graph::{CsrGraph, DenseGraph};
    use crate::util::Rng;

    fn cfg(engine: EngineMode) -> GsaConfig {
        GsaConfig {
            k: 3,
            s: 100,
            m: 32,
            batch: 16,
            workers: 2,
            shards: 2,
            variant: crate::features::Variant::Opu,
            engine,
            seed: 9,
            ..Default::default()
        }
    }

    #[test]
    fn streaming_matches_batch_adapter() {
        // Jobs submitted one-by-one through the persistent pipeline must
        // reproduce embed_dataset exactly (same seeds, same math) —
        // including when submitted out of index order, and for the
        // structured engine as well as the dense one.
        let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }
            .generate(&mut Rng::new(4));
        for mode in [EngineMode::Cpu, EngineMode::CpuSorf] {
            let c = cfg(mode);
            let (want, _) = super::super::pipeline::embed_dataset(&ds, &c, None).unwrap();
            let pipe = StreamingPipeline::new(&c, None).unwrap();
            let seeds = pipe.graph_seeds(ds.len());
            let (tx, rx) = std::sync::mpsc::channel();
            let mut order: Vec<usize> = (0..ds.len()).collect();
            order.reverse();
            for g_idx in order {
                pipe.submit(GraphJob {
                    graph: Arc::new(ds.graphs[g_idx].clone()),
                    seed: seeds[g_idx],
                    tag: g_idx as u64,
                    done: tx.clone(),
                    trace: None,
                })
                .unwrap();
            }
            drop(tx);
            let mut got = vec![0.0f32; want.len()];
            for _ in 0..ds.len() {
                let done = rx.recv().unwrap();
                assert!(done.error.is_none(), "{:?}", done.error);
                let g = done.tag as usize;
                got[g * 32..(g + 1) * 32].copy_from_slice(&done.row);
            }
            let metrics = pipe.shutdown().unwrap();
            assert_eq!(got, want, "{mode:?}");
            assert_eq!(metrics.samples, ds.len() * 100);
            assert_eq!(metrics.graphs, ds.len());
        }
    }

    #[test]
    fn graph_smaller_than_k_fails_its_own_job_only() {
        let c = cfg(EngineMode::Cpu);
        let pipe = StreamingPipeline::new(&c, None).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let tiny = {
            let mut g = DenseGraph::new(2);
            g.add_edge(0, 1);
            AnyGraph::Dense(g)
        };
        pipe.submit(GraphJob {
            graph: Arc::new(tiny),
            seed: 1,
            tag: 7,
            done: tx.clone(),
            trace: None,
        })
        .unwrap();
        let c1 = rx.recv().unwrap();
        assert_eq!(c1.tag, 7);
        let err = c1.error.expect("too-small graph must fail");
        assert!(err.contains("graphlet size"), "{err}");
        // The pipeline is still healthy: a valid job completes.
        let ok_graph = AnyGraph::Csr(CsrGraph::from_edges(
            6,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)],
        ));
        pipe.submit(GraphJob { graph: Arc::new(ok_graph), seed: 2, tag: 8, done: tx, trace: None })
            .unwrap();
        let c2 = rx.recv().unwrap();
        assert!(c2.error.is_none());
        assert_eq!(c2.tag, 8);
        assert_eq!(c2.samples, 100);
        assert!(c2.row.iter().all(|v| v.is_finite()));
        pipe.shutdown().unwrap();
    }

    #[test]
    fn try_submit_reports_overload_on_full_queue() {
        // One slow worker + minimal queue: a burst of non-blocking
        // submits must hit the admission-control bound.
        let mut c = cfg(EngineMode::Cpu);
        c.workers = 1;
        c.shards = 1;
        c.queue_cap = 1;
        c.s = 4000; // keep the single worker busy during the burst
        let pipe = StreamingPipeline::new(&c, None).unwrap();
        let ds = SbmConfig { per_class: 1, r: 1.5, ..Default::default() }
            .generate(&mut Rng::new(2));
        let g = Arc::new(ds.graphs[0].clone());
        let (tx, rx) = std::sync::mpsc::channel();
        let mut accepted = 0usize;
        let mut overloaded = 0usize;
        for i in 0..32u64 {
            match pipe
                .try_submit(GraphJob {
                    graph: g.clone(),
                    seed: i,
                    tag: i,
                    done: tx.clone(),
                    trace: None,
                })
                .unwrap()
            {
                SubmitOutcome::Accepted => accepted += 1,
                SubmitOutcome::Overloaded => overloaded += 1,
            }
        }
        drop(tx);
        assert!(overloaded > 0, "queue of capacity 1 absorbed 32 instant submits");
        assert!(accepted > 0);
        for _ in 0..accepted {
            let done = rx.recv().unwrap();
            assert!(done.error.is_none());
        }
        pipe.shutdown().unwrap();
    }

    #[test]
    fn queue_depth_and_occupancy_observable_then_drain() {
        // One worker pinned on a long job: later submits must be
        // visible as queue depth before the admission bound trips, and
        // the gauges must read clean (zero) once everything drains.
        let mut c = cfg(EngineMode::Cpu);
        c.workers = 1;
        c.shards = 2;
        c.s = 20_000; // job 1 keeps the lone worker busy for a while
        let pipe = StreamingPipeline::new(&c, None).unwrap();
        assert_eq!(pipe.queue_depth(), 0);
        assert_eq!(pipe.shard_occupancy(), [0, 0]);
        let ds = SbmConfig { per_class: 2, r: 1.5, ..Default::default() }
            .generate(&mut Rng::new(3));
        let g = Arc::new(ds.graphs[0].clone());
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..4u64 {
            pipe.submit(GraphJob {
                graph: g.clone(),
                seed: i,
                tag: i,
                done: tx.clone(),
                trace: None,
            })
            .unwrap();
        }
        drop(tx);
        // The single worker claims at most one job instantly; the rest
        // sit in the queue while it samples 20k subgraphs.
        assert!(pipe.queue_depth() > 0, "backlog behind a busy worker must be visible");
        assert_eq!(pipe.shard_occupancy().len(), 2);
        for _ in 0..4 {
            let done = rx.recv().unwrap();
            assert!(done.error.is_none(), "{:?}", done.error);
        }
        // All jobs delivered: the queue is empty by construction, and
        // every sent batch was drained before its job could complete.
        assert_eq!(pipe.queue_depth(), 0);
        assert_eq!(pipe.shard_occupancy(), [0, 0]);
        pipe.shutdown().unwrap();
    }

    #[test]
    fn sorf_fwht_threads_do_not_move_bits_through_the_pipeline() {
        // The per-shard FWHT budget is a scheduling knob: streaming
        // embeddings must be bitwise identical across budgets.
        let ds = SbmConfig { per_class: 3, r: 1.5, ..Default::default() }
            .generate(&mut Rng::new(4));
        let run = |fwht_threads: usize| {
            let mut c = cfg(EngineMode::CpuSorf);
            c.fwht_threads = fwht_threads;
            super::super::pipeline::embed_dataset(&ds, &c, None).unwrap().0
        };
        let reference = run(1);
        for threads in [2usize, 4] {
            assert_eq!(run(threads), reference, "fwht_threads={threads}");
        }
    }

    #[test]
    fn graph_seed_matches_seed_stream() {
        let c = cfg(EngineMode::Cpu);
        let pipe = StreamingPipeline::new(&c, None).unwrap();
        let seeds = pipe.graph_seeds(8);
        for (i, &s) in seeds.iter().enumerate() {
            assert_eq!(pipe.graph_seed(i), s);
        }
        pipe.shutdown().unwrap();
    }
}
