//! The GSA-phi coordinator: dataset -> sampler workers -> per-shard
//! batchers -> N feature-engine shards -> merge -> per-graph averaging
//! -> embeddings.
//!
//! This is the L3 "system" of the reproduction (DESIGN.md §3): a
//! multi-threaded dataflow with bounded channels for backpressure.
//! Sampler workers (std::thread, seeded per *graph* so scheduling never
//! changes results) draw subgraphs and pack their feature-map inputs
//! into cross-graph batches of exactly the artifact's batch size — one
//! open batch per feature shard, routed by the deterministic assignment
//! `graph g -> shard g % shards`. Each shard owns its own executor (a
//! PJRT engine + [`crate::runtime::RfExecutor`], or a CPU map clone) and
//! its own per-graph accumulators; the merge stage copies the disjoint
//! per-shard results into the output matrix, so embeddings are **bitwise
//! identical for every shard and worker count**. PJRT handles are not
//! `Sync`, which is why each shard thread constructs its own engine
//! (from a shared parsed manifest) rather than sharing one. Python never
//! runs here.

pub mod metrics;
pub mod pipeline;

pub use metrics::PipelineMetrics;
pub use pipeline::{embed_dataset, EngineMode, GsaConfig};
