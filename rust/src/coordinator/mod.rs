//! The GSA-phi coordinator: a persistent streaming dataflow — sampler
//! workers -> per-shard batchers -> N feature-engine shards -> per-job
//! accumulators -> embeddings — plus the one-shot dataset adapter on
//! top.
//!
//! This is the L3 "system" of the reproduction (DESIGN.md §3): a
//! multi-threaded dataflow with bounded channels for backpressure.
//! Since the serve subsystem landed, the dataflow is a long-lived
//! [`StreamingPipeline`]: graphs enter as tagged jobs (from a one-shot
//! `embed_dataset` call *or* from concurrent network requests), sampler
//! workers pack rows from different jobs into cross-request batches of
//! exactly the artifact's batch size, and finished per-graph embeddings
//! stream back out on each job's own completion channel. Each feature
//! shard owns its own executor (a PJRT engine +
//! [`crate::runtime::RfExecutor`], or a CPU map clone) and its own
//! per-job accumulators, so embeddings are **bitwise identical for
//! every shard and worker count** — see [`streaming`] for the stage
//! diagram and invariants, [`pipeline`] for the batch adapter. PJRT
//! handles are not `Sync`, which is why each shard thread constructs
//! its own engine (from a shared parsed manifest) rather than sharing
//! one. Python never runs here.

pub mod metrics;
pub mod pipeline;
pub mod streaming;

pub use metrics::PipelineMetrics;
pub use pipeline::{embed_dataset, fwht_threads_from_env_or, EngineMode, GsaConfig};
pub use streaming::{Completed, GraphJob, StreamingPipeline, SubmitOutcome};
