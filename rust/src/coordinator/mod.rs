//! The GSA-phi coordinator: dataset -> sampler workers -> dynamic batcher
//! -> feature engine -> per-graph averaging -> embeddings.
//!
//! This is the L3 "system" of the reproduction (DESIGN.md §3): a
//! multi-threaded dataflow with bounded channels for backpressure.
//! Sampler workers (std::thread, seeded independently via `Rng::fork`)
//! draw subgraphs and pack their feature-map inputs into *cross-graph*
//! batches of exactly the artifact's batch size; the feature engine —
//! which owns the PJRT handles, confined to one thread because they are
//! not `Sync` — executes batches as they arrive and scatters feature rows
//! into per-graph accumulators. Python never runs here.

pub mod metrics;
pub mod pipeline;

pub use metrics::PipelineMetrics;
pub use pipeline::{embed_dataset, EngineMode, GsaConfig};
