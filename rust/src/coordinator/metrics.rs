//! Pipeline metrics: what the coordinator reports after an embedding run.
//!
//! With the sharded executor each feature shard accumulates its own
//! [`PipelineMetrics`] locally (no cross-thread contention on the hot
//! path); the coordinator folds them together with [`merge_shard`] at
//! join time and keeps the per-shard feature busy-times around so load
//! imbalance is visible in the report.
//!
//! [`merge_shard`]: PipelineMetrics::merge_shard

use crate::util::Stats;

/// Aggregated counters/timings for one `embed_dataset` run.
#[derive(Debug, Default, Clone)]
pub struct PipelineMetrics {
    /// Graphs embedded.
    pub graphs: usize,
    /// Total subgraph samples drawn.
    pub samples: usize,
    /// Batches executed by the feature engines.
    pub batches: usize,
    /// Rows that were padding (partial final batches).
    pub padded_rows: usize,
    /// Wall-clock of the whole run (seconds).
    pub wall_secs: f64,
    /// Cumulative sampler-thread busy time (seconds, summed over workers).
    pub sample_secs: f64,
    /// Feature-engine execution time (seconds, summed over shards).
    pub feature_secs: f64,
    /// Per-batch feature latency (merged over shards).
    pub batch_latency: Stats,
    /// Feature-engine shard count of the run (1 = unsharded).
    pub shards: usize,
    /// Per-shard feature busy time, indexed by shard id (merge order).
    pub shard_feature_secs: Vec<f64>,
}

impl PipelineMetrics {
    /// Throughput in subgraph samples per wall second.
    pub fn samples_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.samples as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Fold one shard's locally-accumulated metrics into the run total.
    /// Counter fields add; `shard_feature_secs` records the shard's own
    /// feature time so imbalance stays observable after the merge.
    pub fn merge_shard(&mut self, shard: PipelineMetrics) {
        self.graphs += shard.graphs;
        self.samples += shard.samples;
        self.batches += shard.batches;
        self.padded_rows += shard.padded_rows;
        self.sample_secs += shard.sample_secs;
        self.feature_secs += shard.feature_secs;
        self.batch_latency.merge(&shard.batch_latency);
        self.shard_feature_secs.push(shard.feature_secs);
    }

    /// Max/mean ratio of per-shard feature busy time (1.0 = perfectly
    /// balanced; meaningful only when `shards > 1`).
    pub fn shard_imbalance(&self) -> f64 {
        if self.shard_feature_secs.len() < 2 {
            return 1.0;
        }
        let max = self.shard_feature_secs.iter().cloned().fold(0.0f64, f64::max);
        let mean = self.shard_feature_secs.iter().sum::<f64>()
            / self.shard_feature_secs.len() as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }

    pub fn report(&self) -> String {
        let mut out = format!(
            "graphs={} samples={} batches={} padded_rows={} wall={:.2}s \
             sample_busy={:.2}s feature={:.2}s throughput={:.0} samples/s \
             batch_p50={:.2}ms p95={:.2}ms shards={}",
            self.graphs,
            self.samples,
            self.batches,
            self.padded_rows,
            self.wall_secs,
            self.sample_secs,
            self.feature_secs,
            self.samples_per_sec(),
            self.batch_latency.percentile(50.0) * 1e3,
            self.batch_latency.percentile(95.0) * 1e3,
            self.shards.max(1),
        );
        if self.shard_feature_secs.len() > 1 {
            out.push_str(&format!(" shard_imbalance={:.2}", self.shard_imbalance()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_report() {
        let mut m = PipelineMetrics {
            samples: 1000,
            wall_secs: 2.0,
            graphs: 10,
            ..Default::default()
        };
        m.batch_latency.record(0.01);
        assert_eq!(m.samples_per_sec(), 500.0);
        let r = m.report();
        assert!(r.contains("graphs=10"), "{r}");
        assert!(r.contains("500 samples/s"), "{r}");
        assert!(r.contains("shards=1"), "{r}");
    }

    #[test]
    fn zero_wall_clock_safe() {
        let m = PipelineMetrics::default();
        assert_eq!(m.samples_per_sec(), 0.0);
    }

    #[test]
    fn merge_shard_adds_counters_and_tracks_imbalance() {
        let mut total = PipelineMetrics { shards: 2, ..Default::default() };
        let mut a = PipelineMetrics {
            samples: 300,
            batches: 3,
            feature_secs: 1.0,
            ..Default::default()
        };
        a.batch_latency.record(0.01);
        let b = PipelineMetrics {
            samples: 200,
            batches: 2,
            feature_secs: 3.0,
            ..Default::default()
        };
        total.merge_shard(a);
        total.merge_shard(b);
        assert_eq!(total.samples, 500);
        assert_eq!(total.batches, 5);
        assert_eq!(total.feature_secs, 4.0);
        assert_eq!(total.shard_feature_secs, vec![1.0, 3.0]);
        assert!((total.shard_imbalance() - 1.5).abs() < 1e-12);
        let r = total.report();
        assert!(r.contains("shards=2"), "{r}");
        assert!(r.contains("shard_imbalance=1.50"), "{r}");
    }
}
