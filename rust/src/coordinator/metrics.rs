//! Pipeline metrics: what the coordinator reports after an embedding run.

use crate::util::Stats;

/// Aggregated counters/timings for one `embed_dataset` run.
#[derive(Debug, Default, Clone)]
pub struct PipelineMetrics {
    /// Graphs embedded.
    pub graphs: usize,
    /// Total subgraph samples drawn.
    pub samples: usize,
    /// Batches executed by the feature engine.
    pub batches: usize,
    /// Rows that were padding (partial final batch).
    pub padded_rows: usize,
    /// Wall-clock of the whole run (seconds).
    pub wall_secs: f64,
    /// Cumulative sampler-thread busy time (seconds, summed over workers).
    pub sample_secs: f64,
    /// Feature-engine execution time (seconds).
    pub feature_secs: f64,
    /// Per-batch feature latency.
    pub batch_latency: Stats,
}

impl PipelineMetrics {
    /// Throughput in subgraph samples per wall second.
    pub fn samples_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.samples as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "graphs={} samples={} batches={} padded_rows={} wall={:.2}s \
             sample_busy={:.2}s feature={:.2}s throughput={:.0} samples/s \
             batch_p50={:.2}ms p95={:.2}ms",
            self.graphs,
            self.samples,
            self.batches,
            self.padded_rows,
            self.wall_secs,
            self.sample_secs,
            self.feature_secs,
            self.samples_per_sec(),
            self.batch_latency.percentile(50.0) * 1e3,
            self.batch_latency.percentile(95.0) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_report() {
        let mut m = PipelineMetrics::default();
        m.samples = 1000;
        m.wall_secs = 2.0;
        m.graphs = 10;
        m.batch_latency.record(0.01);
        assert_eq!(m.samples_per_sec(), 500.0);
        let r = m.report();
        assert!(r.contains("graphs=10"), "{r}");
        assert!(r.contains("500 samples/s"), "{r}");
    }

    #[test]
    fn zero_wall_clock_safe() {
        let m = PipelineMetrics::default();
        assert_eq!(m.samples_per_sec(), 0.0);
    }
}
