//! The embedding pipeline (Alg. 1 of the paper, as a sharded dataflow
//! system).
//!
//! ```text
//!   graphs ──► sampler workers ──► per-shard bounded channels ──► feature shards
//!              (std::thread x W)    (graph g → shard g mod N)      (N x RfExecutor
//!               sample s subgraphs   (backpressure per shard)       or CPU map, one
//!               pack per-shard                                      thread each)
//!               batches of B rows                                        │
//!                                                                        ▼
//!                                                          per-shard partial sums
//!                                                                        │ merge
//!                                                                        ▼ (copy)
//!                                                     per-graph mean over s ──► (n, m)
//! ```
//!
//! Design notes:
//! - **Sharding**: `cfg.shards` feature engines run in parallel, each
//!   owning its own executor ([`RfExecutor`] + its own PJRT engine, or a
//!   [`CpuFeatureMap`] clone). Graph `g` is assigned to shard
//!   `g % shards` — a pure function of the graph index — so each graph's
//!   accumulator lives in exactly one shard and the merge is a plain
//!   copy into the output matrix, never a float re-reduction.
//! - **Determinism**: workers fork seeded RNG streams per *graph* (not
//!   per worker), every graph is sampled by exactly one worker in sample
//!   order, and each shard accumulates its graphs' rows in that same
//!   order. Embeddings are therefore **bitwise identical** for any
//!   worker count and any shard count (tests pin this).
//! - **Cross-graph batching**: a batch carries `(graph, rows)` segments
//!   so executed batches have exactly the artifact's compiled size B.
//!   Workers keep one open batch per shard; padding happens at most
//!   `workers x shards` times per run (the final flushes).
//! - **Backpressure**: each shard channel holds at most `queue_cap`
//!   batches; samplers block when a feature shard falls behind, bounding
//!   memory at O(shards * queue_cap * B * d).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::metrics::PipelineMetrics;
use crate::data::Dataset;
use crate::features::{CpuFeatureMap, RfParams, Variant};
use crate::runtime::{Engine, RfExecutor};
use crate::sample::sampler_by_name;
use crate::util::{Rng, Timer};

/// Which feature engine executes batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// AOT artifacts over PJRT (the paper's OPU stand-in; default).
    Pjrt,
    /// Rust CPU fallback on the feature-engine thread(s).
    Cpu,
    /// CPU features computed inside the sampler workers; only per-graph
    /// sums cross the channel. Perf ablation (EXPERIMENTS.md §Perf).
    CpuInline,
}

impl EngineMode {
    /// Parse an engine name; bad input is an `Err`, not a panic, so CLI
    /// callers can fail gracefully.
    pub fn parse(s: &str) -> Result<EngineMode> {
        Ok(match s {
            "pjrt" => EngineMode::Pjrt,
            "cpu" => EngineMode::Cpu,
            "cpu-inline" => EngineMode::CpuInline,
            other => bail!("unknown engine {other:?} (expected pjrt|cpu|cpu-inline)"),
        })
    }
}

/// Configuration of one GSA-phi embedding run.
#[derive(Clone, Debug)]
pub struct GsaConfig {
    /// Graphlet size.
    pub k: usize,
    /// Samples per graph (s in the paper).
    pub s: usize,
    /// Number of random features (m).
    pub m: usize,
    pub variant: Variant,
    /// Artifact implementation: "xla" (fused fast path) or "pallas".
    pub impl_: String,
    /// "uniform" | "rw".
    pub sampler: String,
    /// Gaussian kernel bandwidth (phi_Gs / phi_Gs+eig only).
    pub sigma: f32,
    /// Batch size (must match a compiled artifact for PJRT mode).
    pub batch: usize,
    /// Sampler worker threads.
    pub workers: usize,
    /// Bounded queue capacity per shard (batches in flight).
    pub queue_cap: usize,
    /// Feature-engine shards. Graph `g` maps to shard `g % shards`;
    /// results are bitwise independent of the count. In PJRT mode each
    /// shard constructs its own engine over the same artifacts.
    pub shards: usize,
    pub engine: EngineMode,
    pub seed: u64,
}

impl Default for GsaConfig {
    fn default() -> Self {
        GsaConfig {
            k: 6,
            s: 2000,
            m: 5000,
            variant: Variant::Opu,
            impl_: "xla".into(),
            sampler: "rw".into(),
            sigma: 0.1,
            batch: 256,
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8),
            queue_cap: 8,
            shards: 1,
            engine: EngineMode::Pjrt,
            seed: 0,
        }
    }
}

impl GsaConfig {
    pub fn input_dim(&self) -> usize {
        self.variant.input_dim(self.k)
    }
}

/// A batch in flight: row-major input rows + the (graph, rows) segments
/// they belong to. All segments of one batch target the same shard.
struct Batch {
    data: Vec<f32>,
    segments: Vec<(usize, usize)>,
    rows: usize,
    /// Sampler busy-time attributed to this batch (metrics).
    sample_secs: f64,
}

/// Message from CpuInline workers: a finished per-graph feature sum.
struct GraphSum {
    graph: usize,
    sum: Vec<f32>,
    samples: usize,
    sample_secs: f64,
}

enum Msg {
    Batch(Batch),
    Sum(GraphSum),
}

/// One open cross-graph batch a worker is filling for one shard.
struct Packer {
    data: Vec<f32>,
    rows: usize,
    segments: Vec<(usize, usize)>,
    sample_secs: f64,
}

impl Packer {
    fn new(batch: usize, d: usize) -> Packer {
        Packer { data: vec![0.0f32; batch * d], rows: 0, segments: Vec::new(), sample_secs: 0.0 }
    }
}

/// What one feature shard hands back at join time.
struct ShardResult {
    /// Row-major (n_local, m) partial sums; local slot `l` holds graph
    /// `l * shards + shard`.
    sums: Vec<f32>,
    counts: Vec<usize>,
    metrics: PipelineMetrics,
}

/// Number of graphs owned by `shard` out of `n` under round-robin.
fn shard_len(n: usize, shard: usize, shards: usize) -> usize {
    n / shards + usize::from(shard < n % shards)
}

/// Drain one shard's channel: execute batches on this shard's engine,
/// accumulate per-graph sums (local slot = graph / shards).
fn run_feature_shard(
    rx: Receiver<Msg>,
    pjrt: Option<(&Engine, &RfExecutor)>,
    cpu_map: Option<&CpuFeatureMap>,
    cfg: &GsaConfig,
    n: usize,
    shard: usize,
    shards: usize,
) -> Result<ShardResult> {
    let m = cfg.m;
    let n_local = shard_len(n, shard, shards);
    let mut sums = vec![0.0f32; n_local * m];
    let mut counts = vec![0usize; n_local];
    let mut metrics = PipelineMetrics::default();
    let mut cpu_out = vec![0.0f32; cfg.batch * m];
    for msg in rx {
        match msg {
            Msg::Sum(gs) => {
                debug_assert_eq!(gs.graph % shards, shard);
                let local = gs.graph / shards;
                metrics.samples += gs.samples;
                metrics.sample_secs += gs.sample_secs;
                metrics.batches += 1;
                counts[local] += gs.samples;
                let row = &mut sums[local * m..(local + 1) * m];
                for (acc, v) in row.iter_mut().zip(gs.sum) {
                    *acc += v;
                }
            }
            Msg::Batch(b) => {
                let t = Timer::start();
                let feats: &[f32] = match (pjrt, cpu_map) {
                    (Some((engine, exec)), _) => {
                        metrics.padded_rows += cfg.batch - b.rows.min(cfg.batch);
                        cpu_out = exec.map(engine, &b.data, b.rows)?;
                        &cpu_out
                    }
                    (None, Some(map)) => {
                        cpu_out.resize(b.rows * m, 0.0);
                        map.map_batch(&b.data, b.rows, &mut cpu_out[..b.rows * m]);
                        &cpu_out[..b.rows * m]
                    }
                    _ => unreachable!("batch message in inline mode"),
                };
                let dt = t.elapsed_secs();
                metrics.feature_secs += dt;
                metrics.batch_latency.record(dt);
                metrics.batches += 1;
                metrics.samples += b.rows;
                metrics.sample_secs += b.sample_secs;
                // Scatter rows into per-graph accumulators (sample order
                // within each graph — the determinism invariant).
                let mut row0 = 0usize;
                for (g_idx, rows) in b.segments {
                    debug_assert_eq!(g_idx % shards, shard);
                    let local = g_idx / shards;
                    counts[local] += rows;
                    let acc = &mut sums[local * m..(local + 1) * m];
                    for r in row0..row0 + rows {
                        let frow = &feats[r * m..(r + 1) * m];
                        for (a, &v) in acc.iter_mut().zip(frow) {
                            *a += v;
                        }
                    }
                    row0 += rows;
                }
            }
        }
    }
    Ok(ShardResult { sums, counts, metrics })
}

/// Embed every graph of `ds`: returns row-major (n, m) embeddings and the
/// run metrics. `engine` must be Some for [`EngineMode::Pjrt`]; with
/// `shards > 1` it additionally serves as the template (artifacts dir +
/// parsed manifest) from which each shard builds its own engine.
pub fn embed_dataset(
    ds: &Dataset,
    cfg: &GsaConfig,
    engine: Option<&Engine>,
) -> Result<(Vec<f32>, PipelineMetrics)> {
    let n = ds.len();
    let d = cfg.input_dim();
    let shards = cfg.shards.max(1);
    let wall = Timer::start();

    // Shared feature parameters: one draw for the whole run (the paper's
    // W is fixed across all graphs — it's the same "device"). Every shard
    // uses the same draw, so shard count cannot change the math.
    let mut seed_rng = Rng::new(cfg.seed);
    let params = RfParams::generate(cfg.variant, d, cfg.m, cfg.sigma, &mut seed_rng);
    // Per-graph RNG seeds, independent of scheduling AND of shard count.
    let graph_seeds: Vec<u64> = seed_rng.seed_stream(n);

    if cfg.engine == EngineMode::Pjrt && engine.is_none() {
        bail!("PJRT mode requires an Engine");
    }
    // Send-able spec from which spawned shards rebuild a PJRT engine:
    // artifacts dir + the already-parsed manifest (shared artifact load).
    let pjrt_spawn = if cfg.engine == EngineMode::Pjrt && shards > 1 {
        let e = engine.unwrap();
        Some((e.dir().to_path_buf(), e.manifest().clone(), cfg.impl_.clone()))
    } else {
        None
    };

    let next_graph = Arc::new(AtomicUsize::new(0));
    let mut txs: Vec<SyncSender<Msg>> = Vec::with_capacity(shards);
    let mut rxs: Vec<Receiver<Msg>> = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap.max(1));
        txs.push(tx);
        rxs.push(rx);
    }

    let mut metrics = PipelineMetrics::default();
    metrics.graphs = n;
    metrics.shards = shards;

    let sums = std::thread::scope(|scope| -> Result<Vec<f32>> {
        // ---- sampler workers ------------------------------------------
        for _w in 0..cfg.workers.max(1) {
            let worker_txs = txs.clone();
            let next = next_graph.clone();
            let params_ref = &params;
            let graph_seeds = &graph_seeds;
            let cfg = cfg.clone();
            let ds_ref = ds;
            scope.spawn(move || {
                let sampler = sampler_by_name(&cfg.sampler);
                let inline_map = match cfg.engine {
                    EngineMode::CpuInline => Some(CpuFeatureMap::new(params_ref.clone())),
                    _ => None,
                };
                let d = cfg.input_dim();
                let mut scratch: Vec<usize> = Vec::with_capacity(cfg.k);
                // One open batch per shard (batch mode only).
                let mut packers: Vec<Packer> = match inline_map {
                    None => (0..shards).map(|_| Packer::new(cfg.batch, d)).collect(),
                    Some(_) => Vec::new(),
                };
                // Inline-mode scratch: inputs + feature rows for one chunk.
                let (mut inline_x, mut inline_feat) = match inline_map {
                    Some(_) => (vec![0.0f32; cfg.batch * d], vec![0.0f32; cfg.batch * cfg.m]),
                    None => (Vec::new(), Vec::new()),
                };
                loop {
                    let g_idx = next.fetch_add(1, Ordering::Relaxed);
                    if g_idx >= ds_ref.len() {
                        break;
                    }
                    let g = &ds_ref.graphs[g_idx];
                    let q = g_idx % shards;
                    let mut rng = Rng::new(graph_seeds[g_idx]);
                    let mut t = Timer::start();
                    match &inline_map {
                        Some(map) => {
                            // Compute features locally; ship only the sum.
                            let mut sum = vec![0.0f32; cfg.m];
                            let mut done = 0usize;
                            while done < cfg.s {
                                let chunk = (cfg.s - done).min(cfg.batch);
                                for r in 0..chunk {
                                    let gl = sampler.sample(g, cfg.k, &mut rng, &mut scratch);
                                    cfg.variant
                                        .write_input(&gl, &mut inline_x[r * d..(r + 1) * d]);
                                }
                                map.map_batch(
                                    &inline_x[..chunk * d],
                                    chunk,
                                    &mut inline_feat[..chunk * cfg.m],
                                );
                                for r in 0..chunk {
                                    for (acc, &v) in sum
                                        .iter_mut()
                                        .zip(&inline_feat[r * cfg.m..(r + 1) * cfg.m])
                                    {
                                        *acc += v;
                                    }
                                }
                                done += chunk;
                            }
                            let msg = GraphSum {
                                graph: g_idx,
                                sum,
                                samples: cfg.s,
                                sample_secs: t.elapsed_secs(),
                            };
                            if worker_txs[q].send(Msg::Sum(msg)).is_err() {
                                return;
                            }
                        }
                        None => {
                            // Fill this shard's cross-graph batch.
                            let mut remaining = cfg.s;
                            while remaining > 0 {
                                let p = &mut packers[q];
                                let take = remaining.min(cfg.batch - p.rows);
                                for r in 0..take {
                                    let gl = sampler.sample(g, cfg.k, &mut rng, &mut scratch);
                                    let row = p.rows + r;
                                    cfg.variant
                                        .write_input(&gl, &mut p.data[row * d..(row + 1) * d]);
                                }
                                p.segments.push((g_idx, take));
                                p.rows += take;
                                remaining -= take;
                                if p.rows == cfg.batch {
                                    p.sample_secs += t.elapsed_secs();
                                    let msg = Batch {
                                        data: std::mem::replace(
                                            &mut p.data,
                                            vec![0.0f32; cfg.batch * d],
                                        ),
                                        segments: std::mem::take(&mut p.segments),
                                        rows: cfg.batch,
                                        sample_secs: std::mem::take(&mut p.sample_secs),
                                    };
                                    p.rows = 0;
                                    if worker_txs[q].send(Msg::Batch(msg)).is_err() {
                                        return;
                                    }
                                    t = Timer::start();
                                }
                            }
                            packers[q].sample_secs += t.elapsed_secs();
                        }
                    }
                }
                // Flush the partial batches (one per shard at most).
                for (q, p) in packers.iter_mut().enumerate() {
                    if p.rows > 0 {
                        let mut data = std::mem::take(&mut p.data);
                        data.truncate(p.rows * d);
                        let _ = worker_txs[q].send(Msg::Batch(Batch {
                            data,
                            segments: std::mem::take(&mut p.segments),
                            rows: p.rows,
                            sample_secs: p.sample_secs,
                        }));
                    }
                }
            });
        }
        drop(txs);

        // ---- feature shards -------------------------------------------
        let mut rx_iter = rxs.into_iter();
        let (mut sums, counts) = if shards == 1 {
            // Single shard runs on this thread: required for a borrowed
            // PJRT engine (PJRT handles are not Sync), and it keeps the
            // unsharded hot path identical to the pre-sharding pipeline.
            let rx = rx_iter.next().expect("one channel");
            let rf_exec = match cfg.engine {
                EngineMode::Pjrt => {
                    Some(RfExecutor::new(engine.unwrap(), &cfg.impl_, &params, cfg.batch)?)
                }
                _ => None,
            };
            let cpu_map = match cfg.engine {
                EngineMode::Cpu => Some(CpuFeatureMap::new(params.clone())),
                _ => None,
            };
            let pjrt = rf_exec.as_ref().map(|exec| (engine.unwrap(), exec));
            let r = run_feature_shard(rx, pjrt, cpu_map.as_ref(), cfg, n, 0, 1)?;
            metrics.merge_shard(r.metrics);
            (r.sums, r.counts)
        } else {
            // One engine thread per shard; each builds its own executor.
            let mut handles = Vec::with_capacity(shards);
            for (q, rx) in rx_iter.enumerate() {
                let spawn_spec = pjrt_spawn.clone();
                let params_ref = &params;
                let cfg_ref = cfg;
                handles.push(scope.spawn(move || -> Result<ShardResult> {
                    match (cfg_ref.engine, spawn_spec) {
                        (EngineMode::Pjrt, Some((dir, manifest, impl_))) => {
                            let shard_engine = Engine::with_manifest(&dir, manifest)?;
                            let exec = RfExecutor::new(
                                &shard_engine,
                                &impl_,
                                params_ref,
                                cfg_ref.batch,
                            )?;
                            run_feature_shard(
                                rx,
                                Some((&shard_engine, &exec)),
                                None,
                                cfg_ref,
                                n,
                                q,
                                shards,
                            )
                        }
                        (EngineMode::Cpu, _) => {
                            let map = CpuFeatureMap::new(params_ref.clone());
                            run_feature_shard(rx, None, Some(&map), cfg_ref, n, q, shards)
                        }
                        _ => run_feature_shard(rx, None, None, cfg_ref, n, q, shards),
                    }
                }));
            }
            // ---- merge (copy: per-graph rows are disjoint) ------------
            let mut sums = vec![0.0f32; n * cfg.m];
            let mut counts = vec![0usize; n];
            for (q, h) in handles.into_iter().enumerate() {
                let r = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("feature shard {q} panicked"))??;
                metrics.merge_shard(r.metrics);
                for (local, row) in r.sums.chunks_exact(cfg.m).enumerate() {
                    let g_idx = local * shards + q;
                    sums[g_idx * cfg.m..(g_idx + 1) * cfg.m].copy_from_slice(row);
                    counts[g_idx] = r.counts[local];
                }
            }
            (sums, counts)
        };

        // Mean over samples (identical post-pass for every shard count).
        for g_idx in 0..n {
            anyhow::ensure!(
                counts[g_idx] == cfg.s,
                "graph {g_idx} got {} samples",
                counts[g_idx]
            );
            let inv = 1.0 / cfg.s as f32;
            for v in &mut sums[g_idx * cfg.m..(g_idx + 1) * cfg.m] {
                *v *= inv;
            }
        }
        Ok(sums)
    })?;

    metrics.wall_secs = wall.elapsed_secs();
    Ok((sums, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SbmConfig;
    use crate::runtime::artifacts_dir;
    use crate::util::check;

    fn small_ds() -> Dataset {
        SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11))
    }

    fn small_cfg(engine: EngineMode) -> GsaConfig {
        GsaConfig {
            k: 3,
            s: 100,
            m: 64,
            batch: 32,
            workers: 3,
            variant: Variant::Opu,
            engine,
            seed: 42,
            ..Default::default()
        }
    }

    #[test]
    fn cpu_modes_agree_exactly() {
        // Per-graph RNG seeding makes the embedding independent of worker
        // scheduling AND of the batching strategy.
        let ds = small_ds();
        let (e1, m1) = embed_dataset(&ds, &small_cfg(EngineMode::Cpu), None).unwrap();
        let (e2, m2) = embed_dataset(&ds, &small_cfg(EngineMode::CpuInline), None).unwrap();
        check::assert_allclose(&e1, &e2, 1e-5, 1e-5);
        assert_eq!(m1.samples, 6 * 100);
        assert_eq!(m2.graphs, 6);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let ds = small_ds();
        let mut cfg_a = small_cfg(EngineMode::Cpu);
        cfg_a.workers = 1;
        let mut cfg_b = small_cfg(EngineMode::Cpu);
        cfg_b.workers = 7;
        let (e1, _) = embed_dataset(&ds, &cfg_a, None).unwrap();
        let (e2, _) = embed_dataset(&ds, &cfg_b, None).unwrap();
        check::assert_allclose(&e1, &e2, 1e-5, 1e-5);
    }

    #[test]
    fn sharded_embeddings_bitwise_identical() {
        // The tentpole invariant: embeddings are a pure function of
        // (dataset, cfg.seed, feature math) — shard count and worker
        // count must not move a single bit.
        let ds = small_ds();
        for mode in [EngineMode::Cpu, EngineMode::CpuInline] {
            let mut ref_cfg = small_cfg(mode);
            ref_cfg.shards = 1;
            ref_cfg.workers = 1;
            let (reference, _) = embed_dataset(&ds, &ref_cfg, None).unwrap();
            for shards in [1usize, 2, 4] {
                for workers in [1usize, 4] {
                    let mut cfg = small_cfg(mode);
                    cfg.shards = shards;
                    cfg.workers = workers;
                    let (e, m) = embed_dataset(&ds, &cfg, None).unwrap();
                    assert_eq!(
                        e, reference,
                        "bitwise drift: mode={mode:?} shards={shards} workers={workers}"
                    );
                    assert_eq!(m.samples, 6 * 100);
                    assert_eq!(m.shards, shards);
                }
            }
        }
    }

    #[test]
    fn more_shards_than_graphs_is_fine() {
        let ds = small_ds(); // 6 graphs
        let mut cfg = small_cfg(EngineMode::Cpu);
        cfg.shards = 8;
        let mut ref_cfg = small_cfg(EngineMode::Cpu);
        ref_cfg.shards = 1;
        let (e, m) = embed_dataset(&ds, &cfg, None).unwrap();
        let (reference, _) = embed_dataset(&ds, &ref_cfg, None).unwrap();
        assert_eq!(e, reference);
        assert_eq!(m.shards, 8);
        assert_eq!(m.shard_feature_secs.len(), 8);
    }

    #[test]
    fn shard_metrics_cover_all_samples() {
        let ds = small_ds();
        let mut cfg = small_cfg(EngineMode::Cpu);
        cfg.shards = 3;
        let (_, m) = embed_dataset(&ds, &cfg, None).unwrap();
        assert_eq!(m.samples, 6 * 100);
        assert_eq!(m.graphs, 6);
        assert_eq!(m.shards, 3);
        assert_eq!(m.shard_feature_secs.len(), 3);
        assert!(m.batches >= 3, "each shard executes at least one batch");
        let report = m.report();
        assert!(report.contains("shards=3"), "{report}");
    }

    #[test]
    fn shard_len_partitions_exactly() {
        for n in [0usize, 1, 5, 6, 17] {
            for shards in [1usize, 2, 3, 4, 8] {
                let total: usize = (0..shards).map(|q| shard_len(n, q, shards)).sum();
                assert_eq!(total, n, "n={n} shards={shards}");
            }
        }
    }

    #[test]
    fn engine_mode_parse_roundtrip_and_errors() {
        assert_eq!(EngineMode::parse("pjrt").unwrap(), EngineMode::Pjrt);
        assert_eq!(EngineMode::parse("cpu").unwrap(), EngineMode::Cpu);
        assert_eq!(EngineMode::parse("cpu-inline").unwrap(), EngineMode::CpuInline);
        let err = EngineMode::parse("opu").unwrap_err().to_string();
        assert!(err.contains("unknown engine") && err.contains("pjrt|cpu|cpu-inline"), "{err}");
    }

    #[test]
    fn pjrt_matches_cpu_when_artifacts_present() {
        let Some(engine) = crate::runtime::try_engine(&artifacts_dir()) else {
            return;
        };
        let ds = small_ds();
        let cfg = small_cfg(EngineMode::Pjrt);
        let (e_pjrt, m) = embed_dataset(&ds, &cfg, Some(&engine)).unwrap();
        let (e_cpu, _) = embed_dataset(&ds, &small_cfg(EngineMode::Cpu), None).unwrap();
        check::assert_allclose(&e_pjrt, &e_cpu, 1e-3, 1e-4);
        assert!(m.batches > 0 && m.samples == 600);
        // Sharded PJRT: each shard builds its own engine from the shared
        // manifest; results must still match.
        let mut cfg_sharded = small_cfg(EngineMode::Pjrt);
        cfg_sharded.shards = 2;
        let (e_sharded, _) = embed_dataset(&ds, &cfg_sharded, Some(&engine)).unwrap();
        check::assert_allclose(&e_sharded, &e_pjrt, 1e-6, 1e-6);
    }

    #[test]
    fn gauss_eig_variant_runs() {
        let ds = small_ds();
        let mut cfg = small_cfg(EngineMode::Cpu);
        cfg.variant = Variant::GaussEig;
        cfg.sigma = 0.5;
        let (emb, _) = embed_dataset(&ds, &cfg, None).unwrap();
        assert_eq!(emb.len(), 6 * 64);
        assert!(emb.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn embeddings_separate_easy_classes() {
        // End-to-end sanity: r = 3 SBM should be separable from OPU
        // embeddings with a linear classifier trained on the spot — and
        // sharding must not change that.
        let ds = SbmConfig { per_class: 20, r: 3.0, ..Default::default() }
            .generate(&mut Rng::new(5));
        let mut cfg = small_cfg(EngineMode::CpuInline);
        cfg.k = 4;
        cfg.s = 300;
        cfg.m = 128;
        cfg.shards = 2;
        let (emb, _) = embed_dataset(&ds, &cfg, None).unwrap();
        let mut rng = Rng::new(1);
        let split = ds.split(0.75, &mut rng);
        let acc = crate::classify::train_and_eval(
            &emb,
            &ds.labels,
            cfg.m,
            &split.train,
            &split.test,
            &crate::classify::TrainConfig::default(),
        );
        assert!(acc > 0.8, "accuracy {acc}");
    }
}
