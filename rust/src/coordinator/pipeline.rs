//! The embedding pipeline (Alg. 1 of the paper, as a dataflow system).
//!
//! ```text
//!   graphs ──► sampler workers ──► bounded channel ──► feature engine
//!              (std::thread x W)    (backpressure)      (PJRT or CPU,
//!               sample s subgraphs                       single thread)
//!               pack cross-graph                              │
//!               batches of B rows                             ▼
//!                                                   per-graph accumulators
//!                                                    mean over s  ──► (n, m)
//! ```
//!
//! Design notes:
//! - **Cross-graph batching**: a batch carries `(graph, rows)` segments so
//!   every executed batch is exactly the artifact's compiled size B
//!   (except the final flush). Padding only ever happens once per run.
//! - **Backpressure**: the channel holds at most `queue_cap` batches;
//!   samplers block when the feature engine falls behind, bounding memory
//!   at O(queue_cap * B * d).
//! - **Determinism**: workers fork seeded RNG streams per *graph* (not per
//!   worker), so results are independent of thread scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;

use anyhow::Result;

use super::metrics::PipelineMetrics;
use crate::data::Dataset;
use crate::features::{CpuFeatureMap, RfParams, Variant};
use crate::runtime::{Engine, RfExecutor};
use crate::sample::sampler_by_name;
use crate::util::{Rng, Timer};

/// Which feature engine executes batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// AOT artifacts over PJRT (the paper's OPU stand-in; default).
    Pjrt,
    /// Rust CPU fallback on the feature-engine thread.
    Cpu,
    /// CPU features computed inside the sampler workers; only per-graph
    /// sums cross the channel. Perf ablation (EXPERIMENTS.md §Perf).
    CpuInline,
}

impl EngineMode {
    pub fn parse(s: &str) -> EngineMode {
        match s {
            "pjrt" => EngineMode::Pjrt,
            "cpu" => EngineMode::Cpu,
            "cpu-inline" => EngineMode::CpuInline,
            other => panic!("unknown engine {other:?} (pjrt|cpu|cpu-inline)"),
        }
    }
}

/// Configuration of one GSA-phi embedding run.
#[derive(Clone, Debug)]
pub struct GsaConfig {
    /// Graphlet size.
    pub k: usize,
    /// Samples per graph (s in the paper).
    pub s: usize,
    /// Number of random features (m).
    pub m: usize,
    pub variant: Variant,
    /// Artifact implementation: "xla" (fused fast path) or "pallas".
    pub impl_: String,
    /// "uniform" | "rw".
    pub sampler: String,
    /// Gaussian kernel bandwidth (phi_Gs / phi_Gs+eig only).
    pub sigma: f32,
    /// Batch size (must match a compiled artifact for PJRT mode).
    pub batch: usize,
    /// Sampler worker threads.
    pub workers: usize,
    /// Bounded queue capacity (batches in flight).
    pub queue_cap: usize,
    pub engine: EngineMode,
    pub seed: u64,
}

impl Default for GsaConfig {
    fn default() -> Self {
        GsaConfig {
            k: 6,
            s: 2000,
            m: 5000,
            variant: Variant::Opu,
            impl_: "xla".into(),
            sampler: "rw".into(),
            sigma: 0.1,
            batch: 256,
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8),
            queue_cap: 8,
            engine: EngineMode::Pjrt,
            seed: 0,
        }
    }
}

impl GsaConfig {
    pub fn input_dim(&self) -> usize {
        self.variant.input_dim(self.k)
    }
}

/// A batch in flight: row-major input rows + the (graph, rows) segments
/// they belong to.
struct Batch {
    data: Vec<f32>,
    segments: Vec<(usize, usize)>,
    rows: usize,
    /// Sampler busy-time attributed to this batch (metrics).
    sample_secs: f64,
}

/// Message from CpuInline workers: a finished per-graph feature sum.
struct GraphSum {
    graph: usize,
    sum: Vec<f32>,
    samples: usize,
    sample_secs: f64,
}

enum Msg {
    Batch(Batch),
    Sum(GraphSum),
}

/// Embed every graph of `ds`: returns row-major (n, m) embeddings and the
/// run metrics. `engine` must be Some for [`EngineMode::Pjrt`].
pub fn embed_dataset(
    ds: &Dataset,
    cfg: &GsaConfig,
    engine: Option<&Engine>,
) -> Result<(Vec<f32>, PipelineMetrics)> {
    let n = ds.len();
    let d = cfg.input_dim();
    let wall = Timer::start();

    // Shared feature parameters: one draw for the whole run (the paper's
    // W is fixed across all graphs — it's the same "device").
    let mut seed_rng = Rng::new(cfg.seed);
    let params = RfParams::generate(cfg.variant, d, cfg.m, cfg.sigma, &mut seed_rng);
    // Per-graph RNG seeds, independent of scheduling.
    let graph_seeds: Vec<u64> = (0..n).map(|_| seed_rng.next_u64()).collect();

    let next_graph = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap.max(1));

    let mut metrics = PipelineMetrics::default();
    metrics.graphs = n;

    let sums = std::thread::scope(|scope| -> Result<Vec<f32>> {
        // ---- sampler workers ------------------------------------------
        for _w in 0..cfg.workers.max(1) {
            let tx = tx.clone();
            let next = next_graph.clone();
            let params_ref = &params;
            let graph_seeds = &graph_seeds;
            let cfg = cfg.clone();
            let ds_ref = ds;
            scope.spawn(move || {
                let sampler = sampler_by_name(&cfg.sampler);
                let inline_map = match cfg.engine {
                    EngineMode::CpuInline => Some(CpuFeatureMap::new(params_ref.clone())),
                    _ => None,
                };
                let d = cfg.input_dim();
                let mut scratch: Vec<usize> = Vec::with_capacity(cfg.k);
                let mut batch_data = vec![0.0f32; cfg.batch * d];
                let mut batch_rows = 0usize;
                let mut segments: Vec<(usize, usize)> = Vec::new();
                let mut batch_sample_secs = 0.0f64;
                // Inline mode scratch: feature rows for one chunk.
                let mut feat_chunk = vec![0.0f32; if inline_map.is_some() { cfg.batch * cfg.m } else { 0 }];
                loop {
                    let g_idx = next.fetch_add(1, Ordering::Relaxed);
                    if g_idx >= ds_ref.len() {
                        break;
                    }
                    let g = &ds_ref.graphs[g_idx];
                    let mut rng = Rng::new(graph_seeds[g_idx]);
                    let mut t = Timer::start();
                    match &inline_map {
                        Some(map) => {
                            // Compute features locally; ship only the sum.
                            let mut sum = vec![0.0f32; cfg.m];
                            let mut done = 0usize;
                            while done < cfg.s {
                                let chunk = (cfg.s - done).min(cfg.batch);
                                for r in 0..chunk {
                                    let gl = sampler.sample(g, cfg.k, &mut rng, &mut scratch);
                                    cfg.variant
                                        .write_input(&gl, &mut batch_data[r * d..(r + 1) * d]);
                                }
                                map.map_batch(
                                    &batch_data[..chunk * d],
                                    chunk,
                                    &mut feat_chunk[..chunk * cfg.m],
                                );
                                for r in 0..chunk {
                                    for (acc, &v) in
                                        sum.iter_mut().zip(&feat_chunk[r * cfg.m..(r + 1) * cfg.m])
                                    {
                                        *acc += v;
                                    }
                                }
                                done += chunk;
                            }
                            let msg = GraphSum {
                                graph: g_idx,
                                sum,
                                samples: cfg.s,
                                sample_secs: t.elapsed_secs(),
                            };
                            if tx.send(Msg::Sum(msg)).is_err() {
                                return;
                            }
                        }
                        None => {
                            // Fill cross-graph batches of exactly cfg.batch.
                            let mut remaining = cfg.s;
                            while remaining > 0 {
                                let take = remaining.min(cfg.batch - batch_rows);
                                for r in 0..take {
                                    let gl = sampler.sample(g, cfg.k, &mut rng, &mut scratch);
                                    let row = batch_rows + r;
                                    cfg.variant
                                        .write_input(&gl, &mut batch_data[row * d..(row + 1) * d]);
                                }
                                segments.push((g_idx, take));
                                batch_rows += take;
                                remaining -= take;
                                if batch_rows == cfg.batch {
                                    batch_sample_secs += t.elapsed_secs();
                                    t = Timer::start();
                                    let msg = Batch {
                                        data: std::mem::replace(
                                            &mut batch_data,
                                            vec![0.0f32; cfg.batch * d],
                                        ),
                                        segments: std::mem::take(&mut segments),
                                        rows: cfg.batch,
                                        sample_secs: std::mem::take(&mut batch_sample_secs),
                                    };
                                    batch_rows = 0;
                                    if tx.send(Msg::Batch(msg)).is_err() {
                                        return;
                                    }
                                }
                            }
                        }
                    }
                }
                // Flush the partial batch.
                if batch_rows > 0 {
                    let mut data = std::mem::take(&mut batch_data);
                    data.truncate(batch_rows * d);
                    let _ = tx.send(Msg::Batch(Batch {
                        data,
                        segments: std::mem::take(&mut segments),
                        rows: batch_rows,
                        sample_secs: batch_sample_secs,
                    }));
                }
            });
        }
        drop(tx);

        // ---- feature engine (this thread; owns any PJRT handles) ------
        let rf_exec = match cfg.engine {
            EngineMode::Pjrt => {
                let engine =
                    engine.ok_or_else(|| anyhow::anyhow!("PJRT mode requires an Engine"))?;
                Some(RfExecutor::new(engine, &cfg.impl_, &params, cfg.batch)?)
            }
            _ => None,
        };
        let cpu_map = match cfg.engine {
            EngineMode::Cpu => Some(CpuFeatureMap::new(params.clone())),
            _ => None,
        };

        let mut sums = vec![0.0f32; n * cfg.m];
        let mut counts = vec![0usize; n];
        let mut cpu_out = vec![0.0f32; cfg.batch * cfg.m];
        for msg in rx {
            match msg {
                Msg::Sum(gs) => {
                    metrics.samples += gs.samples;
                    metrics.sample_secs += gs.sample_secs;
                    metrics.batches += 1;
                    counts[gs.graph] += gs.samples;
                    let row = &mut sums[gs.graph * cfg.m..(gs.graph + 1) * cfg.m];
                    for (acc, v) in row.iter_mut().zip(gs.sum) {
                        *acc += v;
                    }
                }
                Msg::Batch(b) => {
                    let t = Timer::start();
                    let feats: &[f32] = match (&rf_exec, &cpu_map) {
                        (Some(exec), _) => {
                            let engine = engine.unwrap();
                            metrics.padded_rows += cfg.batch - b.rows.min(cfg.batch);
                            cpu_out.clear();
                            cpu_out = exec.map(engine, &b.data, b.rows)?;
                            &cpu_out
                        }
                        (None, Some(map)) => {
                            cpu_out.resize(b.rows * cfg.m, 0.0);
                            map.map_batch(&b.data, b.rows, &mut cpu_out[..b.rows * cfg.m]);
                            &cpu_out[..b.rows * cfg.m]
                        }
                        _ => unreachable!("batch message in inline mode"),
                    };
                    let dt = t.elapsed_secs();
                    metrics.feature_secs += dt;
                    metrics.batch_latency.record(dt);
                    metrics.batches += 1;
                    metrics.samples += b.rows;
                    metrics.sample_secs += b.sample_secs;
                    // Scatter rows into per-graph accumulators.
                    let mut row0 = 0usize;
                    for (g_idx, rows) in b.segments {
                        counts[g_idx] += rows;
                        let acc = &mut sums[g_idx * cfg.m..(g_idx + 1) * cfg.m];
                        for r in row0..row0 + rows {
                            let frow = &feats[r * cfg.m..(r + 1) * cfg.m];
                            for (a, &v) in acc.iter_mut().zip(frow) {
                                *a += v;
                            }
                        }
                        row0 += rows;
                    }
                }
            }
        }
        // Mean over samples.
        for g_idx in 0..n {
            anyhow::ensure!(counts[g_idx] == cfg.s, "graph {g_idx} got {} samples", counts[g_idx]);
            let inv = 1.0 / cfg.s as f32;
            for v in &mut sums[g_idx * cfg.m..(g_idx + 1) * cfg.m] {
                *v *= inv;
            }
        }
        Ok(sums)
    })?;

    metrics.wall_secs = wall.elapsed_secs();
    Ok((sums, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SbmConfig;
    use crate::runtime::artifacts_dir;
    use crate::util::check;

    fn small_ds() -> Dataset {
        SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11))
    }

    fn small_cfg(engine: EngineMode) -> GsaConfig {
        GsaConfig {
            k: 3,
            s: 100,
            m: 64,
            batch: 32,
            workers: 3,
            variant: Variant::Opu,
            engine,
            seed: 42,
            ..Default::default()
        }
    }

    #[test]
    fn cpu_modes_agree_exactly() {
        // Per-graph RNG seeding makes the embedding independent of worker
        // scheduling AND of the batching strategy.
        let ds = small_ds();
        let (e1, m1) = embed_dataset(&ds, &small_cfg(EngineMode::Cpu), None).unwrap();
        let (e2, m2) = embed_dataset(&ds, &small_cfg(EngineMode::CpuInline), None).unwrap();
        check::assert_allclose(&e1, &e2, 1e-5, 1e-5);
        assert_eq!(m1.samples, 6 * 100);
        assert_eq!(m2.graphs, 6);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let ds = small_ds();
        let mut cfg_a = small_cfg(EngineMode::Cpu);
        cfg_a.workers = 1;
        let mut cfg_b = small_cfg(EngineMode::Cpu);
        cfg_b.workers = 7;
        let (e1, _) = embed_dataset(&ds, &cfg_a, None).unwrap();
        let (e2, _) = embed_dataset(&ds, &cfg_b, None).unwrap();
        check::assert_allclose(&e1, &e2, 1e-5, 1e-5);
    }

    #[test]
    fn pjrt_matches_cpu_when_artifacts_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let engine = Engine::new(&dir).unwrap();
        let ds = small_ds();
        let cfg = small_cfg(EngineMode::Pjrt);
        let (e_pjrt, m) = embed_dataset(&ds, &cfg, Some(&engine)).unwrap();
        let (e_cpu, _) = embed_dataset(&ds, &small_cfg(EngineMode::Cpu), None).unwrap();
        check::assert_allclose(&e_pjrt, &e_cpu, 1e-3, 1e-4);
        assert!(m.batches > 0 && m.samples == 600);
    }

    #[test]
    fn gauss_eig_variant_runs() {
        let ds = small_ds();
        let mut cfg = small_cfg(EngineMode::Cpu);
        cfg.variant = Variant::GaussEig;
        cfg.sigma = 0.5;
        let (emb, _) = embed_dataset(&ds, &cfg, None).unwrap();
        assert_eq!(emb.len(), 6 * 64);
        assert!(emb.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn embeddings_separate_easy_classes() {
        // End-to-end sanity: r = 3 SBM should be separable from OPU
        // embeddings with a linear classifier trained on the spot.
        let ds = SbmConfig { per_class: 20, r: 3.0, ..Default::default() }
            .generate(&mut Rng::new(5));
        let mut cfg = small_cfg(EngineMode::CpuInline);
        cfg.k = 4;
        cfg.s = 300;
        cfg.m = 128;
        let (emb, _) = embed_dataset(&ds, &cfg, None).unwrap();
        let mut rng = Rng::new(1);
        let split = ds.split(0.75, &mut rng);
        let acc = crate::classify::train_and_eval(
            &emb,
            &ds.labels,
            cfg.m,
            &split.train,
            &split.test,
            &crate::classify::TrainConfig::default(),
        );
        assert!(acc > 0.8, "accuracy {acc}");
    }
}
