//! The batch embedding entrypoint (Alg. 1 of the paper) as a thin
//! adapter over the persistent streaming core.
//!
//! ```text
//!   embed_dataset(ds, cfg, engine)
//!       │  build StreamingPipeline (workers + shards, one param draw)
//!       │  submit one GraphJob per graph (seed = per-graph seed stream)
//!       │  collect n Completed rows (order-independent: tagged by index)
//!       │  shutdown → merged PipelineMetrics
//!       ▼
//!   row-major (n, m) embeddings — bitwise identical to the historical
//!   batch pipeline for every worker/shard count (pinned by the tests
//!   below and in tests/integration.rs).
//! ```
//!
//! The dataflow itself — sampler workers, per-shard bounded channels,
//! cross-request batching, per-job accumulators — lives in
//! [`super::streaming`]; see its module docs for the stage diagram and
//! invariants. This module owns the run *configuration* ([`GsaConfig`],
//! [`EngineMode`]) and the one-shot dataset adapter.

use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::metrics::PipelineMetrics;
use super::streaming::{GraphJob, StreamingPipeline};
use crate::data::Dataset;
use crate::features::Variant;
use crate::obs::{self, TraceCtx};
use crate::runtime::Engine;
use crate::util::Timer;

/// Which feature engine executes batches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineMode {
    /// AOT artifacts over PJRT (the paper's OPU stand-in; default).
    Pjrt,
    /// Rust CPU fallback on the feature-engine thread(s).
    Cpu,
    /// CPU features computed inside the sampler workers; only per-graph
    /// sums cross the channel. Perf ablation (EXPERIMENTS.md §Perf).
    CpuInline,
    /// Structured random features (SORF) on the feature shards:
    /// `HD`-product blocks via the in-place FWHT, `O(p log p)` per
    /// block instead of the dense `O(d·m)` — see [`crate::fastrf`].
    /// A different random-feature *family* than `cpu` (statistically
    /// equivalent, not bitwise), still deterministic per seed.
    CpuSorf,
}

impl EngineMode {
    /// Parse an engine name; bad input is an `Err`, not a panic, so CLI
    /// callers can fail gracefully.
    pub fn parse(s: &str) -> Result<EngineMode> {
        Ok(match s {
            "pjrt" => EngineMode::Pjrt,
            "cpu" => EngineMode::Cpu,
            "cpu-inline" => EngineMode::CpuInline,
            "cpu-sorf" => EngineMode::CpuSorf,
            other => bail!("unknown engine {other:?} (expected pjrt|cpu|cpu-inline|cpu-sorf)"),
        })
    }

    /// The CLI name of this mode (inverse of [`parse`](Self::parse)) —
    /// what the serve banner and `stats.server.engine` report.
    pub fn name(self) -> &'static str {
        match self {
            EngineMode::Pjrt => "pjrt",
            EngineMode::Cpu => "cpu",
            EngineMode::CpuInline => "cpu-inline",
            EngineMode::CpuSorf => "cpu-sorf",
        }
    }

    /// Engine for engine-agnostic tests: the `GRAPHLET_RF_TEST_ENGINE`
    /// env var when set (the CI engine-matrix job runs the tier-1 suite
    /// once per CPU engine), else `default`. Panics on an unparsable
    /// value — a broken matrix entry must fail loudly, not silently
    /// fall back.
    pub fn from_env_or(default: EngineMode) -> EngineMode {
        match std::env::var("GRAPHLET_RF_TEST_ENGINE") {
            Ok(s) => EngineMode::parse(&s).expect("GRAPHLET_RF_TEST_ENGINE"),
            Err(_) => default,
        }
    }
}

/// FWHT thread budget for engine-agnostic tests: the
/// `GRAPHLET_RF_TEST_THREADS` env var when set (the CI matrix runs the
/// suite at budgets 1 and 4 so the parallel panel path is exercised on
/// every push), else `default`. Panics on an unparsable value — a
/// broken matrix entry must fail loudly, not silently fall back.
pub fn fwht_threads_from_env_or(default: usize) -> usize {
    match std::env::var("GRAPHLET_RF_TEST_THREADS") {
        Ok(s) => s.parse().expect("GRAPHLET_RF_TEST_THREADS"),
        Err(_) => default,
    }
}

/// Configuration of one GSA-phi embedding run.
#[derive(Clone, Debug)]
pub struct GsaConfig {
    /// Graphlet size.
    pub k: usize,
    /// Samples per graph (s in the paper).
    pub s: usize,
    /// Number of random features (m).
    pub m: usize,
    pub variant: Variant,
    /// Artifact implementation: "xla" (fused fast path) or "pallas".
    pub impl_: String,
    /// "uniform" | "rw".
    pub sampler: String,
    /// Gaussian kernel bandwidth (phi_Gs / phi_Gs+eig only).
    pub sigma: f32,
    /// Batch size (must match a compiled artifact for PJRT mode).
    pub batch: usize,
    /// Sampler worker threads.
    pub workers: usize,
    /// Bounded queue capacity per shard (batches in flight).
    pub queue_cap: usize,
    /// Feature-engine shards. Jobs round-robin over shards; results are
    /// bitwise independent of the count. In PJRT mode each shard
    /// constructs its own engine over the same artifacts.
    pub shards: usize,
    /// Per-shard FWHT thread budget for the `cpu-sorf` engine: each
    /// shard hands its batches to `SorfMap::map_batch_threads` with
    /// this many panel workers. Default 1, so shard-level parallelism
    /// owns the cores; raise it (`--fwht-threads N`) when shards are
    /// few and batches large. A pure scheduling knob: embeddings are
    /// bitwise identical for every value (pinned by tests), and it is
    /// deliberately excluded from the serve cache fingerprint.
    pub fwht_threads: usize,
    pub engine: EngineMode,
    pub seed: u64,
}

impl Default for GsaConfig {
    fn default() -> Self {
        GsaConfig {
            k: 6,
            s: 2000,
            m: 5000,
            variant: Variant::Opu,
            impl_: "xla".into(),
            sampler: "rw".into(),
            sigma: 0.1,
            batch: 256,
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(8),
            queue_cap: 8,
            shards: 1,
            fwht_threads: 1,
            engine: EngineMode::Pjrt,
            seed: 0,
        }
    }
}

impl GsaConfig {
    pub fn input_dim(&self) -> usize {
        self.variant.input_dim(self.k)
    }
}

/// Embed every graph of `ds`: returns row-major (n, m) embeddings and the
/// run metrics. `engine` must be Some for [`EngineMode::Pjrt`]; it serves
/// as the template (artifacts dir + parsed manifest) from which each
/// feature shard builds its own engine.
///
/// This is a batch adapter over [`StreamingPipeline`]: the pipeline is
/// built for this call, every graph is submitted as one job seeded from
/// the per-graph seed stream, and rows are collected by graph index. The
/// embeddings are a pure function of (dataset, cfg.seed, feature math) —
/// worker count, shard count, and batching schedule never move a bit.
pub fn embed_dataset(
    ds: &Dataset,
    cfg: &GsaConfig,
    engine: Option<&Engine>,
) -> Result<(Vec<f32>, PipelineMetrics)> {
    let n = ds.len();
    let wall = Timer::start();
    let pipeline = StreamingPipeline::new(cfg, engine)?;
    let seeds = pipeline.graph_seeds(n);

    // Completed rows park in this unbounded channel, so the bounded job
    // queue (admission control in serve) can never deadlock submission
    // against collection.
    let (done_tx, done_rx) = channel();
    for (g_idx, g) in ds.graphs.iter().enumerate() {
        // One O(edges) clone per graph: GraphJob owns its graph so the
        // pipeline can outlive any caller. Negligible next to the
        // s x (sample + feature-map) work per graph; if Dataset ever
        // holds Arc<AnyGraph> this becomes a refcount bump.
        pipeline.submit(GraphJob {
            graph: Arc::new(g.clone()),
            seed: seeds[g_idx],
            tag: g_idx as u64,
            done: done_tx.clone(),
            // Batch jobs share the serve vocabulary: admission →
            // queue_wait → projection spans land in the process-global
            // ring. Observation-only, so tracing never moves a bit.
            trace: Some(TraceCtx::new("embed_dataset", g_idx as u64, obs::global_ring().clone())),
        })?;
    }
    drop(done_tx);

    let mut sums = vec![0.0f32; n * cfg.m];
    for _ in 0..n {
        let c = done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("pipeline dropped a job without completing it"))?;
        if let Some(e) = c.error {
            bail!("graph {} failed: {e}", c.tag);
        }
        anyhow::ensure!(c.samples == cfg.s, "graph {} got {} samples", c.tag, c.samples);
        let g_idx = c.tag as usize;
        sums[g_idx * cfg.m..(g_idx + 1) * cfg.m].copy_from_slice(&c.row);
    }

    let mut metrics = pipeline.shutdown()?;
    metrics.graphs = n;
    metrics.wall_secs = wall.elapsed_secs();
    Ok((sums, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SbmConfig;
    use crate::runtime::artifacts_dir;
    use crate::util::{check, Rng};

    fn small_ds() -> Dataset {
        SbmConfig { per_class: 3, r: 1.5, ..Default::default() }.generate(&mut Rng::new(11))
    }

    fn small_cfg(engine: EngineMode) -> GsaConfig {
        GsaConfig {
            k: 3,
            s: 100,
            m: 64,
            batch: 32,
            workers: 3,
            variant: Variant::Opu,
            engine,
            seed: 42,
            ..Default::default()
        }
    }

    #[test]
    fn cpu_modes_agree_exactly() {
        // Per-graph RNG seeding makes the embedding independent of worker
        // scheduling AND of the batching strategy.
        let ds = small_ds();
        let (e1, m1) = embed_dataset(&ds, &small_cfg(EngineMode::Cpu), None).unwrap();
        let (e2, m2) = embed_dataset(&ds, &small_cfg(EngineMode::CpuInline), None).unwrap();
        check::assert_allclose(&e1, &e2, 1e-5, 1e-5);
        assert_eq!(m1.samples, 6 * 100);
        assert_eq!(m2.graphs, 6);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let ds = small_ds();
        let mut cfg_a = small_cfg(EngineMode::Cpu);
        cfg_a.workers = 1;
        let mut cfg_b = small_cfg(EngineMode::Cpu);
        cfg_b.workers = 7;
        let (e1, _) = embed_dataset(&ds, &cfg_a, None).unwrap();
        let (e2, _) = embed_dataset(&ds, &cfg_b, None).unwrap();
        check::assert_allclose(&e1, &e2, 1e-5, 1e-5);
    }

    #[test]
    fn sharded_embeddings_bitwise_identical() {
        // The core invariant: embeddings are a pure function of
        // (dataset, cfg.seed, feature math) — shard count and worker
        // count must not move a single bit, including through the
        // streaming core's idle-flush partial batches.
        let ds = small_ds();
        for mode in [EngineMode::Cpu, EngineMode::CpuInline, EngineMode::CpuSorf] {
            let mut ref_cfg = small_cfg(mode);
            ref_cfg.shards = 1;
            ref_cfg.workers = 1;
            let (reference, _) = embed_dataset(&ds, &ref_cfg, None).unwrap();
            for shards in [1usize, 2, 4] {
                for workers in [1usize, 4] {
                    let mut cfg = small_cfg(mode);
                    cfg.shards = shards;
                    cfg.workers = workers;
                    let (e, m) = embed_dataset(&ds, &cfg, None).unwrap();
                    assert_eq!(
                        e, reference,
                        "bitwise drift: mode={mode:?} shards={shards} workers={workers}"
                    );
                    assert_eq!(m.samples, 6 * 100);
                    assert_eq!(m.shards, shards);
                }
            }
        }
    }

    #[test]
    fn more_shards_than_graphs_is_fine() {
        let ds = small_ds(); // 6 graphs
        let mut cfg = small_cfg(EngineMode::Cpu);
        cfg.shards = 8;
        let mut ref_cfg = small_cfg(EngineMode::Cpu);
        ref_cfg.shards = 1;
        let (e, m) = embed_dataset(&ds, &cfg, None).unwrap();
        let (reference, _) = embed_dataset(&ds, &ref_cfg, None).unwrap();
        assert_eq!(e, reference);
        assert_eq!(m.shards, 8);
        assert_eq!(m.shard_feature_secs.len(), 8);
    }

    #[test]
    fn shard_metrics_cover_all_samples() {
        let ds = small_ds();
        let mut cfg = small_cfg(EngineMode::Cpu);
        cfg.shards = 3;
        let (_, m) = embed_dataset(&ds, &cfg, None).unwrap();
        assert_eq!(m.samples, 6 * 100);
        assert_eq!(m.graphs, 6);
        assert_eq!(m.shards, 3);
        assert_eq!(m.shard_feature_secs.len(), 3);
        assert!(m.batches >= 3, "each shard executes at least one batch");
        let report = m.report();
        assert!(report.contains("shards=3"), "{report}");
    }

    #[test]
    fn engine_mode_parse_roundtrip_and_errors() {
        assert_eq!(EngineMode::parse("pjrt").unwrap(), EngineMode::Pjrt);
        assert_eq!(EngineMode::parse("cpu").unwrap(), EngineMode::Cpu);
        assert_eq!(EngineMode::parse("cpu-inline").unwrap(), EngineMode::CpuInline);
        assert_eq!(EngineMode::parse("cpu-sorf").unwrap(), EngineMode::CpuSorf);
        let err = EngineMode::parse("opu").unwrap_err().to_string();
        assert!(
            err.contains("unknown engine") && err.contains("pjrt|cpu|cpu-inline|cpu-sorf"),
            "{err}"
        );
    }

    #[test]
    fn pjrt_matches_cpu_when_artifacts_present() {
        let Some(engine) = crate::runtime::try_engine(&artifacts_dir()) else {
            return;
        };
        let ds = small_ds();
        let cfg = small_cfg(EngineMode::Pjrt);
        let (e_pjrt, m) = embed_dataset(&ds, &cfg, Some(&engine)).unwrap();
        let (e_cpu, _) = embed_dataset(&ds, &small_cfg(EngineMode::Cpu), None).unwrap();
        check::assert_allclose(&e_pjrt, &e_cpu, 1e-3, 1e-4);
        assert!(m.batches > 0 && m.samples == 600);
        // Sharded PJRT: each shard builds its own engine from the shared
        // manifest; results must still match.
        let mut cfg_sharded = small_cfg(EngineMode::Pjrt);
        cfg_sharded.shards = 2;
        let (e_sharded, _) = embed_dataset(&ds, &cfg_sharded, Some(&engine)).unwrap();
        check::assert_allclose(&e_sharded, &e_pjrt, 1e-6, 1e-6);
    }

    #[test]
    fn gauss_eig_variant_runs() {
        let ds = small_ds();
        // Both dense shards and SORF shards must handle the d = k
        // eigenvalue inputs (SORF pads k up to the next power of two).
        for engine in [EngineMode::Cpu, EngineMode::CpuSorf] {
            let mut cfg = small_cfg(engine);
            cfg.variant = Variant::GaussEig;
            cfg.sigma = 0.5;
            let (emb, _) = embed_dataset(&ds, &cfg, None).unwrap();
            assert_eq!(emb.len(), 6 * 64);
            assert!(emb.iter().all(|v| v.is_finite()), "{engine:?}");
        }
    }

    #[test]
    fn embeddings_separate_easy_classes() {
        // End-to-end sanity: r = 3 SBM should be separable from OPU
        // embeddings with a linear classifier trained on the spot — and
        // sharding must not change that.
        let ds = SbmConfig { per_class: 20, r: 3.0, ..Default::default() }
            .generate(&mut Rng::new(5));
        let mut cfg = small_cfg(EngineMode::CpuInline);
        cfg.k = 4;
        cfg.s = 300;
        cfg.m = 128;
        cfg.shards = 2;
        let (emb, _) = embed_dataset(&ds, &cfg, None).unwrap();
        let mut rng = Rng::new(1);
        let split = ds.split(0.75, &mut rng);
        let acc = crate::classify::train_and_eval(
            &emb,
            &ds.labels,
            cfg.m,
            &split.train,
            &split.test,
            &crate::classify::TrainConfig::default(),
        );
        assert!(acc > 0.8, "accuracy {acc}");
    }
}
