//! Graph isomorphism for graphlets: canonical forms, the matching map
//! `phi_match`, and graphlet enumeration.
//!
//! The graphlet kernel (paper §2.2) needs an isomorphism test per sampled
//! subgraph — the cost the paper is attacking. We implement it properly so
//! the baseline `GSA-phi_match` is real:
//!
//! 1. **Canonical form**: the minimum upper-triangle bitmask over a set of
//!    node permutations that is (a) isomorphism-invariant and (b) contains
//!    at least one permutation per isomorphism class. We use 1-WL colour
//!    refinement to partition nodes into invariant cells, order cells by
//!    their invariant colour keys, and take the minimum over all
//!    permutations that respect the cell order. Two graphlets are
//!    isomorphic iff their canonical forms are equal.
//! 2. **GraphletRegistry**: assigns dense indices to canonical forms on
//!    first sight. `phi_match` histograms are built over the registry, so
//!    the full `N_k` enumeration (exponential in k) is never materialized
//!    unless asked for (see [`enumerate_canonical`], used in tests to
//!    verify N_k = 1, 2, 4, 11, 34, 156, ...).

use std::collections::HashMap;

use crate::graph::Graphlet;

/// Number of non-isomorphic graphs on k nodes (OEIS A000088), used by
/// tests and the complexity tables.
pub const N_K: [u64; 9] = [1, 1, 2, 4, 11, 34, 156, 1044, 12346];

/// 1-WL colour refinement. Returns a per-node colour id in [0, n_colors),
/// where colours are *canonical*: they depend only on the isomorphism
/// class, not on node numbering (colour ids are assigned by sorted
/// signature, and signatures are built from sorted multisets).
fn wl_colors(g: &Graphlet) -> Vec<u32> {
    let k = g.k();
    // Initial colour: degree.
    let mut colors: Vec<u32> = (0..k).map(|i| g.degree(i) as u32).collect();
    // Normalize to dense ids ordered by value.
    normalize(&mut colors);
    for _round in 0..k {
        // Signature of node i: (own colour, sorted neighbour colours).
        let mut sigs: Vec<(u32, Vec<u32>)> = (0..k)
            .map(|i| {
                let mut ns: Vec<u32> = (0..k)
                    .filter(|&j| g.has_edge(i, j))
                    .map(|j| colors[j])
                    .collect();
                ns.sort_unstable();
                (colors[i], ns)
            })
            .collect();
        // Canonical dense ids: sort unique signatures, map each node.
        let mut uniq: Vec<(u32, Vec<u32>)> = sigs.clone();
        uniq.sort();
        uniq.dedup();
        let new: Vec<u32> = sigs
            .drain(..)
            .map(|s| uniq.binary_search(&s).unwrap() as u32)
            .collect();
        if new == colors {
            break;
        }
        colors = new;
    }
    colors
}

fn normalize(colors: &mut [u32]) {
    let mut uniq: Vec<u32> = colors.to_vec();
    uniq.sort_unstable();
    uniq.dedup();
    for c in colors.iter_mut() {
        *c = uniq.binary_search(c).unwrap() as u32;
    }
}

/// Canonical form: minimum bitmask over all permutations that order nodes
/// by nondecreasing WL colour (cells in colour order; all orders within a
/// cell). Isomorphic graphlets map to the same form; non-isomorphic ones
/// cannot collide because the form *is* an adjacency encoding.
pub fn canonical_form(g: &Graphlet) -> Graphlet {
    let k = g.k();
    if k == 1 {
        return *g;
    }
    let colors = wl_colors(g);
    // Nodes grouped by colour (colour ids are canonical, so cell order is).
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&i| (colors[i], i));
    // Cell boundaries.
    let mut cells: Vec<(usize, usize)> = Vec::new();
    let mut start = 0;
    for i in 1..=k {
        if i == k || colors[order[i]] != colors[order[start]] {
            cells.push((start, i));
            start = i;
        }
    }
    // Enumerate permutations within cells (product of per-cell perms),
    // tracking the minimum permuted bitmask.
    let mut best: Option<Graphlet> = None;
    let mut perm = order.clone();
    permute_cells(&mut perm, &cells, 0, g, &mut best);
    best.expect("at least one permutation")
}

fn permute_cells(
    perm: &mut Vec<usize>,
    cells: &[(usize, usize)],
    ci: usize,
    g: &Graphlet,
    best: &mut Option<Graphlet>,
) {
    if ci == cells.len() {
        let cand = g.permute(perm);
        if best.map(|b| cand.bits() < b.bits()).unwrap_or(true) {
            *best = Some(cand);
        }
        return;
    }
    let (lo, hi) = cells[ci];
    heap_permute(perm, lo, hi - lo, cells, ci, g, best);
}

/// Heap's algorithm over perm[lo..lo+n], recursing into the next cell for
/// each arrangement.
fn heap_permute(
    perm: &mut Vec<usize>,
    lo: usize,
    n: usize,
    cells: &[(usize, usize)],
    ci: usize,
    g: &Graphlet,
    best: &mut Option<Graphlet>,
) {
    if n <= 1 {
        permute_cells(perm, cells, ci + 1, g, best);
        return;
    }
    for i in 0..n {
        heap_permute(perm, lo, n - 1, cells, ci, g, best);
        if n % 2 == 0 {
            perm.swap(lo + i, lo + n - 1);
        } else {
            perm.swap(lo, lo + n - 1);
        }
    }
}

/// Isomorphism test via canonical forms.
pub fn are_isomorphic(a: &Graphlet, b: &Graphlet) -> bool {
    a.k() == b.k() && canonical_form(a) == canonical_form(b)
}

/// Assigns dense indices to canonical forms on first sight. This is how
/// `phi_match` histograms are dimensioned without enumerating all N_k
/// graphlets: unseen graphlets contribute zeros to every histogram, so
/// dropping them changes no pairwise distance.
#[derive(Default, Debug, Clone)]
pub struct GraphletRegistry {
    index: HashMap<Graphlet, u32>,
}

impl GraphletRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Index of the isomorphism class of `g`, canonicalizing first.
    pub fn classify(&mut self, g: &Graphlet) -> u32 {
        let canon = canonical_form(g);
        let next = self.index.len() as u32;
        *self.index.entry(canon).or_insert(next)
    }

    /// Index if the class has been seen (no insertion).
    pub fn lookup(&self, g: &Graphlet) -> Option<u32> {
        self.index.get(&canonical_form(g)).copied()
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
}

/// phi_match over a registry: one-hot at the class index (eq. 1/2's
/// matching function, with lazily-discovered dimensions).
pub fn phi_match(reg: &mut GraphletRegistry, g: &Graphlet) -> u32 {
    reg.classify(g)
}

/// Exhaustively enumerate all canonical forms on k nodes (2^C(k,2) work;
/// call only for k <= 6 — tests verify against OEIS A000088).
pub fn enumerate_canonical(k: usize) -> Vec<Graphlet> {
    let n_pairs = k * (k - 1) / 2;
    let mut seen = std::collections::HashSet::new();
    for bits in 0..(1u64 << n_pairs) {
        let g = Graphlet::from_bits(k, bits as u32);
        seen.insert(canonical_form(&g));
    }
    let mut out: Vec<Graphlet> = seen.into_iter().collect();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{check, Rng};

    fn random_graphlet(rng: &mut Rng, k: usize) -> Graphlet {
        let n_pairs = k * (k - 1) / 2;
        let mask = if n_pairs == 64 { u64::MAX } else { (1u64 << n_pairs) - 1 };
        Graphlet::from_bits(k, (rng.next_u64() & mask) as u32)
    }

    #[test]
    fn canonical_is_isomorphic_invariant() {
        check::check("canon-invariant", 0xB1, 300, |rng| {
            let k = 2 + rng.usize(7); // 2..=8
            let g = random_graphlet(rng, k);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let h = g.permute(&perm);
            assert_eq!(canonical_form(&g), canonical_form(&h), "k={k} g={g:?}");
        });
    }

    #[test]
    fn canonical_is_idempotent_and_isomorphic_to_input() {
        check::check("canon-idempotent", 0xB2, 200, |rng| {
            let k = 2 + rng.usize(7);
            let g = random_graphlet(rng, k);
            let c = canonical_form(&g);
            assert_eq!(canonical_form(&c), c);
            assert_eq!(c.num_edges(), g.num_edges());
            assert_eq!(c.degree_sequence(), g.degree_sequence());
        });
    }

    #[test]
    fn distinguishes_path_from_star() {
        // P4 and K1,3 have different degree sequences.
        let mut p4 = Graphlet::empty(4);
        p4.set_edge(0, 1);
        p4.set_edge(1, 2);
        p4.set_edge(2, 3);
        let mut star = Graphlet::empty(4);
        star.set_edge(0, 1);
        star.set_edge(0, 2);
        star.set_edge(0, 3);
        assert!(!are_isomorphic(&p4, &star));
        // But a relabelled path IS isomorphic.
        let relabeled = p4.permute(&[2, 0, 3, 1]);
        assert!(are_isomorphic(&p4, &relabeled));
    }

    #[test]
    fn distinguishes_regular_cospectral_like_pairs() {
        // C6 (6-cycle) vs 2x K3 (two triangles): both 2-regular with 6
        // edges; WL alone can't split them but the canonical bitmask can.
        let mut c6 = Graphlet::empty(6);
        for i in 0..6 {
            c6.set_edge(i, (i + 1) % 6);
        }
        let mut kk = Graphlet::empty(6);
        kk.set_edge(0, 1);
        kk.set_edge(1, 2);
        kk.set_edge(0, 2);
        kk.set_edge(3, 4);
        kk.set_edge(4, 5);
        kk.set_edge(3, 5);
        assert!(!are_isomorphic(&c6, &kk));
    }

    #[test]
    fn enumeration_matches_oeis() {
        for k in 1..=5 {
            assert_eq!(enumerate_canonical(k).len() as u64, N_K[k], "k={k}");
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "k=6 enumeration is release-only")]
    fn enumeration_matches_oeis_k6() {
        assert_eq!(enumerate_canonical(6).len() as u64, N_K[6]);
    }

    #[test]
    fn registry_assigns_stable_dense_indices() {
        let mut reg = GraphletRegistry::new();
        let mut tri = Graphlet::empty(3);
        tri.set_edge(0, 1);
        tri.set_edge(1, 2);
        tri.set_edge(0, 2);
        let mut path = Graphlet::empty(3);
        path.set_edge(0, 1);
        path.set_edge(1, 2);
        let i_tri = reg.classify(&tri);
        let i_path = reg.classify(&path);
        assert_ne!(i_tri, i_path);
        // Isomorphic copy maps to the same index.
        let path2 = path.permute(&[2, 1, 0]);
        assert_eq!(reg.classify(&path2), i_path);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.lookup(&tri), Some(i_tri));
    }

    #[test]
    fn registry_covers_all_k4_classes() {
        let mut reg = GraphletRegistry::new();
        for bits in 0..64u32 {
            reg.classify(&Graphlet::from_bits(4, bits));
        }
        assert_eq!(reg.len() as u64, N_K[4]);
    }

    #[test]
    fn non_isomorphic_never_collide_exhaustive_k4() {
        // Canonical forms of all 64 labelled 4-graphs partition them into
        // exactly the 11 classes, and forms within a class are identical.
        let mut groups: std::collections::HashMap<Graphlet, Vec<u32>> = Default::default();
        for bits in 0..64u32 {
            let g = Graphlet::from_bits(4, bits);
            groups.entry(canonical_form(&g)).or_default().push(bits);
        }
        assert_eq!(groups.len(), 11);
        let total: usize = groups.values().map(|v| v.len()).sum();
        assert_eq!(total, 64);
    }

    /// The strongest canonicalization guarantee: the WL-pruned canonical
    /// form must partition labelled graphs into EXACTLY the same classes
    /// as the unpruned min-over-all-k!-permutations form. Brute force is
    /// feasible for k <= 5 (1024 graphs x 120 perms).
    #[test]
    fn canonical_matches_bruteforce_min_over_all_perms() {
        fn brute_canonical(g: &Graphlet) -> Graphlet {
            let k = g.k();
            let mut perm: Vec<usize> = (0..k).collect();
            let mut best = g.permute(&perm);
            // Heap's algorithm over all k! permutations.
            let mut c = vec![0usize; k];
            let mut i = 1;
            while i < k {
                if c[i] < i {
                    if i % 2 == 0 {
                        perm.swap(0, i);
                    } else {
                        perm.swap(c[i], i);
                    }
                    let cand = g.permute(&perm);
                    if cand.bits() < best.bits() {
                        best = cand;
                    }
                    c[i] += 1;
                    i = 1;
                } else {
                    c[i] = 0;
                    i += 1;
                }
            }
            best
        }
        for k in 2..=4usize {
            let n_pairs = k * (k - 1) / 2;
            for bits in 0..(1u32 << n_pairs) {
                let g = Graphlet::from_bits(k, bits);
                // Not necessarily the same representative, but the same
                // partition: two graphs share a WL-canonical form iff they
                // share a brute-force canonical form.
                let brute = brute_canonical(&g);
                let wl = canonical_form(&g);
                assert_eq!(
                    canonical_form(&brute),
                    wl,
                    "partition mismatch at k={k} bits={bits:#b}"
                );
            }
        }
        // Spot-check k = 5 on random graphs (full space is 1024 graphs
        // but permute is the hot cost; sample instead).
        check::check("canon-vs-brute-k5", 0xB7, 100, |rng| {
            let g = random_graphlet(rng, 5);
            let mut perm: Vec<usize> = (0..5).collect();
            rng.shuffle(&mut perm);
            // canonical(g) must be invariant AND isomorphic to g via
            // SOME permutation found by brute force.
            let c = canonical_form(&g);
            assert_eq!(c, canonical_form(&g.permute(&perm)));
            assert!(are_isomorphic(&g, &c));
        });
    }

    /// Canonical forms of all k=5 labelled graphs produce exactly N_5=34
    /// classes with class sizes summing to 2^10 (orbit-stabilizer check).
    #[test]
    fn k5_partition_complete() {
        let mut classes: std::collections::HashMap<Graphlet, u32> = Default::default();
        for bits in 0..(1u32 << 10) {
            *classes.entry(canonical_form(&Graphlet::from_bits(5, bits))).or_default() += 1;
        }
        assert_eq!(classes.len() as u64, N_K[5]);
        assert_eq!(classes.values().sum::<u32>(), 1 << 10);
        // Each class size must divide k! = 120 (it is 120 / |Aut|).
        for (g, &size) in &classes {
            assert_eq!(120 % size, 0, "class of {g:?} has size {size}");
        }
    }

    #[test]
    fn wl_colors_are_invariant() {
        check::check("wl-invariant", 0xB3, 200, |rng| {
            let k = 2 + rng.usize(7);
            let g = random_graphlet(rng, k);
            let mut perm: Vec<usize> = (0..k).collect();
            rng.shuffle(&mut perm);
            let h = g.permute(&perm);
            let mut cg = wl_colors(&g);
            let mut ch = wl_colors(&h);
            cg.sort_unstable();
            ch.sort_unstable();
            assert_eq!(cg, ch);
        });
    }
}
