//! Minimal GET-only HTTP/1.1 sidecar for the serve daemon: the
//! Prometheus scrape endpoint plus health/readiness probes, hand-rolled
//! over [`TcpListener`] so the build stays zero-dependency.
//!
//! | path | reply |
//! |---|---|
//! | `/metrics` | [`crate::obs::prom::render`] of **this daemon's** registry, `Content-Type: text/plain; version=0.0.4` |
//! | `/healthz` | `200 ok` — the process is alive and accepting |
//! | `/readyz` | `200 ready` / `503 not ready` per the flag handed to [`HttpServer::spawn`] |
//!
//! Scope is deliberately tiny: GET only (anything else → 405), no
//! keep-alive (`Connection: close` on every reply), request line + a
//! drained header block and nothing more. Monitoring traffic stays off
//! the TCP protocol port, and scraping is observation-only — reading
//! `/metrics` in a loop cannot perturb embeddings (pinned by
//! `tests/obs.rs`).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::obs::{prom, BuildInfo, Registry};

/// Shared state the accept loop and every connection handler read.
struct HttpState {
    registry: Arc<Registry>,
    build_info: BuildInfo,
    /// `/readyz` gate. The daemon's `Server::bind` is synchronous
    /// (pipeline spawned, store recovered, ANN cell built) so it spawns
    /// this listener with `ready = true`; the flag stays dynamic so the
    /// not-ready reply is testable and a future async-recovery daemon
    /// can flip it late.
    ready: AtomicBool,
    stop: AtomicBool,
}

/// A running HTTP sidecar listener. Dropping it does **not** stop the
/// accept thread; call [`HttpServer::stop`] for a clean join (the
/// daemon's `run` does this on shutdown).
pub struct HttpServer {
    state: Arc<HttpState>,
    addr: SocketAddr,
    accept: JoinHandle<()>,
}

impl HttpServer {
    /// Bind `127.0.0.1:<port>` (0 picks an ephemeral port) and spawn
    /// the accept loop.
    pub fn spawn(
        port: u16,
        registry: Arc<Registry>,
        build_info: BuildInfo,
        ready: bool,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("http: bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("http: local_addr")?;
        let state = Arc::new(HttpState {
            registry,
            build_info,
            ready: AtomicBool::new(ready),
            stop: AtomicBool::new(false),
        });
        let st = state.clone();
        let accept = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || accept_loop(&listener, &st))
            .context("http: spawn accept thread")?;
        Ok(HttpServer { state, addr, accept })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Flip the `/readyz` gate.
    pub fn set_ready(&self, ready: bool) {
        self.state.ready.store(ready, Ordering::Release);
    }

    /// Stop accepting and join the accept thread. In-flight connection
    /// handlers finish on their own (each serves exactly one request).
    pub fn stop(self) {
        self.state.stop.store(true, Ordering::Release);
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
    }
}

fn accept_loop(listener: &TcpListener, state: &Arc<HttpState>) {
    for conn in listener.incoming() {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let st = state.clone();
        // One short-lived thread per connection, mirroring the TCP
        // protocol server; scrape traffic is low-rate by construction.
        let _ = std::thread::Builder::new()
            .name("http-conn".into())
            .spawn(move || handle_conn(stream, &st));
    }
}

/// Serve exactly one request on `stream`, then close. Any parse or I/O
/// failure just drops the connection — probes retry, nothing to unwind.
fn handle_conn(stream: TcpStream, state: &HttpState) {
    let Ok(peer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(peer);
    let mut line = String::new();
    if reader.read_line(&mut line).is_err() || line.is_empty() {
        return;
    }
    // "GET /path HTTP/1.1" — keep only method + path.
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    // Drain headers to the blank line; we act on none of them.
    let mut hdr = String::new();
    loop {
        hdr.clear();
        match reader.read_line(&mut hdr) {
            Ok(0) => break,
            Ok(_) if hdr == "\r\n" || hdr == "\n" => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
    let mut stream = stream;
    if method != "GET" {
        let _ = write_response(&mut stream, 405, "Method Not Allowed", TEXT_PLAIN, "method not allowed\n");
        return;
    }
    let _ = match path {
        "/metrics" => {
            let body = prom::render(&state.registry, Some(&state.build_info));
            write_response(&mut stream, 200, "OK", PROM_TEXT, &body)
        }
        "/healthz" => write_response(&mut stream, 200, "OK", TEXT_PLAIN, "ok\n"),
        "/readyz" => {
            if state.ready.load(Ordering::Acquire) {
                write_response(&mut stream, 200, "OK", TEXT_PLAIN, "ready\n")
            } else {
                write_response(&mut stream, 503, "Service Unavailable", TEXT_PLAIN, "not ready\n")
            }
        }
        _ => write_response(&mut stream, 404, "Not Found", TEXT_PLAIN, "not found\n"),
    };
}

/// The exposition-format content type Prometheus' scraper negotiates.
const PROM_TEXT: &str = "text/plain; version=0.0.4; charset=utf-8";
const TEXT_PLAIN: &str = "text/plain; charset=utf-8";

fn write_response(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn test_build_info() -> BuildInfo {
        BuildInfo {
            engine: "cpu".to_string(),
            config_fp: "00000000deadbeef".to_string(),
            version: "0.0.0-test".to_string(),
        }
    }

    /// Raw one-shot GET: returns (status line, headers, body).
    fn get(addr: SocketAddr, path: &str) -> (String, String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nAccept: */*\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        let (status, headers) = head.split_once("\r\n").unwrap_or((head, ""));
        (status.to_string(), headers.to_string(), body.to_string())
    }

    fn spawn_test_server(ready: bool) -> (HttpServer, Arc<Registry>) {
        let registry = Arc::new(Registry::new());
        let srv = HttpServer::spawn(0, registry.clone(), test_build_info(), ready).unwrap();
        (srv, registry)
    }

    #[test]
    fn healthz_and_readyz_when_ready() {
        let (srv, _reg) = spawn_test_server(true);
        let addr = srv.local_addr();
        let (status, _, body) = get(addr, "/healthz");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert_eq!(body, "ok\n");
        let (status, _, body) = get(addr, "/readyz");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert_eq!(body, "ready\n");
        srv.stop();
    }

    #[test]
    fn readyz_is_503_until_ready_flips() {
        let (srv, _reg) = spawn_test_server(false);
        let addr = srv.local_addr();
        let (status, _, body) = get(addr, "/readyz");
        assert_eq!(status, "HTTP/1.1 503 Service Unavailable");
        assert_eq!(body, "not ready\n");
        srv.set_ready(true);
        let (status, _, _) = get(addr, "/readyz");
        assert_eq!(status, "HTTP/1.1 200 OK");
        srv.stop();
    }

    #[test]
    fn metrics_serves_the_instance_registry_in_prom_format() {
        let (srv, registry) = spawn_test_server(true);
        registry.counter("serve.errors.embed").add(3);
        registry.histo("serve.request_us.embed").record_us(7);
        let (status, headers, body) = get(srv.local_addr(), "/metrics");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert!(
            headers.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8"),
            "exposition content type missing: {headers}"
        );
        assert!(body.contains("serve_errors{op=\"embed\"} 3"), "counter missing:\n{body}");
        assert!(body.contains("serve_request_us_count{op=\"embed\"} 1"), "histo missing:\n{body}");
        assert!(
            body.contains(
                "graphlet_rf_build_info{config_fp=\"00000000deadbeef\",engine=\"cpu\",version=\"0.0.0-test\"} 1"
            ),
            "build info missing:\n{body}"
        );
        // Content-Length must match the body byte count the client read.
        let len: usize = headers
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .expect("Content-Length header")
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        srv.stop();
    }

    #[test]
    fn unknown_path_is_404_and_post_is_405() {
        let (srv, _reg) = spawn_test_server(true);
        let addr = srv.local_addr();
        let (status, _, _) = get(addr, "/nope");
        assert_eq!(status, "HTTP/1.1 404 Not Found");
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405 "), "want 405, got: {raw}");
        srv.stop();
    }

    #[test]
    fn two_listeners_serve_isolated_registries() {
        let (a, reg_a) = spawn_test_server(true);
        let (b, reg_b) = spawn_test_server(true);
        reg_a.counter("serve.errors.embed").add(5);
        reg_b.counter("serve.errors.nearest").inc();
        let (_, _, body_a) = get(a.local_addr(), "/metrics");
        let (_, _, body_b) = get(b.local_addr(), "/metrics");
        assert!(body_a.contains("serve_errors{op=\"embed\"} 5"));
        assert!(!body_a.contains("op=\"nearest\""), "a leaked b's counter:\n{body_a}");
        assert!(body_b.contains("serve_errors{op=\"nearest\"} 1"));
        assert!(!body_b.contains("op=\"embed\""), "b leaked a's counter:\n{body_b}");
        a.stop();
        b.stop();
    }
}
