//! Minimal GET-only HTTP/1.1 sidecar for the serve daemon: the
//! Prometheus scrape endpoint plus health/readiness probes, hand-rolled
//! over [`TcpListener`] so the build stays zero-dependency.
//!
//! | path | reply |
//! |---|---|
//! | `/metrics` | [`crate::obs::prom::render`] of **this daemon's** registry, `Content-Type: text/plain; version=0.0.4` |
//! | `/healthz` | `200 ok` — the process is alive and accepting |
//! | `/readyz` | `200 ready` / `503 not ready` per the flag handed to [`HttpServer::spawn`] |
//! | `/profile` | collapsed-stack text (`role;stage N`, flamegraph-ready) from the sampling profiler's cumulative table |
//! | `/profile?seconds=N` | same format, but only activity inside an N-second window measured on this request (capped at 10 s) |
//! | `/debug/threads` | JSON list of registered threads: role, index, current stage, cpu_us, wall_us, busy fraction |
//!
//! Scope is deliberately tiny: GET only (anything else → 405), no
//! keep-alive (`Connection: close` on every reply), request line + a
//! drained header block and nothing more (a `?query` is split off the
//! path and only `/profile` reads it). Monitoring traffic stays off
//! the TCP protocol port, and scraping is observation-only — reading
//! `/metrics` or `/profile` in a loop cannot perturb embeddings
//! (pinned by `tests/obs.rs`).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::obs::{profile, prom, BuildInfo, Registry};
use crate::util::Json;

/// Longest `/profile?seconds=N` window honored: the handler sleeps on
/// its own connection thread for the window, so cap how long a client
/// can park one.
const MAX_PROFILE_WINDOW_SECS: u64 = 10;

/// Shared state the accept loop and every connection handler read.
struct HttpState {
    registry: Arc<Registry>,
    build_info: BuildInfo,
    /// `/readyz` gate. The daemon's `Server::bind` is synchronous
    /// (pipeline spawned, store recovered, ANN cell built) so it spawns
    /// this listener with `ready = true`; the flag stays dynamic so the
    /// not-ready reply is testable and a future async-recovery daemon
    /// can flip it late.
    ready: AtomicBool,
    stop: AtomicBool,
}

/// A running HTTP sidecar listener. Dropping it does **not** stop the
/// accept thread; call [`HttpServer::stop`] for a clean join (the
/// daemon's `run` does this on shutdown).
pub struct HttpServer {
    state: Arc<HttpState>,
    addr: SocketAddr,
    accept: JoinHandle<()>,
}

impl HttpServer {
    /// Bind `127.0.0.1:<port>` (0 picks an ephemeral port) and spawn
    /// the accept loop.
    pub fn spawn(
        port: u16,
        registry: Arc<Registry>,
        build_info: BuildInfo,
        ready: bool,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("http: bind 127.0.0.1:{port}"))?;
        let addr = listener.local_addr().context("http: local_addr")?;
        let state = Arc::new(HttpState {
            registry,
            build_info,
            ready: AtomicBool::new(ready),
            stop: AtomicBool::new(false),
        });
        let st = state.clone();
        let accept = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || accept_loop(&listener, &st))
            .context("http: spawn accept thread")?;
        Ok(HttpServer { state, addr, accept })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Flip the `/readyz` gate.
    pub fn set_ready(&self, ready: bool) {
        self.state.ready.store(ready, Ordering::Release);
    }

    /// Stop accepting and join the accept thread. In-flight connection
    /// handlers finish on their own (each serves exactly one request).
    pub fn stop(self) {
        self.state.stop.store(true, Ordering::Release);
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
    }
}

fn accept_loop(listener: &TcpListener, state: &Arc<HttpState>) {
    // The accept thread is long-lived, so it shows up in /debug/threads
    // like every other daemon thread; it spends its life blocked in
    // accept(), i.e. parked on the "http" stage with ~zero CPU.
    let prof = state.registry.threads().register("http", 0);
    prof.set_stage("http");
    for conn in listener.incoming() {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let st = state.clone();
        // One short-lived thread per connection, mirroring the TCP
        // protocol server; scrape traffic is low-rate by construction.
        let _ = std::thread::Builder::new()
            .name("http-conn".into())
            .spawn(move || handle_conn(stream, &st));
    }
}

/// Serve exactly one request on `stream`, then close. Any parse or I/O
/// failure just drops the connection — probes retry, nothing to unwind.
fn handle_conn(stream: TcpStream, state: &HttpState) {
    let Ok(peer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(peer);
    let mut line = String::new();
    if reader.read_line(&mut line).is_err() || line.is_empty() {
        return;
    }
    // "GET /path HTTP/1.1" — keep only method + path.
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    // Split off the query string; only /profile reads it.
    let (path, query) = target.split_once('?').map_or((target, ""), |(p, q)| (p, q));
    // Drain headers to the blank line; we act on none of them.
    let mut hdr = String::new();
    loop {
        hdr.clear();
        match reader.read_line(&mut hdr) {
            Ok(0) => break,
            Ok(_) if hdr == "\r\n" || hdr == "\n" => break,
            Ok(_) => continue,
            Err(_) => return,
        }
    }
    let mut stream = stream;
    if method != "GET" {
        let _ = write_response(&mut stream, 405, "Method Not Allowed", TEXT_PLAIN, "method not allowed\n");
        return;
    }
    let _ = match path {
        "/metrics" => {
            let body = prom::render(&state.registry, Some(&state.build_info));
            write_response(&mut stream, 200, "OK", PROM_TEXT, &body)
        }
        "/profile" => {
            let body = profile_body(state, query);
            write_response(&mut stream, 200, "OK", TEXT_PLAIN, &body)
        }
        "/debug/threads" => {
            let body = threads_body(state);
            write_response(&mut stream, 200, "OK", APP_JSON, &body)
        }
        "/healthz" => write_response(&mut stream, 200, "OK", TEXT_PLAIN, "ok\n"),
        "/readyz" => {
            if state.ready.load(Ordering::Acquire) {
                write_response(&mut stream, 200, "OK", TEXT_PLAIN, "ready\n")
            } else {
                write_response(&mut stream, 503, "Service Unavailable", TEXT_PLAIN, "not ready\n")
            }
        }
        _ => write_response(&mut stream, 404, "Not Found", TEXT_PLAIN, "not found\n"),
    };
}

/// Collapsed-stack reply for `/profile`. With no (or a zero) `seconds`
/// query the cumulative table since daemon start is rendered; with
/// `seconds=N` two snapshots bracket an N-second sleep **on this
/// connection's thread** (capped so a client cannot park one forever)
/// and only the window's activity is reported.
fn profile_body(state: &HttpState, query: &str) -> String {
    let secs = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("seconds="))
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0)
        .min(MAX_PROFILE_WINDOW_SECS);
    let threads = state.registry.threads();
    if secs == 0 {
        return threads.collapsed();
    }
    let before = threads.stage_table();
    std::thread::sleep(std::time::Duration::from_secs(secs));
    let after = threads.stage_table();
    profile::collapsed_between(&before, &after)
}

/// JSON reply for `/debug/threads`: one object per registered thread.
fn threads_body(state: &HttpState) -> String {
    let mut arr = Json::arr();
    for t in state.registry.threads().snapshot() {
        arr.push(
            Json::obj()
                .set("role", t.role)
                .set("index", t.index as u64)
                .set("stage", t.stage)
                .set("cpu_us", t.cpu_us)
                .set("wall_us", t.wall_us)
                .set("busy", t.busy),
        );
    }
    Json::obj()
        .set("cpu_clock", profile::cpu_clock_supported())
        .set("threads", arr)
        .to_string()
}

/// The exposition-format content type Prometheus' scraper negotiates.
const PROM_TEXT: &str = "text/plain; version=0.0.4; charset=utf-8";
const TEXT_PLAIN: &str = "text/plain; charset=utf-8";
const APP_JSON: &str = "application/json";

fn write_response(
    stream: &mut TcpStream,
    code: u16,
    reason: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn test_build_info() -> BuildInfo {
        BuildInfo {
            engine: "cpu".to_string(),
            config_fp: "00000000deadbeef".to_string(),
            version: "0.0.0-test".to_string(),
        }
    }

    /// Raw one-shot GET: returns (status line, headers, body).
    fn get(addr: SocketAddr, path: &str) -> (String, String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nAccept: */*\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").expect("header/body split");
        let (status, headers) = head.split_once("\r\n").unwrap_or((head, ""));
        (status.to_string(), headers.to_string(), body.to_string())
    }

    fn spawn_test_server(ready: bool) -> (HttpServer, Arc<Registry>) {
        let registry = Arc::new(Registry::new());
        let srv = HttpServer::spawn(0, registry.clone(), test_build_info(), ready).unwrap();
        (srv, registry)
    }

    #[test]
    fn healthz_and_readyz_when_ready() {
        let (srv, _reg) = spawn_test_server(true);
        let addr = srv.local_addr();
        let (status, _, body) = get(addr, "/healthz");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert_eq!(body, "ok\n");
        let (status, _, body) = get(addr, "/readyz");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert_eq!(body, "ready\n");
        srv.stop();
    }

    #[test]
    fn readyz_is_503_until_ready_flips() {
        let (srv, _reg) = spawn_test_server(false);
        let addr = srv.local_addr();
        let (status, _, body) = get(addr, "/readyz");
        assert_eq!(status, "HTTP/1.1 503 Service Unavailable");
        assert_eq!(body, "not ready\n");
        srv.set_ready(true);
        let (status, _, _) = get(addr, "/readyz");
        assert_eq!(status, "HTTP/1.1 200 OK");
        srv.stop();
    }

    #[test]
    fn metrics_serves_the_instance_registry_in_prom_format() {
        let (srv, registry) = spawn_test_server(true);
        registry.counter("serve.errors.embed").add(3);
        registry.histo("serve.request_us.embed").record_us(7);
        let (status, headers, body) = get(srv.local_addr(), "/metrics");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert!(
            headers.contains("Content-Type: text/plain; version=0.0.4; charset=utf-8"),
            "exposition content type missing: {headers}"
        );
        assert!(body.contains("serve_errors{op=\"embed\"} 3"), "counter missing:\n{body}");
        assert!(body.contains("serve_request_us_count{op=\"embed\"} 1"), "histo missing:\n{body}");
        assert!(
            body.contains(
                "graphlet_rf_build_info{config_fp=\"00000000deadbeef\",engine=\"cpu\",version=\"0.0.0-test\"} 1"
            ),
            "build info missing:\n{body}"
        );
        // Content-Length must match the body byte count the client read.
        let len: usize = headers
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .expect("Content-Length header")
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        srv.stop();
    }

    #[test]
    fn unknown_path_is_404_and_post_is_405() {
        let (srv, _reg) = spawn_test_server(true);
        let addr = srv.local_addr();
        let (status, _, _) = get(addr, "/nope");
        assert_eq!(status, "HTTP/1.1 404 Not Found");
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /metrics HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405 "), "want 405, got: {raw}");
        srv.stop();
    }

    #[test]
    fn profile_returns_collapsed_stack_lines() {
        let (srv, registry) = spawn_test_server(true);
        // Register a fake worker and publish a stage so the cumulative
        // table has at least one (role, stage) pair beyond the accept
        // thread's own "http" entry.
        let guard = registry.threads().register("worker", 3);
        guard.set_stage("projection");
        registry.threads().sample_once();
        let (status, _, body) = get(srv.local_addr(), "/profile");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert!(!body.trim().is_empty(), "empty collapsed output");
        for line in body.lines() {
            let (frames, weight) = line.rsplit_once(' ').expect("`role;stage N` shape");
            let (role, stage) = frames.split_once(';').expect("role;stage frames");
            assert!(!role.is_empty() && profile::is_stage(stage), "bad line: {line}");
            weight.parse::<u64>().expect("numeric weight");
        }
        assert!(
            body.lines().any(|l| l.starts_with("worker;projection ")),
            "worker stage missing:\n{body}"
        );
        drop(guard);
        srv.stop();
    }

    #[test]
    fn profile_window_query_reports_only_window_activity() {
        let (srv, registry) = spawn_test_server(true);
        let guard = registry.threads().register("worker", 0);
        guard.set_stage("projection");
        registry.threads().sample_once();
        // Windowed scrape: nothing advances during the 1 s window, so
        // the pre-window "projection" entry must not reappear.
        let (status, _, body) = get(srv.local_addr(), "/profile?seconds=1");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert!(
            !body.lines().any(|l| l.starts_with("worker;projection ")),
            "stale pre-window activity leaked:\n{body}"
        );
        drop(guard);
        srv.stop();
    }

    #[test]
    fn debug_threads_lists_registered_threads_as_json() {
        let (srv, registry) = spawn_test_server(true);
        let guard = registry.threads().register("worker", 7);
        guard.set_stage("queue_wait");
        let (status, headers, body) = get(srv.local_addr(), "/debug/threads");
        assert_eq!(status, "HTTP/1.1 200 OK");
        assert!(headers.contains("Content-Type: application/json"), "{headers}");
        let doc = Json::parse(&body).expect("valid json");
        let threads = doc.get("threads").and_then(|t| t.as_array()).expect("threads array");
        let worker = threads
            .iter()
            .find(|t| t.get("role").and_then(|r| r.as_str()) == Some("worker"))
            .expect("worker row");
        assert_eq!(worker.get("index").and_then(|v| v.as_u64()), Some(7));
        assert_eq!(worker.get("stage").and_then(|v| v.as_str()), Some("queue_wait"));
        let busy = worker.get("busy").and_then(|v| v.as_f64()).expect("busy fraction");
        assert!((0.0..=1.0).contains(&busy), "busy out of range: {busy}");
        drop(guard);
        srv.stop();
    }

    #[test]
    fn query_strings_do_not_break_path_routing() {
        let (srv, _reg) = spawn_test_server(true);
        let (status, _, _) = get(srv.local_addr(), "/metrics?foo=bar");
        assert_eq!(status, "HTTP/1.1 200 OK");
        let (status, _, _) = get(srv.local_addr(), "/nope?seconds=3");
        assert_eq!(status, "HTTP/1.1 404 Not Found");
        srv.stop();
    }

    #[test]
    fn two_listeners_serve_isolated_registries() {
        let (a, reg_a) = spawn_test_server(true);
        let (b, reg_b) = spawn_test_server(true);
        reg_a.counter("serve.errors.embed").add(5);
        reg_b.counter("serve.errors.nearest").inc();
        let (_, _, body_a) = get(a.local_addr(), "/metrics");
        let (_, _, body_b) = get(b.local_addr(), "/metrics");
        assert!(body_a.contains("serve_errors{op=\"embed\"} 5"));
        assert!(!body_a.contains("op=\"nearest\""), "a leaked b's counter:\n{body_a}");
        assert!(body_b.contains("serve_errors{op=\"nearest\"} 1"));
        assert!(!body_b.contains("op=\"embed\""), "b leaked a's counter:\n{body_b}");
        a.stop();
        b.stop();
    }
}
