//! serve-bench: a loopback load-generation client for the serve daemon.
//!
//! C client threads each run a synchronous request/reply loop over one
//! TCP connection (pipeline concurrency comes from the C parallel
//! connections — that is exactly the traffic shape cross-request
//! batching exists for). Passes carry explicit labels so a cache hit at
//! one tier can never masquerade as another:
//!
//! - **cold**: every request uses a fresh `graph_index`, so every
//!   embedding is computed by the pipeline;
//! - **warm_l1**: the identical requests replayed against the same
//!   daemon, so every reply should come from the in-RAM cache;
//! - **warm_l2 / warm_l2_mmap** ([`run_restart_bench`] only): the
//!   daemon is shut down and *two* fresh daemons reopen the same
//!   `--store-dir` in turn — one with `--store-mmap false` (the legacy
//!   seek+read+copy path), one with it on (zero-copy page-cache views)
//!   — and the requests replay against each. Every reply must come off
//!   the segment log with **zero pipeline recomputes** (self-checked
//!   per pass: any computed graph or full miss fails the run); the mmap
//!   pass additionally requires the daemon's `store.mmap_reads` delta
//!   to equal the request count (every read really took the mapped
//!   path) and, where views are supported, its ANN index to own zero
//!   row bytes. Both passes bracket the daemon's `cache.l2_read_us`
//!   histogram, so the JSON line reports the two read paths' ns/row
//!   side by side (`l2_read_ns_per_row`);
//! - **nearest_p10 / nearest_p50 / nearest_p100** ([`run_restart_bench`]
//!   only): k-NN `nearest` queries against the restarted daemon's ANN
//!   index at probe factors 0.1 / 0.5 / 1.0, replaying the same
//!   (graph, graph_index) pairs so every query row is already cached —
//!   the passes time the IVFFlat search itself, not the embedding
//!   pipeline (self-checked: zero errors, zero recomputes). The index
//!   build cost over the full corpus is reported once as
//!   `ann_build_ms` (the restarted daemon's open-time build).
//!
//! Reported per pass: throughput (requests/s), p50/p99 latency from a
//! merged per-request latency reservoir, and the daemon-side
//! `pipeline.graphs` / `cache.l2_misses` deltas measured through the
//! `stats` op (so "the cache served everything" is a daemon-verified
//! fact, not an inference from reply flags). Fixed seed → fixed
//! workload, so numbers are comparable across PRs; the final line of
//! `graphlet-rf serve-bench` is one machine-readable JSON object
//! ([`BenchRun::json`]).
//!
//! Every pass additionally cross-checks itself against the daemon's
//! `metrics` op: the `serve.request_us.<op>` histogram's count delta
//! across the pass must equal the number of requests the clients sent —
//! the daemon observed exactly what the bench believes it sent, neither
//! dropping requests nor double-counting. The daemon-side p50/p99 from
//! that histogram ride along in the report (`daemon_p50_ms` /
//! `daemon_p99_ms`) so queueing inside the daemon is distinguishable
//! from client-side RTT. The deltas bracket a pass *window*; the
//! registry itself is instance-scoped to the daemon, so no other
//! in-process daemon (restart mode hosts two) can leak into the window.
//!
//! Restart mode also attaches an ephemeral HTTP sidecar to its hosted
//! daemons and ends with a **scrape cross-check**: in the quiesced
//! window after the last pass (all client threads joined), the
//! Prometheus `/metrics` exposition must agree with the TCP `metrics`
//! op on every per-op request count — one fact, two wire formats. The
//! scrape latency rides along in the JSON line as `scrape_ms`.
//!
//! Every pass additionally brackets the daemon's `profile` op (the
//! sampling profiler's per-thread CPU attribution): the JSON line
//! reports each feature shard's **busy fraction** over the pass window
//! (per-shard CPU µs delta / pass wall time, in [0, 1]) and the
//! daemon's **CPU-ms-per-row** (total CPU delta across registered
//! threads / requests) alongside the wall p50/p99 — so "the daemon got
//! slower" is separable into "it burned more CPU per request" vs "it
//! waited longer". When the hosted daemon profiles (`profile_hz > 0`),
//! restart mode ends with a **flame coverage self-check**: the
//! `/profile` collapsed-stack output must be format-clean and contain
//! every stage the passes exercised (connection read/probe/write,
//! worker queue-wait, shard batch-wait, the profiler's own sample
//! stage) — deterministic because entered-stage counts are unioned
//! into the collapsed output regardless of sampling luck.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::gen::SbmConfig;
use crate::graph::AnyGraph;
use crate::obs::HistoSnapshot;
use crate::runtime::Engine;
use crate::util::{Json, Rng, Stats, Timer};

use super::protocol::{embed_request, nearest_request, parse_embed_reply, parse_nearest_reply};
use super::server::{ServeConfig, Server};

/// One pass's aggregate numbers.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub requests: usize,
    pub errors: usize,
    pub cached_replies: usize,
    /// Daemon-side `pipeline.graphs` delta across the pass: embeddings
    /// the pipeline actually computed (0 for a fully cached pass).
    pub recomputed_graphs: u64,
    /// Daemon-side `cache.l2_misses` delta: requests absent from both
    /// cache tiers (always 0 when every reply was served from cache).
    pub l2_miss_delta: u64,
    /// Daemon-side `serve.request_us.<op>` histogram count delta —
    /// self-checked equal to `requests` by every pass.
    pub daemon_count_delta: u64,
    /// Daemon-side request latency (bucket-derived, so quantized to
    /// power-of-two upper bounds) from the same histogram delta window.
    pub daemon_p50_ms: f64,
    pub daemon_p99_ms: f64,
    /// Each feature shard's busy fraction over the pass window, indexed
    /// by shard id: per-thread CPU µs delta (from the `profile` op) over
    /// the pass wall time, clamped to [0, 1]. Without per-thread CPU
    /// clocks the delta is wall-based, so the fractions read high.
    pub shard_busy: Vec<f64>,
    /// Daemon CPU burned per request over the pass window: total CPU µs
    /// delta across the daemon's registered threads / requests, in ms.
    /// Threads that deregistered mid-pass (short-lived connection loops)
    /// drop out of the total, so this tracks the persistent pipeline.
    pub cpu_ms_per_row: f64,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl BenchReport {
    pub fn line(&self) -> String {
        format!(
            "requests={} errors={} cached={} recomputed={} wall={:.2}s \
             throughput={:.0} req/s p50={:.2}ms p99={:.2}ms \
             daemon_p50={:.2}ms daemon_p99={:.2}ms cpu_per_row={:.3}ms",
            self.requests,
            self.errors,
            self.cached_replies,
            self.recomputed_graphs,
            self.wall_secs,
            self.requests_per_sec,
            self.p50_ms,
            self.p99_ms,
            self.daemon_p50_ms,
            self.daemon_p99_ms,
            self.cpu_ms_per_row
        )
    }

    fn json(&self, label: &str) -> Json {
        let mut busy = Json::arr();
        for b in &self.shard_busy {
            busy.push(*b);
        }
        Json::obj()
            .set("label", label)
            .set("requests", self.requests)
            .set("errors", self.errors)
            .set("cached_replies", self.cached_replies)
            .set("recomputed_graphs", self.recomputed_graphs)
            .set("l2_miss_delta", self.l2_miss_delta)
            .set("daemon_count_delta", self.daemon_count_delta)
            .set("daemon_p50_ms", self.daemon_p50_ms)
            .set("daemon_p99_ms", self.daemon_p99_ms)
            .set("shard_busy", busy)
            .set("cpu_ms_per_row", self.cpu_ms_per_row)
            .set("wall_secs", self.wall_secs)
            .set("throughput_rps", self.requests_per_sec)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
    }
}

/// An ordered set of labeled passes (`cold`, `warm_l1`, and — in
/// restart mode — `warm_l2` plus the `nearest_p*` retrieval passes).
#[derive(Clone, Debug)]
pub struct BenchRun {
    pub passes: Vec<(String, BenchReport)>,
    /// The restarted daemon's open-time ANN index build over the full
    /// persisted corpus, in milliseconds (restart mode with a store
    /// only; `None` for [`run_bench`]).
    pub ann_build_ms: Option<f64>,
    /// Wall time of the final `/metrics` HTTP scrape in the quiesced
    /// cross-check window (restart mode only; `None` for [`run_bench`],
    /// which has no hosted daemon to attach a sidecar to).
    pub scrape_ms: Option<f64>,
    /// Mean store-read cost per row, ns/row, of the two restart-warm
    /// passes as `(warm_l2, warm_l2_mmap)` — legacy copy path vs mmap
    /// view path — derived from each daemon's `cache.l2_read_us`
    /// histogram delta (restart mode only).
    pub l2_read_ns_per_row: Option<(f64, f64)>,
}

impl BenchRun {
    pub fn get(&self, label: &str) -> Option<&BenchReport> {
        self.passes.iter().find(|(l, _)| l == label).map(|(_, r)| r)
    }

    /// The machine-readable form printed as serve-bench's last line.
    pub fn json(&self) -> Json {
        let mut passes = Json::arr();
        for (label, r) in &self.passes {
            passes.push(r.json(label));
        }
        let mut out = Json::obj().set("bench", "serve").set("passes", passes);
        if let Some(ms) = self.ann_build_ms {
            out = out.set("ann_build_ms", ms);
        }
        if let Some(ms) = self.scrape_ms {
            out = out.set("scrape_ms", ms);
        }
        if let Some((legacy, mmap)) = self.l2_read_ns_per_row {
            out = out.set(
                "l2_read_ns_per_row",
                Json::obj().set("warm_l2", legacy).set("warm_l2_mmap", mmap),
            );
        }
        out
    }
}

/// Drive `addr` with `clients` threads of `per_client` requests each,
/// twice (`cold` then `warm_l1`). The workload is `seed`-deterministic
/// SBM graphs, so two runs against equally-configured servers measure
/// the same thing. NOTE: "cold" assumes a fresh server cache; replaying
/// against a warm long-lived server shifts cold-pass numbers toward
/// warm ones (the `recomputed_graphs` column makes that visible).
pub fn run_bench(addr: &str, clients: usize, per_client: usize, seed: u64) -> Result<BenchRun> {
    let graphs = workload(seed);
    let cold = run_pass(addr, clients, per_client, &graphs)?;
    let warm_l1 = run_pass(addr, clients, per_client, &graphs)?;
    Ok(BenchRun {
        passes: vec![("cold".to_string(), cold), ("warm_l1".to_string(), warm_l1)],
        ann_build_ms: None,
        scrape_ms: None,
        l2_read_ns_per_row: None,
    })
}

/// The restart benchmark (requires `cfg.store_dir`): host a daemon
/// in-process, run `cold` + `warm_l1`, shut it down, then host *two*
/// fresh daemons over the same store directory in turn — one with the
/// mmap read path disabled (`warm_l2`, the legacy seek+read+copy), one
/// with it enabled (`warm_l2_mmap`, zero-copy page-cache views) — and
/// measure restart-warm throughput on each. Self-checks that neither L2
/// pass recomputed anything (any `pipeline.graphs` or `cache.l2_misses`
/// movement fails the run), that the mmap pass served *every* read off
/// a mapping (`store.mmap_reads` delta == requests), and — where view
/// support exists — that the mmap daemon's ANN index owns zero row
/// bytes. Each L2 pass also brackets `cache.l2_read_us`, so the run
/// reports both read paths' ns/row head to head.
///
/// `engine` is the PJRT template exactly as for `Server::bind` — pass
/// it when `cfg.gsa.engine` is PJRT (the CLI forwards its detected
/// engine), `None` for the CPU engines.
pub fn run_restart_bench(
    cfg: &ServeConfig,
    clients: usize,
    per_client: usize,
    seed: u64,
    engine: Option<&Engine>,
) -> Result<BenchRun> {
    anyhow::ensure!(
        cfg.store_dir.is_some(),
        "run_restart_bench requires ServeConfig.store_dir (the L2 segment log)"
    );
    let graphs = workload(seed);

    let (addr, _http, handle) = host(cfg.clone(), engine)?;
    let cold = run_pass(&addr, clients, per_client, &graphs)?;
    let warm_l1 = run_pass(&addr, clients, per_client, &graphs)?;
    stop(&addr, handle)?;

    // "Restart" #1: a brand-new daemon process-equivalent — fresh
    // pipeline, empty L1 — over the store directory the first daemon
    // populated, with the mmap path OFF: the legacy read+copy baseline.
    let legacy_cfg = ServeConfig { store_mmap: false, ..cfg.clone() };
    let (addr, _http, handle) = host(legacy_cfg, engine)?;
    let (warm_l2, legacy_ns) =
        run_l2_pass(&addr, clients, per_client, &graphs, "warm_l2")?;
    stop(&addr, handle)?;

    // "Restart" #2: same store, mmap path ON — every sealed row is
    // served as a zero-copy view. Its open-time ANN build (reported as
    // ann_build_ms) covers the whole persisted corpus through views.
    let mmap_cfg = ServeConfig { store_mmap: true, ..cfg.clone() };
    let (addr, http, handle) = host(mmap_cfg, engine)?;
    let ann_build = ann_build_ms(&addr)?;
    let reads0 = store_mmap_reads(&addr)?;
    let (warm_l2_mmap, mmap_ns) =
        run_l2_pass(&addr, clients, per_client, &graphs, "warm_l2_mmap")?;
    let reads1 = store_mmap_reads(&addr)?;
    let requests = (clients.max(1) * per_client.max(1)) as u64;
    anyhow::ensure!(
        reads1.saturating_sub(reads0) == requests,
        "warm_l2_mmap self-check: store.mmap_reads moved by {} for {requests} requests — \
         every L2 read must take the mapped path",
        reads1.saturating_sub(reads0)
    );
    if cfg!(all(unix, target_endian = "little", target_pointer_width = "64")) {
        let owned = ann_indexed_bytes(&addr)?;
        anyhow::ensure!(
            owned == 0,
            "warm_l2_mmap self-check: the ANN index owns {owned} row bytes — with mmap on \
             it must reference rows in place"
        );
    }

    // k-NN retrieval over that corpus: replaying the same
    // (graph, graph_index) pairs means every query row is already in
    // L1 after warm_l2_mmap, so these passes time the IVFFlat search
    // alone.
    let k = 10.min(clients.max(1) * per_client.max(1));
    let mut nearest_passes = Vec::new();
    for probe in [0.1, 0.5, 1.0] {
        let label = format!("nearest_p{:.0}", probe * 100.0);
        let pass = run_nearest_pass(&addr, clients, per_client, &graphs, k, probe)?;
        anyhow::ensure!(
            pass.errors == 0,
            "{label} self-check: {} requests errored",
            pass.errors
        );
        anyhow::ensure!(
            pass.recomputed_graphs == 0,
            "{label} self-check: the daemon recomputed {} graphs — every query row must \
             already be cached",
            pass.recomputed_graphs
        );
        nearest_passes.push((label, pass));
    }
    // The scrape cross-check runs in a quiesced window — every client
    // thread above has joined, nothing is in flight — so the HTTP
    // exposition and the TCP snapshot must agree exactly.
    let scrape_ms = match &http {
        Some(h) => Some(scrape_crosscheck(&addr, h)?),
        None => None,
    };
    // Flame coverage: a profiling daemon's collapsed-stack output must
    // name every stage the passes above exercised (see module docs).
    if cfg.profile_hz > 0 {
        if let Some(h) = &http {
            profile_coverage_check(h)?;
        }
    }
    stop(&addr, handle)?;

    let mut passes = vec![
        ("cold".to_string(), cold),
        ("warm_l1".to_string(), warm_l1),
        ("warm_l2".to_string(), warm_l2),
        ("warm_l2_mmap".to_string(), warm_l2_mmap),
    ];
    passes.extend(nearest_passes);
    Ok(BenchRun {
        passes,
        ann_build_ms: ann_build,
        scrape_ms,
        l2_read_ns_per_row: Some((legacy_ns, mmap_ns)),
    })
}

/// One restart-warm pass against a freshly hosted daemon (empty L1, so
/// every request is exactly one store read): runs the standard embed
/// pass bracketed by the daemon's `cache.l2_read_us` histogram, applies
/// the zero-recompute self-checks, and returns the pass plus the mean
/// store-read cost in ns/row.
fn run_l2_pass(
    addr: &str,
    clients: usize,
    per_client: usize,
    graphs: &[AnyGraph],
    label: &str,
) -> Result<(BenchReport, f64)> {
    let read0 = fetch_histo(addr, "cache.l2_read_us")?;
    let pass = run_pass(addr, clients, per_client, graphs)?;
    let read1 = fetch_histo(addr, "cache.l2_read_us")?;
    anyhow::ensure!(
        pass.errors == 0,
        "{label} self-check: {} requests errored",
        pass.errors
    );
    anyhow::ensure!(
        pass.recomputed_graphs == 0,
        "{label} self-check: the daemon recomputed {} graphs — the pass must be served \
         entirely from the store",
        pass.recomputed_graphs
    );
    anyhow::ensure!(
        pass.l2_miss_delta == 0,
        "{label} self-check: {} full misses — every key must be on the segment log",
        pass.l2_miss_delta
    );
    // Unique (client, i) → graph_index pairs mean unique keys: every
    // request of the pass is exactly one L2 read, no more, no fewer.
    let delta = histo_delta(&read0, &read1);
    anyhow::ensure!(
        delta.count == pass.requests as u64,
        "{label} self-check: {} L2 reads for {} requests — each key must be read once",
        delta.count,
        pass.requests
    );
    let ns_per_row = delta.sum_us as f64 * 1e3 / delta.count.max(1) as f64;
    Ok((pass, ns_per_row))
}

/// The fixed bench workload: a seed-deterministic SBM set.
fn workload(seed: u64) -> Vec<AnyGraph> {
    SbmConfig { per_class: 4, ..Default::default() }.generate(&mut Rng::new(seed)).graphs
}

/// Bind + run a daemon on an ephemeral loopback port. Hosted daemons
/// always get an ephemeral HTTP sidecar (unless the caller pinned a
/// port) so the restart bench can run the scrape cross-check without
/// any configuration.
fn host(
    mut cfg: ServeConfig,
    engine: Option<&Engine>,
) -> Result<(String, Option<String>, JoinHandle<Result<()>>)> {
    if cfg.http_port.is_none() {
        cfg.http_port = Some(0);
    }
    let server = Server::bind("127.0.0.1:0", cfg, engine)?;
    let addr = server.local_addr().to_string();
    let http = server.http_addr().map(|a| a.to_string());
    let handle = std::thread::spawn(move || server.run());
    Ok((addr, http, handle))
}

/// One-shot HTTP GET against the daemon's sidecar; returns the body of
/// a 200 reply.
fn http_get(http_addr: &str, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(http_addr)
        .with_context(|| format!("connecting scrape probe to {http_addr}"))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {http_addr}\r\nAccept: text/plain\r\n\r\n")?;
    stream.flush()?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    anyhow::ensure!(
        raw.starts_with("HTTP/1.1 200"),
        "GET {path}: expected 200, got {:?}",
        raw.lines().next().unwrap_or("")
    );
    let (_, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("GET {path}: malformed HTTP reply"))?;
    Ok(body.to_string())
}

/// One sample out of a Prometheus text body: the value of the line that
/// starts with exactly `series` (name plus its full label selector).
fn prom_value(body: &str, series: &str) -> Option<u64> {
    body.lines()
        .filter(|l| !l.starts_with('#'))
        .find_map(|l| l.strip_prefix(series)?.strip_prefix(' ')?.trim().parse().ok())
}

/// The scrape cross-check: with the daemon quiesced, `/metrics` and the
/// TCP `metrics` op are two wire formats over the same registry, so
/// their per-op request counts must be equal — not merely close.
/// Returns the scrape's wall time in milliseconds for the JSON line.
fn scrape_crosscheck(addr: &str, http_addr: &str) -> Result<f64> {
    let t = Timer::start();
    let body = http_get(http_addr, "/metrics")?;
    let scrape_ms = t.elapsed_secs() * 1e3;
    for op in ["embed", "nearest"] {
        let tcp = request_histo(addr, op)?;
        let series = format!("serve_request_us_count{{op=\"{op}\"}}");
        let http_count = prom_value(&body, &series).unwrap_or(0);
        anyhow::ensure!(
            http_count == tcp.count,
            "scrape cross-check ({op}): /metrics says {http_count} requests, the TCP \
             metrics op says {}",
            tcp.count
        );
    }
    anyhow::ensure!(
        body.contains("graphlet_rf_build_info{"),
        "scrape cross-check: graphlet_rf_build_info series missing from /metrics"
    );
    Ok(scrape_ms)
}

fn stop(addr: &str, handle: JoinHandle<Result<()>>) -> Result<()> {
    send_shutdown(addr)?;
    handle.join().map_err(|_| anyhow::anyhow!("serve daemon panicked"))?
}

/// One `stats` op round-trip on a throwaway connection.
fn stats_json(addr: &str) -> Result<Json> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting stats probe to {addr}"))?;
    stream.write_all(b"{\"op\":\"stats\"}\n")?;
    stream.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    Json::parse(reply.trim()).map_err(|e| anyhow::anyhow!("stats reply: {e}"))
}

/// Daemon-side counters a pass brackets itself with: cumulative
/// `pipeline.graphs` (computed embeddings) and `cache.l2_misses` (full
/// misses), read through the `stats` op on a throwaway connection.
fn snapshot(addr: &str) -> Result<(u64, u64)> {
    let j = stats_json(addr)?;
    let graphs = j
        .get("pipeline")
        .and_then(|p| p.get("graphs"))
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("stats reply missing pipeline.graphs"))?;
    let l2_misses = j
        .get("cache")
        .and_then(|c| c.get("l2_misses"))
        .and_then(Json::as_u64)
        .unwrap_or(0);
    Ok((graphs, l2_misses))
}

/// Fetch the daemon's `serve.request_us.<op>` histogram (see
/// [`fetch_histo`]). Two of these bracket a pass; their bucket-wise
/// difference is the pass's own latency distribution.
fn request_histo(addr: &str, op: &str) -> Result<HistoSnapshot> {
    fetch_histo(addr, &format!("serve.request_us.{op}"))
}

/// Fetch the daemon's full metric registry (the `metrics` op) and
/// reconstruct the named histogram as a [`HistoSnapshot`] — zeroed when
/// the histogram doesn't exist yet (first probe against a fresh
/// process).
fn fetch_histo(addr: &str, name: &str) -> Result<HistoSnapshot> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting metrics probe to {addr}"))?;
    stream.write_all(b"{\"op\":\"metrics\"}\n")?;
    stream.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    let j = Json::parse(reply.trim()).map_err(|e| anyhow::anyhow!("metrics reply: {e}"))?;
    let mut snap = HistoSnapshot {
        count: 0,
        sum_us: 0,
        max_us: 0,
        buckets: [0; crate::obs::metrics::NUM_BUCKETS],
    };
    let Some(h) = j.get("histograms").and_then(|hs| hs.get(name)) else {
        return Ok(snap);
    };
    snap.count = h.get("count").and_then(Json::as_u64).unwrap_or(0);
    snap.sum_us = h.get("sum_us").and_then(Json::as_u64).unwrap_or(0);
    snap.max_us = h.get("max_us").and_then(Json::as_u64).unwrap_or(0);
    if let Some(buckets) = h.get("buckets").and_then(Json::as_array) {
        for (i, b) in buckets.iter().take(snap.buckets.len()).enumerate() {
            snap.buckets[i] = b.as_u64().unwrap_or(0);
        }
    }
    Ok(snap)
}

/// `after − before`, bucket-wise: the latency distribution of exactly
/// the requests that completed between the two probes. `max_us` keeps
/// the cumulative max (a conservative overflow-bucket bound — exact
/// unless an earlier window held the true max).
fn histo_delta(before: &HistoSnapshot, after: &HistoSnapshot) -> HistoSnapshot {
    let mut d = after.clone();
    d.count = after.count.saturating_sub(before.count);
    d.sum_us = after.sum_us.saturating_sub(before.sum_us);
    for (db, bb) in d.buckets.iter_mut().zip(before.buckets.iter()) {
        *db = db.saturating_sub(*bb);
    }
    d
}

/// The restarted daemon's ANN index build cost (stats
/// `ann.last_build_ms`); `None` when the daemon runs without a store.
fn ann_build_ms(addr: &str) -> Result<Option<f64>> {
    let j = stats_json(addr)?;
    Ok(j.get("ann").and_then(|a| a.get("last_build_ms")).and_then(Json::as_f64))
}

/// Cumulative `store.mmap_reads` (stats `store.mmap_reads`): rows the
/// daemon served through a mapped segment. Two of these bracket the
/// `warm_l2_mmap` pass.
fn store_mmap_reads(addr: &str) -> Result<u64> {
    let j = stats_json(addr)?;
    Ok(j.get("store").and_then(|s| s.get("mmap_reads")).and_then(Json::as_u64).unwrap_or(0))
}

/// Bytes of row data the daemon's ANN index owns (stats
/// `ann.indexed_bytes`): 0 when every indexed row is a zero-copy view.
fn ann_indexed_bytes(addr: &str) -> Result<u64> {
    let j = stats_json(addr)?;
    Ok(j.get("ann").and_then(|a| a.get("indexed_bytes")).and_then(Json::as_u64).unwrap_or(0))
}

/// One `profile` op round-trip: the daemon's per-thread CPU attribution
/// snapshot. Two of these bracket every pass.
fn profile_json(addr: &str) -> Result<Json> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting profile probe to {addr}"))?;
    stream.write_all(b"{\"op\":\"profile\"}\n")?;
    stream.flush()?;
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply)?;
    Json::parse(reply.trim()).map_err(|e| anyhow::anyhow!("profile reply: {e}"))
}

/// Per-thread cumulative CPU µs out of a `profile` reply, keyed by
/// `(role, index)`.
fn thread_cpu(j: &Json) -> Vec<(String, u64, u64)> {
    let mut out = Vec::new();
    let Some(threads) = j.get("threads").and_then(Json::as_array) else {
        return out;
    };
    for t in threads {
        let role = t.get("role").and_then(Json::as_str).unwrap_or("").to_string();
        let index = t.get("index").and_then(Json::as_u64).unwrap_or(0);
        let cpu = t.get("cpu_us").and_then(Json::as_u64).unwrap_or(0);
        out.push((role, index, cpu));
    }
    out
}

/// Per-shard busy fractions and total daemon CPU ms across a pass
/// window, from the two bracketing `profile` replies. A thread present
/// only in `after` (registered mid-pass) contributes its full reading;
/// one present only in `before` (deregistered mid-pass) contributes
/// nothing.
fn cpu_window(before: &Json, after: &Json, wall_secs: f64) -> (Vec<f64>, f64) {
    let mut base = std::collections::HashMap::new();
    for (role, index, cpu) in thread_cpu(before) {
        base.insert((role, index), cpu);
    }
    let mut shard_delta: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
    let mut total_us = 0u64;
    for (role, index, cpu) in thread_cpu(after) {
        let delta = cpu.saturating_sub(base.get(&(role.clone(), index)).copied().unwrap_or(0));
        total_us += delta;
        if role == "shard" {
            *shard_delta.entry(index).or_default() += delta;
        }
    }
    let wall_us = (wall_secs * 1e6).max(1.0);
    let shards = match shard_delta.keys().next_back() {
        Some(&max) => (0..=max)
            .map(|i| (*shard_delta.get(&i).unwrap_or(&0) as f64 / wall_us).clamp(0.0, 1.0))
            .collect(),
        None => Vec::new(),
    };
    (shards, total_us as f64 / 1e3)
}

/// Every `(role, stage)` frame the restart bench's passes exercise by
/// construction: connection loops touch read/probe/write on any
/// request, workers and shards enter their wait stages at spawn, and a
/// profiling daemon always has its sampler. Entered-stage counts are
/// unioned into the collapsed output, so these appear deterministically.
const EXPECTED_FRAMES: &[&str] = &[
    "conn_reader;read_request",
    "conn_reader;cache_probe",
    "conn_writer;reply_write",
    "worker;queue_wait",
    "shard;batch_wait",
    "profiler;sample",
];

/// The flame coverage self-check (restart mode, profiling daemons
/// only): `/profile` must emit format-clean `role;stage N` lines whose
/// stages are all in the registered vocabulary, covering every frame in
/// [`EXPECTED_FRAMES`]. Dead connection threads fold into the table on
/// the sampler tick after they exit, so the check polls briefly.
fn profile_coverage_check(http_addr: &str) -> Result<()> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let body = http_get(http_addr, "/profile")?;
        anyhow::ensure!(!body.trim().is_empty(), "flame self-check: /profile body is empty");
        for line in body.lines() {
            let frames_weight = line
                .rsplit_once(' ')
                .and_then(|(frames, w)| Some((frames.split_once(';')?, w)));
            let Some(((_, stage), weight)) = frames_weight else {
                anyhow::bail!("flame self-check: malformed collapsed line {line:?}");
            };
            anyhow::ensure!(
                crate::obs::profile::is_stage(stage) && weight.parse::<u64>().is_ok(),
                "flame self-check: unknown stage or weight in {line:?}"
            );
        }
        let missing: Vec<&str> = EXPECTED_FRAMES
            .iter()
            .filter(|f| !body.lines().any(|l| l.starts_with(&format!("{f} "))))
            .copied()
            .collect();
        if missing.is_empty() {
            return Ok(());
        }
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "flame self-check: /profile never covered {missing:?}; output:\n{body}"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
}

fn run_pass(
    addr: &str,
    clients: usize,
    per_client: usize,
    graphs: &[AnyGraph],
) -> Result<BenchReport> {
    let per_client = per_client.max(1);
    run_pass_with(addr, clients, per_client, "embed", |c| {
        client_loop(addr, c, per_client, graphs)
    })
}

/// A `nearest`-op pass: same fan-out and bracketing as [`run_pass`],
/// but every request is a k-NN query at the given probe factor.
fn run_nearest_pass(
    addr: &str,
    clients: usize,
    per_client: usize,
    graphs: &[AnyGraph],
    k: usize,
    probe: f64,
) -> Result<BenchReport> {
    let per_client = per_client.max(1);
    run_pass_with(addr, clients, per_client, "nearest", |c| {
        nearest_client_loop(addr, c, per_client, graphs, k, probe)
    })
}

/// Shared pass skeleton: bracket daemon-side counters *and* the
/// `serve.request_us.<op>` histogram, fan `clients` copies of `job` out
/// over scoped threads, merge latency reservoirs. Fails the pass if the
/// daemon's histogram count delta disagrees with the number of requests
/// the clients sent (the observability self-check).
fn run_pass_with<F>(
    addr: &str,
    clients: usize,
    per_client: usize,
    op: &str,
    job: F,
) -> Result<BenchReport>
where
    F: Fn(usize) -> Result<(Stats, usize, usize)> + Sync,
{
    let clients = clients.max(1);
    let per_client = per_client.max(1);
    let (graphs0, misses0) = snapshot(addr)?;
    let histo0 = request_histo(addr, op)?;
    let prof0 = profile_json(addr)?;
    let wall = Timer::start();
    let results = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        let job = &job;
        for c in 0..clients {
            handles.push(scope.spawn(move || job(c)));
        }
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("bench client panicked"))?)
            .collect::<Result<Vec<_>>>()
    })?;
    let wall_secs = wall.elapsed_secs();
    let (graphs1, misses1) = snapshot(addr)?;
    let histo1 = request_histo(addr, op)?;
    let prof1 = profile_json(addr)?;
    let (shard_busy, cpu_ms) = cpu_window(&prof0, &prof1, wall_secs);
    let mut lat = Stats::new();
    let (mut errors, mut cached) = (0usize, 0usize);
    for (s, e, h) in results {
        lat.merge(&s);
        errors += e;
        cached += h;
    }
    let requests = clients * per_client;
    // The observability self-check: every request a client sent must be
    // exactly one sample in the daemon's per-op request histogram. The
    // daemon records before flushing the reply bytes, so by the time
    // the clients have all read their replies the counts are final.
    let delta = histo_delta(&histo0, &histo1);
    anyhow::ensure!(
        delta.count == requests as u64,
        "metrics self-check ({op}): daemon counted {} requests, clients sent {requests} \
         (is another client driving this daemon?)",
        delta.count
    );
    Ok(BenchReport {
        requests,
        errors,
        cached_replies: cached,
        recomputed_graphs: graphs1.saturating_sub(graphs0),
        l2_miss_delta: misses1.saturating_sub(misses0),
        daemon_count_delta: delta.count,
        daemon_p50_ms: delta.percentile_us(50.0) as f64 / 1e3,
        daemon_p99_ms: delta.percentile_us(99.0) as f64 / 1e3,
        shard_busy,
        cpu_ms_per_row: cpu_ms / requests.max(1) as f64,
        wall_secs,
        requests_per_sec: if wall_secs > 0.0 { requests as f64 / wall_secs } else { 0.0 },
        p50_ms: lat.percentile(50.0) * 1e3,
        p99_ms: lat.percentile(99.0) * 1e3,
    })
}

/// One client: a synchronous send/recv loop. `graph_index` is globally
/// unique per (client, i) pair so the cold pass never self-collides,
/// while a replayed pass re-uses exactly the same indices (cache hits).
fn client_loop(
    addr: &str,
    client: usize,
    per_client: usize,
    graphs: &[AnyGraph],
) -> Result<(Stats, usize, usize)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting bench client to {addr}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut lat = Stats::new();
    let mut errors = 0usize;
    let mut cached = 0usize;
    let mut reply = String::new();
    for i in 0..per_client {
        let g = &graphs[i % graphs.len()];
        let graph_index = client * per_client + i;
        let line = embed_request(i as u64, graph_index, g);
        let t = Timer::start();
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        reply.clear();
        reader.read_line(&mut reply)?;
        lat.record(t.elapsed_secs());
        match parse_embed_reply(&reply) {
            Ok((_, _, was_cached)) => {
                if was_cached {
                    cached += 1;
                }
            }
            Err(_) => errors += 1,
        }
    }
    Ok((lat, errors, cached))
}

/// One retrieval client: `nearest` queries over the same
/// (graph, graph_index) pairs [`client_loop`] embedded, so the query
/// rows are cache hits and the timed work is the ANN search. A reply
/// with fewer than `k` neighbors counts as an error (the corpus holds
/// at least `k` rows by construction).
fn nearest_client_loop(
    addr: &str,
    client: usize,
    per_client: usize,
    graphs: &[AnyGraph],
    k: usize,
    probe: f64,
) -> Result<(Stats, usize, usize)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting bench client to {addr}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut lat = Stats::new();
    let mut errors = 0usize;
    let mut reply = String::new();
    for i in 0..per_client {
        let g = &graphs[i % graphs.len()];
        let graph_index = client * per_client + i;
        let line = nearest_request(i as u64, graph_index, k, Some(probe), g);
        let t = Timer::start();
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        reply.clear();
        reader.read_line(&mut reply)?;
        lat.record(t.elapsed_secs());
        match parse_nearest_reply(&reply) {
            Ok((_, neighbors, _, _)) if neighbors.len() == k => {}
            _ => errors += 1,
        }
    }
    Ok((lat, errors, 0))
}

/// Ask a server to stop (used by benches/tests for clean teardown).
pub fn send_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(b"{\"op\":\"shutdown\"}\n")?;
    stream.flush()?;
    let mut reply = String::new();
    let _ = BufReader::new(stream).read_line(&mut reply);
    Ok(())
}
