//! serve-bench: a loopback load-generation client for the serve daemon.
//!
//! C client threads each run a synchronous request/reply loop over one
//! TCP connection (pipeline concurrency comes from the C parallel
//! connections — that is exactly the traffic shape cross-request
//! batching exists for). Two passes:
//!
//! - **cold**: every request uses a fresh `graph_index`, so every
//!   embedding is computed by the pipeline;
//! - **warm**: the identical requests replayed, so every reply should
//!   come from the embedding cache.
//!
//! Reported per pass: throughput (requests/s) and p50/p99 latency from
//! a merged per-request latency reservoir. Fixed seed → fixed workload,
//! so numbers are comparable across PRs (the serving-perf baseline).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

use crate::gen::SbmConfig;
use crate::graph::AnyGraph;
use crate::util::{Rng, Stats, Timer};

use super::protocol::{embed_request, parse_embed_reply};

/// One pass's aggregate numbers.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub requests: usize,
    pub errors: usize,
    pub cached_replies: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl BenchReport {
    pub fn line(&self) -> String {
        format!(
            "requests={} errors={} cached={} wall={:.2}s throughput={:.0} req/s \
             p50={:.2}ms p99={:.2}ms",
            self.requests,
            self.errors,
            self.cached_replies,
            self.wall_secs,
            self.requests_per_sec,
            self.p50_ms,
            self.p99_ms
        )
    }
}

/// Cold + warm pass results.
#[derive(Clone, Debug)]
pub struct BenchPair {
    pub cold: BenchReport,
    pub warm: BenchReport,
}

/// Drive `addr` with `clients` threads of `per_client` requests each,
/// twice (cold then warm). The workload is `seed`-deterministic SBM
/// graphs, so two runs against equally-configured servers measure the
/// same thing. NOTE: "cold" assumes a fresh server cache; replaying
/// against a warm long-lived server shifts cold-pass numbers toward
/// warm ones.
pub fn run_bench(addr: &str, clients: usize, per_client: usize, seed: u64) -> Result<BenchPair> {
    let ds = SbmConfig { per_class: 4, ..Default::default() }.generate(&mut Rng::new(seed));
    let graphs: Vec<AnyGraph> = ds.graphs;
    let cold = run_pass(addr, clients, per_client, &graphs)?;
    let warm = run_pass(addr, clients, per_client, &graphs)?;
    Ok(BenchPair { cold, warm })
}

fn run_pass(
    addr: &str,
    clients: usize,
    per_client: usize,
    graphs: &[AnyGraph],
) -> Result<BenchReport> {
    let clients = clients.max(1);
    let per_client = per_client.max(1);
    let wall = Timer::start();
    let results = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            handles.push(scope.spawn(move || client_loop(addr, c, per_client, graphs)));
        }
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("bench client panicked"))?)
            .collect::<Result<Vec<_>>>()
    })?;
    let wall_secs = wall.elapsed_secs();
    let mut lat = Stats::new();
    let (mut errors, mut cached) = (0usize, 0usize);
    for (s, e, h) in results {
        lat.merge(&s);
        errors += e;
        cached += h;
    }
    let requests = clients * per_client;
    Ok(BenchReport {
        requests,
        errors,
        cached_replies: cached,
        wall_secs,
        requests_per_sec: if wall_secs > 0.0 { requests as f64 / wall_secs } else { 0.0 },
        p50_ms: lat.percentile(50.0) * 1e3,
        p99_ms: lat.percentile(99.0) * 1e3,
    })
}

/// One client: a synchronous send/recv loop. `graph_index` is globally
/// unique per (client, i) pair so the cold pass never self-collides,
/// while a replayed pass re-uses exactly the same indices (cache hits).
fn client_loop(
    addr: &str,
    client: usize,
    per_client: usize,
    graphs: &[AnyGraph],
) -> Result<(Stats, usize, usize)> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting bench client to {addr}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut lat = Stats::new();
    let mut errors = 0usize;
    let mut cached = 0usize;
    let mut reply = String::new();
    for i in 0..per_client {
        let g = &graphs[i % graphs.len()];
        let graph_index = client * per_client + i;
        let line = embed_request(i as u64, graph_index, g);
        let t = Timer::start();
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        reply.clear();
        reader.read_line(&mut reply)?;
        lat.record(t.elapsed_secs());
        match parse_embed_reply(&reply) {
            Ok((_, _, was_cached)) => {
                if was_cached {
                    cached += 1;
                }
            }
            Err(_) => errors += 1,
        }
    }
    Ok((lat, errors, cached))
}

/// Ask a server to stop (used by benches/tests for clean teardown).
pub fn send_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(b"{\"op\":\"shutdown\"}\n")?;
    stream.flush()?;
    let mut reply = String::new();
    let _ = BufReader::new(stream).read_line(&mut reply);
    Ok(())
}
