//! The serve daemon: TCP listener, per-connection reader/writer threads,
//! cache lookups, and admission control in front of the shared
//! [`StreamingPipeline`].
//!
//! See [`super`] (the module docs) for the dataflow diagram and
//! [`super::protocol`] for the wire format.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::ann::AnnConfig;
use crate::coordinator::{Completed, GraphJob, GsaConfig, StreamingPipeline, SubmitOutcome};
use crate::graph::{canonical_hash, AnyGraph, CsrGraph};
use crate::obs::{self, SpanRing, TraceCtx};
use crate::runtime::Engine;
use crate::store::{EmbeddingStore, StoreConfig};
use crate::util::Json;

use super::cache::{
    config_fingerprint, recompute_cost_estimate, CacheKey, EvictPolicy, TieredCache,
};
use super::protocol::{
    embed_reply, error_reply, nearest_reply, parse_request, ProtoError, Request,
};

/// Serve-layer configuration wrapping the embedding [`GsaConfig`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// The embedding configuration the pipeline is built with; requests
    /// cannot change it (it selects compiled artifacts and the cache
    /// fingerprint).
    pub gsa: GsaConfig,
    /// Per-request guard: reject graphs with more nodes than this.
    pub max_nodes: usize,
    /// Per-request guard: reject graphs with more edges than this.
    pub max_edges: usize,
    /// Reject request lines longer than this many bytes (the connection
    /// is closed afterwards — the stream is no longer line-synchronized).
    /// This also bounds per-request parse memory: every JSON node
    /// consumes at least one input byte, so the parsed tree is O(line
    /// length) nodes. The default (8 MiB, roughly a 400k-edge graph)
    /// keeps worst-case transient parse memory per connection in the
    /// low hundreds of MB; raise it only alongside `max_edges`.
    pub max_line_bytes: usize,
    /// Highest accepted `graph_index`: deriving the seed at stream
    /// position i costs O(i) RNG draws, so an unbounded client-supplied
    /// index would let one request pin a reader thread.
    pub max_graph_index: usize,
    /// Per-connection cap on registered-but-unwritten replies. A client
    /// that sends requests without reading replies hits this bound and
    /// simply stops being read (TCP backpressure) instead of growing
    /// server memory.
    pub max_pending_replies: usize,
    /// Embedding cache capacity in rows (0 disables caching).
    pub cache_capacity: usize,
    /// L1 eviction policy (`--cache-policy lru|cost-aware`).
    pub cache_policy: EvictPolicy,
    /// Segment-log directory for the persistent L2 tier
    /// (`--store-dir`); `None` keeps the cache RAM-only. With a store,
    /// rows computed by a previous daemon process are served bitwise
    /// identical from disk after a restart instead of being recomputed.
    pub store_dir: Option<std::path::PathBuf>,
    /// Memory-map sealed store segments (`--store-mmap true|false`) so
    /// L2 probes and ANN index rows are zero-copy views into the page
    /// cache instead of read+copy. Defaults to
    /// [`crate::store::mmap_default`] (on for unix unless the
    /// `GRAPHLET_RF_TEST_MMAP` axis overrides it); only meaningful with
    /// `store_dir` set.
    pub store_mmap: bool,
    /// IVFFlat probe factor (`--ann-probe`) for `nearest` queries that
    /// do not carry an explicit `probe`: the fraction of inverted lists
    /// scanned, in (0, 1]. At 1.0 every query is an exhaustive (exact)
    /// scan. Only meaningful with `store_dir` set.
    pub ann_probe: f64,
    /// Below this many indexed rows `nearest` brute-forces the whole
    /// corpus instead of probing lists (`--ann-min-brute`) — at small n
    /// the exact scan is cheaper than the centroid ranking it skips.
    pub ann_min_brute: usize,
    /// Slow-span threshold in ms (`--slow-ms`): any request span whose
    /// total time is ≥ this is captured separately by the trace ring
    /// and logged as one structured JSON line to stderr. `u64::MAX`
    /// (the default) disables slow capture; `0` marks every request —
    /// the CI obs axis uses that to exercise the slow path everywhere.
    pub slow_ms: u64,
    /// HTTP observability port (`--http-port`): serves `GET /metrics`
    /// (Prometheus text format), `/healthz`, and `/readyz` on
    /// `127.0.0.1:<port>` next to the TCP protocol socket. `Some(0)`
    /// binds an ephemeral port (tests); `None` (the default) disables
    /// the listener entirely.
    pub http_port: Option<u16>,
    /// Sampling-profiler rate in Hz (`--profile-hz`): the sampler
    /// thread walks the thread registry this many times per second,
    /// attributing per-thread CPU time to `(role, stage)` pairs (see
    /// [`crate::obs::profile`]). `0` disables the sampler (the
    /// registry still tracks threads; `profile` / `/profile` then
    /// report entered stages with zero samples). Defaults to a low
    /// always-on rate; the `GRAPHLET_RF_TEST_PROFILE` CI axis
    /// overrides it.
    pub profile_hz: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            gsa: GsaConfig::default(),
            max_nodes: 100_000,
            max_edges: 400_000,
            max_line_bytes: 8 << 20,
            max_graph_index: 1 << 20,
            max_pending_replies: 1024,
            cache_capacity: 4096,
            cache_policy: EvictPolicy::Lru,
            store_dir: None,
            store_mmap: crate::store::mmap_default(),
            ann_probe: crate::ann::DEFAULT_PROBE,
            ann_min_brute: crate::ann::DEFAULT_MIN_BRUTE,
            slow_ms: slow_ms_default(),
            http_port: None,
            profile_hz: profile_hz_default(),
        }
    }
}

/// Default slow-span threshold: `GRAPHLET_RF_TEST_OBS=1` (the CI obs
/// axis) means 0 ms — every request takes the slow path — otherwise
/// disabled. The `--slow-ms` flag overrides either way.
fn slow_ms_default() -> u64 {
    match std::env::var("GRAPHLET_RF_TEST_OBS") {
        Ok(v) if v == "1" => 0,
        _ => u64::MAX,
    }
}

/// Default sampler rate: always on at a deliberately low 19 Hz (a
/// prime, so ticks don't phase-lock with millisecond-periodic work;
/// per tick the sampler does one registry walk — observation-only
/// either way). The `GRAPHLET_RF_TEST_PROFILE` CI axis overrides it
/// outright (`0` = off, `997` = the aggressive full-rate legs), and
/// `--profile-hz` overrides both.
fn profile_hz_default() -> u64 {
    match std::env::var("GRAPHLET_RF_TEST_PROFILE") {
        Ok(v) => v.trim().parse().unwrap_or(19),
        Err(_) => 19,
    }
}

/// Capacity of the daemon's recent-span ring (`trace` op).
const TRACE_RING_CAP: usize = 256;

/// Shared server state: the pipeline, the tiered cache, and counters.
struct ServeCtx {
    cfg: ServeConfig,
    pipeline: StreamingPipeline,
    cache: TieredCache,
    config_fp: u64,
    addr: SocketAddr,
    stop: AtomicBool,
    connections: AtomicU64,
    requests: AtomicU64,
    errors: AtomicU64,
    /// This daemon's instance-scoped metric registry: every recording
    /// site in its pipeline/cache/store/ANN/span-ring lands here, so
    /// two in-process daemons report fully isolated metrics. The
    /// process-global registry is only the batch-CLI default.
    registry: Arc<obs::Registry>,
    /// Finished request spans (`trace` op + slow-span stderr lines).
    ring: Arc<SpanRing>,
    /// Daemon start time (`stats.server.uptime_secs`).
    started: Instant,
}

/// Count one per-request error reply: the coarse total (`stats.server.
/// errors`) plus the per-op `serve.errors.<op>` counter surfaced by
/// `stats` and `/metrics`.
fn record_error(ctx: &ServeCtx, op: &str) {
    ctx.errors.fetch_add(1, Ordering::Relaxed);
    ctx.registry.counter(&format!("serve.errors.{op}")).inc();
}

/// A bound, not-yet-running server (bind early so callers learn the
/// ephemeral port before spawning `run`).
pub struct Server {
    listener: TcpListener,
    /// The observability HTTP listener (`--http-port`), if enabled;
    /// stopped when `run` returns.
    http: Option<super::http::HttpServer>,
    /// The sampling-profiler thread (`--profile-hz`), if enabled;
    /// stopped when `run` returns (and on drop).
    profiler: Option<obs::Profiler>,
    ctx: Arc<ServeCtx>,
}

impl Server {
    /// Build the persistent pipeline and bind the listener. `engine` is
    /// the PJRT template when `cfg.gsa.engine` is PJRT (same contract as
    /// `embed_dataset`). With `cfg.store_dir` set, the segment log is
    /// opened (recovering whatever a previous daemon left, torn tails
    /// skipped) and tiered under the in-RAM cache.
    pub fn bind(addr: &str, cfg: ServeConfig, engine: Option<&Engine>) -> Result<Server> {
        // One registry per daemon: everything constructed below records
        // into it, never into the process-global default.
        let registry = Arc::new(obs::Registry::new());
        let pipeline = StreamingPipeline::with_registry(&cfg.gsa, engine, registry.clone())?;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve socket {addr}"))?;
        let local = listener.local_addr()?;
        let config_fp = config_fingerprint(pipeline.cfg());
        let store = match &cfg.store_dir {
            Some(dir) => {
                let store_cfg =
                    StoreConfig { mmap: cfg.store_mmap, ..StoreConfig::new(dir.clone()) };
                let mut s = EmbeddingStore::open(store_cfg)
                    .with_context(|| format!("opening embedding store {}", dir.display()))?;
                s.set_registry(registry.clone());
                Some(s)
            }
            None => None,
        };
        // The ANN side-car rides on the persistent tier: without a
        // store there is no corpus to search, so `nearest` is refused.
        let ann = cfg.store_dir.as_ref().map(|_| {
            (
                AnnConfig {
                    probe_factor: cfg.ann_probe,
                    min_brute: cfg.ann_min_brute,
                    seed: cfg.gsa.seed,
                    ..AnnConfig::default()
                },
                cfg.gsa.m,
            )
        });
        let cache = TieredCache::with_ann_registry(
            cfg.cache_capacity,
            cfg.cache_policy,
            recompute_cost_estimate(pipeline.cfg()),
            store,
            ann,
            registry.clone(),
        );
        // Everything /readyz vouches for is now up: the pipeline's
        // worker/shard threads are spawned, the store (if any) finished
        // its recovery scan, and the ANN cell (if any) completed its
        // synchronous first build — so the HTTP listener starts ready.
        let http = match cfg.http_port {
            Some(port) => Some(super::http::HttpServer::spawn(
                port,
                registry.clone(),
                obs::BuildInfo {
                    engine: cfg.gsa.engine.name().to_string(),
                    config_fp: format!("{config_fp:016x}"),
                    version: env!("CARGO_PKG_VERSION").to_string(),
                },
                true,
            )?),
            None => None,
        };
        // The sampler rides on the same instance-scoped registry every
        // thread registers with, so two in-process daemons profile in
        // full isolation.
        let profiler = obs::Profiler::start(registry.clone(), cfg.profile_hz);
        let cfg_slow_ms = cfg.slow_ms;
        Ok(Server {
            listener,
            http,
            profiler,
            ctx: Arc::new(ServeCtx {
                cfg,
                pipeline,
                cache,
                config_fp,
                addr: local,
                stop: AtomicBool::new(false),
                connections: AtomicU64::new(0),
                requests: AtomicU64::new(0),
                errors: AtomicU64::new(0),
                ring: SpanRing::with_registry(TRACE_RING_CAP, cfg_slow_ms, registry.clone()),
                registry,
                started: Instant::now(),
            }),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.ctx.addr
    }

    /// The observability HTTP address, when `--http-port` is set
    /// (resolves ephemeral ports).
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http.as_ref().map(|h| h.local_addr())
    }

    /// Fingerprint of the *normalized* pipeline config — the value
    /// baked into cache keys and reported by `stats` (as 16 hex
    /// digits). Exposed so the CLI banner can print the same number a
    /// client will see.
    pub fn config_fp(&self) -> u64 {
        self.ctx.config_fp
    }

    /// Accept loop: one reader + one writer thread per connection. Runs
    /// until a client sends the `shutdown` op.
    pub fn run(self) -> Result<()> {
        for stream in self.listener.incoming() {
            if self.ctx.stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let conn_id = self.ctx.connections.fetch_add(1, Ordering::Relaxed);
                    let ctx = self.ctx.clone();
                    std::thread::spawn(move || handle_conn(s, &ctx, conn_id as usize));
                }
                Err(e) => eprintln!("serve: accept error: {e}"),
            }
        }
        // The daemon is going down: take the scrape endpoint and the
        // sampler with it.
        if let Some(http) = self.http {
            http.stop();
        }
        if let Some(mut profiler) = self.profiler {
            profiler.stop();
        }
        Ok(())
    }
}

/// How the writer thread should render a completed tag.
enum PendingReply {
    /// A fully formatted reply line (errors, ping, stats, cache hits).
    Raw(String),
    /// A pipeline-computed embedding; `key` = Some means "insert into
    /// the cache on arrival".
    Embed { id: u64, key: Option<CacheKey> },
    /// A pipeline-computed *query* embedding for a k-NN request: on
    /// arrival the row is cached L1-only (never persisted — `nearest`
    /// is read-only) and then searched against the ANN index.
    Nearest { id: u64, key: CacheKey, k: usize, probe: Option<f64> },
}

/// Per-connection state shared between the reader and writer threads:
/// the tag → reply registry plus the backpressure machinery (the reader
/// sleeps on `drained` while `pending` is at the configured cap, and
/// the writer wakes it per written reply — or permanently via
/// `writer_gone` when the client stops reading and the write half dies).
struct ConnShared {
    /// tag → (how to render, the request's span). The span rides along
    /// so the writer can stamp `reply_write` and record the per-op
    /// request histogram; dropping the entry's last handle deposits the
    /// finished span into the daemon's ring.
    pending: Mutex<HashMap<u64, (PendingReply, TraceCtx)>>,
    drained: Condvar,
    writer_gone: AtomicBool,
}

/// Synthetic completion for replies that never enter the pipeline.
fn synthetic(tag: u64) -> Completed {
    Completed { tag, row: Vec::new(), samples: 0, error: None }
}

/// Block until the pending-reply registry has room (or the writer is
/// gone). Returns false when the connection is no longer writable —
/// the reader should stop consuming requests.
fn wait_for_capacity(shared: &ConnShared, cap: usize) -> bool {
    let cap = cap.max(1);
    let mut g = shared.pending.lock().expect("pending lock");
    while g.len() >= cap {
        if shared.writer_gone.load(Ordering::Acquire) {
            return false;
        }
        g = shared.drained.wait(g).expect("pending lock");
    }
    !shared.writer_gone.load(Ordering::Acquire)
}

fn handle_conn(stream: TcpStream, ctx: &Arc<ServeCtx>, conn_id: usize) {
    let read_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = channel::<Completed>();
    let shared = Arc::new(ConnShared {
        pending: Mutex::new(HashMap::new()),
        drained: Condvar::new(),
        writer_gone: AtomicBool::new(false),
    });
    let writer = {
        let shared = shared.clone();
        let ctx = ctx.clone();
        std::thread::spawn(move || writer_loop(stream, &reply_rx, &shared, &ctx, conn_id))
    };

    // Register with the profiler: blocked on the socket the thread is
    // `read_request`; handling a parsed line starts at the cache probe.
    let prof = ctx.registry.threads().register("conn_reader", conn_id);
    let mut reader = BufReader::new(read_half);
    let mut line = String::new();
    let mut next_tag: u64 = 0;
    loop {
        line.clear();
        prof.set_stage("read_request");
        // Cap line length so one hostile request cannot exhaust memory.
        let n = match (&mut reader)
            .take(ctx.cfg.max_line_bytes as u64 + 1)
            .read_line(&mut line)
        {
            Ok(n) => n,
            Err(_) => break,
        };
        if n == 0 {
            break; // EOF: client closed the connection.
        }
        if line.len() > ctx.cfg.max_line_bytes {
            // The rest of the oversized line is unread: the stream is no
            // longer line-synchronized, so reply and drop the connection.
            record_error(ctx, "error");
            let trace = TraceCtx::new("error", 0, ctx.ring.clone());
            send_raw(
                &shared,
                &reply_tx,
                next_tag,
                error_reply(None, "request line too long"),
                trace,
            );
            break;
        }
        if line.trim().is_empty() {
            continue;
        }
        // Backpressure: never hold more than max_pending_replies
        // unwritten replies for one connection.
        if !wait_for_capacity(&shared, ctx.cfg.max_pending_replies) {
            break;
        }
        ctx.requests.fetch_add(1, Ordering::Relaxed);
        let tag = next_tag;
        next_tag += 1;
        prof.set_stage("cache_probe");
        if handle_request(&line, tag, ctx, &shared, &reply_tx) == Flow::Shutdown {
            break;
        }
    }
    // Dropping reply_tx lets the writer drain in-flight pipeline
    // completions for this connection and then exit.
    drop(reply_tx);
    let _ = writer.join();
}

/// Register a pre-rendered reply and wake the writer.
fn send_raw(
    shared: &ConnShared,
    reply_tx: &Sender<Completed>,
    tag: u64,
    line: String,
    trace: TraceCtx,
) {
    shared
        .pending
        .lock()
        .expect("pending lock")
        .insert(tag, (PendingReply::Raw(line), trace));
    let _ = reply_tx.send(synthetic(tag));
}

#[derive(PartialEq, Eq)]
enum Flow {
    Continue,
    Shutdown,
}

fn handle_request(
    line: &str,
    tag: u64,
    ctx: &Arc<ServeCtx>,
    shared: &ConnShared,
    reply_tx: &Sender<Completed>,
) -> Flow {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(ProtoError { id, msg }) => {
            record_error(ctx, "error");
            let trace = TraceCtx::new("error", id.unwrap_or(0), ctx.ring.clone());
            send_raw(shared, reply_tx, tag, error_reply(id, &msg), trace);
            return Flow::Continue;
        }
    };
    let op = match &req {
        Request::Ping { .. } => "ping",
        Request::Stats { .. } => "stats",
        Request::Metrics { .. } => "metrics",
        Request::Trace { .. } => "trace",
        Request::Profile { .. } => "profile",
        Request::Shutdown { .. } => "shutdown",
        Request::Embed { .. } => "embed",
        Request::Nearest { .. } => "nearest",
    };
    let req_id = match &req {
        Request::Ping { id }
        | Request::Stats { id }
        | Request::Metrics { id }
        | Request::Trace { id, .. }
        | Request::Profile { id }
        | Request::Shutdown { id }
        | Request::Embed { id, .. }
        | Request::Nearest { id, .. } => *id,
    };
    // One span per request, whatever the op; it finishes (deposits into
    // the ring) when its last handle drops — after the writer stamped
    // `reply_write`, or when an error path drops the pending entry.
    let trace = TraceCtx::new(op, req_id, ctx.ring.clone());
    match req {
        Request::Ping { id } => {
            let line = Json::obj().set("id", id).set("ok", true).set("op", "ping").to_string();
            send_raw(shared, reply_tx, tag, line, trace);
            Flow::Continue
        }
        Request::Stats { id } => {
            send_raw(shared, reply_tx, tag, stats_reply(id, ctx), trace);
            Flow::Continue
        }
        Request::Metrics { id } => {
            // This daemon's full registry snapshot: counters, gauges,
            // and every histogram's log₂ buckets + derived percentiles.
            let line = ctx
                .registry
                .snapshot_json()
                .set("id", id)
                .set("ok", true)
                .set("op", "metrics")
                .to_string();
            send_raw(shared, reply_tx, tag, line, trace);
            Flow::Continue
        }
        Request::Trace { id, n, span_id } => {
            let line = match span_id {
                // Point lookup: line a slow-span stderr line (which
                // carries its span_id) up against the full span.
                Some(sid) => match ctx.ring.find(sid) {
                    Some(rec) => Json::obj()
                        .set("id", id)
                        .set("ok", true)
                        .set("op", "trace")
                        .set("span", rec.to_json())
                        .to_string(),
                    None => {
                        record_error(ctx, "trace");
                        error_reply(
                            Some(id),
                            &format!("trace: span {sid} not found (aged out of both buffers)"),
                        )
                    }
                },
                None => {
                    let mut spans = Json::arr();
                    for s in ctx.ring.recent(n) {
                        spans.push(s.to_json());
                    }
                    let mut slow = Json::arr();
                    for s in ctx.ring.slow() {
                        slow.push(s.to_json());
                    }
                    Json::obj()
                        .set("id", id)
                        .set("ok", true)
                        .set("op", "trace")
                        .set("spans", spans)
                        .set("slow", slow)
                        .set("slow_emitted", ctx.ring.slow_emitted())
                        .to_string()
                }
            };
            send_raw(shared, reply_tx, tag, line, trace);
            Flow::Continue
        }
        Request::Profile { id } => {
            send_raw(shared, reply_tx, tag, profile_reply(id, ctx), trace);
            Flow::Continue
        }
        Request::Shutdown { id } => {
            let line =
                Json::obj().set("id", id).set("ok", true).set("op", "shutdown").to_string();
            send_raw(shared, reply_tx, tag, line, trace);
            ctx.stop.store(true, Ordering::SeqCst);
            // Self-connect to unblock the accept loop.
            let _ = TcpStream::connect(ctx.addr);
            Flow::Shutdown
        }
        Request::Embed { id, v, edges, graph_index } => {
            if let Err(msg) = validate_query(ctx, v, &edges, graph_index) {
                record_error(ctx, "embed");
                send_raw(shared, reply_tx, tag, error_reply(Some(id), &msg), trace);
                return Flow::Continue;
            }
            let graph = AnyGraph::Csr(CsrGraph::from_edges(v, &edges));
            let seed = ctx.pipeline.graph_seed(graph_index);
            let key =
                CacheKey { graph_hash: canonical_hash(&graph), config_fp: ctx.config_fp, seed };
            let hit = ctx.cache.get(&key);
            trace.stamp("cache_probe");
            if let Some(row) = hit {
                send_raw(shared, reply_tx, tag, embed_reply(id, &row, true), trace);
                return Flow::Continue;
            }
            // Register BEFORE submitting: the completion may race ahead.
            shared
                .pending
                .lock()
                .expect("pending lock")
                .insert(tag, (PendingReply::Embed { id, key: Some(key) }, trace.clone()));
            submit_job(ctx, shared, reply_tx, tag, id, graph, seed, trace);
            Flow::Continue
        }
        Request::Nearest { id, v, edges, graph_index, k, probe } => {
            if let Err(msg) = validate_query(ctx, v, &edges, graph_index) {
                record_error(ctx, "nearest");
                send_raw(shared, reply_tx, tag, error_reply(Some(id), &msg), trace);
                return Flow::Continue;
            }
            // k is validated against the *stored* corpus up front so the
            // obvious misuses fail fast, before the query is embedded.
            let Some(n) = ctx.cache.store_len() else {
                record_error(ctx, "nearest");
                let msg =
                    "nearest requires a persistent store (start the daemon with --store-dir)";
                send_raw(shared, reply_tx, tag, error_reply(Some(id), msg), trace);
                return Flow::Continue;
            };
            if k > n {
                record_error(ctx, "nearest");
                let msg = format!("nearest: k={k} exceeds the {n} stored rows");
                send_raw(shared, reply_tx, tag, error_reply(Some(id), &msg), trace);
                return Flow::Continue;
            }
            let graph = AnyGraph::Csr(CsrGraph::from_edges(v, &edges));
            let seed = ctx.pipeline.graph_seed(graph_index);
            let key =
                CacheKey { graph_hash: canonical_hash(&graph), config_fp: ctx.config_fp, seed };
            let hit = ctx.cache.get(&key);
            trace.stamp("cache_probe");
            if let Some(row) = hit {
                let line = render_nearest(ctx, id, &row, k, probe, &trace);
                send_raw(shared, reply_tx, tag, line, trace);
                return Flow::Continue;
            }
            shared
                .pending
                .lock()
                .expect("pending lock")
                .insert(tag, (PendingReply::Nearest { id, key, k, probe }, trace.clone()));
            submit_job(ctx, shared, reply_tx, tag, id, graph, seed, trace);
            Flow::Continue
        }
    }
}

/// Hand an embedding job to the pipeline, mapping admission-control
/// rejections to per-request error replies (shared by embed/nearest).
/// The job carries a clone of the request span, so pipeline stages
/// stamp into the same trace the writer finishes.
#[allow(clippy::too_many_arguments)]
fn submit_job(
    ctx: &ServeCtx,
    shared: &ConnShared,
    reply_tx: &Sender<Completed>,
    tag: u64,
    id: u64,
    graph: AnyGraph,
    seed: u64,
    trace: TraceCtx,
) {
    let job = GraphJob {
        graph: Arc::new(graph),
        seed,
        tag,
        done: reply_tx.clone(),
        trace: Some(trace.clone()),
    };
    match ctx.pipeline.try_submit(job) {
        Ok(SubmitOutcome::Accepted) => {}
        Ok(SubmitOutcome::Overloaded) => {
            record_error(ctx, trace.op());
            send_raw(
                shared,
                reply_tx,
                tag,
                error_reply(Some(id), "server overloaded: job queue full, retry later"),
                trace,
            );
        }
        Err(e) => {
            record_error(ctx, trace.op());
            send_raw(shared, reply_tx, tag, error_reply(Some(id), &e.to_string()), trace);
        }
    }
}

/// Run the k-NN search for an already-embedded query row and render the
/// reply line (used from both the cache-hit fast path and the writer).
fn render_nearest(
    ctx: &ServeCtx,
    id: u64,
    row: &[f32],
    k: usize,
    probe: Option<f64>,
    trace: &TraceCtx,
) -> String {
    let out = ctx.cache.nearest(row, k, probe);
    trace.stamp("ann_search");
    match out {
        Ok(out) => nearest_reply(id, &out.neighbors, out.probed, out.scanned),
        Err(e) => {
            record_error(ctx, trace.op());
            error_reply(Some(id), &e.to_string())
        }
    }
}

/// The guards shared by every graph-carrying request: graph shape
/// limits plus the seed-stream position bound (deriving the seed at
/// position i costs O(i) RNG draws, so an unbounded client-supplied
/// index would let one request pin a reader thread).
fn validate_query(
    ctx: &ServeCtx,
    v: usize,
    edges: &[(usize, usize)],
    graph_index: usize,
) -> Result<(), String> {
    validate_graph(ctx, v, edges)?;
    if graph_index > ctx.cfg.max_graph_index {
        return Err(format!(
            "graph_index {graph_index} exceeds limit {}",
            ctx.cfg.max_graph_index
        ));
    }
    Ok(())
}

fn validate_graph(ctx: &ServeCtx, v: usize, edges: &[(usize, usize)]) -> Result<(), String> {
    let cfg = &ctx.cfg;
    if v == 0 {
        return Err("graph must have at least one node".to_string());
    }
    if v > cfg.max_nodes {
        return Err(format!("graph too large: {v} nodes > limit {}", cfg.max_nodes));
    }
    if edges.len() > cfg.max_edges {
        return Err(format!("graph too large: {} edges > limit {}", edges.len(), cfg.max_edges));
    }
    if v < cfg.gsa.k {
        return Err(format!(
            "graph has {v} nodes but graphlet size k={} requires at least k",
            cfg.gsa.k
        ));
    }
    for &(a, b) in edges {
        if a >= v || b >= v {
            return Err(format!("edge ({a}, {b}) out of range for v={v}"));
        }
    }
    Ok(())
}

/// The `profile` op reply: the aggregated `(role, stage)` table, the
/// live thread list with busy fractions, and enough metadata (`hz`,
/// tick/sample totals, CPU-clock availability) for a client to judge
/// how much signal the numbers carry.
fn profile_reply(id: u64, ctx: &ServeCtx) -> String {
    let threads = ctx.registry.threads();
    let mut stages = Json::arr();
    for r in threads.stage_table() {
        stages.push(
            Json::obj()
                .set("role", r.role)
                .set("stage", r.stage)
                .set("samples", r.samples)
                .set("cpu_us", r.cpu_us)
                .set("entered", r.entered),
        );
    }
    let mut listed = Json::arr();
    for t in threads.snapshot() {
        listed.push(
            Json::obj()
                .set("role", t.role)
                .set("index", t.index)
                .set("stage", t.stage)
                .set("cpu_us", t.cpu_us)
                .set("wall_us", t.wall_us)
                .set("busy", t.busy),
        );
    }
    Json::obj()
        .set("id", id)
        .set("ok", true)
        .set("op", "profile")
        .set("profile_hz", ctx.cfg.profile_hz)
        .set("ticks", threads.ticks())
        .set("samples", threads.samples())
        .set("cpu_clock", obs::cpu_clock_supported())
        .set("stages", stages)
        .set("threads", listed)
        .to_string()
}

fn stats_reply(id: u64, ctx: &ServeCtx) -> String {
    // Refresh the proc.* gauges on demand so a --profile-hz 0 daemon
    // still reports live process numbers here and in /metrics.
    obs::profile::refresh_proc_gauges(&ctx.registry);
    let tiered = ctx.cache.stats();
    let cache = tiered.l1;
    let pipe = ctx.pipeline.metrics_snapshot();
    // Backpressure gauges: admitted-but-unclaimed jobs and per-shard
    // channel occupancy, so overload (`Overloaded`) is observable as
    // rising depth before admission control starts rejecting.
    let mut occupancy = Json::arr();
    for occ in ctx.pipeline.shard_occupancy() {
        occupancy.push(occ);
    }
    let mut out = Json::obj()
        .set("id", id)
        .set("ok", true)
        .set("op", "stats")
        .set(
            "cache",
            // L1 counters keep their historical names; the l2_* trio is
            // always present (zero without a store) so clients can
            // track the full-miss rate — `l2_misses` is the number of
            // requests the pipeline actually had to compute when a
            // store is attached.
            Json::obj()
                .set("hits", cache.hits)
                .set("misses", cache.misses)
                .set("evictions", cache.evictions)
                .set("len", cache.len)
                .set("capacity", cache.capacity)
                .set("policy", cache.policy)
                .set("l2_hits", tiered.l2_hits)
                .set("l2_misses", tiered.l2_misses)
                .set("l2_promotions", tiered.l2_promotions),
        );
    if let Some(st) = tiered.store {
        out = out.set(
            "store",
            Json::obj()
                .set("segments", st.segments)
                .set("records", st.records)
                .set("live_bytes", st.live_bytes)
                .set("dead_bytes", st.dead_bytes)
                .set("corrupt_skipped", st.corrupt_skipped)
                .set("compactions", st.compactions)
                .set("mmap_segments", st.mmap_segments)
                .set("mmap_bytes", st.mmap_bytes)
                .set("mmap_reads", st.mmap_reads),
        );
    }
    if let Some(ann) = tiered.ann {
        out = out.set(
            "ann",
            // `lists` mirrors `centroids` (IVFFlat has one inverted
            // list per centroid); `indexed + pending` covers every
            // live stored row between rebuilds.
            Json::obj()
                .set("centroids", ann.centroids)
                .set("lists", ann.centroids)
                .set("indexed", ann.indexed)
                .set("pending", ann.pending)
                .set("builds", ann.builds)
                .set("last_build_ms", ann.last_build_ms)
                .set("queries", ann.queries)
                .set("probed_lists", ann.probed_lists)
                .set("scanned_rows", ann.scanned_rows)
                .set("indexed_bytes", ann.indexed_bytes)
                .set("probe_factor", ctx.cfg.ann_probe)
                .set("min_brute", ctx.cfg.ann_min_brute),
        );
    }
    out
        .set(
            "pipeline",
            Json::obj()
                .set("graphs", pipe.graphs)
                .set("samples", pipe.samples)
                .set("batches", pipe.batches)
                .set("padded_rows", pipe.padded_rows)
                .set("feature_secs", pipe.feature_secs)
                .set("queue_depth", ctx.pipeline.queue_depth())
                .set("shard_occupancy", occupancy)
                .set("shards", ctx.cfg.gsa.shards.max(1))
                .set("workers", ctx.cfg.gsa.workers.max(1)),
        )
        .set(
            "server",
            // uptime/engine/config_fp let a client tell daemons apart
            // across a restart: the fingerprint hex matches the hex in
            // stored cache keys, the engine names the CLI mode.
            Json::obj()
                .set("connections", ctx.connections.load(Ordering::Relaxed))
                .set("requests", ctx.requests.load(Ordering::Relaxed))
                .set("errors", ctx.errors.load(Ordering::Relaxed))
                .set("uptime_secs", ctx.started.elapsed().as_secs())
                .set("engine", ctx.cfg.gsa.engine.name())
                .set("config_fp", format!("{:016x}", ctx.config_fp))
                .set("errors_by_op", errors_by_op(&ctx.registry)),
        )
        .set(
            "proc",
            // Process self-metrics (refreshed above; zero off Linux).
            Json::obj()
                .set("rss_bytes", ctx.registry.gauge("proc.rss_bytes").get())
                .set("threads", ctx.registry.gauge("proc.threads").get())
                .set("open_fds", ctx.registry.gauge("proc.open_fds").get()),
        )
        .set("request_latency", request_latency_summaries(&ctx.registry))
        .to_string()
}

/// Per-op `serve.request_us.<op>` summaries (count + percentiles, no
/// buckets) for the `stats` reply. The registry is instance-scoped, so
/// these are exactly this daemon's requests — absolute values, no
/// cross-daemon contamination to diff away.
fn request_latency_summaries(registry: &obs::Registry) -> Json {
    let mut out = Json::obj();
    let prefix = "serve.request_us.";
    for (name, snap) in registry.histo_snapshots_prefixed(prefix) {
        let op = &name[prefix.len()..];
        out = out.set(op, snap.to_json(false));
    }
    out
}

/// Per-op `serve.errors.<op>` counts for the `stats` reply (empty
/// object until the first error).
fn errors_by_op(registry: &obs::Registry) -> Json {
    let mut out = Json::obj();
    let prefix = "serve.errors.";
    for (name, count) in registry.counters_prefixed(prefix) {
        let op = &name[prefix.len()..];
        out = out.set(op, count);
    }
    out
}

/// Writer: the single owner of the connection's write half. Receives
/// both synthetic completions (registered raw lines) and pipeline
/// completions, renders them, and inserts fresh rows into the cache.
/// Exits when every sender (reader + in-flight jobs) is gone, or on the
/// first failed write (client disconnected mid-request — pending jobs
/// then complete into a closed channel and are dropped harmlessly).
fn writer_loop(
    stream: TcpStream,
    rx: &Receiver<Completed>,
    shared: &ConnShared,
    ctx: &ServeCtx,
    conn_id: usize,
) {
    // Blocked on the completion channel the writer is `idle`; rendering
    // + flushing a reply is `reply_write` — matching the span stamp.
    let prof = ctx.registry.threads().register("conn_writer", conn_id);
    let mut w = BufWriter::new(stream);
    for done in rx.iter() {
        prof.set_stage("reply_write");
        let Some((p, trace)) = shared.pending.lock().expect("pending lock").remove(&done.tag)
        else {
            prof.set_stage("idle");
            continue;
        };
        let line = match p {
            PendingReply::Raw(s) => s,
            PendingReply::Embed { id, key } => match done.error {
                Some(e) => {
                    record_error(ctx, trace.op());
                    error_reply(Some(id), &e)
                }
                None => {
                    if let Some(k) = key {
                        ctx.cache.insert(k, done.row.clone());
                    }
                    embed_reply(id, &done.row, false)
                }
            },
            PendingReply::Nearest { id, key, k, probe } => match done.error {
                Some(e) => {
                    record_error(ctx, trace.op());
                    error_reply(Some(id), &e)
                }
                None => {
                    // L1-only: repeat queries stay warm without the
                    // query row ever joining the stored corpus.
                    ctx.cache.insert_query_row(key, done.row.clone());
                    render_nearest(ctx, id, &done.row, k, probe, &trace)
                }
            },
        };
        // Last stage + the per-op request histogram, recorded before
        // the bytes flush so a client that reads its reply and then
        // asks for `metrics` always sees its own request counted.
        trace.stamp("reply_write");
        ctx.registry
            .histo(&format!("serve.request_us.{}", trace.op()))
            .record_us(trace.elapsed_us());
        drop(trace);
        let wrote = w
            .write_all(line.as_bytes())
            .and_then(|()| w.write_all(b"\n"))
            .and_then(|()| w.flush());
        if wrote.is_err() {
            break;
        }
        // One reply drained: admit one more request past backpressure.
        shared.drained.notify_one();
        prof.set_stage("idle");
    }
    // Whether the channel drained (connection done) or a write failed
    // (client stopped reading / disconnected): release a reader that
    // may be parked on the capacity gate. The store happens under the
    // pending lock so a reader cannot check the flag and then sleep
    // through this very notification (lost wakeup).
    {
        let _g = shared.pending.lock().expect("pending lock");
        shared.writer_gone.store(true, Ordering::Release);
    }
    shared.drained.notify_all();
}
