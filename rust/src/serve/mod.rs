//! `serve`: a persistent graph-embedding service with cross-request
//! batching and an embedding cache.
//!
//! The daemon (`graphlet-rf serve --port N`) keeps one
//! [`StreamingPipeline`] warm — sampler workers, feature shards, and
//! (in PJRT mode) compiled artifacts live for the process, not for one
//! dataset — and serves embedding requests over a line-delimited JSON
//! protocol on plain TCP (no new dependencies; the build stays
//! hermetic/offline).
//!
//! ```text
//!                        ┌──────────────── serve daemon ────────────────────┐
//!  client A ──TCP──► reader thread A ──┬─ cache hit ──► writer A ──► client A
//!  client B ──TCP──► reader thread B … │   (graph hash + config fp + seed)
//!                        │ parse / validate / admission control
//!                        │ miss: GraphJob{graph, seed, tag, done=writer chan}
//!                        ▼
//!            shared StreamingPipeline (one per daemon)
//!               sampler workers ──► per-shard bounded channels
//!                  │  rows from jobs of *different requests* pack into
//!                  │  one compiled-size batch (cross-request batching)
//!                  ▼
//!               N feature shards ──► per-job accumulators
//!                        │ job's s-th sample lands → mean row
//!                        ▼
//!            Completed{tag, row} ──► that request's writer ──► its client
//!                        └── fresh rows also land in the embedding cache ──┘
//! ```
//!
//! Request/reply format and per-request error semantics live in
//! [`protocol`]; the cache key discipline in [`cache`]; the
//! load-generator (`graphlet-rf serve-bench`, throughput + p50/p99) in
//! [`bench`].
//!
//! Robustness contract (pinned by `tests/serve.rs`): malformed JSON
//! lines, oversized graphs, unknown ops, and mid-request disconnects
//! fail *that request* (or that connection) only — the daemon and its
//! pipeline keep serving everyone else.
//!
//! [`StreamingPipeline`]: crate::coordinator::StreamingPipeline

pub mod bench;
pub mod cache;
pub mod protocol;
pub mod server;

pub use bench::{run_bench, send_shutdown, BenchPair, BenchReport};
pub use cache::{config_fingerprint, CacheKey, CacheStats, EmbeddingCache};
pub use protocol::{embed_request, parse_embed_reply, parse_request, Request};
pub use server::{ServeConfig, Server};
