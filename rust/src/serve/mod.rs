//! `serve`: a persistent graph-embedding service with cross-request
//! batching and an embedding cache.
//!
//! The daemon (`graphlet-rf serve --port N`) keeps one
//! [`StreamingPipeline`] warm — sampler workers, feature shards, and
//! (in PJRT mode) compiled artifacts live for the process, not for one
//! dataset — and serves embedding requests over a line-delimited JSON
//! protocol on plain TCP (no new dependencies; the build stays
//! hermetic/offline).
//!
//! ```text
//!                        ┌──────────────── serve daemon ────────────────────┐
//!  client A ──TCP──► reader thread A ──┬─ cache hit ──► writer A ──► client A
//!  client B ──TCP──► reader thread B … │   (graph hash + config fp + seed)
//!                        │ parse / validate / admission control
//!                        │ miss: GraphJob{graph, seed, tag, done=writer chan}
//!                        ▼
//!            shared StreamingPipeline (one per daemon)
//!               sampler workers ──► per-shard bounded channels
//!                  │  rows from jobs of *different requests* pack into
//!                  │  one compiled-size batch (cross-request batching)
//!                  ▼
//!               N feature shards ──► per-job accumulators
//!                        │ job's s-th sample lands → mean row
//!                        ▼
//!            Completed{tag, row} ──► that request's writer ──► its client
//!                        └── fresh rows also land in the embedding cache ──┘
//! ```
//!
//! The "cache hit" box above is **two-level** ([`cache::TieredCache`]):
//!
//! ```text
//!   reader ── get(key) ──► L1: in-RAM LRU / cost-aware (cache_capacity)
//!                            │ miss                     ▲ promote
//!                            ▼                          │
//!                          L2: segment log (--store-dir, crate::store)
//!                            │ miss                 durable across
//!                            ▼                      daemon restarts
//!                          pipeline computes ── writer inserts through
//!                          BOTH tiers (L2 append first, then L1)
//! ```
//!
//! Without `--store-dir` the L2 box disappears and behavior is the
//! historical RAM-only cache. With it, a restarted daemon reopens the
//! log (skipping torn/corrupt tail records with a counter — see
//! [`crate::store`]) and serves previously computed rows **bitwise
//! identical** with zero pipeline recomputes — pinned end-to-end by
//! `tests/store.rs` and measured by serve-bench's `warm_l2` restart
//! pass.
//!
//! With a store attached the daemon also answers **k-NN retrieval**:
//! the `nearest` op embeds a query graph through the same cache/pipeline
//! path above, then searches an IVFFlat index ([`crate::ann`]) kept as a
//! side-car over the stored corpus:
//!
//! ```text
//!   nearest ── embed query (cache or pipeline; row stays L1-only) ──┐
//!                                                                  ▼
//!   AnnIndex (k-means centroids + inverted lists, rebuilt in the
//!   background off the request thread) ∪ pending tail (rows stored
//!   since the last build, brute-scanned) ──► k keys + exact L2
//! ```
//!
//! At `probe >= 1.0` (or below `--ann-min-brute` rows) the search is an
//! exhaustive scan, bitwise identical to the brute-force oracle —
//! pinned by `tests/ann.rs`.
//!
//! ## Ops
//!
//! | op | does | observability |
//! |---|---|---|
//! | `embed` | embed one graph (cache → pipeline) | span: cache_probe → admission → queue_wait → projection → reply_write |
//! | `nearest` | embed query + IVFFlat k-NN | adds an `ann_search` stamp |
//! | `stats` | counters + per-op latency summaries, uptime, engine, config fingerprint | cheap, poll-friendly |
//! | `metrics` | full [`crate::obs`] registry snapshot (every histogram with buckets) | the scrape endpoint |
//! | `trace` | last *n* finished request spans + captured slow spans; `"span_id": N` fetches one span by id | stage-level "where did the time go" |
//! | `profile` | the sampling profiler's `(role, stage) → {samples, cpu_us, entered}` table + live registered threads with busy fractions | per-thread CPU attribution |
//! | `ping` / `shutdown` | liveness / clean stop | traced like any request |
//!
//! Every request carries a [`crate::obs::TraceCtx`] from admission to
//! reply; spans slower than `--slow-ms` also emit one JSON line to
//! stderr, carrying the span's monotone `span_id` so it can be fetched
//! later via `trace`. A sampling profiler (`--profile-hz`, default on
//! at 19 Hz) attributes per-thread CPU time to the same stage
//! vocabulary — see [`crate::obs::profile`]. Recording is
//! observation-only, so neither tracing nor full-rate profiling can
//! perturb embeddings (pinned by `tests/obs.rs`). Each daemon owns its
//! own [`crate::obs::Registry`] — two in-process daemons report fully
//! isolated numbers.
//!
//! ## HTTP endpoints (`--http-port`, module [`http`])
//!
//! A minimal GET-only HTTP/1.1 sidecar listener (still zero deps) so
//! standard tooling can scrape without speaking the TCP protocol:
//!
//! | path | reply |
//! |---|---|
//! | `/metrics` | this daemon's registry in Prometheus text format v0.0.4 ([`crate::obs::prom`]), plus `graphlet_rf_build_info` |
//! | `/healthz` | `200 ok` while the process accepts connections |
//! | `/readyz` | `200 ready` once pipeline is up, store recovered, and the ANN cell initialized; `503` before that |
//! | `/profile` | cumulative collapsed-stack flame text (`role;stage N`); `?seconds=N` profiles an N-second window on the request |
//! | `/debug/threads` | JSON list of registered threads (role, index, stage, cpu_us, wall_us, busy) |
//!
//! Without `--http-port` no HTTP socket is opened and the daemon is
//! exactly the historical TCP-only service.
//!
//! Request/reply format and per-request error semantics live in
//! [`protocol`]; the cache key + tiering discipline in [`cache`]; the
//! load-generator (`graphlet-rf serve-bench`, labeled
//! `cold`/`warm_l1`/`warm_l2`/`nearest_p*` passes with throughput +
//! p50/p99, a per-pass daemon-side `metrics` cross-check, and a
//! machine-readable JSON line) in [`bench`].
//!
//! Robustness contract (pinned by `tests/serve.rs`): malformed JSON
//! lines, oversized graphs, unknown ops, and mid-request disconnects
//! fail *that request* (or that connection) only — the daemon and its
//! pipeline keep serving everyone else.
//!
//! [`StreamingPipeline`]: crate::coordinator::StreamingPipeline

pub mod bench;
pub mod cache;
pub mod http;
pub mod protocol;
pub mod server;

pub use bench::{run_bench, run_restart_bench, send_shutdown, BenchReport, BenchRun};
pub use http::HttpServer;
pub use cache::{
    config_fingerprint, recompute_cost_estimate, AnnStats, CacheKey, CacheStats, EmbeddingCache,
    EvictPolicy, NearestOutcome, TieredCache, TieredStats,
};
pub use protocol::{
    embed_request, nearest_request, parse_embed_reply, parse_nearest_reply, parse_request, Request,
};
pub use server::{ServeConfig, Server};
