//! Content-addressed embedding cache.
//!
//! Key = (canonical graph hash, config fingerprint, per-job sampling
//! seed): with all three fixed an embedding is a pure function of its
//! inputs, so cached rows are bitwise identical to recomputed ones.
//! The fingerprint covers every [`GsaConfig`] field that changes the
//! math (k, s, m, variant, impl, sampler, sigma, engine mode, seed) —
//! deliberately *not* the scheduling knobs (workers, shards, queue_cap,
//! fwht_threads; batch in CPU modes would be safe too, but batch
//! selects the PJRT artifact, so it is included).
//!
//! Eviction is LRU at a fixed capacity: embeddings are all the same
//! size (m floats), so the cache's memory is `capacity * m * 4` bytes,
//! and under serving traffic with popular repeat graphs recency is a
//! strictly better eviction signal than insertion order (a hot row
//! inserted early must not be evicted before a cold row inserted
//! late). Every hit bumps the row's recency; eviction removes the
//! least-recently-*used* row. Implemented as a monotonic-stamp index
//! (`BTreeMap<stamp, key>`, O(log n) per touch) — no unsafe, no
//! hand-rolled linked list. Hit/miss counters feed the serve `stats`
//! op.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::coordinator::GsaConfig;

/// The content address of one embedding row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub graph_hash: u64,
    pub config_fp: u64,
    pub seed: u64,
}

/// Counters + size snapshot for the `stats` op.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Rows dropped by LRU eviction since the cache was built (inserts
    /// refused at capacity 0 are not evictions — nothing was cached).
    /// Eviction telemetry: a high rate relative to hits means the
    /// working set exceeds `capacity` and the cache is churning.
    pub evictions: u64,
    pub len: usize,
    pub capacity: usize,
}

/// A cached row plus its recency stamp (the key into `order`).
struct Entry {
    row: Vec<f32>,
    stamp: u64,
}

struct CacheInner {
    map: HashMap<CacheKey, Entry>,
    /// Recency index: stamp → key, oldest stamp first. Stamps are drawn
    /// from a monotonic counter, so the first entry is always the LRU
    /// victim; a hit moves its key to a fresh stamp in O(log n).
    order: BTreeMap<u64, CacheKey>,
    next_stamp: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl CacheInner {
    /// Move `key`'s entry (already in `map`) to the freshest stamp.
    fn touch(&mut self, key: &CacheKey) {
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        if let Some(e) = self.map.get_mut(key) {
            self.order.remove(&e.stamp);
            e.stamp = stamp;
            self.order.insert(stamp, *key);
        }
    }
}

/// Thread-safe LRU-evicting embedding cache.
pub struct EmbeddingCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

impl EmbeddingCache {
    /// `capacity` = maximum cached rows; 0 disables caching entirely
    /// (every lookup is a miss, inserts are dropped).
    pub fn new(capacity: usize) -> EmbeddingCache {
        EmbeddingCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                next_stamp: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            capacity,
        }
    }

    /// Look up a row, counting the hit or miss. A hit bumps the row's
    /// recency (that is what makes eviction LRU, not FIFO).
    pub fn get(&self, key: &CacheKey) -> Option<Vec<f32>> {
        let mut g = self.inner.lock().expect("cache lock");
        match g.map.get(key).map(|e| e.row.clone()) {
            Some(row) => {
                g.hits += 1;
                g.touch(key);
                Some(row)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly computed row (first write wins; LRU eviction at
    /// capacity — the least-recently-used row is dropped).
    pub fn insert(&self, key: CacheKey, row: Vec<f32>) {
        if self.capacity == 0 {
            return;
        }
        let mut g = self.inner.lock().expect("cache lock");
        if g.map.contains_key(&key) {
            return;
        }
        while g.map.len() >= self.capacity {
            // First stamp in the recency index = least recently used.
            match g.order.first_key_value().map(|(&stamp, &old)| (stamp, old)) {
                Some((stamp, old)) => {
                    g.order.remove(&stamp);
                    g.map.remove(&old);
                    g.evictions += 1;
                }
                None => break,
            }
        }
        let stamp = g.next_stamp;
        g.next_stamp += 1;
        g.order.insert(stamp, key);
        g.map.insert(key, Entry { row, stamp });
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("cache lock");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            len: g.map.len(),
            capacity: self.capacity,
        }
    }
}

/// Hash the math-relevant parts of a [`GsaConfig`] into the cache key's
/// `config_fp` component (FNV-1a, mirroring `graph::canonical_hash`).
pub fn config_fingerprint(cfg: &GsaConfig) -> u64 {
    use crate::util::fnv;
    fn mix_bytes(h: u64, bytes: &[u8]) -> u64 {
        // Field separator byte so adjacent fields cannot alias.
        fnv::mix_bytes(fnv::mix_bytes(h, bytes), &[0xff])
    }
    let mut h = fnv::OFFSET;
    h = mix_bytes(h, &(cfg.k as u64).to_le_bytes());
    h = mix_bytes(h, &(cfg.s as u64).to_le_bytes());
    h = mix_bytes(h, &(cfg.m as u64).to_le_bytes());
    h = mix_bytes(h, cfg.variant.name().as_bytes());
    h = mix_bytes(h, cfg.impl_.as_bytes());
    h = mix_bytes(h, cfg.sampler.as_bytes());
    h = mix_bytes(h, &cfg.sigma.to_bits().to_le_bytes());
    h = mix_bytes(h, &(cfg.batch as u64).to_le_bytes());
    h = mix_bytes(h, format!("{:?}", cfg.engine).as_bytes());
    h = mix_bytes(h, &cfg.seed.to_le_bytes());
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineMode;

    fn key(n: u64) -> CacheKey {
        CacheKey { graph_hash: n, config_fp: 1, seed: 2 }
    }

    #[test]
    fn hit_miss_counting_and_roundtrip() {
        let c = EmbeddingCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), vec![1.0, 2.0]);
        assert_eq!(c.get(&key(1)), Some(vec![1.0, 2.0]));
        assert!(c.get(&key(2)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.len, s.capacity), (1, 2, 1, 4));
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        c.insert(key(3), vec![3.0]); // evicts key(1), the LRU
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.get(&key(2)), Some(vec![2.0]));
        assert_eq!(c.get(&key(3)), Some(vec![3.0]));
        assert_eq!(c.stats().len, 2);
    }

    #[test]
    fn hit_bumps_recency_so_eviction_is_lru_not_fifo() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        // Touch key(1): under FIFO it would still be evicted first;
        // under LRU the victim becomes key(2).
        assert_eq!(c.get(&key(1)), Some(vec![1.0]));
        c.insert(key(3), vec![3.0]);
        assert_eq!(c.get(&key(1)), Some(vec![1.0]), "recently used row must survive");
        assert!(c.get(&key(2)).is_none(), "LRU row must be the victim");
        assert_eq!(c.get(&key(3)), Some(vec![3.0]));
        assert_eq!(c.stats().len, 2);
    }

    #[test]
    fn eviction_chain_follows_usage_order() {
        let c = EmbeddingCache::new(3);
        for n in 1..=3 {
            c.insert(key(n), vec![n as f32]);
        }
        // Usage order now: 2, 3, 1 (oldest → newest after touches).
        assert!(c.get(&key(2)).is_some());
        assert!(c.get(&key(3)).is_some());
        assert!(c.get(&key(1)).is_some());
        c.insert(key(4), vec![4.0]); // evicts 2
        assert!(c.get(&key(2)).is_none());
        c.insert(key(5), vec![5.0]); // evicts 3
        assert!(c.get(&key(3)).is_none());
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(4)).is_some());
        assert!(c.get(&key(5)).is_some());
    }

    /// The eviction counter tracks LRU drops one-for-one: inserts below
    /// capacity and duplicate inserts count nothing; every insert at
    /// capacity counts exactly one victim.
    #[test]
    fn eviction_counter_counts_lru_drops() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        assert_eq!(c.stats().evictions, 0, "filling to capacity evicts nothing");
        c.insert(key(2), vec![9.0]);
        assert_eq!(c.stats().evictions, 0, "duplicate insert evicts nothing");
        c.insert(key(3), vec![3.0]);
        assert_eq!(c.stats().evictions, 1);
        c.insert(key(4), vec![4.0]);
        let s = c.stats();
        assert_eq!((s.evictions, s.len), (2, 2));
        // Hits never evict.
        assert!(c.get(&key(4)).is_some());
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn zero_capacity_drops_inserts_without_counting_evictions() {
        let c = EmbeddingCache::new(0);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        let s = c.stats();
        assert_eq!(s.evictions, 0, "nothing cached means nothing evicted");
        assert_eq!(s.len, 0);
    }

    #[test]
    fn duplicate_insert_keeps_first_row() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(1), vec![9.0]);
        assert_eq!(c.get(&key(1)), Some(vec![1.0]));
        assert_eq!(c.stats().len, 1);
    }

    #[test]
    fn zero_capacity_disables() {
        let c = EmbeddingCache::new(0);
        c.insert(key(1), vec![1.0]);
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.stats().len, 0);
    }

    #[test]
    fn fingerprint_separates_math_configs() {
        let base = GsaConfig {
            k: 3,
            s: 100,
            m: 64,
            engine: EngineMode::Cpu,
            seed: 42,
            ..Default::default()
        };
        let fp = config_fingerprint(&base);
        assert_eq!(fp, config_fingerprint(&base.clone()), "deterministic");
        for (name, changed) in [
            ("k", GsaConfig { k: 4, ..base.clone() }),
            ("s", GsaConfig { s: 101, ..base.clone() }),
            ("m", GsaConfig { m: 65, ..base.clone() }),
            ("sigma", GsaConfig { sigma: 0.7, ..base.clone() }),
            ("seed", GsaConfig { seed: 43, ..base.clone() }),
            ("engine", GsaConfig { engine: EngineMode::CpuInline, ..base.clone() }),
            // cpu-sorf is a different random-feature family: its rows
            // must never alias dense rows in the cache.
            ("engine-sorf", GsaConfig { engine: EngineMode::CpuSorf, ..base.clone() }),
            ("sampler", GsaConfig { sampler: "uniform".into(), ..base.clone() }),
        ] {
            assert_ne!(fp, config_fingerprint(&changed), "{name} must change the fingerprint");
        }
        // Scheduling knobs must NOT change the key (the embeddings are
        // bitwise identical across them).
        for same in [
            GsaConfig { workers: 7, ..base.clone() },
            GsaConfig { shards: 3, ..base.clone() },
            GsaConfig { queue_cap: 99, ..base.clone() },
            GsaConfig { fwht_threads: 4, ..base.clone() },
        ] {
            assert_eq!(fp, config_fingerprint(&same));
        }
    }
}
