//! The two-level content-addressed embedding cache: an in-RAM LRU (L1)
//! over the persistent segment-log store (L2, optional).
//!
//! Key = (canonical graph hash, config fingerprint, per-job sampling
//! seed): with all three fixed an embedding is a pure function of its
//! inputs, so cached rows are bitwise identical to recomputed ones —
//! which is exactly what makes them safe to serve from RAM *or* from a
//! segment log written by a previous daemon process. The fingerprint
//! covers every [`GsaConfig`] field that changes the math (k, s, m,
//! variant, impl, sampler, sigma, engine mode, seed) — deliberately
//! *not* the scheduling knobs (workers, shards, queue_cap,
//! fwht_threads; batch in CPU modes would be safe too, but batch
//! selects the PJRT artifact, so it is included).
//!
//! Tiering ([`TieredCache`], the type the serve daemon actually holds):
//!
//! ```text
//!   get(key) ──► L1 (RAM, LRU / cost-aware) ── hit ──► row
//!                  │ miss
//!                  ▼
//!                L2 (segment log, --store-dir) ── hit ──► promote to L1,
//!                  │ miss                                 count l2_hit
//!                  ▼
//!                None  (caller computes; insert() then writes the row
//!                       through BOTH tiers — L2 first, so a row a
//!                       client saw is already durable)
//! ```
//!
//! L1 eviction is LRU at a fixed capacity by default. The optional
//! **cost-aware** policy ([`EvictPolicy::CostAware`]) examines the
//! `window` least-recently-used rows and evicts the one that is
//! cheapest to recompute (weight = `row_len ×
//! recompute_cost_estimate`); under mixed workloads this keeps the
//! expensive SORF/dense rows resident a little longer than plain
//! recency would. Both policies are implemented on the same
//! monotonic-stamp index (`BTreeMap<stamp, key>`, O(log n) per touch,
//! O(window) per eviction) — no unsafe, no hand-rolled linked list.
//! Hit/miss/eviction counters feed the serve `stats` op.
//!
//! When the store is enabled the cache also carries the **ANN
//! retrieval side-car** (the `nearest` op's state): an immutable
//! [`crate::ann::AnnIndex`] behind an `RwLock` plus a *pending tail*
//! of rows persisted since the last build. Queries scan
//! `index ∪ pending`, so retrieval at probe 1.0 is exact-complete at
//! every moment; rebuilds run on a background thread that holds the
//! store mutex only long enough to snapshot rows (never for the
//! k-means), triggered at construction, on pending-tail overflow, and
//! after a put trips the store's auto-compaction.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::ann::{neighbor_cmp, AnnConfig, AnnIndex, Neighbor};
use crate::coordinator::{EngineMode, GsaConfig};
use crate::store::{EmbeddingStore, StoreStats};

pub use crate::store::CacheKey;

/// L1 eviction policy (`--cache-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Evict the least-recently-used row (the default).
    Lru,
    /// Among the `window` least-recently-used rows, evict the one with
    /// the smallest recompute weight (`row_len × recompute cost`); ties
    /// fall back to recency. `window` bounds the scan so eviction stays
    /// O(window) — outside the window plain recency still rules.
    CostAware { window: usize },
}

/// Default candidate window for [`EvictPolicy::CostAware`].
pub const COST_WINDOW: usize = 8;

impl EvictPolicy {
    /// Parse a policy name (CLI); bad input is an `Err`, not a panic.
    pub fn parse(s: &str) -> Result<EvictPolicy> {
        Ok(match s {
            "lru" => EvictPolicy::Lru,
            "cost" | "cost-aware" => EvictPolicy::CostAware { window: COST_WINDOW },
            other => bail!("unknown cache policy {other:?} (expected lru|cost-aware)"),
        })
    }

    /// The name reported by the `stats` op.
    pub fn name(&self) -> &'static str {
        match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::CostAware { .. } => "cost-aware",
        }
    }
}

/// Counters + size snapshot for the `stats` op.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Rows dropped by eviction since the cache was built (inserts
    /// refused at capacity 0 are not evictions — nothing was cached).
    /// Eviction telemetry: a high rate relative to hits means the
    /// working set exceeds `capacity` and the cache is churning.
    pub evictions: u64,
    pub len: usize,
    pub capacity: usize,
    /// The active eviction policy name (`lru` / `cost-aware`).
    pub policy: &'static str,
}

/// A cached row plus its recency stamp (the key into `order`) and its
/// recompute weight (consulted by the cost-aware policy only).
struct Entry {
    row: Vec<f32>,
    stamp: u64,
    cost: f64,
}

struct CacheInner {
    map: HashMap<CacheKey, Entry>,
    /// Recency index: stamp → key, oldest stamp first. Stamps are drawn
    /// from a monotonic counter, so the first entry is always the LRU
    /// row; a hit moves its key to a fresh stamp in O(log n).
    order: BTreeMap<u64, CacheKey>,
    next_stamp: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl CacheInner {
    /// Move `key`'s entry (already in `map`) to the freshest stamp.
    fn touch(&mut self, key: &CacheKey) {
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        if let Some(e) = self.map.get_mut(key) {
            self.order.remove(&e.stamp);
            e.stamp = stamp;
            self.order.insert(stamp, *key);
        }
    }
}

/// Thread-safe in-RAM embedding cache (the L1 tier).
pub struct EmbeddingCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    policy: EvictPolicy,
}

impl EmbeddingCache {
    /// `capacity` = maximum cached rows; 0 disables caching entirely
    /// (every lookup is a miss, inserts are dropped). Plain LRU.
    pub fn new(capacity: usize) -> EmbeddingCache {
        EmbeddingCache::with_policy(capacity, EvictPolicy::Lru)
    }

    pub fn with_policy(capacity: usize, policy: EvictPolicy) -> EmbeddingCache {
        EmbeddingCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                next_stamp: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
            capacity,
            policy,
        }
    }

    /// Look up a row, counting the hit or miss. A hit bumps the row's
    /// recency (that is what makes eviction LRU, not FIFO).
    pub fn get(&self, key: &CacheKey) -> Option<Vec<f32>> {
        let mut g = self.inner.lock().expect("cache lock");
        match g.map.get(key).map(|e| e.row.clone()) {
            Some(row) => {
                g.hits += 1;
                g.touch(key);
                Some(row)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly computed row with the default recompute weight
    /// (its length — correct when every row costs the same, which is
    /// all a plain-LRU cache can assume).
    pub fn insert(&self, key: CacheKey, row: Vec<f32>) {
        let cost = row.len() as f64;
        self.insert_with_cost(key, row, cost);
    }

    /// Insert a freshly computed row (first write wins) with an
    /// explicit recompute weight. At capacity the victim is chosen by
    /// the configured [`EvictPolicy`].
    pub fn insert_with_cost(&self, key: CacheKey, row: Vec<f32>, cost: f64) {
        if self.capacity == 0 {
            return;
        }
        let mut g = self.inner.lock().expect("cache lock");
        if g.map.contains_key(&key) {
            return;
        }
        while g.map.len() >= self.capacity {
            let victim = match self.policy {
                EvictPolicy::Lru => g.order.first_key_value().map(|(&s, &k)| (s, k)),
                EvictPolicy::CostAware { window } => g
                    .order
                    .iter()
                    .take(window.max(1))
                    .map(|(&stamp, &old)| (stamp, old))
                    // Ascending-stamp iteration + strict min: among
                    // equal weights the OLDEST candidate wins, so the
                    // policy degrades to LRU when costs are uniform.
                    .min_by(|a, b| {
                        let ca = g.map.get(&a.1).map_or(0.0, |e| e.cost);
                        let cb = g.map.get(&b.1).map_or(0.0, |e| e.cost);
                        ca.partial_cmp(&cb)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.0.cmp(&b.0))
                    }),
            };
            match victim {
                Some((stamp, old)) => {
                    g.order.remove(&stamp);
                    g.map.remove(&old);
                    g.evictions += 1;
                }
                None => break,
            }
        }
        let stamp = g.next_stamp;
        g.next_stamp += 1;
        g.order.insert(stamp, key);
        g.map.insert(key, Entry { row, stamp, cost });
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("cache lock");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            len: g.map.len(),
            capacity: self.capacity,
            policy: self.policy.name(),
        }
    }
}

/// Combined snapshot of both tiers for the `stats` op.
#[derive(Clone, Copy, Debug, Default)]
pub struct TieredStats {
    pub l1: CacheStats,
    /// L1 misses that the store answered (each one a recompute avoided).
    pub l2_hits: u64,
    /// Full misses: absent from both tiers — the pipeline computes.
    pub l2_misses: u64,
    /// Rows copied L2 → L1 on an L2 hit (always equals `l2_hits` today;
    /// kept separate so a future no-promote read path stays honest).
    pub l2_promotions: u64,
    /// Segment-log counters when the store is enabled.
    pub store: Option<StoreStats>,
    /// ANN retrieval-index counters when the index is enabled.
    pub ann: Option<AnnStats>,
}

/// Snapshot of the ANN retrieval index for the `stats` op.
#[derive(Clone, Copy, Debug, Default)]
pub struct AnnStats {
    /// Centroid count of the current index (== posting lists).
    pub centroids: usize,
    /// Rows covered by the current index.
    pub indexed: usize,
    /// Rows in the pending tail (persisted after the last build;
    /// brute-scanned by every query until a rebuild absorbs them).
    pub pending: usize,
    /// Index builds since the cache was constructed (≥ 1: one runs at
    /// construction).
    pub builds: u64,
    /// Wall time of the most recent build, milliseconds.
    pub last_build_ms: f64,
    /// `nearest` queries answered.
    pub queries: u64,
    /// Posting lists scanned across all queries (0 for brute scans).
    pub probed_lists: u64,
    /// Rows distance-computed across all queries (index + pending).
    pub scanned_rows: u64,
    /// Bytes of row data the current index *owns* (copied into RAM).
    /// View-backed rows (mmap'd sealed segments) count zero, so with
    /// the store's mmap path on this sits at ≈ 0 — the index reads rows
    /// in place out of the page cache.
    pub indexed_bytes: u64,
}

/// Result of one tiered `nearest` query (index ∪ pending tail).
#[derive(Clone, Debug)]
pub struct NearestOutcome {
    /// Up to k neighbors in `(distance, key)` order.
    pub neighbors: Vec<Neighbor>,
    /// Posting lists scanned (0 on a brute-force path).
    pub probed: usize,
    /// Rows distance-computed, pending tail included.
    pub scanned: usize,
}

/// The ANN side-car of a [`TieredCache`]: an immutable IVF index swapped
/// whole behind an `RwLock`, plus the pending tail of rows persisted
/// since the last build. Invariant: `index ∪ pending ⊇ live store rows`
/// (a row may transiently appear in both right after a swap; queries
/// dedup by key), so `nearest` at probe 1.0 is exact-complete no matter
/// when rebuilds land.
struct AnnCell {
    cfg: AnnConfig,
    /// Row dimensionality (the pipeline's `m`); rows of any other
    /// length are excluded from retrieval.
    dim: usize,
    index: RwLock<Arc<AnnIndex>>,
    pending: Mutex<Vec<(CacheKey, Vec<f32>)>>,
    /// Guard: at most one background rebuild in flight.
    rebuilding: AtomicBool,
    builds: AtomicU64,
    last_build_us: AtomicU64,
    queries: AtomicU64,
    probed_lists: AtomicU64,
    scanned_rows: AtomicU64,
    /// Where `ann.build_us` records — owned (not borrowed) because the
    /// background rebuild thread outlives any caller frame.
    registry: Arc<crate::obs::Registry>,
}

impl AnnCell {
    fn new(cfg: AnnConfig, dim: usize, registry: Arc<crate::obs::Registry>) -> AnnCell {
        let empty = Arc::new(AnnIndex::build(Vec::<(CacheKey, Vec<f32>)>::new(), dim, &cfg));
        AnnCell {
            cfg,
            dim,
            index: RwLock::new(empty),
            pending: Mutex::new(Vec::new()),
            rebuilding: AtomicBool::new(false),
            builds: AtomicU64::new(0),
            last_build_us: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            probed_lists: AtomicU64::new(0),
            scanned_rows: AtomicU64::new(0),
            registry,
        }
    }

    /// Rebuild the index from a store snapshot. The store mutex is held
    /// only for the row snapshot — and the snapshot itself is zero-copy
    /// for sealed segments ([`crate::store::RowData::View`]s into the
    /// mmap'd pages; only the active tail is copied), so the lock is
    /// held for an index walk, not a data copy. The k-means (the
    /// expensive part) runs off the lock against the views, then the
    /// fresh index is swapped in and the pending rows it covers are
    /// pruned. Swap-then-prune order matters: between the two a query
    /// may see a row in both places (deduped), but never in neither.
    fn rebuild(cell: &AnnCell, store: &Mutex<EmbeddingStore>) {
        let t = Instant::now();
        let entries = store.lock().expect("store lock").snapshot_row_data();
        let index = Arc::new(AnnIndex::build(entries, cell.dim, &cell.cfg));
        *cell.index.write().expect("ann index lock") = Arc::clone(&index);
        cell.pending.lock().expect("ann pending lock").retain(|(k, _)| !index.contains(k));
        cell.builds.fetch_add(1, Ordering::Relaxed);
        cell.last_build_us.store(t.elapsed().as_micros() as u64, Ordering::Relaxed);
        cell.registry.histo("ann.build_us").record(t.elapsed());
    }

    fn stats(&self) -> AnnStats {
        let index = Arc::clone(&self.index.read().expect("ann index lock"));
        AnnStats {
            centroids: index.nlist(),
            indexed: index.len(),
            pending: self.pending.lock().expect("ann pending lock").len(),
            builds: self.builds.load(Ordering::Relaxed),
            last_build_ms: self.last_build_us.load(Ordering::Relaxed) as f64 / 1000.0,
            queries: self.queries.load(Ordering::Relaxed),
            probed_lists: self.probed_lists.load(Ordering::Relaxed),
            scanned_rows: self.scanned_rows.load(Ordering::Relaxed),
            indexed_bytes: index.indexed_bytes(),
        }
    }
}

/// The serve daemon's cache: L1 in RAM, L2 on disk (optional).
///
/// `get` probes L1 then L2, promoting L2 hits into L1; `insert` writes
/// through both tiers (L2 first — once a client holds a reply, the row
/// is already in the OS page cache on its way to disk). The store is
/// behind one `Mutex`: L2 traffic is the *miss* path of an L1 whose hit
/// path stays as concurrent as before, and one store writer at a time
/// is exactly the append-only log's contract.
pub struct TieredCache {
    l1: EmbeddingCache,
    l2: Option<Arc<Mutex<EmbeddingStore>>>,
    /// The ANN retrieval index over the store (requires `l2`).
    ann: Option<Arc<AnnCell>>,
    /// Per-float recompute weight (from [`recompute_cost_estimate`]);
    /// multiplied by `row_len` to weight cost-aware eviction.
    row_cost: f64,
    l2_hits: AtomicU64,
    l2_misses: AtomicU64,
    l2_promotions: AtomicU64,
    /// Where `cache.probe_us` / `cache.l2_read_us` / `ann.probe_us`
    /// record: the owning daemon's instance-scoped registry, or the
    /// process-global default for caches built via [`TieredCache::new`]
    /// / [`TieredCache::with_ann`].
    registry: Arc<crate::obs::Registry>,
}

impl TieredCache {
    /// `row_cost` is the per-row recompute weight (use
    /// [`recompute_cost_estimate`]; only the cost-aware policy reads
    /// it). `store: None` gives the previous single-tier behavior.
    pub fn new(
        l1_capacity: usize,
        policy: EvictPolicy,
        row_cost: f64,
        store: Option<EmbeddingStore>,
    ) -> TieredCache {
        TieredCache::with_ann(l1_capacity, policy, row_cost, store, None)
    }

    /// Like [`TieredCache::new`], plus an optional ANN retrieval index
    /// over the store: `ann = Some((cfg, dim))` builds the index
    /// synchronously over the rows already on disk (so a restarted
    /// daemon answers `nearest` from its first request), with `dim` the
    /// pipeline's row length. Ignored without a store — retrieval is
    /// defined over the durable corpus, not the RAM tier.
    pub fn with_ann(
        l1_capacity: usize,
        policy: EvictPolicy,
        row_cost: f64,
        store: Option<EmbeddingStore>,
        ann: Option<(AnnConfig, usize)>,
    ) -> TieredCache {
        TieredCache::with_ann_registry(
            l1_capacity,
            policy,
            row_cost,
            store,
            ann,
            crate::obs::global_arc(),
        )
    }

    /// Like [`TieredCache::with_ann`], but every cache/ANN histogram
    /// records into the given instance-scoped registry (the serve
    /// daemon passes its own).
    pub fn with_ann_registry(
        l1_capacity: usize,
        policy: EvictPolicy,
        row_cost: f64,
        store: Option<EmbeddingStore>,
        ann: Option<(AnnConfig, usize)>,
        registry: Arc<crate::obs::Registry>,
    ) -> TieredCache {
        let l2 = store.map(|s| Arc::new(Mutex::new(s)));
        let ann = match (&l2, ann) {
            (Some(store), Some((cfg, dim))) => {
                let cell = Arc::new(AnnCell::new(cfg, dim, registry.clone()));
                AnnCell::rebuild(&cell, store);
                Some(cell)
            }
            _ => None,
        };
        TieredCache {
            l1: EmbeddingCache::with_policy(l1_capacity, policy),
            l2,
            ann,
            row_cost,
            l2_hits: AtomicU64::new(0),
            l2_misses: AtomicU64::new(0),
            l2_promotions: AtomicU64::new(0),
            registry,
        }
    }

    fn weight(&self, row: &[f32]) -> f64 {
        row.len() as f64 * self.row_cost
    }

    /// Probe L1 then L2. An L2 hit is promoted into L1 (without a
    /// write-back — the row is already durable) and served bitwise as
    /// stored. Records `cache.probe_us` (the full probe) and, inside an
    /// L1 miss, `cache.l2_read_us` (just the store read).
    pub fn get(&self, key: &CacheKey) -> Option<Vec<f32>> {
        let probe_start = Instant::now();
        let out = self.get_inner(key);
        self.registry.histo("cache.probe_us").record(probe_start.elapsed());
        out
    }

    fn get_inner(&self, key: &CacheKey) -> Option<Vec<f32>> {
        if let Some(row) = self.l1.get(key) {
            return Some(row);
        }
        let store = self.l2.as_ref()?;
        // `get_row` hands back a RowData: for a sealed (mmap'd) segment
        // that is a zero-copy view whose Arc keeps the mapping alive
        // after the store lock drops, so `l2_read_us` measures the
        // probe, not a row copy — the one copy happens below, on L1
        // promotion.
        let read_start = Instant::now();
        let found = store.lock().expect("store lock").get_row(key);
        self.registry.histo("cache.l2_read_us").record(read_start.elapsed());
        match found {
            Some(data) => {
                self.l2_hits.fetch_add(1, Ordering::Relaxed);
                self.l2_promotions.fetch_add(1, Ordering::Relaxed);
                let row = data.to_vec();
                self.l1.insert_with_cost(*key, row.clone(), self.weight(&row));
                Some(row)
            }
            None => {
                self.l2_misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Write a freshly computed row through both tiers. A store append
    /// failure (disk full, permissions) degrades to RAM-only for that
    /// row — logged, never fatal to the request.
    ///
    /// A row that actually persisted also enters the ANN pending tail
    /// (immediately searchable); a rebuild is kicked in the background
    /// when the tail overflows or when this put tripped the store's
    /// auto-compaction.
    pub fn insert(&self, key: CacheKey, row: Vec<f32>) {
        let mut persisted = false;
        let mut compacted = false;
        if let Some(store) = &self.l2 {
            let mut s = store.lock().expect("store lock");
            if !s.contains(&key) {
                let before = s.stats().compactions;
                match s.put(key, &row) {
                    Ok(()) => {
                        persisted = true;
                        compacted = s.stats().compactions > before;
                    }
                    Err(e) => eprintln!("serve: embedding store write-through failed: {e:#}"),
                }
            }
        }
        if let Some(cell) = self.ann.as_ref().filter(|_| persisted) {
            let mut trigger = compacted;
            if row.len() == cell.dim {
                let mut p = cell.pending.lock().expect("ann pending lock");
                p.push((key, row.clone()));
                trigger = trigger || p.len() >= cell.cfg.rebuild_pending.max(1);
            }
            if trigger {
                self.spawn_ann_rebuild();
            }
        }
        let w = self.weight(&row);
        self.l1.insert_with_cost(key, row, w);
    }

    /// Insert into L1 only — used for `nearest` query rows, which must
    /// NOT enter the store (a retrieval query must not grow the corpus
    /// it searches) but are worth keeping warm for repeat queries.
    pub fn insert_query_row(&self, key: CacheKey, row: Vec<f32>) {
        let w = self.weight(&row);
        self.l1.insert_with_cost(key, row, w);
    }

    /// k nearest stored rows to `query`, exact L2 distances, merged
    /// across the current index and the pending tail.
    /// `probe_override` replaces the configured probe factor for this
    /// query only. Errors when the ANN index is not enabled (no store).
    pub fn nearest(
        &self,
        query: &[f32],
        k: usize,
        probe_override: Option<f64>,
    ) -> Result<NearestOutcome> {
        let Some(cell) = &self.ann else {
            bail!("nearest requires a persistent store (start the daemon with --store-dir)");
        };
        let probe_start = Instant::now();
        let probe = probe_override.unwrap_or(cell.cfg.probe_factor);
        let index = Arc::clone(&cell.index.read().expect("ann index lock"));
        let mut result = index.nearest(query, k, probe);
        // The pending tail is always brute-scanned: rows persisted
        // after the last build stay exactly as searchable as indexed
        // ones. Dedup by key (sorting makes duplicates adjacent) in
        // case a rebuild swapped mid-flight.
        {
            let pending = cell.pending.lock().expect("ann pending lock");
            for (pk, prow) in pending.iter() {
                if prow.len() != query.len() {
                    continue;
                }
                result.scanned += 1;
                result
                    .neighbors
                    .push(Neighbor { key: *pk, distance: crate::ann::l2_distance(query, prow) });
            }
        }
        result.neighbors.sort_unstable_by(neighbor_cmp);
        result.neighbors.dedup_by(|a, b| a.key == b.key);
        result.neighbors.truncate(k);
        cell.queries.fetch_add(1, Ordering::Relaxed);
        cell.probed_lists.fetch_add(result.probed as u64, Ordering::Relaxed);
        cell.scanned_rows.fetch_add(result.scanned as u64, Ordering::Relaxed);
        self.registry.histo("ann.probe_us").record(probe_start.elapsed());
        Ok(NearestOutcome {
            neighbors: result.neighbors,
            probed: result.probed,
            scanned: result.scanned,
        })
    }

    /// Live row count of the store, `None` without one. (`nearest`
    /// callers use this to validate `k` against the corpus size.)
    pub fn store_len(&self) -> Option<usize> {
        self.l2.as_ref().map(|s| s.lock().expect("store lock").len())
    }

    /// Kick a background index rebuild (at most one in flight; a
    /// concurrent request returns immediately). The rebuild thread
    /// holds the store mutex only for the row snapshot — never for the
    /// k-means — so request threads are not stalled behind it. A row
    /// that lands after the in-flight snapshot simply stays in the
    /// pending tail until the next trigger; retrieval is never stale.
    fn spawn_ann_rebuild(&self) {
        let (Some(store), Some(cell)) = (self.l2.as_ref(), self.ann.as_ref()) else {
            return;
        };
        if cell
            .rebuilding
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return;
        }
        let store = Arc::clone(store);
        let cell = Arc::clone(cell);
        std::thread::spawn(move || {
            // Visible to the sampling profiler for its lifetime: k-means
            // CPU burn shows up as (ann_rebuild, ann_rebuild) in /profile.
            let prof = cell.registry.threads().register("ann_rebuild", 0);
            prof.set_stage("ann_rebuild");
            AnnCell::rebuild(&cell, &store);
            cell.rebuilding.store(false, Ordering::Release);
        });
    }

    pub fn stats(&self) -> TieredStats {
        TieredStats {
            l1: self.l1.stats(),
            l2_hits: self.l2_hits.load(Ordering::Relaxed),
            l2_misses: self.l2_misses.load(Ordering::Relaxed),
            l2_promotions: self.l2_promotions.load(Ordering::Relaxed),
            store: self
                .l2
                .as_ref()
                .map(|s| s.lock().expect("store lock").stats()),
            ann: self.ann.as_ref().map(|cell| cell.stats()),
        }
    }
}

/// Relative cost of recomputing one embedding row under `cfg` — the
/// feature-map work for its s samples (the sampler walk is common to
/// every engine and omitted). Dense engines project each sample in
/// O(d·m); the structured SORF engine in O(m·log p) with p the padded
/// power-of-two input width. Only *ratios* matter (the cost-aware
/// eviction policy compares weights), so constant factors are dropped.
pub fn recompute_cost_estimate(cfg: &GsaConfig) -> f64 {
    let d = cfg.input_dim().max(1) as f64;
    let per_sample = match cfg.engine {
        EngineMode::CpuSorf => {
            let p = crate::fastrf::next_pow2(cfg.input_dim().max(2)) as f64;
            cfg.m as f64 * p.log2()
        }
        _ => d * cfg.m as f64,
    };
    cfg.s as f64 * per_sample
}

/// Hash the math-relevant parts of a [`GsaConfig`] into the cache key's
/// `config_fp` component (FNV-1a, mirroring `graph::canonical_hash`).
pub fn config_fingerprint(cfg: &GsaConfig) -> u64 {
    use crate::util::fnv;
    fn mix_bytes(h: u64, bytes: &[u8]) -> u64 {
        // Field separator byte so adjacent fields cannot alias.
        fnv::mix_bytes(fnv::mix_bytes(h, bytes), &[0xff])
    }
    let mut h = fnv::OFFSET;
    h = mix_bytes(h, &(cfg.k as u64).to_le_bytes());
    h = mix_bytes(h, &(cfg.s as u64).to_le_bytes());
    h = mix_bytes(h, &(cfg.m as u64).to_le_bytes());
    h = mix_bytes(h, cfg.variant.name().as_bytes());
    h = mix_bytes(h, cfg.impl_.as_bytes());
    h = mix_bytes(h, cfg.sampler.as_bytes());
    h = mix_bytes(h, &cfg.sigma.to_bits().to_le_bytes());
    h = mix_bytes(h, &(cfg.batch as u64).to_le_bytes());
    h = mix_bytes(h, format!("{:?}", cfg.engine).as_bytes());
    h = mix_bytes(h, &cfg.seed.to_le_bytes());
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreConfig;

    fn key(n: u64) -> CacheKey {
        CacheKey { graph_hash: n, config_fp: 1, seed: 2 }
    }

    #[test]
    fn hit_miss_counting_and_roundtrip() {
        let c = EmbeddingCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.insert(key(1), vec![1.0, 2.0]);
        assert_eq!(c.get(&key(1)), Some(vec![1.0, 2.0]));
        assert!(c.get(&key(2)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.len, s.capacity), (1, 2, 1, 4));
        assert_eq!(s.policy, "lru", "plain LRU stays the default policy");
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        c.insert(key(3), vec![3.0]); // evicts key(1), the LRU
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.get(&key(2)), Some(vec![2.0]));
        assert_eq!(c.get(&key(3)), Some(vec![3.0]));
        assert_eq!(c.stats().len, 2);
    }

    #[test]
    fn hit_bumps_recency_so_eviction_is_lru_not_fifo() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        // Touch key(1): under FIFO it would still be evicted first;
        // under LRU the victim becomes key(2).
        assert_eq!(c.get(&key(1)), Some(vec![1.0]));
        c.insert(key(3), vec![3.0]);
        assert_eq!(c.get(&key(1)), Some(vec![1.0]), "recently used row must survive");
        assert!(c.get(&key(2)).is_none(), "LRU row must be the victim");
        assert_eq!(c.get(&key(3)), Some(vec![3.0]));
        assert_eq!(c.stats().len, 2);
    }

    #[test]
    fn eviction_chain_follows_usage_order() {
        let c = EmbeddingCache::new(3);
        for n in 1..=3 {
            c.insert(key(n), vec![n as f32]);
        }
        // Usage order now: 2, 3, 1 (oldest → newest after touches).
        assert!(c.get(&key(2)).is_some());
        assert!(c.get(&key(3)).is_some());
        assert!(c.get(&key(1)).is_some());
        c.insert(key(4), vec![4.0]); // evicts 2
        assert!(c.get(&key(2)).is_none());
        c.insert(key(5), vec![5.0]); // evicts 3
        assert!(c.get(&key(3)).is_none());
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(4)).is_some());
        assert!(c.get(&key(5)).is_some());
    }

    /// The eviction counter tracks drops one-for-one: inserts below
    /// capacity and duplicate inserts count nothing; every insert at
    /// capacity counts exactly one victim.
    #[test]
    fn eviction_counter_counts_lru_drops() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        assert_eq!(c.stats().evictions, 0, "filling to capacity evicts nothing");
        c.insert(key(2), vec![9.0]);
        assert_eq!(c.stats().evictions, 0, "duplicate insert evicts nothing");
        c.insert(key(3), vec![3.0]);
        assert_eq!(c.stats().evictions, 1);
        c.insert(key(4), vec![4.0]);
        let s = c.stats();
        assert_eq!((s.evictions, s.len), (2, 2));
        // Hits never evict.
        assert!(c.get(&key(4)).is_some());
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn zero_capacity_drops_inserts_without_counting_evictions() {
        let c = EmbeddingCache::new(0);
        c.insert(key(1), vec![1.0]);
        c.insert(key(2), vec![2.0]);
        let s = c.stats();
        assert_eq!(s.evictions, 0, "nothing cached means nothing evicted");
        assert_eq!(s.len, 0);
    }

    #[test]
    fn duplicate_insert_keeps_first_row() {
        let c = EmbeddingCache::new(2);
        c.insert(key(1), vec![1.0]);
        c.insert(key(1), vec![9.0]);
        assert_eq!(c.get(&key(1)), Some(vec![1.0]));
        assert_eq!(c.stats().len, 1);
    }

    #[test]
    fn zero_capacity_disables() {
        let c = EmbeddingCache::new(0);
        c.insert(key(1), vec![1.0]);
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.stats().len, 0);
    }

    /// Cost-aware eviction prefers the cheapest-to-recompute candidate
    /// over the strictly least-recently-used one.
    #[test]
    fn cost_aware_evicts_cheap_rows_before_expensive_ones() {
        let c = EmbeddingCache::with_policy(2, EvictPolicy::CostAware { window: 8 });
        assert_eq!(c.stats().policy, "cost-aware");
        c.insert_with_cost(key(1), vec![1.0], 100.0); // expensive, oldest
        c.insert_with_cost(key(2), vec![2.0], 1.0); // cheap, newer
        c.insert_with_cost(key(3), vec![3.0], 50.0);
        // Plain LRU would evict key(1); cost-aware drops cheap key(2).
        assert_eq!(c.get(&key(1)), Some(vec![1.0]), "expensive row must survive");
        assert!(c.get(&key(2)).is_none(), "cheap row must be the victim");
        assert_eq!(c.get(&key(3)), Some(vec![3.0]));
        assert_eq!(c.stats().evictions, 1);
    }

    /// With uniform costs the cost-aware policy is exactly LRU (ties
    /// break by age), so enabling it on a single-config daemon never
    /// degrades the eviction order.
    #[test]
    fn cost_aware_with_uniform_costs_degrades_to_lru() {
        let c = EmbeddingCache::with_policy(2, EvictPolicy::CostAware { window: 8 });
        c.insert_with_cost(key(1), vec![1.0], 7.0);
        c.insert_with_cost(key(2), vec![2.0], 7.0);
        assert_eq!(c.get(&key(1)), Some(vec![1.0])); // bump 1's recency
        c.insert_with_cost(key(3), vec![3.0], 7.0);
        assert!(c.get(&key(2)).is_none(), "equal costs: LRU row is the victim");
        assert!(c.get(&key(1)).is_some());
    }

    /// Outside the candidate window recency still rules: a cheap row
    /// that is *recent enough* is not considered for eviction.
    #[test]
    fn cost_aware_window_bounds_the_candidate_scan() {
        let c = EmbeddingCache::with_policy(3, EvictPolicy::CostAware { window: 1 });
        c.insert_with_cost(key(1), vec![1.0], 100.0);
        c.insert_with_cost(key(2), vec![2.0], 1.0);
        c.insert_with_cost(key(3), vec![3.0], 1.0);
        // Window of 1 = plain LRU: key(1) is the only candidate.
        c.insert_with_cost(key(4), vec![4.0], 1.0);
        assert!(c.get(&key(1)).is_none(), "window 1 must behave as LRU");
        assert!(c.get(&key(2)).is_some());
    }

    #[test]
    fn evict_policy_parse_roundtrip_and_errors() {
        assert_eq!(EvictPolicy::parse("lru").unwrap(), EvictPolicy::Lru);
        assert_eq!(
            EvictPolicy::parse("cost-aware").unwrap(),
            EvictPolicy::CostAware { window: COST_WINDOW }
        );
        assert_eq!(
            EvictPolicy::parse("cost").unwrap(),
            EvictPolicy::CostAware { window: COST_WINDOW }
        );
        let err = EvictPolicy::parse("mru").unwrap_err().to_string();
        assert!(err.contains("unknown cache policy") && err.contains("lru|cost-aware"), "{err}");
    }

    /// The structured engine's rows are cheaper to recompute than the
    /// dense engines' at the same shape — the whole point of SORF — and
    /// the estimate must reflect that so cost-aware eviction prefers
    /// dropping them first.
    #[test]
    fn recompute_cost_estimate_ranks_sorf_below_dense() {
        let dense = GsaConfig {
            k: 6,
            s: 2000,
            m: 5000,
            engine: EngineMode::Cpu,
            ..Default::default()
        };
        let sorf = GsaConfig { engine: EngineMode::CpuSorf, ..dense.clone() };
        let (cd, cs) = (recompute_cost_estimate(&dense), recompute_cost_estimate(&sorf));
        assert!(cs < cd, "sorf estimate {cs} must undercut dense {cd}");
        assert!(cs > 0.0 && cd.is_finite());
        // More samples cost more, for both families.
        let heavier = GsaConfig { s: 4000, ..dense.clone() };
        assert!(recompute_cost_estimate(&heavier) > cd);
    }

    fn temp_store(tag: &str) -> StoreConfig {
        let dir = std::env::temp_dir()
            .join(format!("graphlet_tiered_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        StoreConfig::new(dir)
    }

    /// The tiering contract: L1 evictions are not data loss (the store
    /// still answers), L2 hits promote, and a brand-new TieredCache
    /// over the same directory still serves every row bitwise.
    #[test]
    fn tiered_cache_promotes_from_store_and_survives_reopen() {
        let cfg = temp_store("promote");
        let store = EmbeddingStore::open(cfg.clone()).unwrap();
        let t = TieredCache::new(1, EvictPolicy::Lru, 1.0, Some(store));
        t.insert(key(1), vec![1.0, -0.0, f32::MIN_POSITIVE]);
        t.insert(key(2), vec![2.0]); // evicts key(1) from the 1-row L1
        let s = t.stats();
        assert_eq!(s.l1.evictions, 1);
        assert_eq!(s.store.unwrap().records, 2, "write-through persists both rows");

        // key(1) is gone from L1 but must come back from the store.
        let row = t.get(&key(1)).expect("L2 must answer after L1 eviction");
        assert_eq!(
            row.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            [1.0f32, -0.0, f32::MIN_POSITIVE].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "store round-trip must be bitwise"
        );
        let s = t.stats();
        assert_eq!((s.l2_hits, s.l2_promotions), (1, 1));
        // Promoted: the next get is a pure L1 hit (no new l2 counters).
        assert!(t.get(&key(1)).is_some());
        assert_eq!(t.stats().l2_hits, 1);
        // Full miss: both tiers empty for this key.
        assert!(t.get(&key(9)).is_none());
        assert_eq!(t.stats().l2_misses, 1);

        // A fresh cache over the same dir (daemon restart): cold L1,
        // warm L2.
        drop(t);
        let store = EmbeddingStore::open(cfg.clone()).unwrap();
        let t = TieredCache::new(4, EvictPolicy::Lru, 1.0, Some(store));
        assert_eq!(t.get(&key(2)), Some(vec![2.0]));
        let s = t.stats();
        assert_eq!((s.l2_hits, s.l1.misses), (1, 1));
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    /// Without a store the tiered cache is exactly the old single-tier
    /// cache: L2 counters stay zero and misses are full misses.
    #[test]
    fn tiered_cache_without_store_is_single_tier() {
        let t = TieredCache::new(2, EvictPolicy::Lru, 1.0, None);
        assert!(t.get(&key(1)).is_none());
        t.insert(key(1), vec![1.0]);
        assert_eq!(t.get(&key(1)), Some(vec![1.0]));
        let s = t.stats();
        assert_eq!((s.l2_hits, s.l2_misses, s.l2_promotions), (0, 0, 0));
        assert!(s.store.is_none());
        assert_eq!((s.l1.hits, s.l1.misses), (1, 1));
    }

    /// Duplicate inserts do not bloat the log: write-through is
    /// append-once per key.
    #[test]
    fn tiered_insert_is_append_once_per_key() {
        let cfg = temp_store("dedupe");
        let store = EmbeddingStore::open(cfg.clone()).unwrap();
        let t = TieredCache::new(4, EvictPolicy::Lru, 1.0, Some(store));
        t.insert(key(1), vec![1.0]);
        t.insert(key(1), vec![9.9]); // L1 keeps first; L2 must not re-append
        let st = t.stats().store.unwrap();
        assert_eq!((st.records, st.dead_bytes), (1, 0));
        assert_eq!(t.get(&key(1)), Some(vec![1.0]));
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn tiered_nearest_searches_index_and_pending_tail() {
        let cfg = temp_store("ann_pending");
        // Rows already on disk are indexed by the open-time build…
        {
            let mut s = EmbeddingStore::open(cfg.clone()).unwrap();
            s.put(key(1), &[0.0, 0.0]).unwrap();
            s.put(key(2), &[1.0, 0.0]).unwrap();
        }
        let store = EmbeddingStore::open(cfg.clone()).unwrap();
        let t = TieredCache::with_ann(
            4,
            EvictPolicy::Lru,
            1.0,
            Some(store),
            Some((AnnConfig::default(), 2)),
        );
        let s = t.stats().ann.unwrap();
        assert_eq!((s.indexed, s.pending, s.builds), (2, 0, 1));
        assert_eq!(t.store_len(), Some(2));
        // Seal-on-open made both pre-existing rows view-backed, so the
        // open-time index owns no row bytes; with mmap off (or no view
        // support on this target) it owns both rows outright.
        let st = t.stats().store.unwrap();
        if st.mmap_segments > 0 && cfg!(all(unix, target_endian = "little")) {
            assert_eq!(s.indexed_bytes, 0, "view-backed index must own nothing");
        } else {
            assert!(s.indexed_bytes <= 2 * 2 * 4, "{}", s.indexed_bytes);
        }

        // …while a fresh insert lands in the pending tail and is
        // immediately searchable, exactly like an indexed row.
        t.insert(key(3), vec![0.1, 0.0]);
        let s = t.stats().ann.unwrap();
        assert_eq!((s.indexed, s.pending), (2, 1));
        let out = t.nearest(&[0.0, 0.0], 3, Some(1.0)).unwrap();
        let keys: Vec<CacheKey> = out.neighbors.iter().map(|n| n.key).collect();
        assert_eq!(keys, vec![key(1), key(3), key(2)]);
        assert_eq!(out.neighbors[0].distance.to_bits(), 0.0f32.to_bits());
        assert_eq!(out.scanned, 3, "index rows + pending row all scanned");

        // A wrong-dimension row persists but never enters retrieval.
        t.insert(key(4), vec![1.0, 2.0, 3.0]);
        assert_eq!(t.stats().ann.unwrap().pending, 1);

        // Query rows (insert_query_row) stay out of store and tail.
        t.insert_query_row(key(5), vec![9.0, 9.0]);
        let s = t.stats();
        assert_eq!(s.ann.unwrap().pending, 1);
        assert_eq!(s.store.unwrap().records, 4);
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn pending_overflow_triggers_a_background_rebuild() {
        let cfg = temp_store("ann_rebuild");
        let store = EmbeddingStore::open(cfg.clone()).unwrap();
        let acfg = AnnConfig { rebuild_pending: 3, ..AnnConfig::default() };
        let t = TieredCache::with_ann(8, EvictPolicy::Lru, 1.0, Some(store), Some((acfg, 2)));
        for n in 0..3u64 {
            t.insert(key(10 + n), vec![n as f32, 0.0]);
        }
        // The rebuild runs off-thread; poll for it (bounded).
        let deadline = Instant::now() + std::time::Duration::from_secs(30);
        loop {
            let s = t.stats().ann.unwrap();
            if s.builds >= 2 && s.pending == 0 {
                assert_eq!(s.indexed, 3);
                break;
            }
            assert!(Instant::now() < deadline, "background rebuild never landed: {s:?}");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // Absorbed rows stay searchable.
        let out = t.nearest(&[2.0, 0.0], 1, Some(1.0)).unwrap();
        assert_eq!(out.neighbors[0].key, key(12));
        let _ = std::fs::remove_dir_all(&cfg.dir);
    }

    #[test]
    fn nearest_without_a_store_is_an_error() {
        let t = TieredCache::new(2, EvictPolicy::Lru, 1.0, None);
        let err = t.nearest(&[0.0], 1, None).unwrap_err().to_string();
        assert!(err.contains("--store-dir"), "{err}");
        assert!(t.stats().ann.is_none());
        assert!(t.store_len().is_none());
    }

    #[test]
    fn fingerprint_separates_math_configs() {
        let base = GsaConfig {
            k: 3,
            s: 100,
            m: 64,
            engine: EngineMode::Cpu,
            seed: 42,
            ..Default::default()
        };
        let fp = config_fingerprint(&base);
        assert_eq!(fp, config_fingerprint(&base.clone()), "deterministic");
        for (name, changed) in [
            ("k", GsaConfig { k: 4, ..base.clone() }),
            ("s", GsaConfig { s: 101, ..base.clone() }),
            ("m", GsaConfig { m: 65, ..base.clone() }),
            ("sigma", GsaConfig { sigma: 0.7, ..base.clone() }),
            ("seed", GsaConfig { seed: 43, ..base.clone() }),
            ("engine", GsaConfig { engine: EngineMode::CpuInline, ..base.clone() }),
            // cpu-sorf is a different random-feature family: its rows
            // must never alias dense rows in the cache.
            ("engine-sorf", GsaConfig { engine: EngineMode::CpuSorf, ..base.clone() }),
            ("sampler", GsaConfig { sampler: "uniform".into(), ..base.clone() }),
        ] {
            assert_ne!(fp, config_fingerprint(&changed), "{name} must change the fingerprint");
        }
        // Scheduling knobs must NOT change the key (the embeddings are
        // bitwise identical across them).
        for same in [
            GsaConfig { workers: 7, ..base.clone() },
            GsaConfig { shards: 3, ..base.clone() },
            GsaConfig { queue_cap: 99, ..base.clone() },
            GsaConfig { fwht_threads: 4, ..base.clone() },
        ] {
            assert_eq!(fp, config_fingerprint(&same));
        }
    }
}
