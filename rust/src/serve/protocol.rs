//! The serve wire protocol: one JSON object per line, both directions.
//!
//! Requests (`op` selects the operation; `id` is an arbitrary client
//! correlation number echoed in the reply, default 0):
//!
//! ```text
//! {"op":"embed","id":7,"v":60,"edges":[[0,1],[1,2],...],"graph_index":0}
//! {"op":"nearest","id":8,"k":10,"v":60,"edges":[[0,1],...],"probe":0.5}
//! {"op":"ping","id":1}
//! {"op":"stats","id":2}
//! {"op":"metrics","id":4}
//! {"op":"trace","id":5,"n":16}
//! {"op":"trace","id":5,"span_id":42}
//! {"op":"profile","id":6}
//! {"op":"shutdown","id":3}
//! ```
//!
//! Op table:
//!
//! | op         | fields                                   | reply |
//! |------------|------------------------------------------|-------|
//! | `embed`    | `v`, `edges`, [`graph_index`]            | the graph's embedding row (cached or computed) |
//! | `nearest`  | `v`, `edges`, `k`, [`graph_index`], [`probe`] | the `k` stored keys nearest to the graph's embedding, exact L2 distances (requires `--store-dir`) |
//! | `ping`     | —                                        | `{"ok":true}` |
//! | `stats`    | —                                        | pipeline/cache/store/ann counters + proc self-metrics + uptime/engine/config fingerprint + per-op latency summaries |
//! | `metrics`  | —                                        | full `obs` registry snapshot: counters, gauges, every histogram's log₂ buckets + derived p50/p90/p99 |
//! | `trace`    | [`n`], [`span_id`]                       | the `n` most recent finished spans (default 16) plus every captured slow span (≥ `--slow-ms`); with `span_id`, that single span (error once it aged out) |
//! | `profile`  | —                                        | the sampling profiler's `(role, stage) → {samples, cpu_us}` table plus the live thread list with per-thread busy fractions |
//! | `shutdown` | —                                        | ack, then the daemon drains and exits |
//!
//! `graph_index` selects the position in the server's per-graph seed
//! stream (default 0); submitting graph i of a dataset with
//! `graph_index = i` reproduces `embed_dataset` output bit for bit.
//! `nearest.k` must be ≥ 1 and at most the store's row count;
//! `nearest.probe`, when present, overrides the daemon's `--ann-probe`
//! for this query and must lie in (0, 1] — at 1.0 the scan is
//! exhaustive (exact). A `nearest` query is **read-only**: it embeds
//! the query graph (through cache or pipeline) but never adds it to
//! the stored corpus.
//!
//! Replies (order is NOT guaranteed to match request order — replies
//! stream out as cross-request batches complete; match on `id`):
//!
//! ```text
//! {"id":7,"ok":true,"cached":false,"m":5000,"embedding":[...]}
//! {"id":8,"ok":true,"op":"nearest","k":10,
//!  "neighbors":[{"key":"00ab..:01cd..:02ef..","distance":0.37},...],
//!  "probed":4,"scanned":130}
//! {"id":9,"ok":false,"error":"..."}
//! ```
//!
//! Neighbor keys are colon-separated hex triples
//! (`graph_hash:config_fp:seed`, 16 digits each): the protocol's JSON
//! numbers are f64-backed (exact only below 2^53), so full-width u64
//! key fields travel as strings.
//!
//! Every malformed line produces an `ok:false` reply for that request
//! only; the connection and the daemon keep running.

use crate::ann::Neighbor;
use crate::graph::AnyGraph;
use crate::store::CacheKey;
use crate::util::Json;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Embed {
        id: u64,
        v: usize,
        edges: Vec<(usize, usize)>,
        graph_index: usize,
    },
    /// k-NN retrieval: embed the query graph, return the k nearest
    /// stored keys. `probe` overrides the daemon's probe factor for
    /// this query when present.
    Nearest {
        id: u64,
        v: usize,
        edges: Vec<(usize, usize)>,
        graph_index: usize,
        k: usize,
        probe: Option<f64>,
    },
    Ping { id: u64 },
    Stats { id: u64 },
    /// Full observability-registry snapshot (histogram buckets +
    /// derived percentiles), suitable for scraping.
    Metrics { id: u64 },
    /// The `n` most recent finished spans plus captured slow spans —
    /// or, with `span_id`, that single span fetched by id.
    Trace { id: u64, n: usize, span_id: Option<u64> },
    /// The sampling profiler's aggregated `(role, stage)` table and
    /// registered-thread list (see `crate::obs::profile`).
    Profile { id: u64 },
    Shutdown { id: u64 },
}

/// Parse failure: the request id when one was recoverable (so the error
/// reply can still be correlated), plus the message.
#[derive(Debug)]
pub struct ProtoError {
    pub id: Option<u64>,
    pub msg: String,
}

impl ProtoError {
    fn new(id: Option<u64>, msg: impl Into<String>) -> ProtoError {
        ProtoError { id, msg: msg.into() }
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let j = Json::parse(line).map_err(|e| ProtoError::new(None, format!("bad json: {e}")))?;
    let id = match j.get("id") {
        None => 0,
        Some(v) => v
            .as_u64()
            .ok_or_else(|| ProtoError::new(None, "\"id\" must be a non-negative integer"))?,
    };
    let op = j
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| ProtoError::new(Some(id), "missing \"op\" string"))?;
    match op {
        "ping" => Ok(Request::Ping { id }),
        "stats" => Ok(Request::Stats { id }),
        "metrics" => Ok(Request::Metrics { id }),
        "trace" => {
            let n = match j.get("n") {
                None => 16,
                Some(v) => v.as_usize().filter(|&n| n >= 1).ok_or_else(|| {
                    ProtoError::new(Some(id), "trace: \"n\" must be a positive integer")
                })?,
            };
            let span_id = match j.get("span_id") {
                None => None,
                Some(v) => Some(v.as_u64().filter(|&s| s >= 1).ok_or_else(|| {
                    ProtoError::new(Some(id), "trace: \"span_id\" must be a positive integer")
                })?),
            };
            Ok(Request::Trace { id, n, span_id })
        }
        "profile" => Ok(Request::Profile { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "embed" => {
            let (v, edges, graph_index) = parse_graph_fields(&j, id, "embed")?;
            Ok(Request::Embed { id, v, edges, graph_index })
        }
        "nearest" => {
            let (v, edges, graph_index) = parse_graph_fields(&j, id, "nearest")?;
            let k = j.get("k").and_then(Json::as_usize).ok_or_else(|| {
                ProtoError::new(Some(id), "nearest: missing neighbor count \"k\"")
            })?;
            if k == 0 {
                return Err(ProtoError::new(Some(id), "nearest: \"k\" must be at least 1"));
            }
            let probe = match j.get("probe") {
                None => None,
                Some(p) => {
                    let p = p.as_f64().filter(|p| p.is_finite() && *p > 0.0 && *p <= 1.0);
                    Some(p.ok_or_else(|| {
                        ProtoError::new(Some(id), "nearest: \"probe\" must be a number in (0, 1]")
                    })?)
                }
            };
            Ok(Request::Nearest { id, v, edges, graph_index, k, probe })
        }
        other => Err(ProtoError::new(Some(id), format!("unknown op {other:?}"))),
    }
}

/// The graph payload shared by `embed` and `nearest` (both embed a
/// client graph through the pipeline): node count, edge list, and the
/// seed-stream position.
fn parse_graph_fields(
    j: &Json,
    id: u64,
    op: &str,
) -> Result<(usize, Vec<(usize, usize)>, usize), ProtoError> {
    let v = j
        .get("v")
        .and_then(Json::as_usize)
        .ok_or_else(|| ProtoError::new(Some(id), format!("{op}: missing node count \"v\"")))?;
    let raw_edges = j
        .get("edges")
        .and_then(Json::as_array)
        .ok_or_else(|| ProtoError::new(Some(id), format!("{op}: missing \"edges\" array")))?;
    let mut edges = Vec::with_capacity(raw_edges.len());
    for e in raw_edges {
        let pair = e.as_array().filter(|p| p.len() == 2).ok_or_else(|| {
            ProtoError::new(Some(id), format!("{op}: each edge must be a [a, b] pair"))
        })?;
        let a = pair[0].as_usize();
        let b = pair[1].as_usize();
        match (a, b) {
            (Some(a), Some(b)) => edges.push((a, b)),
            _ => {
                return Err(ProtoError::new(
                    Some(id),
                    format!("{op}: edge endpoints must be non-negative integers"),
                ))
            }
        }
    }
    let graph_index = match j.get("graph_index") {
        None => 0,
        Some(v) => v.as_usize().ok_or_else(|| {
            ProtoError::new(Some(id), "\"graph_index\" must be a non-negative integer")
        })?,
    };
    Ok((v, edges, graph_index))
}

/// Format a successful embed reply.
pub fn embed_reply(id: u64, row: &[f32], cached: bool) -> String {
    Json::obj()
        .set("id", id)
        .set("ok", true)
        .set("cached", cached)
        .set("m", row.len())
        .set("embedding", row)
        .to_string()
}

/// Format a per-request error reply.
pub fn error_reply(id: Option<u64>, msg: &str) -> String {
    Json::obj().set("id", id.unwrap_or(0)).set("ok", false).set("error", msg).to_string()
}

/// Serialize an embed request for a graph (client side: serve-bench and
/// the integration tests).
pub fn embed_request(id: u64, graph_index: usize, g: &AnyGraph) -> String {
    let mut edges = Json::arr();
    for u in 0..g.v() {
        for w in g.neighbors(u) {
            if u < w {
                edges.push(vec![u, w]);
            }
        }
    }
    Json::obj()
        .set("op", "embed")
        .set("id", id)
        .set("graph_index", graph_index)
        .set("v", g.v())
        .set("edges", edges)
        .to_string()
}

/// Parse an embed reply into (id, row, cached) — client side.
pub fn parse_embed_reply(line: &str) -> Result<(u64, Vec<f32>, bool), String> {
    let j = Json::parse(line)?;
    let id = j.get("id").and_then(Json::as_u64).ok_or("reply missing id")?;
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown server error");
        return Err(format!("request {id} failed: {msg}"));
    }
    let cached = j.get("cached").and_then(Json::as_bool).unwrap_or(false);
    let arr = j.get("embedding").and_then(Json::as_array).ok_or("reply missing embedding")?;
    let mut row = Vec::with_capacity(arr.len());
    for x in arr {
        row.push(x.as_f64().ok_or("non-numeric embedding entry")? as f32);
    }
    Ok((id, row, cached))
}

/// Format a successful nearest reply. Keys render as hex triples (see
/// module docs); distances as f64 (an exact widening of the f32, so
/// the client's narrowing read is bitwise).
pub fn nearest_reply(id: u64, neighbors: &[Neighbor], probed: usize, scanned: usize) -> String {
    let mut arr = Json::arr();
    for n in neighbors {
        arr.push(Json::obj().set("key", n.key.to_hex()).set("distance", n.distance));
    }
    Json::obj()
        .set("id", id)
        .set("ok", true)
        .set("op", "nearest")
        .set("k", neighbors.len())
        .set("neighbors", arr)
        .set("probed", probed)
        .set("scanned", scanned)
        .to_string()
}

/// Serialize a nearest request for a query graph (client side:
/// serve-bench and the integration tests). `probe` is omitted from the
/// wire when `None` (the daemon then uses its `--ann-probe` default).
pub fn nearest_request(
    id: u64,
    graph_index: usize,
    k: usize,
    probe: Option<f64>,
    g: &AnyGraph,
) -> String {
    let mut edges = Json::arr();
    for u in 0..g.v() {
        for w in g.neighbors(u) {
            if u < w {
                edges.push(vec![u, w]);
            }
        }
    }
    let mut obj = Json::obj()
        .set("op", "nearest")
        .set("id", id)
        .set("graph_index", graph_index)
        .set("k", k)
        .set("v", g.v())
        .set("edges", edges);
    if let Some(p) = probe {
        obj = obj.set("probe", p);
    }
    obj.to_string()
}

/// Parse a nearest reply into (id, neighbors, probed, scanned) —
/// client side.
pub fn parse_nearest_reply(line: &str) -> Result<(u64, Vec<Neighbor>, usize, usize), String> {
    let j = Json::parse(line)?;
    let id = j.get("id").and_then(Json::as_u64).ok_or("reply missing id")?;
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown server error");
        return Err(format!("request {id} failed: {msg}"));
    }
    let arr = j.get("neighbors").and_then(Json::as_array).ok_or("reply missing neighbors")?;
    let mut neighbors = Vec::with_capacity(arr.len());
    for n in arr {
        let key = n
            .get("key")
            .and_then(Json::as_str)
            .and_then(CacheKey::from_hex)
            .ok_or("neighbor missing hex key")?;
        let distance = n.get("distance").and_then(Json::as_f64).ok_or("neighbor missing distance")?;
        neighbors.push(Neighbor { key, distance: distance as f32 });
    }
    let probed = j.get("probed").and_then(Json::as_usize).unwrap_or(0);
    let scanned = j.get("scanned").and_then(Json::as_usize).unwrap_or(0);
    Ok((id, neighbors, probed, scanned))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CsrGraph;

    #[test]
    fn embed_request_roundtrip() {
        let g = AnyGraph::Csr(CsrGraph::from_edges(4, &[(0, 1), (2, 3), (1, 2)]));
        let line = embed_request(9, 3, &g);
        match parse_request(&line).unwrap() {
            Request::Embed { id, v, edges, graph_index } => {
                assert_eq!(id, 9);
                assert_eq!(v, 4);
                assert_eq!(graph_index, 3);
                assert_eq!(edges, vec![(0, 1), (1, 2), (2, 3)]);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn simple_ops_parse() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping { id: 0 });
        assert_eq!(parse_request(r#"{"op":"stats","id":5}"#).unwrap(), Request::Stats { id: 5 });
        assert_eq!(
            parse_request(r#"{"id":1,"op":"shutdown"}"#).unwrap(),
            Request::Shutdown { id: 1 }
        );
    }

    #[test]
    fn metrics_and_trace_ops_parse() {
        assert_eq!(parse_request(r#"{"op":"metrics","id":4}"#).unwrap(), Request::Metrics { id: 4 });
        assert_eq!(
            parse_request(r#"{"op":"trace","id":5}"#).unwrap(),
            Request::Trace { id: 5, n: 16, span_id: None },
            "n defaults to 16"
        );
        assert_eq!(
            parse_request(r#"{"op":"trace","id":5,"n":3}"#).unwrap(),
            Request::Trace { id: 5, n: 3, span_id: None }
        );
        let e = parse_request(r#"{"op":"trace","id":5,"n":0}"#).unwrap_err();
        assert_eq!(e.id, Some(5));
        assert!(e.msg.contains("positive"), "{}", e.msg);
    }

    #[test]
    fn trace_by_span_id_and_profile_parse() {
        assert_eq!(
            parse_request(r#"{"op":"trace","id":5,"span_id":42}"#).unwrap(),
            Request::Trace { id: 5, n: 16, span_id: Some(42) }
        );
        let e = parse_request(r#"{"op":"trace","id":5,"span_id":0}"#).unwrap_err();
        assert_eq!(e.id, Some(5));
        assert!(e.msg.contains("span_id"), "{}", e.msg);
        let e = parse_request(r#"{"op":"trace","id":5,"span_id":-1}"#).unwrap_err();
        assert!(e.msg.contains("span_id"), "{}", e.msg);
        let parsed = parse_request(r#"{"op":"profile","id":6}"#).unwrap();
        assert_eq!(parsed, Request::Profile { id: 6 });
    }

    #[test]
    fn malformed_requests_error_with_best_effort_id() {
        let e = parse_request("not json at all").unwrap_err();
        assert!(e.id.is_none());
        assert!(e.msg.contains("bad json"), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"warp"}"#).unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.msg.contains("unknown op"), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"embed","v":3,"edges":[[0]]}"#).unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.msg.contains("pair"), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"embed","edges":[]}"#).unwrap_err();
        assert!(e.msg.contains("\"v\""), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"embed","v":3,"edges":[[0,-1]]}"#).unwrap_err();
        assert!(e.msg.contains("non-negative"), "{}", e.msg);

        let e = parse_request(r#"{"id":-3,"op":"ping"}"#).unwrap_err();
        assert!(e.id.is_none());
    }

    #[test]
    fn nearest_request_roundtrip() {
        let g = AnyGraph::Csr(CsrGraph::from_edges(4, &[(0, 1), (2, 3), (1, 2)]));
        let line = nearest_request(11, 2, 5, Some(0.5), &g);
        match parse_request(&line).unwrap() {
            Request::Nearest { id, v, edges, graph_index, k, probe } => {
                assert_eq!(id, 11);
                assert_eq!(v, 4);
                assert_eq!(graph_index, 2);
                assert_eq!(k, 5);
                assert_eq!(probe, Some(0.5));
                assert_eq!(edges, vec![(0, 1), (1, 2), (2, 3)]);
            }
            other => panic!("wrong request: {other:?}"),
        }
        // probe omitted on the wire stays None after parsing.
        let line = nearest_request(12, 0, 1, None, &g);
        assert!(!line.contains("probe"), "{line}");
        match parse_request(&line).unwrap() {
            Request::Nearest { probe, .. } => assert_eq!(probe, None),
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn nearest_requests_validate_k_and_probe() {
        let e = parse_request(r#"{"id":4,"op":"nearest","v":3,"edges":[]}"#).unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.msg.contains("\"k\""), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"nearest","v":3,"edges":[],"k":0}"#).unwrap_err();
        assert!(e.msg.contains("at least 1"), "{}", e.msg);

        for bad in [r#""probe":1.5"#, r#""probe":0"#, r#""probe":-0.2"#] {
            let line = format!(r#"{{"id":4,"op":"nearest","v":3,"edges":[],"k":1,{bad}}}"#);
            let e = parse_request(&line).unwrap_err();
            assert!(e.msg.contains("probe"), "{bad}: {}", e.msg);
        }

        // the shared graph-payload errors name the nearest op.
        let e = parse_request(r#"{"id":4,"op":"nearest","v":3,"edges":[[0]],"k":1}"#).unwrap_err();
        assert!(e.msg.contains("nearest") && e.msg.contains("pair"), "{}", e.msg);
        let e = parse_request(r#"{"id":4,"op":"nearest","edges":[],"k":1}"#).unwrap_err();
        assert!(e.msg.contains("nearest") && e.msg.contains("\"v\""), "{}", e.msg);
    }

    #[test]
    fn nearest_reply_roundtrip_is_bitwise() {
        let neighbors = vec![
            Neighbor {
                key: CacheKey { graph_hash: u64::MAX, config_fp: 1 << 63, seed: 0 },
                distance: 0.0,
            },
            Neighbor {
                key: CacheKey { graph_hash: 7, config_fp: 0xC0FFEE, seed: 42 },
                distance: 3.25e-7,
            },
        ];
        let line = nearest_reply(8, &neighbors, 4, 130);
        let (id, back, probed, scanned) = parse_nearest_reply(&line).unwrap();
        assert_eq!(id, 8);
        assert_eq!(probed, 4);
        assert_eq!(scanned, 130);
        assert_eq!(back.len(), neighbors.len());
        for (a, b) in back.iter().zip(&neighbors) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
        }

        let err = parse_nearest_reply(&error_reply(Some(9), "no store")).unwrap_err();
        assert!(err.contains("no store") && err.contains('9'), "{err}");
    }

    #[test]
    fn reply_roundtrip_is_bitwise() {
        let row = vec![1.0f32, -0.37, 3.25e-7, 42.0, f32::MIN_POSITIVE];
        let line = embed_reply(6, &row, true);
        let (id, back, cached) = parse_embed_reply(&line).unwrap();
        assert_eq!(id, 6);
        assert!(cached);
        assert_eq!(back.len(), row.len());
        for (a, b) in back.iter().zip(&row) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn error_reply_parses_as_failure() {
        let line = error_reply(Some(3), "boom");
        let err = parse_embed_reply(&line).unwrap_err();
        assert!(err.contains("boom") && err.contains('3'), "{err}");
    }
}
