//! The serve wire protocol: one JSON object per line, both directions.
//!
//! Requests (`op` selects the operation; `id` is an arbitrary client
//! correlation number echoed in the reply, default 0):
//!
//! ```text
//! {"op":"embed","id":7,"v":60,"edges":[[0,1],[1,2],...],"graph_index":0}
//! {"op":"ping","id":1}
//! {"op":"stats","id":2}
//! {"op":"shutdown","id":3}
//! ```
//!
//! `graph_index` selects the position in the server's per-graph seed
//! stream (default 0); submitting graph i of a dataset with
//! `graph_index = i` reproduces `embed_dataset` output bit for bit.
//!
//! Replies (order is NOT guaranteed to match request order — replies
//! stream out as cross-request batches complete; match on `id`):
//!
//! ```text
//! {"id":7,"ok":true,"cached":false,"m":5000,"embedding":[...]}
//! {"id":9,"ok":false,"error":"..."}
//! ```
//!
//! Every malformed line produces an `ok:false` reply for that request
//! only; the connection and the daemon keep running.

use crate::graph::AnyGraph;
use crate::util::Json;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Embed { id: u64, v: usize, edges: Vec<(usize, usize)>, graph_index: usize },
    Ping { id: u64 },
    Stats { id: u64 },
    Shutdown { id: u64 },
}

/// Parse failure: the request id when one was recoverable (so the error
/// reply can still be correlated), plus the message.
#[derive(Debug)]
pub struct ProtoError {
    pub id: Option<u64>,
    pub msg: String,
}

impl ProtoError {
    fn new(id: Option<u64>, msg: impl Into<String>) -> ProtoError {
        ProtoError { id, msg: msg.into() }
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let j = Json::parse(line).map_err(|e| ProtoError::new(None, format!("bad json: {e}")))?;
    let id = match j.get("id") {
        None => 0,
        Some(v) => v
            .as_u64()
            .ok_or_else(|| ProtoError::new(None, "\"id\" must be a non-negative integer"))?,
    };
    let op = j
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| ProtoError::new(Some(id), "missing \"op\" string"))?;
    match op {
        "ping" => Ok(Request::Ping { id }),
        "stats" => Ok(Request::Stats { id }),
        "shutdown" => Ok(Request::Shutdown { id }),
        "embed" => {
            let v = j
                .get("v")
                .and_then(Json::as_usize)
                .ok_or_else(|| ProtoError::new(Some(id), "embed: missing node count \"v\""))?;
            let raw_edges = j
                .get("edges")
                .and_then(Json::as_array)
                .ok_or_else(|| ProtoError::new(Some(id), "embed: missing \"edges\" array"))?;
            let mut edges = Vec::with_capacity(raw_edges.len());
            for e in raw_edges {
                let pair = e.as_array().filter(|p| p.len() == 2).ok_or_else(|| {
                    ProtoError::new(Some(id), "embed: each edge must be a [a, b] pair")
                })?;
                let a = pair[0].as_usize();
                let b = pair[1].as_usize();
                match (a, b) {
                    (Some(a), Some(b)) => edges.push((a, b)),
                    _ => {
                        return Err(ProtoError::new(
                            Some(id),
                            "embed: edge endpoints must be non-negative integers",
                        ))
                    }
                }
            }
            let graph_index = match j.get("graph_index") {
                None => 0,
                Some(v) => v.as_usize().ok_or_else(|| {
                    ProtoError::new(Some(id), "\"graph_index\" must be a non-negative integer")
                })?,
            };
            Ok(Request::Embed { id, v, edges, graph_index })
        }
        other => Err(ProtoError::new(Some(id), format!("unknown op {other:?}"))),
    }
}

/// Format a successful embed reply.
pub fn embed_reply(id: u64, row: &[f32], cached: bool) -> String {
    Json::obj()
        .set("id", id)
        .set("ok", true)
        .set("cached", cached)
        .set("m", row.len())
        .set("embedding", row)
        .to_string()
}

/// Format a per-request error reply.
pub fn error_reply(id: Option<u64>, msg: &str) -> String {
    Json::obj().set("id", id.unwrap_or(0)).set("ok", false).set("error", msg).to_string()
}

/// Serialize an embed request for a graph (client side: serve-bench and
/// the integration tests).
pub fn embed_request(id: u64, graph_index: usize, g: &AnyGraph) -> String {
    let mut edges = Json::arr();
    for u in 0..g.v() {
        for w in g.neighbors(u) {
            if u < w {
                edges.push(vec![u, w]);
            }
        }
    }
    Json::obj()
        .set("op", "embed")
        .set("id", id)
        .set("graph_index", graph_index)
        .set("v", g.v())
        .set("edges", edges)
        .to_string()
}

/// Parse an embed reply into (id, row, cached) — client side.
pub fn parse_embed_reply(line: &str) -> Result<(u64, Vec<f32>, bool), String> {
    let j = Json::parse(line)?;
    let id = j.get("id").and_then(Json::as_u64).ok_or("reply missing id")?;
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown server error");
        return Err(format!("request {id} failed: {msg}"));
    }
    let cached = j.get("cached").and_then(Json::as_bool).unwrap_or(false);
    let arr = j.get("embedding").and_then(Json::as_array).ok_or("reply missing embedding")?;
    let mut row = Vec::with_capacity(arr.len());
    for x in arr {
        row.push(x.as_f64().ok_or("non-numeric embedding entry")? as f32);
    }
    Ok((id, row, cached))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CsrGraph;

    #[test]
    fn embed_request_roundtrip() {
        let g = AnyGraph::Csr(CsrGraph::from_edges(4, &[(0, 1), (2, 3), (1, 2)]));
        let line = embed_request(9, 3, &g);
        match parse_request(&line).unwrap() {
            Request::Embed { id, v, edges, graph_index } => {
                assert_eq!(id, 9);
                assert_eq!(v, 4);
                assert_eq!(graph_index, 3);
                assert_eq!(edges, vec![(0, 1), (1, 2), (2, 3)]);
            }
            other => panic!("wrong request: {other:?}"),
        }
    }

    #[test]
    fn simple_ops_parse() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping { id: 0 });
        assert_eq!(parse_request(r#"{"op":"stats","id":5}"#).unwrap(), Request::Stats { id: 5 });
        assert_eq!(
            parse_request(r#"{"id":1,"op":"shutdown"}"#).unwrap(),
            Request::Shutdown { id: 1 }
        );
    }

    #[test]
    fn malformed_requests_error_with_best_effort_id() {
        let e = parse_request("not json at all").unwrap_err();
        assert!(e.id.is_none());
        assert!(e.msg.contains("bad json"), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"warp"}"#).unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.msg.contains("unknown op"), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"embed","v":3,"edges":[[0]]}"#).unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.msg.contains("pair"), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"embed","edges":[]}"#).unwrap_err();
        assert!(e.msg.contains("\"v\""), "{}", e.msg);

        let e = parse_request(r#"{"id":4,"op":"embed","v":3,"edges":[[0,-1]]}"#).unwrap_err();
        assert!(e.msg.contains("non-negative"), "{}", e.msg);

        let e = parse_request(r#"{"id":-3,"op":"ping"}"#).unwrap_err();
        assert!(e.id.is_none());
    }

    #[test]
    fn reply_roundtrip_is_bitwise() {
        let row = vec![1.0f32, -0.37, 3.25e-7, 42.0, f32::MIN_POSITIVE];
        let line = embed_reply(6, &row, true);
        let (id, back, cached) = parse_embed_reply(&line).unwrap();
        assert_eq!(id, 6);
        assert!(cached);
        assert_eq!(back.len(), row.len());
        for (a, b) in back.iter().zip(&row) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn error_reply_parses_as_failure() {
        let line = error_reply(Some(3), "boom");
        let err = parse_embed_reply(&line).unwrap_err();
        assert!(err.contains("boom") && err.contains('3'), "{err}");
    }
}
