//! In-place fast Walsh–Hadamard transform (FWHT) on power-of-two
//! lengths.
//!
//! The transform computes `H_p x` for the **unnormalized** Hadamard
//! matrix `H_p` (entries ±1, `H_p H_pᵀ = p·I`) in `O(p log p)`
//! butterflies instead of the naive `O(p²)` multiply. Normalization is
//! the caller's job: [`super::SorfMap`] folds the `p^{-3/2}` factor of
//! its three normalized Hadamard applications into one final scale.
//!
//! Butterfly order note: each stage combines pairs `(a, b) -> (a+b,
//! a-b)` at stride `h`, doubling `h` per stage. On integer-valued
//! inputs every intermediate is exact in f32 (sums of ≤ p inputs of
//! magnitude ≤ 2²³⁻ˡᵒᵍᵖ), so the result is bit-for-bit equal to the
//! naive sign-sum — the property the correctness test pins.

/// Apply the unnormalized Walsh–Hadamard transform to `data` in place.
///
/// # Panics
/// Panics if `data.len()` is not a power of two (zero included): the
/// butterfly network is only defined on 2ᵏ points. [`super::SorfMap`]
/// zero-pads inputs to the next power of two before calling this.
pub fn fwht_inplace(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length {n} is not a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = data[j];
                let b = data[j + h];
                data[j] = a + b;
                data[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
}

/// Naive `O(p²)` Hadamard multiply: `out[i] = Σ_j (-1)^{popcount(i&j)}
/// x[j]`. The reference implementation the FWHT is tested against; also
/// used by the parameter-matrix expansion test in [`super::sorf`].
pub fn naive_hadamard(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two(), "Hadamard length {n} is not a power of two");
    (0..n)
        .map(|i| {
            let mut acc = 0.0f32;
            for (j, &v) in x.iter().enumerate() {
                if (i & j).count_ones() % 2 == 0 {
                    acc += v;
                } else {
                    acc -= v;
                }
            }
            acc
        })
        .collect()
}

/// Smallest power of two ≥ `n` (and ≥ 1). The SORF padding rule.
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;
    use crate::util::Rng;

    #[test]
    fn fwht_matches_naive_bit_for_bit_on_integer_inputs() {
        // Integer-valued inputs keep every intermediate sum exact in
        // f32, so the butterfly network and the naive sign-sum must
        // agree to the last bit — not just within a tolerance.
        check::check("fwht-exact", 0xF1, 40, |rng| {
            let p = 1usize << rng.usize(9); // 1..=256
            let mut x = vec![0.0f32; p];
            for v in x.iter_mut() {
                *v = rng.usize(17) as f32 - 8.0;
            }
            let want = naive_hadamard(&x);
            let mut got = x.clone();
            fwht_inplace(&mut got);
            assert_eq!(got, want, "p={p}");
        });
    }

    #[test]
    fn fwht_close_on_gaussian_inputs() {
        check::check("fwht-gauss", 0xF2, 20, |rng| {
            let p = 1usize << (1 + rng.usize(7));
            let mut x = vec![0.0f32; p];
            rng.fill_gaussian(&mut x, 1.0);
            let want = naive_hadamard(&x);
            let mut got = x.clone();
            fwht_inplace(&mut got);
            check::assert_allclose(&got, &want, 1e-4, 1e-4);
        });
    }

    #[test]
    fn fwht_is_self_inverse_up_to_p() {
        // H² = p·I for the unnormalized transform.
        let mut rng = Rng::new(3);
        let p = 64;
        let mut x = vec![0.0f32; p];
        rng.fill_gaussian(&mut x, 1.0);
        let orig = x.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        let scaled: Vec<f32> = orig.iter().map(|&v| v * p as f32).collect();
        check::assert_allclose(&x, &scaled, 1e-3, 1e-4);
    }

    #[test]
    fn fwht_length_one_is_identity() {
        let mut x = [3.5f32];
        fwht_inplace(&mut x);
        assert_eq!(x, [3.5]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fwht_rejects_non_pow2() {
        fwht_inplace(&mut [0.0; 3]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fwht_rejects_empty() {
        fwht_inplace(&mut []);
    }

    #[test]
    fn next_pow2_padding_rule() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(9), 16);
        assert_eq!(next_pow2(25), 32);
        assert_eq!(next_pow2(32), 32);
        assert_eq!(next_pow2(36), 64);
    }
}
