//! In-place fast Walsh–Hadamard transform (FWHT) on power-of-two
//! lengths.
//!
//! The transform computes `H_p x` for the **unnormalized** Hadamard
//! matrix `H_p` (entries ±1, `H_p H_pᵀ = p·I`) in `O(p log p)`
//! butterflies instead of the naive `O(p²)` multiply. Normalization is
//! the caller's job: [`super::SorfMap`] folds the `p^{-3/2}` factor of
//! its three normalized Hadamard applications into one final scale.
//!
//! Butterfly order note: each stage combines pairs `(a, b) -> (a+b,
//! a-b)` at stride `h`, doubling `h` per stage. On integer-valued
//! inputs every intermediate is exact in f32 (sums of ≤ p inputs of
//! magnitude ≤ 2²³⁻ˡᵒᵍᵖ), so the result is bit-for-bit equal to the
//! naive sign-sum — the property the correctness test pins.
//!
//! Execution shapes, slowest to fastest on a batch:
//! - [`fwht_inplace`] — one row at a time (the scalar reference);
//! - [`fwht_batch`] — the same butterflies over a row-major panel,
//!   with `chunks_exact`/`split_at_mut` inner loops so the hot loop
//!   carries no bounds checks and autovectorizes;
//! - [`fwht_batch_par`] — [`fwht_batch`] with the panel's rows split
//!   across scoped threads.
//!
//! All three apply the identical per-row butterfly order, so their
//! outputs are **bitwise equal on any input** (not merely close) — the
//! property `tests/fastrf_prop.rs` pins across the whole (p, batch,
//! threads) grid and the one that makes the batch-major refactor of
//! [`super::SorfMap`] testable at all.

/// Apply the unnormalized Walsh–Hadamard transform to `data` in place.
///
/// # Panics
/// Panics if `data.len()` is not a power of two (zero included): the
/// butterfly network is only defined on 2ᵏ points. [`super::SorfMap`]
/// zero-pads inputs to the next power of two before calling this.
pub fn fwht_inplace(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length {n} is not a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = data[j];
                let b = data[j + h];
                data[j] = a + b;
                data[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
}

/// Apply the unnormalized Walsh–Hadamard transform to every row of a
/// row-major `(panel.len() / p, p)` panel in place.
///
/// Batch-major workhorse of [`super::SorfMap::map_batch`]: one call
/// transforms the whole batch, and the inner loops are structured for
/// the optimizer — `chunks_exact_mut` rows, `split_at_mut` butterfly
/// halves, and a `zip` over equal-length slices, so the hot loop has
/// no bounds checks and vectorizes. The per-row butterfly order is
/// exactly [`fwht_inplace`]'s, so outputs are bitwise equal to the
/// scalar path on any input.
///
/// # Panics
/// Panics if `p` is not a power of two, or if `panel.len()` is not a
/// multiple of `p`. An empty panel (zero rows) is fine.
pub fn fwht_batch(panel: &mut [f32], p: usize) {
    assert!(p.is_power_of_two(), "FWHT length {p} is not a power of two");
    assert_eq!(panel.len() % p, 0, "panel of {} floats is not rows x p={p}", panel.len());
    for row in panel.chunks_exact_mut(p) {
        let mut h = 1;
        while h < p {
            for pair in row.chunks_exact_mut(2 * h) {
                let (a, b) = pair.split_at_mut(h);
                for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                    let u = *x;
                    let v = *y;
                    *x = u + v;
                    *y = u - v;
                }
            }
            h *= 2;
        }
    }
}

/// [`fwht_batch`] with the panel's rows split across up to `threads`
/// scoped worker threads (rows are independent, so the split is at row
/// granularity and the outputs stay bitwise equal to the serial path).
///
/// `threads <= 1` — or a panel with fewer rows than threads would use —
/// degrades to the serial [`fwht_batch`] without spawning. Note
/// [`super::SorfMap`] spends its `--fwht-threads` budget one level up
/// (block groups or row slabs, one spawn wave per map call) rather than
/// here, so a standalone caller that wants a parallel transform is the
/// audience for this entry point.
pub fn fwht_batch_par(panel: &mut [f32], p: usize, threads: usize) {
    assert!(p.is_power_of_two(), "FWHT length {p} is not a power of two");
    assert_eq!(panel.len() % p, 0, "panel of {} floats is not rows x p={p}", panel.len());
    let rows = panel.len() / p;
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 {
        return fwht_batch(panel, p);
    }
    let rows_per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for chunk in panel.chunks_mut(rows_per * p) {
            s.spawn(move || fwht_batch(chunk, p));
        }
    });
}

/// Naive `O(p²)` Hadamard multiply: `out[i] = Σ_j (-1)^{popcount(i&j)}
/// x[j]`. The reference implementation the FWHT is tested against; also
/// used by the parameter-matrix expansion test in [`super::sorf`].
pub fn naive_hadamard(x: &[f32]) -> Vec<f32> {
    let n = x.len();
    assert!(n.is_power_of_two(), "Hadamard length {n} is not a power of two");
    (0..n)
        .map(|i| {
            let mut acc = 0.0f32;
            for (j, &v) in x.iter().enumerate() {
                if (i & j).count_ones() % 2 == 0 {
                    acc += v;
                } else {
                    acc -= v;
                }
            }
            acc
        })
        .collect()
}

/// Smallest power of two ≥ `n` (and ≥ 1). The SORF padding rule.
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check;
    use crate::util::Rng;

    #[test]
    fn fwht_matches_naive_bit_for_bit_on_integer_inputs() {
        // Integer-valued inputs keep every intermediate sum exact in
        // f32, so the butterfly network and the naive sign-sum must
        // agree to the last bit — not just within a tolerance.
        check::check("fwht-exact", 0xF1, 40, |rng| {
            let p = 1usize << rng.usize(9); // 1..=256
            let mut x = vec![0.0f32; p];
            for v in x.iter_mut() {
                *v = rng.usize(17) as f32 - 8.0;
            }
            let want = naive_hadamard(&x);
            let mut got = x.clone();
            fwht_inplace(&mut got);
            assert_eq!(got, want, "p={p}");
        });
    }

    #[test]
    fn fwht_close_on_gaussian_inputs() {
        check::check("fwht-gauss", 0xF2, 20, |rng| {
            let p = 1usize << (1 + rng.usize(7));
            let mut x = vec![0.0f32; p];
            rng.fill_gaussian(&mut x, 1.0);
            let want = naive_hadamard(&x);
            let mut got = x.clone();
            fwht_inplace(&mut got);
            check::assert_allclose(&got, &want, 1e-4, 1e-4);
        });
    }

    #[test]
    fn fwht_is_self_inverse_up_to_p() {
        // H² = p·I for the unnormalized transform.
        let mut rng = Rng::new(3);
        let p = 64;
        let mut x = vec![0.0f32; p];
        rng.fill_gaussian(&mut x, 1.0);
        let orig = x.clone();
        fwht_inplace(&mut x);
        fwht_inplace(&mut x);
        let scaled: Vec<f32> = orig.iter().map(|&v| v * p as f32).collect();
        check::assert_allclose(&x, &scaled, 1e-3, 1e-4);
    }

    #[test]
    fn fwht_length_one_is_identity() {
        let mut x = [3.5f32];
        fwht_inplace(&mut x);
        assert_eq!(x, [3.5]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fwht_rejects_non_pow2() {
        fwht_inplace(&mut [0.0; 3]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fwht_rejects_empty() {
        fwht_inplace(&mut []);
    }

    #[test]
    fn fwht_batch_bitwise_matches_scalar_rows() {
        // Identical butterfly order per row means identical bits on ANY
        // input, gaussian included — no integer restriction needed.
        check::check("fwht-batch", 0xF3, 25, |rng| {
            let p = 1usize << rng.usize(8); // 1..=128
            let rows = rng.usize(6); // 0..=5, zero rows included
            let mut panel = vec![0.0f32; rows * p];
            rng.fill_gaussian(&mut panel, 1.0);
            let mut want = panel.clone();
            for row in want.chunks_exact_mut(p) {
                fwht_inplace(row);
            }
            fwht_batch(&mut panel, p);
            assert_eq!(panel, want, "p={p} rows={rows}");
        });
    }

    #[test]
    fn fwht_batch_par_bitwise_matches_serial_for_every_split() {
        check::check("fwht-batch-par", 0xF4, 15, |rng| {
            let p = 1usize << rng.usize(7);
            let rows = 1 + rng.usize(9);
            let mut reference = vec![0.0f32; rows * p];
            rng.fill_gaussian(&mut reference, 1.0);
            let orig = reference.clone();
            fwht_batch(&mut reference, p);
            // Thread counts below, at, and above the row count — every
            // split must land on the same bits.
            for threads in [1usize, 2, 3, rows, rows + 3] {
                let mut panel = orig.clone();
                fwht_batch_par(&mut panel, p, threads);
                assert_eq!(panel, reference, "p={p} rows={rows} threads={threads}");
            }
        });
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn fwht_batch_rejects_non_pow2() {
        fwht_batch(&mut [0.0; 6], 3);
    }

    #[test]
    #[should_panic(expected = "rows x p")]
    fn fwht_batch_rejects_ragged_panel() {
        fwht_batch(&mut [0.0; 6], 4);
    }

    #[test]
    fn next_pow2_padding_rule() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(9), 16);
        assert_eq!(next_pow2(25), 32);
        assert_eq!(next_pow2(32), 32);
        assert_eq!(next_pow2(36), 64);
    }
}
