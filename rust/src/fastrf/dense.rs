//! Cache-blocked batched dense feature kernel: the comparison baseline
//! for the SORF map.
//!
//! Same math as [`crate::features::CpuFeatureMap`] (bit-for-bit — the
//! per-output accumulation order over `j` is identical, only the loop
//! *grouping* changes), but tiled so each `W` row segment is streamed
//! once per block of input rows instead of once per row:
//!
//! ```text
//!   for row block (R rows)           R·d·m madds total, but each
//!     for column tile (C outputs)    W tile (d × C floats) is read
//!       out tile = bias tile         once per R rows, and the out
//!       for j in 0..d:               tile (R × C) stays in L1/L2
//!         out[r, tile] += x[r,j] · W[j, tile]
//! ```
//!
//! Per-graphlet cost is still `O(d·m)` — that is the point: the
//! `fastrf_scaling` bench races this best-effort dense kernel against
//! [`super::SorfMap`]'s `O(p log p)` blocks.

use crate::features::{RfParams, Variant};

/// Rows per tile: how many input rows reuse one streamed `W` tile.
const ROW_BLOCK: usize = 8;
/// Output columns per tile: `ROW_BLOCK · COL_BLOCK` accumulators stay
/// resident while a `d × COL_BLOCK` slab of `W` streams through.
const COL_BLOCK: usize = 256;

/// `out[r, c] = bias[c] + Σ_j x[r, j] · w[j, c]`, tiled. `w` is
/// row-major `d × m`; `x` row-major `batch × d`; zero inputs are
/// skipped (adjacency rows are sparse 0/1, same fast path as the
/// unblocked map).
pub fn affine_blocked(
    x: &[f32],
    batch: usize,
    d: usize,
    m: usize,
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    assert_eq!(x.len(), batch * d);
    assert_eq!(w.len(), d * m);
    assert_eq!(bias.len(), m);
    assert_eq!(out.len(), batch * m);
    for r0 in (0..batch).step_by(ROW_BLOCK) {
        let r1 = (r0 + ROW_BLOCK).min(batch);
        for c0 in (0..m).step_by(COL_BLOCK) {
            let c1 = (c0 + COL_BLOCK).min(m);
            for r in r0..r1 {
                out[r * m + c0..r * m + c1].copy_from_slice(&bias[c0..c1]);
            }
            for j in 0..d {
                let wrow = &w[j * m + c0..j * m + c1];
                for r in r0..r1 {
                    let xj = x[r * d + j];
                    if xj == 0.0 {
                        continue;
                    }
                    let or = &mut out[r * m + c0..r * m + c1];
                    for (o, &wv) in or.iter_mut().zip(wrow) {
                        *o += xj * wv;
                    }
                }
            }
        }
    }
}

/// Blocked drop-in for [`crate::features::CpuFeatureMap`]: identical
/// parameters and phi formulas, tiled projection. Outputs are
/// bit-for-bit equal to the unblocked map (pinned by the test below),
/// so this is purely a memory-locality baseline.
#[derive(Clone, Debug)]
pub struct DenseMap {
    pub params: RfParams,
}

impl DenseMap {
    pub fn new(params: RfParams) -> Self {
        DenseMap { params }
    }

    /// Map a row-major batch `x` of shape (batch, d) into `out` of
    /// shape (batch, m).
    pub fn map_batch(&self, x: &[f32], batch: usize, out: &mut [f32]) {
        let p = &self.params;
        assert_eq!(x.len(), batch * p.d);
        assert_eq!(out.len(), batch * p.m);
        match p.variant {
            Variant::Gauss | Variant::GaussEig => {
                let scale = (2.0 / p.m as f32).sqrt();
                affine_blocked(x, batch, p.d, p.m, &p.mats[0], &p.biases[0], out);
                for o in out.iter_mut() {
                    *o = scale * o.cos();
                }
            }
            Variant::Opu => {
                let scale = 1.0 / (p.m as f32).sqrt();
                let mut im = vec![0.0f32; batch * p.m];
                affine_blocked(x, batch, p.d, p.m, &p.mats[0], &p.biases[0], out);
                affine_blocked(x, batch, p.d, p.m, &p.mats[1], &p.biases[1], &mut im);
                for (o, &iv) in out.iter_mut().zip(&im) {
                    *o = scale * (*o * *o + iv * iv);
                }
            }
            Variant::Match => panic!("phi_match is not a dense feature map"),
        }
    }

    /// [`map_batch`](Self::map_batch) with the batch's rows split
    /// across up to `threads` scoped worker threads (the crate-private
    /// `par_row_slabs` idiom in [`super`]) — the same entry point
    /// [`crate::fastrf::SorfMap`] exposes, so the two engines stay
    /// API-symmetric under the `--fwht-threads` budget. Each row's
    /// output depends only on that row's input (the tiling only regroups
    /// loops, never the per-output accumulation order), so any row split
    /// is bitwise equal to the serial path.
    pub fn map_batch_threads(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        let p = &self.params;
        assert_eq!(x.len(), batch * p.d);
        assert_eq!(out.len(), batch * p.m);
        super::par_row_slabs(x, out, batch, p.d, p.m, threads, |xc, rows, oc| {
            self.map_batch(xc, rows, oc)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::CpuFeatureMap;
    use crate::util::check;

    /// Tiling must not move a bit: the per-output accumulation order
    /// over j is unchanged, so blocked and unblocked maps agree
    /// exactly, across sizes that exercise partial tiles.
    #[test]
    fn blocked_map_bit_for_bit_matches_unblocked() {
        check::check("dense-blocked", 0xDB, 20, |rng| {
            let d = 1 + rng.usize(40);
            let m = 1 + rng.usize(600);
            let batch = 1 + rng.usize(20);
            for variant in [Variant::Gauss, Variant::Opu] {
                let params = RfParams::generate(variant, d, m, 0.7, rng);
                let mut x = vec![0.0f32; batch * d];
                for v in x.iter_mut() {
                    // Mix of zeros (sparse fast path) and dense values.
                    *v = if rng.bool(0.4) { rng.f32() * 2.0 - 1.0 } else { 0.0 };
                }
                let mut blocked = vec![0.0f32; batch * m];
                DenseMap::new(params.clone()).map_batch(&x, batch, &mut blocked);
                let mut reference = vec![0.0f32; batch * m];
                CpuFeatureMap::new(params).map_batch(&x, batch, &mut reference);
                assert_eq!(blocked, reference, "variant {variant:?} d={d} m={m} batch={batch}");
            }
        });
    }

    /// Row-parallel dispatch is a pure scheduling knob: every thread
    /// count (including ones exceeding the batch) must reproduce the
    /// serial map bit for bit.
    #[test]
    fn map_batch_threads_bitwise_equals_serial() {
        check::check("dense-threads", 0xD7, 10, |rng| {
            let d = 1 + rng.usize(20);
            let m = 1 + rng.usize(300);
            let batch = 1 + rng.usize(20);
            for variant in [Variant::Gauss, Variant::Opu] {
                let params = RfParams::generate(variant, d, m, 0.7, rng);
                let map = DenseMap::new(params);
                let mut x = vec![0.0f32; batch * d];
                rng.fill_gaussian(&mut x, 1.0);
                let mut reference = vec![0.0f32; batch * m];
                map.map_batch(&x, batch, &mut reference);
                for threads in [2usize, 3, batch + 2] {
                    let mut got = vec![0.0f32; batch * m];
                    map.map_batch_threads(&x, batch, &mut got, threads);
                    assert_eq!(
                        got, reference,
                        "variant {variant:?} d={d} m={m} batch={batch} threads={threads}"
                    );
                }
            }
        });
    }

    #[test]
    fn affine_blocked_tiny_hand_case() {
        // batch=1, d=2, m=3: out = b + x0·w[0,:] + x1·w[1,:].
        let x = [2.0f32, -1.0];
        let w = [1.0f32, 2.0, 3.0, 10.0, 20.0, 30.0];
        let bias = [0.5f32, 0.5, 0.5];
        let mut out = [0.0f32; 3];
        affine_blocked(&x, 1, 2, 3, &w, &bias, &mut out);
        assert_eq!(out, [2.0 * 1.0 - 10.0 + 0.5, 2.0 * 2.0 - 20.0 + 0.5, 2.0 * 3.0 - 30.0 + 0.5]);
    }
}
