//! Structured random features: SORF/Fastfood-style `HD` products
//! computed with an in-place fast Walsh–Hadamard transform.
//!
//! The paper's argument is that *dense* random features are the
//! bottleneck — `O(m·d)` per graphlet — and that the OPU replaces them
//! with a constant-time physical transform. The software analogue of
//! that speedup is a **structured** transform: replace the dense
//! Gaussian matrix `W` with a product of Rademacher diagonals `Dᵢ` and
//! Hadamard transforms `H`, each block computed in `O(p log p)` by the
//! FWHT (Kriege et al.'s survey of explicit feature maps; Choromanski's
//! "Taming graph kernels with random features"):
//!
//! ```text
//!        x ∈ ℝᵈ ── zero-pad ──► x̂ ∈ ℝᵖ,  p = 2^⌈log₂ d⌉
//!
//!   block b = 0 .. ⌈m/p⌉-1   (independent diagonal draws per block)
//!   ┌─────────────────────────────────────────────────────────┐
//!   │  x̂ ──► D₃ᵇ ──► H ──► D₂ᵇ ──► H ──► D₁ᵇ ──► H ──► ·α     │──► z_b ∈ ℝᵖ
//!   └─────────────────────────────────────────────────────────┘
//!        z = concat(z_0, z_1, …)[..m]        (last block truncated)
//!
//!   phi_Gs  :  √(2/m) · cos(z + b)            α = 1/(σ·p)
//!   phi_OPU :  m^{-1/2}·((z_re+b_re)² + (z_im+b_im)²)   α = 1/p
//! ```
//!
//! Each `H` above is the *unnormalized* FWHT; the three `p^{-1/2}`
//! normalizations plus the `√p` row-norm calibration (SORF rows are
//! exactly orthogonal with norm `√p` — tested) fold into the single
//! scale `α`. With `α = 1/(σ·p)` the effective projection entries have
//! variance `1/σ²`, matching the dense `RfParams` draw, so `cpu-sorf`
//! approximates the same kernels as the dense `cpu` engine — in
//! `O(p log p)` per block instead of `O(d·m)` total.
//!
//! Module map:
//! - [`fwht`] — the in-place butterfly transform + naive reference;
//! - [`sorf`] — [`SorfParams`] (seeded Rademacher draws) and
//!   [`SorfMap`] (the batched feature map, a drop-in for
//!   [`crate::features::CpuFeatureMap`]);
//! - [`dense`] — [`DenseMap`], the cache-blocked `O(d·m)` baseline the
//!   `fastrf_scaling` bench races against.
//!
//! Engine wiring: `--engine cpu-sorf`
//! ([`crate::coordinator::EngineMode::CpuSorf`]) runs this map on every
//! feature shard of the streaming pipeline; embeddings are
//! deterministic per seed and bitwise identical across shard/worker
//! counts, exactly like the dense engines (same accumulation dataflow,
//! different projection). The serve cache fingerprint includes the
//! engine mode, so `cpu` and `cpu-sorf` rows never mix.

pub mod dense;
pub mod fwht;
pub mod sorf;

pub use dense::{affine_blocked, DenseMap};
pub use fwht::{fwht_inplace, naive_hadamard, next_pow2};
pub use sorf::{SorfMap, SorfParams, SORF_ROUNDS};

// The sharded pipeline moves SorfMap clones across threads; fail the
// build (not the run) if that ever stops being possible — same pin as
// features::CpuFeatureMap.
const _: () = {
    const fn assert_shardable<T: Clone + Send + Sync>() {}
    assert_shardable::<SorfMap>();
    assert_shardable::<SorfParams>();
    assert_shardable::<DenseMap>();
};
