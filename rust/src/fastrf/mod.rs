//! Structured random features: SORF/Fastfood-style `HD` products
//! computed with an in-place fast Walsh–Hadamard transform.
//!
//! The paper's argument is that *dense* random features are the
//! bottleneck — `O(m·d)` per graphlet — and that the OPU replaces them
//! with a constant-time physical transform. The software analogue of
//! that speedup is a **structured** transform: replace the dense
//! Gaussian matrix `W` with a product of Rademacher diagonals `Dᵢ` and
//! Hadamard transforms `H`, each block computed in `O(p log p)` by the
//! FWHT (Kriege et al.'s survey of explicit feature maps; Choromanski's
//! "Taming graph kernels with random features"):
//!
//! ```text
//!        x ∈ ℝᵈ ── zero-pad ──► x̂ ∈ ℝᵖ,  p = 2^⌈log₂ d⌉
//!
//!   block b = 0 .. ⌈m/p⌉-1   (independent diagonal draws per block)
//!   ┌─────────────────────────────────────────────────────────┐
//!   │  x̂ ──► D₃ᵇ ──► H ──► D₂ᵇ ──► H ──► D₁ᵇ ──► H ──► ·α     │──► z_b ∈ ℝᵖ
//!   └─────────────────────────────────────────────────────────┘
//!        z = concat(z_0, z_1, …)[..m]        (last block truncated)
//!
//!   phi_Gs  :  √(2/m) · cos(z + b)            α = 1/(σ·p)
//!   phi_OPU :  m^{-1/2}·((z_re+b_re)² + (z_im+b_im)²)   α = 1/p
//! ```
//!
//! Each `H` above is the *unnormalized* FWHT; the three `p^{-1/2}`
//! normalizations plus the `√p` row-norm calibration (SORF rows are
//! exactly orthogonal with norm `√p` — tested) fold into the single
//! scale `α`. With `α = 1/(σ·p)` the effective projection entries have
//! variance `1/σ²`, matching the dense `RfParams` draw, so `cpu-sorf`
//! approximates the same kernels as the dense `cpu` engine — in
//! `O(p log p)` per block instead of `O(d·m)` total.
//!
//! Execution is **batch-major**: a block never walks one row at a
//! time. The whole batch is zero-padded into one contiguous row-major
//! panel, each diagonal is applied in a single pass over the panel, and
//! the FWHT butterflies run over all rows per stage. The thread budget
//! is spent once per map call: with at least one row per worker the
//! batch splits into row slabs (one whole-pipeline worker per slab,
//! writing `out` in place); a row-starved stacked map (batch < threads,
//! m > p) dispatches independent blocks instead, each worker computing
//! its own column panel, stitched into `out`:
//!
//! ```text
//!            batch rows ────────────────►
//!   panel   ┌────────────── p ──────────────┐      block 0 ─ thread A ┐
//!   (rows   │ x̂₀ │ x̂₁ │ x̂₂ │ … (row-major)  │      block 1 ─ thread A │ stitch
//!    × p)   └──────────────────────────────┘      block 2 ─ thread B ├─► out
//!     │  per round: one Dᵢᵇ pass over the        block 3 ─ thread B │ (cols
//!     ▼  whole panel, then one batched FWHT      …                  ┘  lo..hi)
//! ```
//!
//! Every execution shape — scalar reference, serial panel, block- or
//! row-parallel — applies the identical per-element arithmetic, so
//! embeddings are **bitwise identical** across batch sizes and thread
//! counts (pinned by `tests/fastrf_prop.rs` and the pipeline stability
//! tests). The thread budget defaults to 1 so shard-level parallelism
//! keeps owning the cores; `--fwht-threads N` hands each shard N panel
//! workers.
//!
//! Module map:
//! - [`fwht`] — the in-place butterfly transform (scalar, batched, and
//!   row-parallel batched) + naive reference;
//! - [`sorf`] — [`SorfParams`] (seeded Rademacher draws) and
//!   [`SorfMap`] (the batched feature map, a drop-in for
//!   [`crate::features::CpuFeatureMap`]);
//! - [`dense`] — [`DenseMap`], the cache-blocked `O(d·m)` baseline the
//!   `fastrf_scaling` bench races against.
//!
//! Engine wiring: `--engine cpu-sorf`
//! ([`crate::coordinator::EngineMode::CpuSorf`]) runs this map on every
//! feature shard of the streaming pipeline; embeddings are
//! deterministic per seed and bitwise identical across shard/worker
//! counts, exactly like the dense engines (same accumulation dataflow,
//! different projection). The serve cache fingerprint includes the
//! engine mode, so `cpu` and `cpu-sorf` rows never mix.

pub mod dense;
pub mod fwht;
pub mod sorf;

pub use dense::{affine_blocked, DenseMap};
pub use fwht::{fwht_batch, fwht_batch_par, fwht_inplace, naive_hadamard, next_pow2};
pub use sorf::{SorfMap, SorfParams, SORF_ROUNDS};

// The sharded pipeline moves SorfMap clones across threads; fail the
// build (not the run) if that ever stops being possible — same pin as
// features::CpuFeatureMap.
const _: () = {
    const fn assert_shardable<T: Clone + Send + Sync>() {}
    assert_shardable::<SorfMap>();
    assert_shardable::<SorfParams>();
    assert_shardable::<DenseMap>();
};

/// Split a row-major `(rows, d) → (rows, m)` map across up to
/// `threads` scoped workers, one contiguous row slab per worker; the
/// shared row-parallel idiom of [`SorfMap::map_batch_threads`] and
/// [`DenseMap::map_batch_threads`]. With an effective budget of 1 (or
/// a single row) it calls `apply` directly — no spawn.
///
/// `apply(x_slab, slab_rows, out_slab)` must compute each output row
/// from that row's input alone; every split is then bitwise equal to
/// the serial call.
pub(crate) fn par_row_slabs<F>(
    x: &[f32],
    out: &mut [f32],
    rows: usize,
    d: usize,
    m: usize,
    threads: usize,
    apply: F,
) where
    F: Fn(&[f32], usize, &mut [f32]) + Sync,
{
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 {
        apply(x, rows, out);
        return;
    }
    let rows_per = rows.div_ceil(threads);
    let apply = &apply;
    std::thread::scope(|s| {
        for (xc, oc) in x.chunks(rows_per * d).zip(out.chunks_mut(rows_per * m)) {
            s.spawn(move || apply(xc, xc.len() / d, oc));
        }
    });
}
