//! Structured Orthogonal Random Features: the `HD` product map.
//!
//! Replaces the dense random matrix `W` of [`crate::features::RfParams`]
//! with a product of Hadamard transforms and Rademacher diagonals,
//! computed by the in-place FWHT in `O(p log p)` per block instead of
//! `O(d·m)` — see [`super`] (the module docs) for the dataflow diagram
//! and the scaling derivation.
//!
//! Parameter draws share the project's [`Rng`] seeding discipline: a
//! [`SorfParams`] is a pure function of `(variant, d, m, sigma, rng
//! state)`, so `cpu-sorf` embeddings are deterministic per seed exactly
//! like the dense engines' (pinned by tests below and by the sharded
//! pipeline's bitwise tests running under `cpu-sorf`).

use crate::features::Variant;
use crate::util::Rng;

use super::fwht::{fwht_batch, next_pow2};

/// Number of (diagonal, Hadamard) rounds per block. Three is the
/// standard SORF depth: enough mixing that the rows behave like
/// Gaussian directions (Yu et al. 2016), still `O(p log p)`.
pub const SORF_ROUNDS: usize = 3;

/// The random parameters of a structured feature map: Rademacher sign
/// diagonals per block plus the same bias draws as the dense map.
///
/// `m > p` is handled by `⌈m/p⌉` *independent* stacked blocks (fresh
/// diagonals per block); the last block is truncated to reach exactly
/// `m` features.
#[derive(Clone, Debug)]
pub struct SorfParams {
    pub variant: Variant,
    /// Input dimension (pre-padding).
    pub d: usize,
    /// Number of output features.
    pub m: usize,
    /// FWHT length: the next power of two ≥ d.
    pub padded: usize,
    /// Independent `HD` blocks stacked to cover m outputs.
    pub blocks: usize,
    /// One sign stack per projection — gauss/gauss-eig: `[signs]`;
    /// opu: `[signs_re, signs_im]`. Each stack stores ±1.0 entries,
    /// flat-indexed as `(block * SORF_ROUNDS + round) * padded + i`.
    pub signs: Vec<Vec<f32>>,
    /// gauss / gauss-eig: phase offsets `b` (m). opu: `br, bi` (m each).
    pub biases: Vec<Vec<f32>>,
    /// Gaussian kernel bandwidth (gauss variants only; opu is
    /// unit-variance like the dense transmission matrix).
    pub sigma: f32,
}

impl SorfParams {
    /// Draw structured parameters. Mirrors
    /// [`crate::features::RfParams::generate`]: same variants, same rng
    /// discipline (signs first, then biases), different — structured —
    /// projection family.
    pub fn generate(variant: Variant, d: usize, m: usize, sigma: f32, rng: &mut Rng) -> Self {
        let padded = next_pow2(d);
        let blocks = m.div_ceil(padded).max(1);
        let stacks = match variant {
            Variant::Opu => 2,
            Variant::Gauss | Variant::GaussEig => 1,
            Variant::Match => 0,
        };
        let mut signs = Vec::with_capacity(stacks);
        for _ in 0..stacks {
            let mut s = vec![0.0f32; blocks * SORF_ROUNDS * padded];
            for v in s.iter_mut() {
                *v = if rng.bool(0.5) { 1.0 } else { -1.0 };
            }
            signs.push(s);
        }
        let biases = match variant {
            Variant::Opu => {
                let mut br = vec![0.0f32; m];
                let mut bi = vec![0.0f32; m];
                rng.fill_gaussian(&mut br, 1.0);
                rng.fill_gaussian(&mut bi, 1.0);
                vec![br, bi]
            }
            Variant::Gauss | Variant::GaussEig => {
                let mut b = vec![0.0f32; m];
                rng.fill_uniform(&mut b, 0.0, 2.0 * std::f32::consts::PI);
                vec![b]
            }
            Variant::Match => Vec::new(),
        };
        SorfParams { variant, d, m, padded, blocks, signs, biases, sigma }
    }
}

/// One `HD` block applied to a whole batch, panel-wise: zero-pad each
/// input row of `x` (row-major `rows × d`) into `panel` (row-major
/// `rows × pad`), then run `SORF_ROUNDS` rounds of (sign diagonal,
/// unnormalized FWHT) — **one pass per diagonal over the whole batch**
/// followed by one batched FWHT over the panel, instead of a per-row
/// round trip.
///
/// Per-row arithmetic (multiply order, butterfly order) is identical to
/// the historical scalar path, so outputs are bitwise equal for every
/// batch size — pinned by `tests/fastrf_prop.rs`. Normalization is
/// deferred to the caller's single output scale. Thread dispatch lives
/// one level up ([`SorfMap::map_batch_threads`] splits blocks or row
/// slabs, spawning once per map call, never per round).
fn project_block_panel(
    x: &[f32],
    d: usize,
    signs: &[f32],
    block: usize,
    pad: usize,
    panel: &mut [f32],
) {
    for (row, xr) in panel.chunks_exact_mut(pad).zip(x.chunks_exact(d)) {
        row[..d].copy_from_slice(xr);
        row[d..].fill(0.0);
    }
    for round in 0..SORF_ROUNDS {
        let base = (block * SORF_ROUNDS + round) * pad;
        let s = &signs[base..base + pad];
        for row in panel.chunks_exact_mut(pad) {
            for (v, &sg) in row.iter_mut().zip(s) {
                *v *= sg;
            }
        }
        fwht_batch(panel, pad);
    }
}

/// Structured drop-in for [`crate::features::CpuFeatureMap`]: same
/// `map_batch` contract (row-major `(batch, d)` in, `(batch, m)` out),
/// same phi formulas, `O(p log p)` projection per block instead of
/// `O(d·m)` total.
///
/// `Clone + Send + Sync` by construction (plain owned buffers), so the
/// sharded coordinator can hand one clone to every feature shard —
/// pinned by the compile-time assertion in [`super`].
#[derive(Clone, Debug)]
pub struct SorfMap {
    pub params: SorfParams,
}

impl SorfMap {
    pub fn new(params: SorfParams) -> Self {
        SorfMap { params }
    }

    /// Map a row-major batch `x` of shape (batch, d) into `out` of
    /// shape (batch, m), single-threaded. Equivalent to
    /// [`map_batch_threads`](Self::map_batch_threads) with a budget of
    /// 1 — the entry the shard loop uses when `--fwht-threads` is left
    /// at its default.
    pub fn map_batch(&self, x: &[f32], batch: usize, out: &mut [f32]) {
        self.map_batch_threads(x, batch, out, 1);
    }

    /// Map a row-major batch `x` of shape (batch, d) into `out` of
    /// shape (batch, m) with up to `threads` worker threads.
    ///
    /// Execution is batch-major: each `HD` block projects the whole
    /// batch as one `(batch, p)` panel (one sign-diagonal pass over the
    /// panel per round, then a batched FWHT) instead of re-walking the
    /// block per row. The thread budget is spent once per call, on
    /// whichever axis has the parallelism: when the batch has at least
    /// one row per worker (the common shard shape) the batch splits
    /// into row slabs — each worker runs the whole pipeline on its slab
    /// and writes `out` in place, no scratch, no stitch; a row-starved
    /// multi-block map (batch < threads, m > p) dispatches independent
    /// blocks instead, each worker computing its own column panel,
    /// stitched into `out` afterwards. Per-element arithmetic is
    /// identical in every configuration, so outputs are bitwise equal
    /// to the scalar path for every (batch, threads) — the contract
    /// pinned by `tests/fastrf_prop.rs` and the pipeline's
    /// cross-thread-count stability tests.
    pub fn map_batch_threads(&self, x: &[f32], batch: usize, out: &mut [f32], threads: usize) {
        let p = &self.params;
        assert_eq!(x.len(), batch * p.d);
        assert_eq!(out.len(), batch * p.m);
        if p.variant == Variant::Match {
            panic!("phi_match is not a dense feature map");
        }
        if batch == 0 || p.m == 0 {
            return; // nothing to write; keeps the panel chunking total
        }
        let threads = threads.max(1);
        if p.blocks == 1 || batch >= threads {
            // Rows are the better (or only) parallel axis: in-place
            // slab writes, serial when the effective budget is 1.
            let blocks = p.blocks;
            super::par_row_slabs(x, out, batch, p.d, p.m, threads, |xc, rows, oc| {
                self.apply_blocks(xc, rows, 0, blocks, oc, self.params.m, 0)
            });
            return;
        }
        let (pad, m) = (p.padded, p.m);
        // Row-starved stacked map (batch < threads, m > p): dispatch
        // independent blocks across the budget instead; each group of
        // blocks computes its own (batch, cols) column panel in a scoped
        // thread, and the panels tile `out`'s columns disjointly.
        let groups = threads.min(p.blocks);
        let per = p.blocks.div_ceil(groups);
        let panels: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..groups)
                .map(|g| (g * per, ((g + 1) * per).min(p.blocks)))
                .filter(|&(b0, b1)| b0 < b1)
                .map(|(b0, b1)| {
                    s.spawn(move || {
                        let lo = b0 * pad;
                        let hi = (b1 * pad).min(m);
                        let mut dst = vec![0.0f32; batch * (hi - lo)];
                        self.apply_blocks(x, batch, b0, b1, &mut dst, hi - lo, lo);
                        (lo, hi, dst)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("sorf block worker")).collect()
        });
        for (lo, hi, dst) in panels {
            for (or, dr) in out.chunks_exact_mut(m).zip(dst.chunks_exact(hi - lo)) {
                or[lo..hi].copy_from_slice(dr);
            }
        }
    }

    /// Apply phi for blocks `b0..b1` of the map into `dst`, a row-major
    /// `(batch, stride)` panel whose column 0 corresponds to feature
    /// column `col0` (`dst = out, stride = m, col0 = 0` for the whole
    /// map). Serial: callers own the thread dispatch.
    fn apply_blocks(
        &self,
        x: &[f32],
        batch: usize,
        b0: usize,
        b1: usize,
        dst: &mut [f32],
        stride: usize,
        col0: usize,
    ) {
        let p = &self.params;
        let pad = p.padded;
        let mut panel = vec![0.0f32; batch * pad];
        match p.variant {
            Variant::Gauss | Variant::GaussEig => {
                let scale = (2.0 / p.m as f32).sqrt();
                // Three normalized Hadamards contribute p^{-3/2}; the
                // √p row-norm calibration and the 1/σ bandwidth fold in
                // to a single 1/(σ·p) — see the module docs.
                let inv_sp = 1.0 / (p.sigma * pad as f32);
                let signs = &p.signs[0];
                let b = &p.biases[0];
                // Block-major loop order: one block's sign diagonals
                // stay hot across the whole batch.
                for block in b0..b1 {
                    let lo = block * pad;
                    let hi = ((block + 1) * pad).min(p.m);
                    project_block_panel(x, p.d, signs, block, pad, &mut panel);
                    for (dr, zr) in dst.chunks_exact_mut(stride).zip(panel.chunks_exact(pad)) {
                        let dr = &mut dr[lo - col0..hi - col0];
                        for ((o, &z), &bj) in dr.iter_mut().zip(zr).zip(&b[lo..hi]) {
                            *o = scale * (z * inv_sp + bj).cos();
                        }
                    }
                }
            }
            Variant::Opu => {
                let scale = 1.0 / (p.m as f32).sqrt();
                // Unit-variance calibration (σ = 1): 1/p per stack.
                let inv_p = 1.0 / pad as f32;
                let (sr, si) = (&p.signs[0], &p.signs[1]);
                let (br, bi) = (&p.biases[0], &p.biases[1]);
                let mut ipanel = vec![0.0f32; batch * pad];
                for block in b0..b1 {
                    let lo = block * pad;
                    let hi = ((block + 1) * pad).min(p.m);
                    project_block_panel(x, p.d, sr, block, pad, &mut panel);
                    project_block_panel(x, p.d, si, block, pad, &mut ipanel);
                    for ((dr, zr), zi) in dst
                        .chunks_exact_mut(stride)
                        .zip(panel.chunks_exact(pad))
                        .zip(ipanel.chunks_exact(pad))
                    {
                        let dr = &mut dr[lo - col0..hi - col0];
                        let it = dr.iter_mut().zip(zr).zip(zi).zip(&br[lo..hi]).zip(&bi[lo..hi]);
                        for ((((o, &re0), &im0), &brj), &bij) in it {
                            let re = re0 * inv_p + brj;
                            let im = im0 * inv_p + bij;
                            *o = scale * (re * re + im * im);
                        }
                    }
                }
            }
            Variant::Match => unreachable!("rejected by map_batch_threads"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::fwht::naive_hadamard;
    use super::*;
    use crate::util::check;

    /// The O(p²) reference: the same block projection with each FWHT
    /// replaced by the naive Hadamard multiply.
    fn naive_block_project(xr: &[f32], signs: &[f32], block: usize, pad: usize) -> Vec<f32> {
        let mut buf = vec![0.0f32; pad];
        buf[..xr.len()].copy_from_slice(xr);
        for round in 0..SORF_ROUNDS {
            let base = (block * SORF_ROUNDS + round) * pad;
            for (v, &sg) in buf.iter_mut().zip(&signs[base..base + pad]) {
                *v *= sg;
            }
            buf = naive_hadamard(&buf);
        }
        buf
    }

    /// On integer-valued inputs the FWHT and the naive Hadamard agree
    /// bit-for-bit (every intermediate is exact in f32), and the phi
    /// formulas are evaluated identically — so the whole map must match
    /// the naive expansion exactly, for both variants.
    #[test]
    fn sorf_map_matches_naive_expansion_bit_for_bit() {
        check::check("sorf-naive", 0x5F, 15, |rng| {
            let d = 1 + rng.usize(20);
            let m = 1 + rng.usize(50);
            let batch = 1 + rng.usize(4);
            let sigma = 0.5f32;
            for variant in [Variant::Gauss, Variant::Opu] {
                let params = SorfParams::generate(variant, d, m, sigma, rng);
                let pad = params.padded;
                let mut x = vec![0.0f32; batch * d];
                for v in x.iter_mut() {
                    *v = rng.usize(9) as f32 - 4.0;
                }
                let mut out = vec![0.0f32; batch * m];
                SorfMap::new(params.clone()).map_batch(&x, batch, &mut out);

                let mut want = vec![0.0f32; batch * m];
                for r in 0..batch {
                    let xr = &x[r * d..(r + 1) * d];
                    for block in 0..params.blocks {
                        let lo = block * pad;
                        let hi = ((block + 1) * pad).min(m);
                        match variant {
                            Variant::Gauss => {
                                let z = naive_block_project(xr, &params.signs[0], block, pad);
                                let scale = (2.0 / m as f32).sqrt();
                                let inv_sp = 1.0 / (sigma * pad as f32);
                                for j in lo..hi {
                                    want[r * m + j] = scale
                                        * (z[j - lo] * inv_sp + params.biases[0][j]).cos();
                                }
                            }
                            Variant::Opu => {
                                let zr = naive_block_project(xr, &params.signs[0], block, pad);
                                let zi = naive_block_project(xr, &params.signs[1], block, pad);
                                let scale = 1.0 / (m as f32).sqrt();
                                let inv_p = 1.0 / pad as f32;
                                for j in lo..hi {
                                    let re = zr[j - lo] * inv_p + params.biases[0][j];
                                    let im = zi[j - lo] * inv_p + params.biases[1][j];
                                    want[r * m + j] = scale * (re * re + im * im);
                                }
                            }
                            _ => unreachable!(),
                        }
                    }
                }
                assert_eq!(out, want, "variant {variant:?} d={d} m={m}");
            }
        });
    }

    /// The unnormalized `H D₁ H D₂ H D₃` stack is exactly orthogonal:
    /// its row Gram matrix is `p³·I`, bit-exact (all-integer
    /// arithmetic). This is the structural property that makes SORF
    /// rows behave like calibrated Gaussian directions.
    #[test]
    fn sorf_block_is_exactly_orthogonal() {
        let mut rng = Rng::new(11);
        let pad = 8usize;
        let params = SorfParams::generate(Variant::Gauss, pad, pad, 1.0, &mut rng);
        assert_eq!(params.padded, pad);
        // Column k of the block matrix = block applied to basis vector
        // k (a one-row panel through the batch-major projection).
        let mut cols = vec![vec![0.0f32; pad]; pad];
        let mut buf = vec![0.0f32; pad];
        for (k, col) in cols.iter_mut().enumerate() {
            let mut e = vec![0.0f32; pad];
            e[k] = 1.0;
            project_block_panel(&e, pad, &params.signs[0], 0, pad, &mut buf);
            col.copy_from_slice(&buf);
        }
        for i in 0..pad {
            for j in 0..pad {
                let g: f64 = (0..pad)
                    .map(|k| cols[k][i] as f64 * cols[k][j] as f64)
                    .sum();
                let want = if i == j { (pad as f64).powi(3) } else { 0.0 };
                assert_eq!(g, want, "row Gram ({i},{j})");
            }
        }
    }

    /// Deterministic per seed, and different seeds give different maps.
    #[test]
    fn sorf_deterministic_per_seed() {
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            SorfParams::generate(Variant::Opu, 9, 40, 1.0, &mut rng)
        };
        let (a, b, c) = (draw(7), draw(7), draw(8));
        assert_eq!(a.signs, b.signs);
        assert_eq!(a.biases, b.biases);
        assert_ne!(a.signs, c.signs, "different seeds must differ");
        let mut x = vec![0.0f32; 3 * 9];
        let mut rng = Rng::new(1);
        rng.fill_gaussian(&mut x, 1.0);
        let (mut ya, mut yb) = (vec![0.0f32; 3 * 40], vec![0.0f32; 3 * 40]);
        SorfMap::new(a).map_batch(&x, 3, &mut ya);
        SorfMap::new(b).map_batch(&x, 3, &mut yb);
        assert_eq!(ya, yb);
    }

    /// The thread budget is a pure scheduling knob: block-parallel and
    /// row-parallel dispatch must land on exactly the bits the serial
    /// path produces, for single-block (m <= p) and stacked (m > p)
    /// maps alike. (The full (p, batch, threads) grid lives in
    /// tests/fastrf_prop.rs; this is the unit-level pin.)
    #[test]
    fn map_batch_threads_bitwise_equals_serial() {
        check::check("sorf-threads", 0x57, 10, |rng| {
            let d = 1 + rng.usize(12);
            // Alternate between single-block and multi-block shapes.
            let m = if rng.bool(0.5) { 1 + rng.usize(8) } else { 20 + rng.usize(80) };
            let batch = 1 + rng.usize(6);
            for variant in [Variant::Gauss, Variant::Opu] {
                let params = SorfParams::generate(variant, d, m, 0.8, rng);
                let map = SorfMap::new(params);
                let mut x = vec![0.0f32; batch * d];
                rng.fill_gaussian(&mut x, 1.0);
                let mut reference = vec![0.0f32; batch * m];
                map.map_batch(&x, batch, &mut reference);
                for threads in [2usize, 3, 8] {
                    let mut got = vec![0.0f32; batch * m];
                    map.map_batch_threads(&x, batch, &mut got, threads);
                    assert_eq!(
                        got, reference,
                        "variant {variant:?} d={d} m={m} batch={batch} threads={threads}"
                    );
                }
            }
        });
    }

    /// Clones are interchangeable (the sharded pipeline's contract).
    #[test]
    fn sorf_map_clones_compute_identical_features() {
        let mut rng = Rng::new(12);
        let params = SorfParams::generate(Variant::Opu, 9, 32, 1.0, &mut rng);
        let map = SorfMap::new(params);
        let clone = map.clone();
        let mut x = vec![0.0f32; 4 * 9];
        for v in x.iter_mut() {
            *v = rng.bool(0.4) as u8 as f32;
        }
        let mut a = vec![0.0f32; 4 * 32];
        let mut b = vec![0.0f32; 4 * 32];
        map.map_batch(&x, 4, &mut a);
        clone.map_batch(&x, 4, &mut b);
        assert_eq!(a, b);
    }

    /// Padding and stacking arithmetic: d pads to the next power of
    /// two, m is covered by ⌈m/p⌉ blocks, outputs stay finite.
    #[test]
    fn sorf_padding_and_stacking_dims() {
        let mut rng = Rng::new(5);
        let params = SorfParams::generate(Variant::Gauss, 9, 20, 0.5, &mut rng);
        assert_eq!(params.padded, 16);
        assert_eq!(params.blocks, 2);
        assert_eq!(params.signs[0].len(), 2 * SORF_ROUNDS * 16);
        assert_eq!(params.biases[0].len(), 20);
        let big = SorfParams::generate(Variant::Opu, 25, 2048, 1.0, &mut rng);
        assert_eq!(big.padded, 32);
        assert_eq!(big.blocks, 64);
        let mut x = vec![0.0f32; 2 * 9];
        rng.fill_gaussian(&mut x, 1.0);
        let mut out = vec![0.0f32; 2 * 20];
        SorfMap::new(params).map_batch(&x, 2, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    /// phi_Gs via SORF approximates the Gaussian kernel, like the dense
    /// map's `gauss_kernel_approximation` test: phi(x)·phi(y) ≈
    /// exp(-||x-y||²/(2σ²)). m is large enough that the tolerance is
    /// many standard deviations wide.
    #[test]
    fn sorf_gauss_kernel_approximation() {
        let mut rng = Rng::new(5);
        let (d, m, sigma) = (20usize, 16_384usize, 1.5f32);
        let params = SorfParams::generate(Variant::Gauss, d, m, sigma, &mut rng);
        let mut xy = vec![0.0f32; 2 * d];
        rng.fill_gaussian(&mut xy, 0.4);
        let mut out = vec![0.0f32; 2 * m];
        SorfMap::new(params).map_batch(&xy, 2, &mut out);
        let dot: f64 = (0..m).map(|i| out[i] as f64 * out[m + i] as f64).sum();
        let dist2: f64 = (0..d)
            .map(|j| ((xy[j] - xy[d + j]) as f64).powi(2))
            .sum();
        let exact = (-dist2 / (2.0 * sigma as f64 * sigma as f64)).exp();
        assert!((dot - exact).abs() < 0.06, "{dot} vs {exact}");
    }

    /// phi_OPU via SORF follows the same kernel law as the dense map's
    /// `opu_kernel_closed_form` test (generous tolerance: SORF fourth
    /// moments deviate from Gaussian by O(1/p)).
    #[test]
    fn sorf_opu_kernel_close_to_closed_form() {
        let mut rng = Rng::new(99);
        let (d, m) = (20usize, 32_768usize);
        let mut params = SorfParams::generate(Variant::Opu, d, m, 1.0, &mut rng);
        params.biases[0].fill(0.0);
        params.biases[1].fill(0.0);
        let mut xy = vec![0.0f32; 2 * d];
        rng.fill_gaussian(&mut xy, 0.8);
        let (x, y) = xy.split_at(d);
        let nx2: f64 = x.iter().map(|&v| (v * v) as f64).sum();
        let ny2: f64 = y.iter().map(|&v| (v * v) as f64).sum();
        let ip: f64 = x.iter().zip(y).map(|(&a, &b)| (a * b) as f64).sum();
        let mut out = vec![0.0f32; 2 * m];
        SorfMap::new(params).map_batch(&xy, 2, &mut out);
        let dot: f64 = (0..m).map(|i| out[i] as f64 * out[m + i] as f64).sum();
        let exact = 4.0 * (nx2 * ny2 + ip * ip);
        assert!((dot - exact).abs() / exact < 0.15, "{dot} vs {exact}");
    }

    #[test]
    #[should_panic(expected = "phi_match")]
    fn sorf_match_variant_panics_like_dense() {
        let mut rng = Rng::new(1);
        let params = SorfParams::generate(Variant::Match, 4, 4, 1.0, &mut rng);
        SorfMap::new(params).map_batch(&[0.0; 4], 1, &mut [0.0; 4]);
    }
}
