//! Maximum Mean Discrepancy machinery + the Theorem 1 bound.
//!
//! Theorem 1 (paper §3.2): with probability >= 1 - delta,
//!
//!   | ||f_G - f_G'||^2 - MMD^2(S_k(G), S_k(G')) |
//!       <= 4 m^{-1/2} sqrt(log(6/delta)) + 8 s^{-1/2} (1 + sqrt(2 log(3/delta)))
//!
//! This module provides: the embedding-space MMD estimator (what GSA-phi
//! computes), the exact MMD under the *matching kernel* (where MMD^2 is
//! just the squared distance of the folded histograms — computable
//! exactly for small k, which is what `examples/thm1_concentration.rs`
//! uses as ground truth), and the bound itself.

use crate::graph::Graphlet;
use crate::iso::GraphletRegistry;

/// Squared Euclidean distance between two mean embeddings — the plug-in
/// MMD^2 estimator of GSA-phi (LHS of Theorem 1 without the expectation).
pub fn embedding_sq_distance(f1: &[f32], f2: &[f32]) -> f64 {
    assert_eq!(f1.len(), f2.len());
    f1.iter()
        .zip(f2)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum()
}

/// Exact MMD^2 under the matching kernel kappa(F, F') = 1{F ~= F'}:
/// fold both sample sets into histograms over isomorphism classes and
/// return the squared histogram distance. For exhaustive inputs (or very
/// large samples) this is the "true" MMD GSA-phi_match approximates.
pub fn match_kernel_mmd2(samples_a: &[Graphlet], samples_b: &[Graphlet]) -> f64 {
    let mut reg = GraphletRegistry::new();
    let hist = |samples: &[Graphlet], reg: &mut GraphletRegistry| {
        let mut counts: Vec<f64> = Vec::new();
        for g in samples {
            let idx = reg.classify(g) as usize;
            if idx >= counts.len() {
                counts.resize(idx + 1, 0.0);
            }
            counts[idx] += 1.0;
        }
        let n = samples.len().max(1) as f64;
        for c in counts.iter_mut() {
            *c /= n;
        }
        counts
    };
    let ha = hist(samples_a, &mut reg);
    let hb = hist(samples_b, &mut reg);
    let dim = ha.len().max(hb.len());
    (0..dim)
        .map(|i| {
            let a = ha.get(i).copied().unwrap_or(0.0);
            let b = hb.get(i).copied().unwrap_or(0.0);
            (a - b) * (a - b)
        })
        .sum()
}

/// The deviation bound of Theorem 1 at confidence `1 - delta`.
pub fn theorem1_bound(m: usize, s: usize, delta: f64) -> f64 {
    assert!(delta > 0.0 && delta < 1.0);
    let term_m = 4.0 / (m as f64).sqrt() * (6.0 / delta).ln().sqrt();
    let term_s = 8.0 / (s as f64).sqrt() * (1.0 + (2.0 * (3.0 / delta).ln()).sqrt());
    term_m + term_s
}

/// Biased (V-statistic) MMD^2 estimate from explicit kernel evaluations:
/// used to cross-check the embedding estimator on small cases.
pub fn mmd2_from_gram<F: Fn(usize, usize) -> f64>(na: usize, nb: usize, k_aa_ab_bb: F) -> f64 {
    // Index convention: nodes 0..na are A, na..na+nb are B.
    let mut kaa = 0.0;
    for i in 0..na {
        for j in 0..na {
            kaa += k_aa_ab_bb(i, j);
        }
    }
    let mut kbb = 0.0;
    for i in 0..nb {
        for j in 0..nb {
            kbb += k_aa_ab_bb(na + i, na + j);
        }
    }
    let mut kab = 0.0;
    for i in 0..na {
        for j in 0..nb {
            kab += k_aa_ab_bb(i, na + j);
        }
    }
    kaa / (na * na) as f64 + kbb / (nb * nb) as f64 - 2.0 * kab / (na * nb) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{check, Rng};

    fn random_graphlet(rng: &mut Rng, k: usize) -> Graphlet {
        let n_pairs = k * (k - 1) / 2;
        Graphlet::from_bits(k, (rng.next_u64() & ((1u64 << n_pairs) - 1)) as u32)
    }

    #[test]
    fn sq_distance_basics() {
        assert_eq!(embedding_sq_distance(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(embedding_sq_distance(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn match_mmd_zero_for_identical_distributions() {
        let mut rng = Rng::new(1);
        let samples: Vec<Graphlet> = (0..200).map(|_| random_graphlet(&mut rng, 4)).collect();
        let d = match_kernel_mmd2(&samples, &samples.clone());
        assert!(d.abs() < 1e-12, "{d}");
    }

    #[test]
    fn match_mmd_positive_for_different_distributions() {
        // A: empty graphlets only; B: complete graphlets only.
        let a: Vec<Graphlet> = (0..50).map(|_| Graphlet::empty(4)).collect();
        let b: Vec<Graphlet> = (0..50).map(|_| Graphlet::from_bits(4, 0b111111)).collect();
        let d = match_kernel_mmd2(&a, &b);
        assert!((d - 2.0).abs() < 1e-12, "disjoint histograms: {d}");
    }

    #[test]
    fn match_mmd_invariant_to_relabelling() {
        check::check("mmd-relabel", 0x101, 50, |rng| {
            let k = 3 + rng.usize(3);
            let a: Vec<Graphlet> = (0..40).map(|_| random_graphlet(rng, k)).collect();
            let b: Vec<Graphlet> = a
                .iter()
                .map(|g| {
                    let mut perm: Vec<usize> = (0..k).collect();
                    rng.shuffle(&mut perm);
                    g.permute(&perm)
                })
                .collect();
            // Same multiset up to isomorphism -> MMD = 0.
            let d = match_kernel_mmd2(&a, &b);
            assert!(d.abs() < 1e-12, "{d}");
        });
    }

    #[test]
    fn theorem1_bound_shrinks_with_m_and_s() {
        let b = theorem1_bound(5000, 2000, 0.05);
        assert!(b < theorem1_bound(500, 2000, 0.05));
        assert!(b < theorem1_bound(5000, 200, 0.05));
        assert!(b > 0.0);
        // Bound at the paper's operating point is macroscopic but finite.
        assert!(b < 1.0, "bound={b}");
    }

    #[test]
    fn gram_mmd_agrees_with_histogram_mmd_for_match_kernel() {
        let mut rng = Rng::new(5);
        let a: Vec<Graphlet> = (0..30).map(|_| random_graphlet(&mut rng, 3)).collect();
        let b: Vec<Graphlet> = (0..20).map(|_| random_graphlet(&mut rng, 3)).collect();
        let hist_mmd = match_kernel_mmd2(&a, &b);
        let all: Vec<Graphlet> = a.iter().chain(&b).copied().collect();
        let gram_mmd = mmd2_from_gram(a.len(), b.len(), |i, j| {
            crate::iso::are_isomorphic(&all[i], &all[j]) as u8 as f64
        });
        assert!((hist_mmd - gram_mmd).abs() < 1e-9, "{hist_mmd} vs {gram_mmd}");
    }
}
