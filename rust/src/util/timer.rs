//! Timing helpers for the bench harness and pipeline metrics.

use std::time::{Duration, Instant};

use super::Rng;

/// A simple scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Online accumulator for latency statistics (count / mean / min / max /
/// percentiles from a bounded **uniform** reservoir: Vitter's algorithm
/// R driven by a seeded [`Rng`], so the sample is unbiased over the
/// whole stream yet identical across runs given the same inputs).
#[derive(Debug, Clone)]
pub struct Stats {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    reservoir: Vec<f64>,
    cap: usize,
    seen: u64,
    rng: Rng,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::new(),
            cap: 4096,
            seen: 0,
            // Fixed seed: percentiles are a deterministic function of
            // the recorded stream (and merge order), nothing else.
            rng: Rng::new(0x5EED_u64),
        }
    }

    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
        self.seen += 1;
        if self.reservoir.len() < self.cap {
            self.reservoir.push(secs);
        } else {
            // Vitter's algorithm R: the i-th value enters with
            // probability cap/i via one uniform draw over [0, i) —
            // every element of the stream ends up in the reservoir with
            // equal probability cap/seen.
            let j = self.rng.gen_range(self.seen);
            if (j as usize) < self.cap {
                self.reservoir[j as usize] = secs;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    pub fn total(&self) -> f64 {
        self.sum
    }

    /// Fold another accumulator into this one (used when merging
    /// per-shard pipeline metrics). Exact for count/sum/min/max. For
    /// the reservoir: when both sides still hold *every* value they
    /// saw and the union fits, concatenation is the exact pooled
    /// sample; otherwise each merged slot draws its source side with
    /// probability proportional to that side's stream length and picks
    /// a uniform element of that side's reservoir (with replacement —
    /// a slight approximation that, unlike a first-come top-up, cannot
    /// let one side's values dominate the pooled percentiles).
    pub fn merge(&mut self, other: &Stats) {
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        if other.reservoir.is_empty() {
            self.seen += other.seen;
            return;
        }
        let exact = self.reservoir.len() as u64 == self.seen
            && other.reservoir.len() as u64 == other.seen
            && self.reservoir.len() + other.reservoir.len() <= self.cap;
        if exact {
            self.reservoir.extend_from_slice(&other.reservoir);
            self.seen += other.seen;
            return;
        }
        let total = self.seen + other.seen;
        let k = self.cap.min(self.reservoir.len() + other.reservoir.len());
        let mut merged = Vec::with_capacity(k);
        for _ in 0..k {
            let from_self =
                !self.reservoir.is_empty() && self.rng.gen_range(total) < self.seen;
            let side = if from_self { &self.reservoir } else { &other.reservoir };
            merged.push(side[self.rng.usize(side.len())]);
        }
        self.reservoir = merged;
        self.seen = total;
    }

    /// Approximate percentile in [0, 100] from the reservoir.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut xs = self.reservoir.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx.min(xs.len() - 1)]
    }
}

impl Default for Stats {
    fn default() -> Self {
        Self::new()
    }
}

/// Measure `f` with warmup, returning per-iteration seconds (median of
/// `runs`). This is the core of the offline bench harness (no criterion).
pub fn bench<F: FnMut()>(warmup: u32, runs: u32, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..runs)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
    }

    #[test]
    fn stats_percentile_ordering() {
        let mut s = Stats::new();
        for i in 0..100 {
            s.record(i as f64);
        }
        assert!(s.percentile(10.0) <= s.percentile(50.0));
        assert!(s.percentile(50.0) <= s.percentile(90.0));
    }

    #[test]
    fn stats_merge_combines_accumulators() {
        let mut a = Stats::new();
        let mut b = Stats::new();
        for x in [1.0, 2.0] {
            a.record(x);
        }
        for x in [0.5, 4.0] {
            b.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 4.0);
        assert!((a.mean() - 1.875).abs() < 1e-12);
        assert!(a.percentile(100.0) >= 4.0 - 1e-12);
        // Merging into an empty accumulator copies the other side.
        let mut empty = Stats::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 4);
        assert_eq!(empty.min(), 0.5);
    }

    #[test]
    fn reservoir_samples_whole_stream_uniformly() {
        // 20k values through a 4096-slot reservoir: a first-`cap`
        // (or otherwise biased) sampler keeps a prefix-heavy sample;
        // algorithm R keeps ~half the slots from the upper half of the
        // stream and puts the median where the stream's median is.
        let mut s = Stats::new();
        let n = 20_000;
        for i in 0..n {
            s.record(i as f64);
        }
        let upper = s.reservoir.iter().filter(|&&x| x >= (n / 2) as f64).count();
        let frac = upper as f64 / s.reservoir.len() as f64;
        assert!((0.42..=0.58).contains(&frac), "upper-half fraction {frac}");
        let p50 = s.percentile(50.0);
        let mid = (n / 2) as f64;
        assert!((p50 - mid).abs() < 0.12 * n as f64, "p50 {p50} vs {mid}");
    }

    #[test]
    fn reservoir_is_deterministic_across_runs() {
        let feed = |s: &mut Stats| {
            for i in 0..10_000u64 {
                s.record((i as f64).sin());
            }
        };
        let (mut a, mut b) = (Stats::new(), Stats::new());
        feed(&mut a);
        feed(&mut b);
        assert_eq!(a.reservoir, b.reservoir, "same stream, same sample");
        assert_eq!(a.percentile(99.0), b.percentile(99.0));
    }

    #[test]
    fn merge_weights_sides_by_stream_length() {
        // Two saturated accumulators over disjoint ranges: the pooled
        // sample must represent both — the old first-come top-up kept
        // only `a`'s values, pinning every percentile under 10_000.
        let mut a = Stats::new();
        let mut b = Stats::new();
        for i in 0..10_000 {
            a.record(i as f64);
            b.record((100_000 + i) as f64);
        }
        a.merge(&b);
        assert_eq!(a.count(), 20_000);
        assert!(a.percentile(75.0) > 50_000.0, "p75 {}", a.percentile(75.0));
        assert!(a.percentile(25.0) < 50_000.0, "p25 {}", a.percentile(25.0));
    }

    #[test]
    fn bench_returns_positive() {
        let t = bench(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
