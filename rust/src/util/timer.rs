//! Timing helpers for the bench harness and pipeline metrics.

use std::time::{Duration, Instant};

/// A simple scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Online accumulator for latency statistics (count / mean / min / max /
/// simple percentiles from a bounded reservoir).
#[derive(Debug, Clone)]
pub struct Stats {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    reservoir: Vec<f64>,
    cap: usize,
    seen: u64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::new(),
            cap: 4096,
            seen: 0,
        }
    }

    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        self.sum += secs;
        self.min = self.min.min(secs);
        self.max = self.max.max(secs);
        self.seen += 1;
        if self.reservoir.len() < self.cap {
            self.reservoir.push(secs);
        } else {
            // Vitter's algorithm R with a cheap deterministic hash of seen.
            let mut h = self.seen.wrapping_mul(0x9E3779B97F4A7C15);
            h ^= h >> 29;
            let j = (h % self.seen) as usize;
            if j < self.cap {
                self.reservoir[j] = secs;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    pub fn total(&self) -> f64 {
        self.sum
    }

    /// Fold another accumulator into this one (used when merging
    /// per-shard pipeline metrics). Exact for count/sum/min/max; the
    /// percentile reservoir is topped up from `other` until this
    /// reservoir's capacity is reached, which keeps percentiles
    /// representative as long as shards see similar batch counts.
    pub fn merge(&mut self, other: &Stats) {
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.seen += other.seen;
        for &x in &other.reservoir {
            if self.reservoir.len() >= self.cap {
                break;
            }
            self.reservoir.push(x);
        }
    }

    /// Approximate percentile in [0, 100] from the reservoir.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut xs = self.reservoir.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx.min(xs.len() - 1)]
    }
}

impl Default for Stats {
    fn default() -> Self {
        Self::new()
    }
}

/// Measure `f` with warmup, returning per-iteration seconds (median of
/// `runs`). This is the core of the offline bench harness (no criterion).
pub fn bench<F: FnMut()>(warmup: u32, runs: u32, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..runs)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
    }

    #[test]
    fn stats_percentile_ordering() {
        let mut s = Stats::new();
        for i in 0..100 {
            s.record(i as f64);
        }
        assert!(s.percentile(10.0) <= s.percentile(50.0));
        assert!(s.percentile(50.0) <= s.percentile(90.0));
    }

    #[test]
    fn stats_merge_combines_accumulators() {
        let mut a = Stats::new();
        let mut b = Stats::new();
        for x in [1.0, 2.0] {
            a.record(x);
        }
        for x in [0.5, 4.0] {
            b.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 4.0);
        assert!((a.mean() - 1.875).abs() < 1e-12);
        assert!(a.percentile(100.0) >= 4.0 - 1e-12);
        // Merging into an empty accumulator copies the other side.
        let mut empty = Stats::new();
        empty.merge(&a);
        assert_eq!(empty.count(), 4);
        assert_eq!(empty.min(), 0.5);
    }

    #[test]
    fn bench_returns_positive() {
        let t = bench(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
