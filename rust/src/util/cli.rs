//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and positional
//! arguments. Typed getters parse on access and produce readable errors.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        self.try_parse(name)
            .unwrap_or_else(|e| panic!("{e}"))
            .unwrap_or(default)
    }

    /// Typed getter that surfaces parse failures as `Err` instead of
    /// panicking, for callers that want graceful CLI errors (`Ok(None)`
    /// when the flag is absent).
    pub fn try_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse()
                .map(Some)
                .map_err(|e| format!("--{name} {raw:?}: {e}")),
        }
    }

    /// Comma-separated list of T, e.g. `--ms 500,1000,5000`.
    pub fn parse_list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default.to_vec(),
            Some(raw) => raw
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse()
                        .unwrap_or_else(|e| panic!("--{name} item {s:?}: {e}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--k", "6", "--m=5000"]);
        assert_eq!(a.parse_or("k", 0usize), 6);
        assert_eq!(a.parse_or("m", 0usize), 5000);
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["quickstart", "--verbose", "--seed", "3"]);
        assert_eq!(a.positional(), &["quickstart".to_string()]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.parse_or("seed", 0u64), 3);
    }

    #[test]
    fn defaults_used_when_missing() {
        let a = parse(&[]);
        assert_eq!(a.parse_or("s", 2000usize), 2000);
        assert_eq!(a.str_or("dataset", "sbm"), "sbm");
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--ms", "100,500,1000"]);
        assert_eq!(a.parse_list("ms", &[5000usize]), vec![100, 500, 1000]);
        assert_eq!(a.parse_list("ks", &[6usize]), vec![6]);
    }

    #[test]
    fn try_parse_reports_errors_gracefully() {
        let a = parse(&["--shards", "4", "--k", "banana"]);
        assert_eq!(a.try_parse::<usize>("shards").unwrap(), Some(4));
        assert_eq!(a.try_parse::<usize>("absent").unwrap(), None);
        let err = a.try_parse::<usize>("k").unwrap_err();
        assert!(err.contains("--k") && err.contains("banana"), "{err}");
    }

    /// Kebab-case option names with numeric values — the
    /// `--fwht-threads 4` shape the engine knobs use — parse in both
    /// the spaced and `=` styles, and absence falls back to defaults.
    #[test]
    fn kebab_case_numeric_options() {
        let a = parse(&["serve", "--fwht-threads", "4", "--cache-cap=512"]);
        assert_eq!(a.try_parse::<usize>("fwht-threads").unwrap(), Some(4));
        assert_eq!(a.parse_or("cache-cap", 0usize), 512);
        assert_eq!(a.try_parse::<usize>("max-nodes").unwrap(), None);
        let b = parse(&[]);
        assert_eq!(b.parse_or("fwht-threads", 1usize), 1);
    }

    #[test]
    fn flag_followed_by_flag_is_flag() {
        let a = parse(&["--fast", "--k", "7"]);
        assert!(a.flag("fast"));
        assert_eq!(a.parse_or("k", 0usize), 7);
    }
}
