//! FNV-1a 64-bit mixing — the one definition shared by
//! [`crate::graph::canonical_hash`] and the serve cache's config
//! fingerprint, so the two halves of a cache key can never drift onto
//! different hash constants.

/// The FNV-1a 64-bit offset basis.
pub const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

const PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into the running hash `h`.
pub fn mix_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Fold one little-endian u64 into the running hash.
pub fn mix_u64(h: u64, x: u64) -> u64 {
    mix_bytes(h, &x.to_le_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let a = mix_u64(mix_u64(OFFSET, 1), 2);
        let b = mix_u64(mix_u64(OFFSET, 1), 2);
        let c = mix_u64(mix_u64(OFFSET, 2), 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(mix_bytes(OFFSET, &1u64.to_le_bytes()), mix_u64(OFFSET, 1));
    }
}
