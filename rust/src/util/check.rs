//! Minimal property-testing harness (the offline environment has no
//! `proptest`). A property is a closure over a seeded [`Rng`]; `check`
//! runs it for `cases` random seeds and reports the failing seed so a
//! failure is reproducible with `check_one`.
//!
//! No shrinking: properties here are over small structured inputs
//! (graphlets, small matrices) where the failing seed is directly
//! debuggable. Used by the property tests across all rust modules.

use super::rng::Rng;

/// Run `prop` for `cases` deterministic seeds derived from `base_seed`.
/// Panics with the failing seed embedded in the message.
pub fn check<F: FnMut(&mut Rng)>(name: &str, base_seed: u64, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn check_one<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "mismatch at {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counting", 1, 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn check_reports_failing_seed() {
        check("fails", 2, 10, |rng| {
            assert!(rng.f64() < 0.5, "too big");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_rejects_distant() {
        assert_allclose(&[1.0], &[2.0], 1e-3, 1e-3);
    }
}
