//! Shared infrastructure: PRNG, property-test harness, CLI parsing,
//! timing/stats, and a tiny JSON writer. All hand-rolled: the offline
//! build environment only ships the `xla` crate's dependency closure
//! (DESIGN.md §6), so `rand` / `clap` / `proptest` / `serde` are replaced
//! by these modules.

pub mod check;
pub mod cli;
pub mod fnv;
pub mod json;
pub mod rng;
pub mod timer;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
pub use timer::{bench, Stats, Timer};
