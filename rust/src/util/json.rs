//! Tiny JSON writer *and parser* (no serde offline).
//!
//! The writer covers what the bench/figure harnesses need: objects,
//! arrays, numbers, strings, booleans; output is deterministic
//! (insertion order preserved). The parser was added for the serve
//! subsystem's line-delimited request protocol: a recursive-descent
//! reader with a nesting-depth limit (malformed or adversarial input
//! must error, never crash the daemon). Numbers are modelled as `f64`
//! on both sides, so writer output round-trips through the parser
//! exactly (Rust's shortest-round-trip float formatting).

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a key into an object (panics on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kvs) => kvs.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Push a value into an array (panics on non-arrays).
    pub fn push(&mut self, val: impl Into<Json>) {
        match self {
            Json::Arr(xs) => xs.push(val.into()),
            _ => panic!("push() on non-array"),
        }
    }

    /// Parse a JSON document. Lenient where it is harmless (number
    /// syntax is whatever `f64::from_str` accepts), strict where it
    /// protects the serve daemon: depth-limited nesting, rejected lone
    /// surrogates, no trailing garbage.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor: finite, integral, in-range numbers only. (Both
    /// sides model numbers as `f64`, so values beyond 2^53 would lose
    /// precision in transit anyway — protocol ids/sizes stay far below.)
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x)
                if x.is_finite() && *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) =>
            {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Maximum container nesting the parser accepts — recursive descent must
/// not let a hostile request line overflow the daemon's stack.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            None => Err("unexpected end of input".to_string()),
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected byte {:?} at {}", c as char, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii number bytes");
        match text.parse::<f64>() {
            Ok(x) if x.is_finite() => Ok(Json::Num(x)),
            _ => Err(format!("invalid number {text:?} at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.pos += 1; // opening quote
        let mut out = String::new();
        loop {
            let start = self.pos;
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            // Boundaries are ASCII bytes, so the slice stays valid UTF-8.
            out.push_str(
                std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| "invalid utf-8")?,
            );
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(c) if c < 0x20 => {
                    return Err("raw control character in string".to_string());
                }
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(_) => {
                    // Backslash escape.
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                if self.b.get(self.pos) == Some(&b'\\')
                                    && self.b.get(self.pos + 1) == Some(&b'u')
                                {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err("invalid low surrogate".to_string());
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err("lone surrogate".to_string());
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err("lone surrogate".to_string());
                            } else {
                                hi
                            };
                            out.push(char::from_u32(cp).ok_or("invalid codepoint")?);
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let bytes = self
            .b
            .get(self.pos..self.pos + 4)
            .ok_or("truncated \\u escape")?;
        let text = std::str::from_utf8(bytes).map_err(|_| "bad \\u escape")?;
        let v = u32::from_str_radix(text, 16).map_err(|_| "bad \\u escape")?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.pos += 1; // '['
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.pos += 1; // '{'
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(format!("expected object key at byte {}", self.pos));
            }
            let k = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(format!("expected ':' at byte {}", self.pos));
            }
            self.pos += 1;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_roundtrip_shape() {
        let j = Json::obj()
            .set("name", "fig1")
            .set("k", 6usize)
            .set("acc", 0.93f64)
            .set("ok", true)
            .set("series", vec![1.0f64, 2.0, 3.5]);
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig1","k":6,"acc":0.93,"ok":true,"series":[1,2,3.5]}"#
        );
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .set("op", "embed")
            .set("id", 3usize)
            .set("x", 0.25f64)
            .set("neg", -1.5f64)
            .set("flag", true)
            .set("none", Json::Null)
            .set("edges", vec![vec![0.0f64, 1.0], vec![1.0, 2.0]]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.to_string(), text);
        assert_eq!(back.get("op").and_then(Json::as_str), Some("embed"));
        assert_eq!(back.get("id").and_then(Json::as_u64), Some(3));
        assert_eq!(back.get("x").and_then(Json::as_f64), Some(0.25));
        assert_eq!(back.get("flag").and_then(Json::as_bool), Some(true));
        let edges = back.get("edges").and_then(Json::as_array).unwrap();
        assert_eq!(edges.len(), 2);
        assert_eq!(edges[1].as_array().unwrap()[1].as_usize(), Some(2));
    }

    #[test]
    fn parse_floats_roundtrip_f32_exactly() {
        // The serve protocol ships f32 embeddings as JSON numbers; the
        // f32 -> f64 -> shortest-display -> parse -> f32 cycle must be
        // the identity (bitwise) for the integration tests to pin
        // server output against embed_dataset.
        let mut rng = crate::util::Rng::new(77);
        for _ in 0..500 {
            let x = (rng.f32() - 0.5) * 1e3;
            let text = Json::Num(x as f64).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), x.to_bits(), "{x} via {text}");
        }
    }

    #[test]
    fn parse_string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndAé😀""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA\u{e9}\u{1f600}"));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\":}",
            "{\"a\" 1}",
            "{a:1}",
            "tru",
            "1.2.3",
            "\"unterminated",
            "\"lone \\ud800 surrogate\"",
            "\"bad \\x escape\"",
            "[1] trailing",
            "nullx",
            "--3",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_depth_limited() {
        let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        let ok = format!("{}1{}", "[".repeat(20), "]".repeat(20));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn accessors_are_type_strict() {
        let j = Json::parse(r#"{"n":1.5,"s":"x","i":-2}"#).unwrap();
        assert_eq!(j.get("n").and_then(Json::as_u64), None, "non-integer");
        assert_eq!(j.get("i").and_then(Json::as_u64), None, "negative");
        assert_eq!(j.get("s").and_then(Json::as_f64), None);
        assert!(j.get("missing").is_none());
        assert_eq!(j.as_str(), None, "object is not a string");
    }
}
