//! Tiny JSON *writer* for experiment result files (no serde offline).
//!
//! Only what the bench/figure harnesses need: objects, arrays, numbers,
//! strings, booleans. Output is deterministic (insertion order preserved).

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a key into an object (panics on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kvs) => kvs.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Push a value into an array (panics on non-arrays).
    pub fn push(&mut self, val: impl Into<Json>) {
        match self {
            Json::Arr(xs) => xs.push(val.into()),
            _ => panic!("push() on non-array"),
        }
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_roundtrip_shape() {
        let j = Json::obj()
            .set("name", "fig1")
            .set("k", 6usize)
            .set("acc", 0.93f64)
            .set("ok", true)
            .set("series", vec![1.0f64, 2.0, 3.5]);
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig1","k":6,"acc":0.93,"ok":true,"series":[1,2,3.5]}"#
        );
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
