//! Seeded PRNG: xoshiro256++ with splitmix64 seeding.
//!
//! The offline environment ships no `rand` crate, so this is the project's
//! single randomness source. Deterministic across runs for a fixed seed;
//! `fork` derives independent streams for worker threads so multi-threaded
//! pipelines stay reproducible regardless of scheduling.

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; more than
/// adequate for sampling subgraphs and random-feature matrices.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for worker `idx`) from this one.
    /// Uses a distinct splitmix64 seeding of (next_u64, idx), so forked
    /// streams do not overlap with the parent in practice.
    pub fn fork(&mut self, idx: u64) -> Rng {
        let base = self.next_u64() ^ idx.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(base)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift with rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill `out` with iid N(0, sigma^2) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32() * sigma;
        }
    }

    /// Fill `out` with iid U[lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    /// Floyd's algorithm: O(k) expected, no O(n) allocation.
    pub fn sample_distinct(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        debug_assert!(k <= n);
        out.clear();
        for j in (n - k)..n {
            let t = self.usize(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
        // Floyd yields a uniform subset but a biased order; shuffle.
        self.shuffle(out);
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }

    /// Draw a block of `n` sequential 64-bit seeds from this stream —
    /// the pipeline's per-graph seed table. Equivalent to `n` calls to
    /// [`Rng::next_u64`]; the block is a pure function of (seed state,
    /// n), which is the determinism contract the sharded coordinator
    /// relies on: per-graph streams never depend on worker or shard
    /// counts.
    pub fn seed_stream(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_u64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_complete() {
        let mut r = Rng::new(13);
        let mut out = Vec::new();
        for _ in 0..200 {
            r.sample_distinct(20, 6, &mut out);
            assert_eq!(out.len(), 6);
            let mut sorted = out.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 6);
            assert!(sorted.iter().all(|&i| i < 20));
        }
        // k == n returns a permutation
        r.sample_distinct(5, 5, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sample_distinct_uniformity() {
        // Each element of [0,6) should appear in a 3-subset w.p. 1/2.
        let mut r = Rng::new(17);
        let mut counts = [0u32; 6];
        let trials = 30_000;
        let mut out = Vec::new();
        for _ in 0..trials {
            r.sample_distinct(6, 3, &mut out);
            for &i in &out {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let p = c as f64 / trials as f64;
            assert!((p - 0.5).abs() < 0.02, "p={p}");
        }
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn seed_stream_matches_sequential_draws() {
        let mut a = Rng::new(31);
        let mut b = Rng::new(31);
        let block = a.seed_stream(16);
        let manual: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_eq!(block, manual);
        // The generator advances: the next draw differs from the block.
        assert_ne!(a.next_u64(), block[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
