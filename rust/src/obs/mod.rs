//! Daemon-side observability: latency histograms + per-request span
//! tracing, zero dependencies. The counters in `stats` say *what*
//! happened; this module says *where the time went* — the substrate
//! every perf PR (mmap L2, replication, accelerator SORF) reports
//! against.
//!
//! Three parts:
//! - [`metrics`]: a [`Registry`] of atomic [`Counter`]s, [`Gauge`]s,
//!   and log₂-bucketed [`Histo`]grams (µs values, fixed power-of-two
//!   boundaries, deterministic bucket-derived p50/p90/p99). Registries
//!   are **instance-scoped** — every serve daemon owns one, threaded
//!   through its pipeline/cache/store/ANN/span-ring — with
//!   [`global()`] as the batch-CLI default. Snapshot served whole by
//!   the `metrics` serve op.
//! - [`trace`]: a [`TraceCtx`] handle carried along each request's
//!   dataflow, stamping named stages; finished spans land in a bounded
//!   [`SpanRing`] served by the `trace` op, and spans slower than
//!   `--slow-ms` also emit one structured JSON line to stderr.
//! - [`prom`]: renders a registry snapshot in Prometheus text format
//!   v0.0.4 for the daemon's HTTP `/metrics` endpoint
//!   (`crate::serve::http`), so standard tooling can scrape what the
//!   bespoke TCP `metrics` op serves.
//! - [`profile`]: an always-on sampling profiler. Every long-lived
//!   daemon thread registers itself (role label + a lock-free
//!   *current stage* slot reusing the stamp vocabulary below) on the
//!   registry's [`profile::ThreadRegistry`]; a sampler thread
//!   (`--profile-hz`, default on at a low rate, 0 = off) walks the
//!   registry each tick, reads each thread's CPU clock
//!   (`pthread_getcpuclockid` + `clock_gettime` via a hand-rolled
//!   shim, gated like `store::mmap`, wall-clock fallback elsewhere),
//!   and aggregates `(role, stage) → {samples, cpu_delta_us}`. Served
//!   by the `profile` op, the `/profile` collapsed-stack endpoint
//!   (flamegraph-ready `role;stage N` lines), and `/debug/threads`.
//!   Wall histograms say how long a stage took; the profile says
//!   whether the thread was *on CPU* for it — a compute-bound shard
//!   and a descheduled one finally look different. The sampler tick
//!   also refreshes process self-metrics (`proc.*` below) parsed from
//!   `/proc/self/{statm,status,fd}`.
//!
//! ## Request lifecycle and its stage stamps
//!
//! ```text
//!  client line ──► handle_request            TraceCtx::new(op, id)
//!                    │  cache probe          stamp "cache_probe"   + cache.probe_us
//!                    │    (L1 miss, L2 hit)                          cache.l2_read_us
//!                    │    (nearest: index)   stamp "ann_search"    + ann.probe_us
//!                    ▼  miss → submit        stamp "admission"
//!              ┌─ JobQueue ─┐                                        pipeline.queue_depth
//!              │  worker claims job          stamp "queue_wait"    + pipeline.queue_wait_us
//!              │  pack rows → shard channel                          shard.batch_wait_us
//!              │  shard executes batch       stamp "projection"    + shard.projection_us
//!              └─ row streams back ─┘
//!                    │  write-through L2                             store.append_us
//!                    ▼                                               (store.compact_us)
//!                 writer_loop                stamp "reply_write"   + serve.request_us.<op>
//!                    │  reply flushed to client
//!                    ▼
//!              last TraceCtx handle drops ──► span deposits into SpanRing
//!                                             (≥ --slow-ms → 1 stderr JSON line)
//! ```
//!
//! `embed_dataset` jobs get the same treatment with op `embed_dataset`
//! (admission → queue_wait → projection), so batch experiments and the
//! serve path share one vocabulary.
//!
//! ## Metric catalog
//!
//! The Prometheus name is what `/metrics` exposes: dots become
//! underscores and the dynamic `<op>` suffix is promoted into an
//! `op` label (histograms additionally fan out into
//! `_bucket`/`_sum`/`_count` series). Keep this table and the HELP
//! catalog in [`prom`] in sync.
//!
//! | name | Prometheus name | kind | recorded by |
//! |---|---|---|---|
//! | `serve.request_us.<op>` | `serve_request_us{op=…}` | histo | writer_loop / direct reply, before the bytes flush |
//! | `serve.errors.<op>` | `serve_errors{op=…}` | counter | every per-request error reply |
//! | `pipeline.queue_wait_us` | `pipeline_queue_wait_us` | histo | worker claiming a job off the queue |
//! | `shard.batch_wait_us` | `shard_batch_wait_us` | histo | shard receiving a packed batch (time in channel) |
//! | `shard.projection_us` | `shard_projection_us` | histo | shard executing one batch (any engine, incl. FWHT) |
//! | `cache.probe_us` | `cache_probe_us` | histo | `TieredCache::get`, full L1+L2 probe |
//! | `cache.l2_read_us` | `cache_l2_read_us` | histo | the store read inside an L1-miss probe |
//! | `store.append_us` | `store_append_us` | histo | `EmbeddingStore::put` |
//! | `store.compact_us` | `store_compact_us` | histo | `EmbeddingStore::compact` |
//! | `store.mmap_segments` | `store_mmap_segments` | gauge | sealed segments currently mapped (set on seal/compact) |
//! | `store.mmap_bytes` | `store_mmap_bytes` | gauge | bytes of sealed data currently mapped |
//! | `store.mmap_reads` | `store_mmap_reads` | counter | every zero-copy row read off a mapped segment |
//! | `ann.build_us` | `ann_build_us` | histo | IVFFlat index (re)build |
//! | `ann.probe_us` | `ann_probe_us` | histo | `nearest` query against index + pending tail |
//! | `serve.slow_spans` | `serve_slow_spans` | counter | every slow-span stderr line |
//! | `profile.samples` | `profile_samples` | counter | sampler tick, one per live registered thread seen |
//! | `shard.busy_permille.<i>` | `shard_busy_permille{shard=…}` | gauge | sampler tick: shard i's CPU µs / wall µs since registration, ×1000 |
//! | `proc.rss_bytes` | `proc_rss_bytes` | gauge | sampler tick (and `stats` on demand) from `/proc/self/statm` |
//! | `proc.threads` | `proc_threads` | gauge | sampler tick (and `stats` on demand) from `/proc/self/status` |
//! | `proc.open_fds` | `proc_open_fds` | gauge | sampler tick (and `stats` on demand) from `/proc/self/fd` |
//!
//! `/metrics` also serves a `graphlet_rf_build_info{engine,config_fp,version} 1`
//! info gauge keyed to the daemon's identity.
//!
//! Recording is relaxed-atomic and observation-only — no RNG draws, no
//! row arithmetic — so tracing on vs off cannot change embeddings, and
//! neither can the sampler at full rate (both bitwise-pinned by
//! `tests/obs.rs`; stage publication is two relaxed atomic stores per
//! transition, and the sampler only ever *reads* thread state). Registries are instance-scoped:
//! each in-process daemon reports only its own traffic, so tests
//! assert **absolute** values on a daemon's registry directly — no
//! before/after delta-diffing.

pub mod metrics;
pub mod profile;
pub mod prom;
pub mod trace;

pub use metrics::{global, global_arc, Counter, Gauge, Histo, HistoSnapshot, MetricValue, Registry};
pub use profile::{cpu_clock_supported, Profiler, ThreadGuard, ThreadRegistry, STAGES};
pub use prom::BuildInfo;
pub use trace::{global_ring, SpanRecord, SpanRing, TraceCtx};
