//! Daemon-side observability: latency histograms + per-request span
//! tracing, zero dependencies. The counters in `stats` say *what*
//! happened; this module says *where the time went* — the substrate
//! every perf PR (mmap L2, replication, accelerator SORF) reports
//! against.
//!
//! Three parts:
//! - [`metrics`]: a [`Registry`] of atomic [`Counter`]s, [`Gauge`]s,
//!   and log₂-bucketed [`Histo`]grams (µs values, fixed power-of-two
//!   boundaries, deterministic bucket-derived p50/p90/p99). Registries
//!   are **instance-scoped** — every serve daemon owns one, threaded
//!   through its pipeline/cache/store/ANN/span-ring — with
//!   [`global()`] as the batch-CLI default. Snapshot served whole by
//!   the `metrics` serve op.
//! - [`trace`]: a [`TraceCtx`] handle carried along each request's
//!   dataflow, stamping named stages; finished spans land in a bounded
//!   [`SpanRing`] served by the `trace` op, and spans slower than
//!   `--slow-ms` also emit one structured JSON line to stderr.
//! - [`prom`]: renders a registry snapshot in Prometheus text format
//!   v0.0.4 for the daemon's HTTP `/metrics` endpoint
//!   (`crate::serve::http`), so standard tooling can scrape what the
//!   bespoke TCP `metrics` op serves.
//!
//! ## Request lifecycle and its stage stamps
//!
//! ```text
//!  client line ──► handle_request            TraceCtx::new(op, id)
//!                    │  cache probe          stamp "cache_probe"   + cache.probe_us
//!                    │    (L1 miss, L2 hit)                          cache.l2_read_us
//!                    │    (nearest: index)   stamp "ann_search"    + ann.probe_us
//!                    ▼  miss → submit        stamp "admission"
//!              ┌─ JobQueue ─┐                                        pipeline.queue_depth
//!              │  worker claims job          stamp "queue_wait"    + pipeline.queue_wait_us
//!              │  pack rows → shard channel                          shard.batch_wait_us
//!              │  shard executes batch       stamp "projection"    + shard.projection_us
//!              └─ row streams back ─┘
//!                    │  write-through L2                             store.append_us
//!                    ▼                                               (store.compact_us)
//!                 writer_loop                stamp "reply_write"   + serve.request_us.<op>
//!                    │  reply flushed to client
//!                    ▼
//!              last TraceCtx handle drops ──► span deposits into SpanRing
//!                                             (≥ --slow-ms → 1 stderr JSON line)
//! ```
//!
//! `embed_dataset` jobs get the same treatment with op `embed_dataset`
//! (admission → queue_wait → projection), so batch experiments and the
//! serve path share one vocabulary.
//!
//! ## Metric catalog
//!
//! The Prometheus name is what `/metrics` exposes: dots become
//! underscores and the dynamic `<op>` suffix is promoted into an
//! `op` label (histograms additionally fan out into
//! `_bucket`/`_sum`/`_count` series). Keep this table and the HELP
//! catalog in [`prom`] in sync.
//!
//! | name | Prometheus name | kind | recorded by |
//! |---|---|---|---|
//! | `serve.request_us.<op>` | `serve_request_us{op=…}` | histo | writer_loop / direct reply, before the bytes flush |
//! | `serve.errors.<op>` | `serve_errors{op=…}` | counter | every per-request error reply |
//! | `pipeline.queue_wait_us` | `pipeline_queue_wait_us` | histo | worker claiming a job off the queue |
//! | `shard.batch_wait_us` | `shard_batch_wait_us` | histo | shard receiving a packed batch (time in channel) |
//! | `shard.projection_us` | `shard_projection_us` | histo | shard executing one batch (any engine, incl. FWHT) |
//! | `cache.probe_us` | `cache_probe_us` | histo | `TieredCache::get`, full L1+L2 probe |
//! | `cache.l2_read_us` | `cache_l2_read_us` | histo | the store read inside an L1-miss probe |
//! | `store.append_us` | `store_append_us` | histo | `EmbeddingStore::put` |
//! | `store.compact_us` | `store_compact_us` | histo | `EmbeddingStore::compact` |
//! | `store.mmap_segments` | `store_mmap_segments` | gauge | sealed segments currently mapped (set on seal/compact) |
//! | `store.mmap_bytes` | `store_mmap_bytes` | gauge | bytes of sealed data currently mapped |
//! | `store.mmap_reads` | `store_mmap_reads` | counter | every zero-copy row read off a mapped segment |
//! | `ann.build_us` | `ann_build_us` | histo | IVFFlat index (re)build |
//! | `ann.probe_us` | `ann_probe_us` | histo | `nearest` query against index + pending tail |
//! | `serve.slow_spans` | `serve_slow_spans` | counter | every slow-span stderr line |
//!
//! `/metrics` also serves a `graphlet_rf_build_info{engine,config_fp,version} 1`
//! info gauge keyed to the daemon's identity.
//!
//! Recording is relaxed-atomic and observation-only — no RNG draws, no
//! row arithmetic — so tracing on vs off cannot change embeddings
//! (bitwise-pinned by `tests/obs.rs`). Registries are instance-scoped:
//! each in-process daemon reports only its own traffic, so tests
//! assert **absolute** values on a daemon's registry directly — no
//! before/after delta-diffing.

pub mod metrics;
pub mod prom;
pub mod trace;

pub use metrics::{global, global_arc, Counter, Gauge, Histo, HistoSnapshot, MetricValue, Registry};
pub use prom::BuildInfo;
pub use trace::{global_ring, SpanRecord, SpanRing, TraceCtx};
