//! Lightweight per-request span tracing.
//!
//! A [`TraceCtx`] is a cheap clonable handle (one `Arc`) created at
//! request admission and carried along the request's dataflow — through
//! the job queue, the shard executors, the cache tiers — each layer
//! calling [`stamp`](TraceCtx::stamp) to record "stage X finished at
//! +N µs". Stamping is pure observation: it reads a clock and pushes
//! into a `Mutex<Vec>` on the span, it never touches RNG state or row
//! math, which is what makes tracing-on vs tracing-off bitwise
//! invisible to embeddings (pinned by `tests/obs.rs`).
//!
//! When the **last** handle drops (reply written, job drained — however
//! the request ends, including error paths), the finished span deposits
//! itself into the [`SpanRing`] exactly once — `Drop` on the inner
//! state is the uniqueness proof, there is no "finish" call to forget
//! or double-invoke. The ring keeps the most recent `cap` spans plus a
//! separate bounded list of *slow* spans (total ≥ the `--slow-ms`
//! threshold); each slow span is also logged as a single structured
//! JSON line to stderr at deposit time.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::Json;

/// Stages recorded per span can't grow without bound (a job split
/// across many batches stamps "projection" once per batch).
const MAX_STAGES: usize = 64;
/// Bound on the separate slow-span list.
const SLOW_CAP: usize = 64;

/// One finished span: where a request's time went, stage by stage.
/// `stages` are `(name, offset_us)` pairs in stamp order — offsets are
/// measured from span start, so stage *durations* are adjacent
/// differences.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Ring-scoped monotonically increasing id (starts at 1), assigned
    /// at span *open*. It appears in the slow-span stderr line and the
    /// `trace` op output, and `trace` can fetch a span by it — so a
    /// slow request lines up against the profile window containing it.
    pub span_id: u64,
    pub op: String,
    pub tag: u64,
    pub total_us: u64,
    pub stages: Vec<(&'static str, u64)>,
}

impl SpanRecord {
    /// The JSON shape used both by the `trace` serve op and the
    /// slow-span stderr line.
    pub fn to_json(&self) -> Json {
        let mut stages = Json::obj();
        for &(name, us) in &self.stages {
            stages = stages.set(name, us);
        }
        Json::obj()
            .set("span_id", self.span_id)
            .set("op", self.op.as_str())
            .set("tag", self.tag)
            .set("total_us", self.total_us)
            .set("stages", stages)
    }
}

struct SpanInner {
    span_id: u64,
    op: String,
    tag: u64,
    start: Instant,
    stages: Mutex<Vec<(&'static str, u64)>>,
    ring: Arc<SpanRing>,
}

impl Drop for SpanInner {
    fn drop(&mut self) {
        // Last handle gone -> the span is complete. `&mut self` means
        // no other stamper exists; `get_mut` skips the lock (and a
        // poisoned mutex just means a stamper panicked — the stamps it
        // did land are still worth depositing).
        let stages = std::mem::take(
            self.stages.get_mut().unwrap_or_else(|e| e.into_inner()),
        );
        let rec = SpanRecord {
            span_id: self.span_id,
            op: std::mem::take(&mut self.op),
            tag: self.tag,
            total_us: self.start.elapsed().as_micros().min(u64::MAX as u128) as u64,
            stages,
        };
        self.ring.deposit(rec);
    }
}

/// A clonable handle on one in-flight span. Dropping the last clone
/// finishes the span and deposits it into the ring.
#[derive(Clone)]
pub struct TraceCtx {
    inner: Arc<SpanInner>,
}

impl std::fmt::Debug for TraceCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceCtx")
            .field("op", &self.inner.op)
            .field("tag", &self.inner.tag)
            .finish()
    }
}

impl TraceCtx {
    /// Open a span. `op` names the request kind (`embed`, `nearest`,
    /// `embed_dataset`); `tag` disambiguates (request id / graph index).
    pub fn new(op: &str, tag: u64, ring: Arc<SpanRing>) -> TraceCtx {
        let span_id = ring.next_span_id.fetch_add(1, Ordering::Relaxed);
        TraceCtx {
            inner: Arc::new(SpanInner {
                span_id,
                op: op.to_string(),
                tag,
                start: Instant::now(),
                stages: Mutex::new(Vec::new()),
                ring,
            }),
        }
    }

    /// This span's ring-scoped id (see [`SpanRecord::span_id`]).
    pub fn span_id(&self) -> u64 {
        self.inner.span_id
    }

    /// Record "stage `name` done at +elapsed µs". Stamps past
    /// [`MAX_STAGES`] are dropped (bounded memory per span).
    pub fn stamp(&self, name: &'static str) {
        let us = self.inner.start.elapsed().as_micros().min(u64::MAX as u128) as u64;
        if let Ok(mut stages) = self.inner.stages.lock() {
            if stages.len() < MAX_STAGES {
                stages.push((name, us));
            }
        }
    }

    /// Elapsed µs since the span opened (what `total_us` would be now).
    pub fn elapsed_us(&self) -> u64 {
        self.inner.start.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// The op the span was opened with (`embed`, `nearest`, …) — the
    /// writer uses it to pick the `serve.request_us.<op>` histogram.
    pub fn op(&self) -> &str {
        &self.inner.op
    }
}

/// Bounded ring of recently finished spans + bounded list of slow ones.
/// Lock-protected (deposits are one small `VecDeque` push at request
/// completion — far off any per-row hot path).
pub struct SpanRing {
    cap: usize,
    slow_threshold_us: u64,
    recent: Mutex<VecDeque<SpanRecord>>,
    slow: Mutex<VecDeque<SpanRecord>>,
    slow_emitted: AtomicU64,
    /// Next [`SpanRecord::span_id`] to hand out (ids start at 1, so 0
    /// is never a valid id and reads as "no span" in client tooling).
    next_span_id: AtomicU64,
    /// Where `serve.slow_spans` lands: the owning daemon's registry
    /// (via [`with_registry`](Self::with_registry)), so two in-process
    /// daemons never cross-contaminate each other's slow-span counts.
    registry: Arc<super::metrics::Registry>,
}

impl SpanRing {
    /// `slow_ms = u64::MAX` disables slow-span capture entirely;
    /// `slow_ms = 0` (the test axis) marks *every* span slow. Slow-span
    /// counting lands in the process-global registry — daemons use
    /// [`with_registry`](Self::with_registry) instead.
    pub fn new(cap: usize, slow_ms: u64) -> Arc<SpanRing> {
        SpanRing::with_registry(cap, slow_ms, super::metrics::global_arc())
    }

    /// Like [`new`](Self::new), but `serve.slow_spans` increments in the
    /// given instance-scoped registry.
    pub fn with_registry(
        cap: usize,
        slow_ms: u64,
        registry: Arc<super::metrics::Registry>,
    ) -> Arc<SpanRing> {
        Arc::new(SpanRing {
            cap: cap.max(1),
            slow_threshold_us: slow_ms.saturating_mul(1000),
            recent: Mutex::new(VecDeque::new()),
            slow: Mutex::new(VecDeque::new()),
            slow_emitted: AtomicU64::new(0),
            next_span_id: AtomicU64::new(1),
            registry,
        })
    }

    fn deposit(&self, rec: SpanRecord) {
        if rec.total_us >= self.slow_threshold_us {
            // Exactly one structured line per slow span: deposit runs
            // once per span (Drop), and this is its only emission site.
            eprintln!("{}", Json::obj().set("slow_span", rec.to_json()));
            self.slow_emitted.fetch_add(1, Ordering::Relaxed);
            self.registry.counter("serve.slow_spans").inc();
            let mut slow = self.slow.lock().unwrap();
            if slow.len() == SLOW_CAP {
                slow.pop_front();
            }
            slow.push_back(rec.clone());
        }
        let mut recent = self.recent.lock().unwrap();
        if recent.len() == self.cap {
            recent.pop_front();
        }
        recent.push_back(rec);
    }

    /// The `n` most recent spans, oldest first.
    pub fn recent(&self, n: usize) -> Vec<SpanRecord> {
        let recent = self.recent.lock().unwrap();
        let skip = recent.len().saturating_sub(n);
        recent.iter().skip(skip).cloned().collect()
    }

    /// Captured slow spans, oldest first (bounded at [`SLOW_CAP`]).
    pub fn slow(&self) -> Vec<SpanRecord> {
        self.slow.lock().unwrap().iter().cloned().collect()
    }

    /// Total slow-span stderr lines emitted since creation (unbounded
    /// counter — unlike the bounded list above, this never forgets).
    pub fn slow_emitted(&self) -> u64 {
        self.slow_emitted.load(Ordering::Relaxed)
    }

    /// Fetch a finished span by id, searching the slow list first (slow
    /// spans outlive the recent ring's churn) and then the recent ring.
    /// `None` once the span has aged out of both bounded buffers.
    pub fn find(&self, span_id: u64) -> Option<SpanRecord> {
        if let Some(rec) =
            self.slow.lock().unwrap().iter().find(|r| r.span_id == span_id).cloned()
        {
            return Some(rec);
        }
        self.recent.lock().unwrap().iter().find(|r| r.span_id == span_id).cloned()
    }
}

/// The process-global ring for spans opened outside a serve daemon
/// (`embed_dataset` batch jobs). Slow capture is disabled here — the
/// `--slow-ms` knob belongs to the daemon, which owns its own ring.
pub fn global_ring() -> &'static Arc<SpanRing> {
    static RING: std::sync::OnceLock<Arc<SpanRing>> = std::sync::OnceLock::new();
    RING.get_or_init(|| SpanRing::new(256, u64::MAX))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_deposits_once_on_last_drop() {
        let ring = SpanRing::new(8, u64::MAX);
        let t = TraceCtx::new("embed", 7, ring.clone());
        let t2 = t.clone();
        t.stamp("admission");
        t2.stamp("queue_wait");
        drop(t);
        assert_eq!(ring.recent(8).len(), 0, "span still has a live handle");
        drop(t2);
        let spans = ring.recent(8);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].op, "embed");
        assert_eq!(spans[0].tag, 7);
        let names: Vec<_> = spans[0].stages.iter().map(|&(n, _)| n).collect();
        assert_eq!(names, ["admission", "queue_wait"]);
        assert_eq!(ring.slow_emitted(), 0, "slow capture disabled");
    }

    #[test]
    fn ring_evicts_oldest_at_capacity() {
        let ring = SpanRing::new(3, u64::MAX);
        for tag in 0..5u64 {
            drop(TraceCtx::new("embed", tag, ring.clone()));
        }
        let tags: Vec<u64> = ring.recent(10).iter().map(|s| s.tag).collect();
        assert_eq!(tags, [2, 3, 4], "oldest evicted, order preserved");
        let last: Vec<u64> = ring.recent(2).iter().map(|s| s.tag).collect();
        assert_eq!(last, [3, 4], "recent(n) returns the newest n");
    }

    #[test]
    fn slow_threshold_zero_marks_every_span() {
        let ring = SpanRing::new(4, 0);
        drop(TraceCtx::new("nearest", 1, ring.clone()));
        drop(TraceCtx::new("nearest", 2, ring.clone()));
        assert_eq!(ring.slow_emitted(), 2);
        assert_eq!(ring.slow().len(), 2);
    }

    #[test]
    fn stamps_are_bounded() {
        let ring = SpanRing::new(2, u64::MAX);
        let t = TraceCtx::new("embed", 0, ring.clone());
        for _ in 0..(MAX_STAGES + 10) {
            t.stamp("projection");
        }
        drop(t);
        assert_eq!(ring.recent(1)[0].stages.len(), MAX_STAGES);
    }

    #[test]
    fn span_json_shape() {
        let ring = SpanRing::new(2, u64::MAX);
        drop(TraceCtx::new("embed", 3, ring.clone()));
        let j = ring.recent(1)[0].to_json();
        assert_eq!(j.get("span_id").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("op").and_then(Json::as_str), Some("embed"));
        assert_eq!(j.get("tag").and_then(Json::as_u64), Some(3));
        assert!(j.get("total_us").and_then(Json::as_u64).is_some());
        assert!(j.get("stages").is_some());
    }

    #[test]
    fn span_ids_are_monotone_and_findable() {
        let ring = SpanRing::new(2, u64::MAX);
        for tag in 0..4u64 {
            let t = TraceCtx::new("embed", tag, ring.clone());
            assert_eq!(t.span_id(), tag + 1, "ids assigned at open, starting at 1");
        }
        // cap 2: spans 3 and 4 survive, 1 and 2 aged out.
        assert!(ring.find(4).is_some_and(|r| r.tag == 3));
        assert!(ring.find(3).is_some());
        assert!(ring.find(1).is_none(), "evicted span is gone");
        assert!(ring.find(0).is_none(), "0 is never a valid id");
    }

    #[test]
    fn slow_spans_stay_findable_past_recent_churn() {
        let ring = SpanRing::new(1, 0); // every span slow, tiny recent ring
        drop(TraceCtx::new("nearest", 7, ring.clone()));
        for tag in 0..5u64 {
            drop(TraceCtx::new("embed", tag, ring.clone()));
        }
        // Span 1 left the recent ring long ago but lives on the slow list.
        assert!(ring.find(1).is_some_and(|r| r.op == "nearest"));
    }
}
