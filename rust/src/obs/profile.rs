//! Always-on sampling profiler: per-thread CPU-time attribution over
//! the same stage vocabulary the tracer stamps.
//!
//! Histograms ([`super::metrics`]) and spans ([`super::trace`]) are
//! wall-clock only: a long `queue_wait` span cannot tell a shard that
//! is compute-bound from one that is descheduled. This module closes
//! that gap with three pieces:
//!
//! - A [`ThreadRegistry`] (one per [`Registry`], so it reaches every
//!   spawn site the metrics already reach): each long-lived thread —
//!   sampler workers, feature shards, connection reader/writer loops,
//!   the ANN rebuild thread, HTTP connections — calls
//!   [`ThreadRegistry::register`] **on itself** with a role label and
//!   publishes its *current stage* into a lock-free atomic slot via
//!   [`ThreadGuard::set_stage`]. Stages come from the fixed [`STAGES`]
//!   vocabulary (the same names `TraceCtx` stamps: `cache_probe`,
//!   `queue_wait`, `projection`, `ann_search`, `reply_write`, …), so
//!   flame output and span output speak one language.
//! - A per-thread **CPU clock**: registration resolves the calling
//!   thread's clock id via `pthread_getcpuclockid(pthread_self())`
//!   through a hand-rolled `extern "C"` shim (same pattern and
//!   unix/64-bit gating as `crate::store::mmap`); the sampler then
//!   reads it with `clock_gettime` from its own thread. Where the
//!   shim is unavailable ([`cpu_clock_supported`] returns false) the
//!   fallback is wall time since registration — busy fractions then
//!   read as 1.0 ("unknown, assumed on-CPU") and the CPU-sensitive
//!   tests gate themselves off.
//! - A sampler thread ([`Profiler`], `--profile-hz N`, default on at a
//!   low rate, 0 = off): each tick walks the registry once and
//!   aggregates `(role, stage) → {samples, cpu_delta_us}` into the
//!   profile table, refreshes the `proc.*` self-metric gauges from
//!   `/proc/self/{statm,status,fd}`, and publishes per-shard busy
//!   fractions (`shard.busy_permille.<i>` gauges, cumulative CPU µs /
//!   wall µs since registration, clamped to [0, 1]).
//!
//! ## Collapsed-stack output
//!
//! [`ThreadRegistry::collapsed`] renders the table as one
//! `role;stage N` line per pair — the collapsed-stack format standard
//! flamegraph tooling consumes — where `N` is the number of sampler
//! ticks that caught the pair. Alongside samples, `set_stage` bumps a
//! per-slot **entry counter** (a fixed atomic array, still lock-free),
//! and the rendered table is the union of sampled pairs and entered
//! pairs: a stage a pass exercised appears in the output even when
//! every visit slipped between ticks (with weight 0). That makes
//! "collapsed output covers every stage the pass exercised" a
//! deterministic contract rather than sampling luck — serve-bench
//! self-checks exactly that.
//!
//! ## Overhead and the observation-only contract
//!
//! Request threads pay two relaxed atomic stores per stage transition
//! (slot index + entry counter); the sampler's tick cost (one mutex'd
//! walk, one `clock_gettime` per thread) lands on its own thread. No
//! RNG draws, no row arithmetic: sampling at full rate is bitwise
//! invisible to embeddings, pinned by `tests/obs.rs` the same way
//! tracing is.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::metrics::Registry;

/// The closed stage vocabulary. `set_stage` accepts only these (an
/// unknown name debug-asserts and maps to `idle`), which is what makes
/// "every `(role, stage)` pair in `/profile` output is in the
/// vocabulary" a structural guarantee.
///
/// `idle` (index 0) is every thread's initial stage; `spin`/`sleep`
/// exist for the busy-fraction sanity tests; the rest are the stamps
/// the request lifecycle already uses (see [`crate::obs`] docs).
pub const STAGES: &[&str] = &[
    "idle",
    "read_request",
    "cache_probe",
    "admission",
    "queue_wait",
    "batch_wait",
    "projection",
    "ann_search",
    "ann_rebuild",
    "reply_write",
    "http",
    "sample",
    "spin",
    "sleep",
];

const STAGE_COUNT: usize = STAGES.len();

/// Is `name` in the registered stage vocabulary? (Format lints in the
/// test suite check `/profile` lines against this.)
pub fn is_stage(name: &str) -> bool {
    STAGES.contains(&name)
}

fn stage_index(name: &str) -> usize {
    match STAGES.iter().position(|s| *s == name) {
        Some(i) => i,
        None => {
            debug_assert!(false, "unknown profile stage {name:?}");
            0
        }
    }
}

/// Hand-rolled libc shim for per-thread CPU clocks and the page size,
/// gated exactly like `crate::store::mmap`: 64-bit unix gets the real
/// syscalls, everything else gets the fallback module below.
#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    // 64-bit unix timespec: two 64-bit fields.
    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    // glibc and musl agree on this value; non-Linux unixes just take
    // the 4 KiB fallback in `page_size`.
    #[cfg(target_os = "linux")]
    const SC_PAGESIZE: i32 = 30;

    extern "C" {
        fn pthread_self() -> usize;
        fn pthread_getcpuclockid(thread: usize, clockid: *mut i32) -> i32;
        fn clock_gettime(clockid: i32, tp: *mut Timespec) -> i32;
        #[cfg(target_os = "linux")]
        fn sysconf(name: i32) -> i64;
    }

    /// The calling thread's CPU clock id; `None` where the libc call
    /// fails (the slot then falls back to wall time).
    pub fn self_cpu_clock() -> Option<i32> {
        let mut id: i32 = 0;
        // SAFETY: pthread_self() is always a valid handle for the
        // calling thread; libc validates and returns non-zero on error.
        let rc = unsafe { pthread_getcpuclockid(pthread_self(), &mut id) };
        if rc == 0 {
            Some(id)
        } else {
            None
        }
    }

    /// Cumulative CPU microseconds on `clockid`; `None` on failure
    /// (e.g. the owning thread already exited).
    pub fn clock_us(clockid: i32) -> Option<u64> {
        let mut ts = Timespec { tv_sec: 0, tv_nsec: 0 };
        // SAFETY: `ts` is a valid out-pointer; failure reports -1.
        let rc = unsafe { clock_gettime(clockid, &mut ts) };
        if rc != 0 || ts.tv_sec < 0 {
            return None;
        }
        Some(ts.tv_sec as u64 * 1_000_000 + ts.tv_nsec as u64 / 1_000)
    }

    pub fn page_size() -> u64 {
        #[cfg(target_os = "linux")]
        {
            // SAFETY: sysconf is a constant lookup with no out-params.
            let v = unsafe { sysconf(SC_PAGESIZE) };
            if v > 0 {
                return v as u64;
            }
        }
        4096
    }
}

#[cfg(not(all(unix, target_pointer_width = "64")))]
mod sys {
    pub fn self_cpu_clock() -> Option<i32> {
        None
    }
    pub fn clock_us(_clockid: i32) -> Option<u64> {
        None
    }
    pub fn page_size() -> u64 {
        4096
    }
}

/// Does this target expose working per-thread CPU clocks? When false,
/// per-thread `cpu_us` is wall time since registration (busy reads as
/// 1.0) and the CPU-sensitive tests skip their assertions.
pub fn cpu_clock_supported() -> bool {
    match sys::self_cpu_clock() {
        Some(c) => sys::clock_us(c).is_some(),
        None => false,
    }
}

/// One registered thread's published state. Shared between the owning
/// thread (stage stores via its [`ThreadGuard`]) and the sampler
/// (everything else) — all cross-thread fields are atomics.
struct ThreadSlot {
    role: &'static str,
    index: usize,
    /// Index into [`STAGES`]; the owning thread stores, readers load.
    stage: AtomicUsize,
    alive: AtomicBool,
    /// CPU clock id resolved at registration *on the owning thread*;
    /// `None` → wall fallback.
    clock: Option<i32>,
    registered: Instant,
    /// Cumulative CPU µs at the previous sampler visit (delta base).
    last_cpu_us: AtomicU64,
    /// Latest cumulative CPU µs reading (what `/debug/threads` shows).
    cpu_us: AtomicU64,
    /// How many times each stage was entered (`set_stage` calls) —
    /// merged into the collapsed output so unsampled stages still
    /// appear (see module docs).
    entered: [AtomicU64; STAGE_COUNT],
}

impl ThreadSlot {
    fn cpu_now_us(&self) -> u64 {
        self.clock
            .and_then(sys::clock_us)
            .unwrap_or_else(|| self.registered.elapsed().as_micros() as u64)
    }

    fn wall_us(&self) -> u64 {
        self.registered.elapsed().as_micros() as u64
    }
}

/// RAII registration handle: the owning thread publishes its stage
/// through it and deregisters by dropping it. After the drop the
/// sampler attributes nothing further to the thread (pinned by test).
pub struct ThreadGuard {
    slot: Arc<ThreadSlot>,
}

impl ThreadGuard {
    /// Publish the thread's current stage (lock-free: two relaxed-ish
    /// atomic ops). `stage` must be in [`STAGES`].
    pub fn set_stage(&self, stage: &'static str) {
        let i = stage_index(stage);
        self.slot.stage.store(i, Ordering::Release);
        self.slot.entered[i].fetch_add(1, Ordering::Relaxed);
    }
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        self.slot.alive.store(false, Ordering::Release);
    }
}

/// Per-`(role, stage)` accumulator cell. `samples`/`cpu_us` come from
/// sampler ticks; `entered` holds entry counts folded in from threads
/// that already deregistered (live threads' counts merge at read
/// time).
#[derive(Clone, Copy, Default)]
struct StageCell {
    samples: u64,
    cpu_us: u64,
    entered: u64,
}

/// One row of the rendered profile table.
#[derive(Clone, Debug)]
pub struct StageRow {
    pub role: &'static str,
    pub stage: &'static str,
    /// Sampler ticks that caught the pair.
    pub samples: u64,
    /// CPU µs attributed to the pair across those ticks.
    pub cpu_us: u64,
    /// Times the pair was entered (≥ 1 even when never sampled).
    pub entered: u64,
}

/// A live registered thread, as `/debug/threads` reports it.
#[derive(Clone, Debug)]
pub struct ThreadInfo {
    pub role: &'static str,
    pub index: usize,
    pub stage: &'static str,
    pub cpu_us: u64,
    pub wall_us: u64,
    /// Cumulative CPU / wall since registration, clamped to [0, 1].
    pub busy: f64,
}

/// The thread registry + profile table. One per [`Registry`] (reach it
/// via [`Registry::threads`]), so every component that can record a
/// metric can also register its threads, and two in-process daemons
/// profile in full isolation.
#[derive(Default)]
pub struct ThreadRegistry {
    slots: Mutex<Vec<Arc<ThreadSlot>>>,
    /// `(role, stage index)` → accumulated cell. Lock order: `slots`
    /// before `table`, everywhere.
    table: Mutex<BTreeMap<(&'static str, usize), StageCell>>,
    ticks: AtomicU64,
    samples: AtomicU64,
}

impl ThreadRegistry {
    /// Register the **calling** thread (the CPU clock id is resolved
    /// on it) under a role label. Keep the guard alive for the
    /// thread's working lifetime; drop it to deregister.
    pub fn register(&self, role: &'static str, index: usize) -> ThreadGuard {
        let slot = Arc::new(ThreadSlot {
            role,
            index,
            stage: AtomicUsize::new(0),
            alive: AtomicBool::new(true),
            clock: sys::self_cpu_clock(),
            registered: Instant::now(),
            last_cpu_us: AtomicU64::new(0),
            cpu_us: AtomicU64::new(0),
            entered: std::array::from_fn(|_| AtomicU64::new(0)),
        });
        // Anchor the CPU delta base at registration, and count the
        // initial "idle" entry so every registered thread has at least
        // one row in the collapsed output.
        let cpu = slot.cpu_now_us();
        slot.last_cpu_us.store(cpu, Ordering::Relaxed);
        slot.cpu_us.store(cpu, Ordering::Relaxed);
        slot.entered[0].fetch_add(1, Ordering::Relaxed);
        self.slots.lock().expect("thread registry lock").push(Arc::clone(&slot));
        ThreadGuard { slot }
    }

    /// One sampler tick: read every live thread's CPU clock, attribute
    /// the delta to its current `(role, stage)`, and prune threads
    /// that deregistered since the last tick (folding their stage
    /// entry counts into the table first). Returns how many threads
    /// were sampled.
    pub fn sample_once(&self) -> u64 {
        let mut slots = self.slots.lock().expect("thread registry lock");
        let mut table = self.table.lock().expect("profile table lock");
        let mut sampled = 0u64;
        slots.retain(|slot| {
            if !slot.alive.load(Ordering::Acquire) {
                for (i, e) in slot.entered.iter().enumerate() {
                    let n = e.load(Ordering::Relaxed);
                    if n > 0 {
                        table.entry((slot.role, i)).or_default().entered += n;
                    }
                }
                return false;
            }
            let cpu = slot.cpu_now_us();
            let last = slot.last_cpu_us.swap(cpu, Ordering::Relaxed);
            slot.cpu_us.store(cpu, Ordering::Relaxed);
            let stage = slot.stage.load(Ordering::Acquire).min(STAGE_COUNT - 1);
            let cell = table.entry((slot.role, stage)).or_default();
            cell.samples += 1;
            cell.cpu_us += cpu.saturating_sub(last);
            sampled += 1;
            true
        });
        self.ticks.fetch_add(1, Ordering::Relaxed);
        self.samples.fetch_add(sampled, Ordering::Relaxed);
        sampled
    }

    /// Sampler ticks so far.
    pub fn ticks(&self) -> u64 {
        self.ticks.load(Ordering::Relaxed)
    }

    /// Thread-samples attributed so far (sum over ticks of live
    /// threads seen).
    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    /// The profile table: sampled pairs unioned with every pair any
    /// thread (live or retired) ever entered. Sorted by role then
    /// stage index, so output is stable.
    pub fn stage_table(&self) -> Vec<StageRow> {
        let slots = self.slots.lock().expect("thread registry lock");
        let table = self.table.lock().expect("profile table lock");
        let mut merged = table.clone();
        // Unpruned slots merge here whether or not they are still alive:
        // a dead slot's counts move into the stored table at prune time,
        // and this merge is ephemeral, so the union is gapless without
        // ever double-counting.
        for slot in slots.iter() {
            for (i, e) in slot.entered.iter().enumerate() {
                let n = e.load(Ordering::Relaxed);
                if n > 0 {
                    merged.entry((slot.role, i)).or_default().entered += n;
                }
            }
        }
        merged
            .into_iter()
            .map(|((role, i), c)| StageRow {
                role,
                stage: STAGES[i],
                samples: c.samples,
                cpu_us: c.cpu_us,
                entered: c.entered,
            })
            .collect()
    }

    /// Live registered threads, CPU readings refreshed at call time
    /// (so a `--profile-hz 0` daemon still reports real numbers).
    pub fn snapshot(&self) -> Vec<ThreadInfo> {
        let slots = self.slots.lock().expect("thread registry lock");
        slots
            .iter()
            .filter(|s| s.alive.load(Ordering::Acquire))
            .map(|s| {
                let cpu = s.cpu_now_us();
                s.cpu_us.store(cpu, Ordering::Relaxed);
                let wall = s.wall_us();
                let busy = if wall == 0 {
                    0.0
                } else {
                    (cpu as f64 / wall as f64).clamp(0.0, 1.0)
                };
                ThreadInfo {
                    role: s.role,
                    index: s.index,
                    stage: STAGES[s.stage.load(Ordering::Acquire).min(STAGE_COUNT - 1)],
                    cpu_us: cpu,
                    wall_us: wall,
                    busy,
                }
            })
            .collect()
    }

    /// Cumulative collapsed-stack text: one `role;stage N` line per
    /// table row, N = samples (0 for entered-but-never-sampled pairs;
    /// see module docs).
    pub fn collapsed(&self) -> String {
        render_collapsed(&self.stage_table())
    }
}

fn render_collapsed(rows: &[StageRow]) -> String {
    let mut out = String::new();
    for r in rows {
        out.push_str(&format!("{};{} {}\n", r.role, r.stage, r.samples));
    }
    out
}

/// Collapsed-stack text for the window between two [`stage_table`]
/// snapshots (the `/profile?seconds=N` path): rows whose samples or
/// entry counts advanced, weighted by the sample delta.
///
/// [`stage_table`]: ThreadRegistry::stage_table
pub fn collapsed_between(before: &[StageRow], after: &[StageRow]) -> String {
    let mut base: BTreeMap<(&str, &str), (u64, u64)> = BTreeMap::new();
    for r in before {
        base.insert((r.role, r.stage), (r.samples, r.entered));
    }
    let mut out = String::new();
    for r in after {
        let (s0, e0) = base.get(&(r.role, r.stage)).copied().unwrap_or((0, 0));
        if r.samples > s0 || r.entered > e0 {
            out.push_str(&format!("{};{} {}\n", r.role, r.stage, r.samples - s0));
        }
    }
    out
}

/// Resident set size in bytes, from `/proc/self/statm` (resident
/// pages × page size). `None` off Linux.
pub fn proc_rss_bytes() -> Option<u64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let resident: u64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    Some(resident * sys::page_size())
}

/// Kernel thread count, from the `Threads:` line of
/// `/proc/self/status`. `None` off Linux.
pub fn proc_thread_count() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// Open file descriptors, counted from `/proc/self/fd`. `None` off
/// Linux. (The count includes the descriptor the walk itself opens.)
pub fn proc_open_fds() -> Option<u64> {
    Some(std::fs::read_dir("/proc/self/fd").ok()?.count() as u64)
}

/// Refresh the `proc.*` self-metric gauges. Missing procfs (non-Linux)
/// leaves the gauges untouched rather than zeroing them.
pub fn refresh_proc_gauges(registry: &Registry) {
    if let Some(v) = proc_rss_bytes() {
        registry.gauge("proc.rss_bytes").set(v);
    }
    if let Some(v) = proc_thread_count() {
        registry.gauge("proc.threads").set(v);
    }
    if let Some(v) = proc_open_fds() {
        registry.gauge("proc.open_fds").set(v);
    }
}

/// One sampler tick against a registry: walk the thread registry, bump
/// the `profile.samples` counter, publish per-shard busy gauges, and
/// refresh the `proc.*` gauges. The [`Profiler`] thread calls this at
/// `--profile-hz`; tests call it directly for determinism.
pub fn tick(registry: &Registry) {
    let sampled = registry.threads().sample_once();
    if sampled > 0 {
        registry.counter("profile.samples").add(sampled);
    }
    for t in registry.threads().snapshot() {
        if t.role == "shard" {
            registry
                .gauge(&format!("shard.busy_permille.{}", t.index))
                .set((t.busy * 1000.0).round() as u64);
        }
    }
    refresh_proc_gauges(registry);
}

/// The sampler thread: calls [`tick`] at a fixed rate until stopped or
/// dropped. `Profiler::start` with `hz == 0` returns `None` (profiling
/// off — the registry still works, it just never accumulates samples).
pub struct Profiler {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Profiler {
    pub fn start(registry: Arc<Registry>, hz: u64) -> Option<Profiler> {
        if hz == 0 {
            return None;
        }
        let period = Duration::from_nanos(1_000_000_000 / hz);
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("profiler".into())
            .spawn(move || {
                let guard = registry.threads().register("profiler", 0);
                guard.set_stage("sample");
                while !stop_flag.load(Ordering::Acquire) {
                    tick(&registry);
                    // Sleep in short chunks so stop() never waits a
                    // full low-rate period.
                    let mut left = period;
                    while !left.is_zero() && !stop_flag.load(Ordering::Acquire) {
                        let chunk = left.min(Duration::from_millis(20));
                        std::thread::sleep(chunk);
                        left = left.saturating_sub(chunk);
                    }
                }
            })
            .expect("spawn profiler thread");
        Some(Profiler { stop, handle: Some(handle) })
    }

    /// Stop and join the sampler thread (idempotent).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Profiler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_vocabulary_is_unique_and_indexed() {
        for (i, s) in STAGES.iter().enumerate() {
            assert!(is_stage(s));
            assert_eq!(stage_index(s), i, "stage {s} maps back to its index");
        }
        let mut sorted: Vec<&str> = STAGES.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), STAGES.len(), "duplicate stage names");
        assert_eq!(STAGES[0], "idle", "index 0 is the default stage");
    }

    #[test]
    fn cpu_clock_reads_advance_under_load() {
        if !cpu_clock_supported() {
            return;
        }
        let clock = sys::self_cpu_clock().unwrap();
        let before = sys::clock_us(clock).unwrap();
        // Burn ~10ms of CPU; the thread clock must advance.
        let mut acc = 0u64;
        let t = Instant::now();
        while t.elapsed() < Duration::from_millis(10) {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let after = sys::clock_us(clock).unwrap();
        assert!(after >= before, "thread CPU clock went backwards");
        assert!(after > before, "10ms of spinning registered no CPU time");
    }

    #[test]
    fn register_sample_deregister_lifecycle() {
        let reg = ThreadRegistry::default();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let reg2: &'static ThreadRegistry = Box::leak(Box::new(reg));
        let h = std::thread::spawn(move || {
            let g = reg2.register("spin_test", 3);
            g.set_stage("spin");
            while !stop2.load(Ordering::Acquire) {
                std::hint::spin_loop();
            }
        });
        // Wait for registration, then tick a few times.
        while reg2.snapshot().is_empty() {
            std::thread::yield_now();
        }
        for _ in 0..5 {
            reg2.sample_once();
            std::thread::sleep(Duration::from_millis(2));
        }
        let spin_samples = |rows: &[StageRow]| {
            rows.iter()
                .find(|r| r.role == "spin_test" && r.stage == "spin")
                .map(|r| r.samples)
                .unwrap_or(0)
        };
        let rows = reg2.stage_table();
        assert!(spin_samples(&rows) >= 1, "live spin thread was never sampled");
        let snap = reg2.snapshot();
        let info = &snap[0];
        assert_eq!((info.role, info.index, info.stage), ("spin_test", 3, "spin"));
        assert!((0.0..=1.0).contains(&info.busy), "busy {} out of range", info.busy);

        stop.store(true, Ordering::Release);
        h.join().unwrap();
        // The prune tick folds the dead thread out of the registry …
        reg2.sample_once();
        assert!(reg2.snapshot().is_empty(), "deregistered thread still listed");
        let frozen = spin_samples(&reg2.stage_table());
        // … and later ticks attribute nothing further to it.
        for _ in 0..3 {
            reg2.sample_once();
        }
        assert_eq!(
            spin_samples(&reg2.stage_table()),
            frozen,
            "samples attributed after deregistration"
        );
        // Entered pairs survive retirement: idle (initial) + spin.
        let rows = reg2.stage_table();
        for stage in ["idle", "spin"] {
            let row = rows.iter().find(|r| r.role == "spin_test" && r.stage == stage);
            assert!(row.is_some_and(|r| r.entered >= 1), "retired {stage} entry lost");
        }
    }

    #[test]
    fn collapsed_lines_are_role_stage_weight() {
        let reg = ThreadRegistry::default();
        let g = reg.register("fmt_test", 0);
        g.set_stage("projection");
        reg.sample_once();
        let text = reg.collapsed();
        assert!(!text.is_empty());
        for line in text.lines() {
            let (frames, weight) = line.rsplit_once(' ').expect("weight separator");
            let (role, stage) = frames.split_once(';').expect("role;stage");
            assert_eq!(role, "fmt_test");
            assert!(is_stage(stage), "unknown stage {stage:?} in {line:?}");
            weight.parse::<u64>().expect("numeric weight");
        }
        // The sampled pair carries weight ≥ 1.
        assert!(
            text.lines().any(|l| l.starts_with("fmt_test;projection ")
                && !l.ends_with(" 0")),
            "{text}"
        );
    }

    #[test]
    fn entered_but_unsampled_stages_still_appear() {
        let reg = ThreadRegistry::default();
        let g = reg.register("cover_test", 0);
        // Enter three stages between ticks; none is ever sampled.
        for s in ["cache_probe", "ann_search", "reply_write"] {
            g.set_stage(s);
        }
        let text = reg.collapsed();
        for s in ["cache_probe", "ann_search", "reply_write"] {
            assert!(
                text.contains(&format!("cover_test;{s} ")),
                "entered stage {s} missing from {text:?}"
            );
        }
    }

    #[test]
    fn collapsed_between_reports_only_window_activity() {
        let reg = ThreadRegistry::default();
        let g = reg.register("win_test", 0);
        g.set_stage("projection");
        reg.sample_once();
        let before = reg.stage_table();
        assert_eq!(collapsed_between(&before, &before), "", "empty window has no lines");
        g.set_stage("ann_search");
        reg.sample_once();
        reg.sample_once();
        let after = reg.stage_table();
        let text = collapsed_between(&before, &after);
        assert!(text.contains("win_test;ann_search 2"), "{text}");
        assert!(!text.contains("win_test;projection"), "stale stage leaked: {text}");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn proc_self_metrics_parse_on_linux() {
        assert!(proc_rss_bytes().unwrap() > 0);
        assert!(proc_thread_count().unwrap() >= 1);
        assert!(proc_open_fds().unwrap() >= 1);
        let r = Registry::new();
        refresh_proc_gauges(&r);
        let j = r.snapshot_json();
        // All three gauges land in the registry.
        for name in ["proc.rss_bytes", "proc.threads", "proc.open_fds"] {
            assert!(j.to_string().contains(name), "{name} missing from snapshot");
        }
    }

    #[test]
    fn profiler_thread_starts_ticks_and_stops() {
        let registry = Arc::new(Registry::new());
        assert!(Profiler::start(Arc::clone(&registry), 0).is_none(), "hz 0 is off");
        let mut p = Profiler::start(Arc::clone(&registry), 500).expect("hz 500 starts");
        let t = Instant::now();
        while registry.threads().ticks() < 3 && t.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(registry.threads().ticks() >= 3, "sampler never ticked");
        p.stop();
        let ticks = registry.threads().ticks();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(registry.threads().ticks(), ticks, "sampler ticked after stop");
        // The profiler registered itself and sampled its own role.
        assert!(
            registry.threads().collapsed().contains("profiler;sample "),
            "{}",
            registry.threads().collapsed()
        );
        assert!(registry.counter("profile.samples").get() >= 1);
    }
}
