//! Prometheus text exposition (format v0.0.4) for a [`Registry`]
//! snapshot. Hand-rolled like everything else in the crate — no client
//! library, no deps — so the daemon's `/metrics` endpoint (see
//! `crate::serve::http`) is scrapeable by stock Prometheus while the
//! build stays hermetic.
//!
//! Mapping from the registry's dotted names:
//!
//! - Dots (and any other character outside `[a-zA-Z0-9_:]`) become
//!   underscores: `pipeline.queue_wait_us` → `pipeline_queue_wait_us`.
//! - Dynamic suffixes are **promoted into labels**, so per-op metrics
//!   form one family instead of an unbounded name set:
//!   `serve.request_us.embed` → `serve_request_us{op="embed"}`,
//!   `serve.errors.nearest` → `serve_errors{op="nearest"}`.
//! - Log₂ histograms become cumulative `le` series with `_sum` and
//!   `_count`: finite buckets expose their inclusive upper bound (µs)
//!   as the `le` value, the overflow bucket becomes `le="+Inf"`, and
//!   the `+Inf` sample always equals `_count` (both are computed from
//!   the same bucket sum, so the invariant holds structurally, not by
//!   luck).
//! - Every family gets `# HELP`/`# TYPE` headers from the metric
//!   catalog (the table in [`crate::obs`]); families are emitted in
//!   sorted name order and label values escape `\`, `"`, and newline,
//!   so output is stable and lintable.
//!
//! A `graphlet_rf_build_info{config_fp,engine,version} 1` gauge rides
//! along (the standard "info metric" idiom) so dashboards can key every
//! series to the daemon's engine and config fingerprint.

use std::collections::BTreeMap;

use super::metrics::{bucket_upper_us, MetricValue, Registry, NUM_BUCKETS};

/// Static identity labels for the `graphlet_rf_build_info` metric.
#[derive(Clone, Debug)]
pub struct BuildInfo {
    /// Engine mode name (`cpu`, `cpu-sorf`, `pjrt`, …).
    pub engine: String,
    /// 16-hex config fingerprint (same value the `stats` op reports).
    pub config_fp: String,
    /// Crate version baked in at compile time.
    pub version: String,
}

/// Dotted-name prefixes whose trailing segment is a dynamic suffix
/// (one entry per request op), promoted into the named label.
const DYNAMIC_SUFFIXES: &[(&str, &str)] = &[
    ("serve.request_us.", "op"),
    ("serve.errors.", "op"),
    ("shard.busy_permille.", "shard"),
];

/// Metric catalog: dotted family name → HELP text. Mirrors the table
/// in the [`crate::obs`] module docs — update both together.
const CATALOG: &[(&str, &str)] = &[
    ("ann.build_us", "IVFFlat index (re)build time over the stored corpus"),
    ("ann.probe_us", "IVFFlat k-NN search time per nearest query"),
    ("cache.l2_read_us", "Segment-log (L2) read time on an L1 miss"),
    ("cache.probe_us", "Tiered-cache probe time (L1, then optional L2)"),
    ("pipeline.queue_wait_us", "Time a job waits in the bounded queue before a worker claims it"),
    ("proc.open_fds", "Open file descriptors, from /proc/self/fd"),
    ("proc.rss_bytes", "Resident set size in bytes, from /proc/self/statm"),
    ("proc.threads", "Kernel thread count, from /proc/self/status"),
    ("profile.samples", "Thread samples taken by the profiler (one per live thread per tick)"),
    ("serve.errors", "Per-request error replies, by op"),
    ("serve.request_us", "End-to-end request time from admission to reply write, by op"),
    ("serve.slow_spans", "Request spans that exceeded the --slow-ms threshold"),
    ("shard.batch_wait_us", "Time a shard's partial batch waits before dispatch"),
    ("shard.busy_permille", "Per-shard busy fraction (CPU us / wall us since registration) x1000, by shard"),
    ("shard.projection_us", "Feature-map projection time per dispatched batch"),
    ("store.append_us", "Segment-log append time per stored row"),
    ("store.compact_us", "Segment-log compaction pass time"),
    ("store.mmap_bytes", "Bytes of sealed segment data currently memory-mapped"),
    ("store.mmap_reads", "Row reads served zero-copy from a mapped sealed segment"),
    ("store.mmap_segments", "Sealed segments currently memory-mapped"),
];

/// Sanitize a dotted metric name into a Prometheus metric name:
/// `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 1);
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit());
        if ok {
            out.push(c);
        } else if i == 0 && c.is_ascii_digit() {
            out.push('_');
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

/// Escape a label value per the exposition format: backslash, double
/// quote, and line feed.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape HELP text: backslash and line feed only (quotes are legal).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Split a dotted registry name into (dotted family, promoted label).
fn family_and_label(name: &str) -> (String, Option<(&'static str, String)>) {
    for &(prefix, label) in DYNAMIC_SUFFIXES {
        if let Some(suffix) = name.strip_prefix(prefix) {
            if !suffix.is_empty() {
                let family = prefix.trim_end_matches('.').to_string();
                return (family, Some((label, suffix.to_string())));
            }
        }
    }
    (name.to_string(), None)
}

fn help_for(dotted_family: &str) -> &'static str {
    CATALOG
        .iter()
        .find(|(n, _)| *n == dotted_family)
        .map(|(_, h)| *h)
        .unwrap_or("(uncataloged metric)")
}

/// One family's accumulated samples, keyed by promoted label value
/// (`None` for label-less metrics).
struct Family {
    dotted: String,
    samples: Vec<(Option<(&'static str, String)>, MetricValue)>,
}

fn label_selector(label: &Option<(&'static str, String)>) -> String {
    match label {
        Some((k, v)) => format!("{k}=\"{}\"", escape_label(v)),
        None => String::new(),
    }
}

/// Join a promoted label with an extra `le` label for bucket samples.
fn bucket_selector(label: &Option<(&'static str, String)>, le: &str) -> String {
    match label {
        Some((k, v)) => format!("{{{k}=\"{}\",le=\"{le}\"}}", escape_label(v)),
        None => format!("{{le=\"{le}\"}}"),
    }
}

fn braced(selector: &str) -> String {
    if selector.is_empty() {
        String::new()
    } else {
        format!("{{{selector}}}")
    }
}

/// Render a registry snapshot as Prometheus text format v0.0.4.
///
/// Output is deterministic for a given registry state: families sorted
/// by name, samples within a family sorted by label value, `HELP` and
/// `TYPE` immediately preceding each family's samples.
pub fn render(registry: &Registry, build_info: Option<&BuildInfo>) -> String {
    // Group the name-sorted export into families (BTreeMap keeps the
    // emission order sorted by *sanitized* family name).
    let mut families: BTreeMap<String, Family> = BTreeMap::new();
    for (name, value) in registry.export() {
        let (dotted, label) = family_and_label(&name);
        let fam = families
            .entry(sanitize(&dotted))
            .or_insert_with(|| Family { dotted: dotted.clone(), samples: Vec::new() });
        fam.samples.push((label, value));
    }

    let mut out = String::new();
    for (fam_name, fam) in &families {
        let help = escape_help(help_for(&fam.dotted));
        // A family's type comes from its first sample; the registry
        // guarantees one kind per name, and promoted families only
        // group same-kind metrics (same instrumentation site).
        let type_str = match fam.samples[0].1 {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histo(_) => "histogram",
        };
        out.push_str(&format!("# HELP {fam_name} {help}\n"));
        out.push_str(&format!("# TYPE {fam_name} {type_str}\n"));
        for (label, value) in &fam.samples {
            match value {
                MetricValue::Counter(v) | MetricValue::Gauge(v) => {
                    out.push_str(&format!(
                        "{fam_name}{} {v}\n",
                        braced(&label_selector(label))
                    ));
                }
                MetricValue::Histo(s) => {
                    let mut cum = 0u64;
                    for i in 0..NUM_BUCKETS {
                        cum += s.buckets[i];
                        let le = match bucket_upper_us(i) {
                            Some(u) => u.to_string(),
                            None => "+Inf".to_string(),
                        };
                        out.push_str(&format!(
                            "{fam_name}_bucket{} {cum}\n",
                            bucket_selector(label, &le)
                        ));
                    }
                    // `cum` now holds the full bucket sum: emitting it
                    // as _count (rather than the snapshot's separate
                    // count field) makes `+Inf == _count` structural.
                    out.push_str(&format!(
                        "{fam_name}_sum{} {}\n",
                        braced(&label_selector(label)),
                        s.sum_us
                    ));
                    out.push_str(&format!(
                        "{fam_name}_count{} {cum}\n",
                        braced(&label_selector(label))
                    ));
                }
            }
        }
    }

    if let Some(info) = build_info {
        out.push_str(
            "# HELP graphlet_rf_build_info Daemon identity labels; the value is always 1\n",
        );
        out.push_str("# TYPE graphlet_rf_build_info gauge\n");
        out.push_str(&format!(
            "graphlet_rf_build_info{{config_fp=\"{}\",engine=\"{}\",version=\"{}\"}} 1\n",
            escape_label(&info.config_fp),
            escape_label(&info.engine),
            escape_label(&info.version),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixed_registry() -> Registry {
        let r = Registry::new();
        r.counter("serve.errors.embed").add(2);
        r.counter("serve.slow_spans").add(1);
        r.gauge("pipeline.queue_depth").set(3);
        let h = r.histo("serve.request_us.embed");
        h.record_us(0);
        h.record_us(3);
        h.record_us(5);
        r
    }

    /// Golden test: the fixed registry above renders to exactly this
    /// text, byte for byte. The bucket tail (cumulative count frozen at
    /// 3 past the 4..7 µs bucket) is generated by the same
    /// `bucket_upper_us` the recorder uses, so the expectation is
    /// independent of the renderer's own cumulation loop.
    #[test]
    fn golden_render_of_a_fixed_registry() {
        let info = BuildInfo {
            engine: "cpu".into(),
            config_fp: "00000000c0ffee00".into(),
            version: "1.2.3".into(),
        };
        let mut want = String::new();
        want.push_str("# HELP pipeline_queue_depth (uncataloged metric)\n");
        want.push_str("# TYPE pipeline_queue_depth gauge\n");
        want.push_str("pipeline_queue_depth 3\n");
        want.push_str("# HELP serve_errors Per-request error replies, by op\n");
        want.push_str("# TYPE serve_errors counter\n");
        want.push_str("serve_errors{op=\"embed\"} 2\n");
        want.push_str(
            "# HELP serve_request_us End-to-end request time from admission to reply write, by op\n",
        );
        want.push_str("# TYPE serve_request_us histogram\n");
        // Recorded 0, 3, 5 µs -> bucket 0 (le 0) holds 1, bucket 2
        // (le 3) brings the cumulation to 2, bucket 3 (le 7) to 3,
        // every later bucket stays at 3.
        for i in 0..NUM_BUCKETS {
            let cum = match i {
                0 | 1 => 1,
                2 => 2,
                _ => 3,
            };
            let le = bucket_upper_us(i).map_or("+Inf".into(), |u| u.to_string());
            want.push_str(&format!("serve_request_us_bucket{{op=\"embed\",le=\"{le}\"}} {cum}\n"));
        }
        want.push_str("serve_request_us_sum{op=\"embed\"} 8\n");
        want.push_str("serve_request_us_count{op=\"embed\"} 3\n");
        want.push_str("# HELP serve_slow_spans Request spans that exceeded the --slow-ms threshold\n");
        want.push_str("# TYPE serve_slow_spans counter\n");
        want.push_str("serve_slow_spans 1\n");
        want.push_str("# HELP graphlet_rf_build_info Daemon identity labels; the value is always 1\n");
        want.push_str("# TYPE graphlet_rf_build_info gauge\n");
        want.push_str(
            "graphlet_rf_build_info{config_fp=\"00000000c0ffee00\",engine=\"cpu\",version=\"1.2.3\"} 1\n",
        );
        let got = render(&fixed_registry(), Some(&info));
        assert_eq!(got, want, "renderer drifted from the golden text");
    }

    #[test]
    fn multiple_ops_stay_one_family_with_one_header_pair() {
        let r = Registry::new();
        r.histo("serve.request_us.embed").record_us(1);
        r.histo("serve.request_us.nearest").record_us(2);
        let text = render(&r, None);
        assert_eq!(text.matches("# TYPE serve_request_us histogram").count(), 1);
        assert!(text.contains("serve_request_us_count{op=\"embed\"} 1"));
        assert!(text.contains("serve_request_us_count{op=\"nearest\"} 1"));
        // Headers precede every sample of the family.
        let type_at = text.find("# TYPE serve_request_us histogram").unwrap();
        let first_sample = text.find("serve_request_us_bucket").unwrap();
        assert!(type_at < first_sample);
    }

    #[test]
    fn shard_busy_gauges_promote_into_a_shard_label() {
        let r = Registry::new();
        r.gauge("shard.busy_permille.0").set(700);
        r.gauge("shard.busy_permille.3").set(12);
        let text = render(&r, None);
        assert_eq!(text.matches("# TYPE shard_busy_permille gauge").count(), 1);
        assert!(text.contains("shard_busy_permille{shard=\"0\"} 700"), "{text}");
        assert!(text.contains("shard_busy_permille{shard=\"3\"} 12"), "{text}");
    }

    #[test]
    fn label_values_escape_backslash_quote_and_newline() {
        assert_eq!(escape_label("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
        let info = BuildInfo {
            engine: "cp\"u".into(),
            config_fp: "a\\b".into(),
            version: "1\n2".into(),
        };
        let text = render(&Registry::new(), Some(&info));
        assert!(
            text.contains("config_fp=\"a\\\\b\",engine=\"cp\\\"u\",version=\"1\\n2\""),
            "{text}"
        );
        // The rendered body is still one sample per line: the newline
        // in the version label must not split the line.
        let info_line =
            text.lines().find(|l| l.starts_with("graphlet_rf_build_info{")).unwrap();
        assert!(info_line.ends_with("} 1"));
    }

    #[test]
    fn inf_bucket_equals_count_and_buckets_are_monotone() {
        let r = Registry::new();
        let h = r.histo("cache.probe_us");
        for us in [0u64, 1, 1, 7, 1_000_000, u64::MAX / 2] {
            h.record_us(us);
        }
        let text = render(&r, None);
        let mut prev = 0u64;
        let mut inf = None;
        for line in text.lines().filter(|l| l.starts_with("cache_probe_us_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "cumulative buckets must be monotone: {line}");
            prev = v;
            if line.contains("le=\"+Inf\"") {
                inf = Some(v);
            }
        }
        let count: u64 = text
            .lines()
            .find(|l| l.starts_with("cache_probe_us_count"))
            .unwrap()
            .rsplit(' ')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(inf, Some(count), "+Inf bucket must equal _count");
        assert_eq!(count, 6);
    }

    #[test]
    fn sanitize_maps_dots_and_leading_digits() {
        assert_eq!(sanitize("serve.request_us"), "serve_request_us");
        assert_eq!(sanitize("9lives"), "_9lives");
        assert_eq!(sanitize("a-b c"), "a_b_c");
    }
}
