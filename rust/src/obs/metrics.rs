//! Metric registry: atomic counters, gauges, and fixed-boundary
//! log₂-bucketed latency histograms. Zero dependencies, lock-free on
//! the record path — the registry's `Mutex` guards only name → handle
//! resolution (done once per call site and cached in an `Arc`), never
//! a `record()`. Registries are instance-scoped (one per serve
//! daemon); [`global()`] is the batch-CLI default.
//!
//! ## Histogram shape
//!
//! Values are **microseconds**. Bucket boundaries are fixed powers of
//! two, so two histograms (or two runs) that record the same multiset
//! of values produce identical bucket arrays — and therefore identical
//! derived percentiles — with no configuration to drift:
//!
//! - bucket `0`: exactly `0` µs
//! - bucket `i` (1 ≤ i < [`OVERFLOW_BUCKET`]): `[2^(i-1), 2^i)` µs
//! - bucket [`OVERFLOW_BUCKET`]: everything ≥ 2^39 µs (≈ 6.4 days)
//!
//! A percentile estimate walks the buckets to the requested rank
//! (`ceil(p/100 · count)`) and reports that bucket's **inclusive upper
//! bound** (`2^i − 1`); the overflow bucket reports the exact recorded
//! maximum. Alongside the buckets the histogram keeps an exact `count`,
//! `sum`, and `max`, so means are exact and only the percentile is
//! bucket-quantized (within 2× of the true value by construction).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::Json;

/// Index of the overflow bucket; finite buckets are `0..OVERFLOW_BUCKET`.
pub const OVERFLOW_BUCKET: usize = 40;
/// Total bucket-array length (finite buckets + overflow).
pub const NUM_BUCKETS: usize = OVERFLOW_BUCKET + 1;

/// Which bucket a microsecond value lands in.
#[inline]
pub fn bucket_index(us: u64) -> usize {
    if us == 0 {
        return 0;
    }
    let idx = 64 - us.leading_zeros() as usize; // 1 -> 1, 2..3 -> 2, 4..7 -> 3, ...
    idx.min(OVERFLOW_BUCKET)
}

/// Inclusive upper bound of a finite bucket (`None` for the overflow
/// bucket, whose "bound" is the recorded maximum).
#[inline]
pub fn bucket_upper_us(idx: usize) -> Option<u64> {
    match idx {
        0 => Some(0),
        i if i < OVERFLOW_BUCKET => Some((1u64 << i) - 1),
        _ => None,
    }
}

/// A monotonically increasing atomic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// An up/down gauge. `dec` saturates at zero rather than wrapping, so a
/// racy extra decrement can never turn the gauge into 2^64 − 1.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        let _ = self
            .v
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
    }

    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A log₂-bucketed latency histogram (see the module docs for the
/// boundary scheme). All fields are relaxed atomics: recording is a
/// handful of `fetch_add`s plus one `fetch_max`, safe from any thread.
#[derive(Debug)]
pub struct Histo {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histo {
    fn default() -> Histo {
        Histo {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo::default()
    }

    /// Record one microsecond value.
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Record a duration (saturating to µs).
    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the histogram. Bucket reads are not
    /// mutually atomic, so a snapshot taken *while* recording races may
    /// be momentarily inconsistent with `count` — a snapshot taken at
    /// quiescence (what every test and self-check does) is exact.
    pub fn snapshot(&self) -> HistoSnapshot {
        HistoSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// An owned copy of a [`Histo`]'s state; percentiles are computed here
/// so the estimate is a pure function of the copied buckets.
#[derive(Clone, Debug)]
pub struct HistoSnapshot {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    pub buckets: [u64; NUM_BUCKETS],
}

impl HistoSnapshot {
    /// Percentile estimate in µs: walk buckets to rank
    /// `ceil(p/100 · count)` and report that bucket's inclusive upper
    /// bound (the recorded max for the overflow bucket). An empty
    /// histogram reports 0. Deterministic given the same recorded set.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= rank {
                return bucket_upper_us(i).unwrap_or(self.max_us);
            }
        }
        // count said there were samples but the buckets raced empty;
        // the max is the least-wrong answer.
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// The JSON shape served by the `metrics` op and embedded (without
    /// buckets) in `stats` summaries.
    pub fn to_json(&self, with_buckets: bool) -> Json {
        let mut j = Json::obj()
            .set("count", self.count)
            .set("sum_us", self.sum_us)
            .set("max_us", self.max_us)
            .set("p50_us", self.percentile_us(50.0))
            .set("p90_us", self.percentile_us(90.0))
            .set("p99_us", self.percentile_us(99.0));
        if with_buckets {
            j = j.set("buckets", self.buckets.to_vec());
        }
        j
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histo(Arc<Histo>),
}

/// One metric's value in a typed registry [`export`](Registry::export)
/// — what the Prometheus renderer consumes.
#[derive(Clone, Debug)]
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Histo(HistoSnapshot),
}

/// A name → metric registry. Call sites resolve a name once (taking the
/// map lock) and keep the returned `Arc` handle; the handle records
/// lock-free forever after. Registries are **instance-scoped**: every
/// serve daemon owns its own `Arc<Registry>` (created in
/// `Server::bind` and threaded through pipeline, cache, store, ANN
/// cell, and span ring), so two in-process daemons never share a
/// counter and tests assert absolute values directly. The process-wide
/// [`global()`] instance survives as the default for the batch CLI
/// path (`embed_dataset` and friends) and for components constructed
/// without an explicit registry.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
    /// The sampling profiler's thread registry + profile table rides on
    /// the metric registry because the same `Arc<Registry>` already
    /// reaches every thread spawn site (pipeline, cache, ANN, serve
    /// loops) — registering a thread needs no new plumbing.
    threads: super::profile::ThreadRegistry,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// This registry's profiler-facing thread registry (see
    /// [`super::profile`]).
    pub fn threads(&self) -> &super::profile::ThreadRegistry {
        &self.threads
    }

    /// Resolve (or create) a counter. Asking for a name that is already
    /// registered as a different kind is a programming error; it yields
    /// a fresh detached handle (recorded values go nowhere) rather than
    /// a panic, so a naming bug can never take the daemon down.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => c.clone(),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Counter::default())
            }
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Gauge::default())
            }
        }
    }

    pub fn histo(&self, name: &str) -> Arc<Histo> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histo(Arc::new(Histo::new())))
        {
            Metric::Histo(h) => h.clone(),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Histo::new())
            }
        }
    }

    /// Point-in-time copy of one histogram, if registered.
    pub fn histo_snapshot(&self, name: &str) -> Option<HistoSnapshot> {
        let m = self.metrics.lock().unwrap();
        match m.get(name) {
            Some(Metric::Histo(h)) => Some(h.snapshot()),
            _ => None,
        }
    }

    /// Snapshots of every histogram whose name starts with `prefix`,
    /// name-sorted (the map is a `BTreeMap`). Feeds the per-op request
    /// summaries in `stats` and serve-bench's count self-checks.
    pub fn histo_snapshots_prefixed(&self, prefix: &str) -> Vec<(String, HistoSnapshot)> {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .filter_map(|(name, metric)| match metric {
                Metric::Histo(h) if name.starts_with(prefix) => {
                    Some((name.clone(), h.snapshot()))
                }
                _ => None,
            })
            .collect()
    }

    /// Values of every counter whose name starts with `prefix`,
    /// name-sorted. Feeds the per-op error counts in `stats`.
    pub fn counters_prefixed(&self, prefix: &str) -> Vec<(String, u64)> {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .filter_map(|(name, metric)| match metric {
                Metric::Counter(c) if name.starts_with(prefix) => Some((name.clone(), c.get())),
                _ => None,
            })
            .collect()
    }

    /// Typed point-in-time copy of the whole registry, name-sorted (the
    /// map is a `BTreeMap`). This is the Prometheus renderer's feed —
    /// [`snapshot_json`](Self::snapshot_json) serves the bespoke TCP
    /// `metrics` op, this serves `/metrics`.
    pub fn export(&self) -> Vec<(String, MetricValue)> {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .map(|(name, metric)| {
                let v = match metric {
                    Metric::Counter(c) => MetricValue::Counter(c.get()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.get()),
                    Metric::Histo(h) => MetricValue::Histo(h.snapshot()),
                };
                (name.clone(), v)
            })
            .collect()
    }

    /// Full registry snapshot as JSON — the `metrics` serve op's reply
    /// body. Deterministic shape: names are emitted in sorted order,
    /// histograms carry their full bucket arrays plus derived
    /// percentiles, so the output is directly scrapable.
    pub fn snapshot_json(&self) -> Json {
        let m = self.metrics.lock().unwrap();
        let mut counters = Json::obj();
        let mut gauges = Json::obj();
        let mut histos = Json::obj();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => counters = counters.set(name, c.get()),
                Metric::Gauge(g) => gauges = gauges.set(name, g.get()),
                Metric::Histo(h) => histos = histos.set(name, h.snapshot().to_json(true)),
            }
        }
        // Finite-bucket inclusive upper bounds, once — scrapers pair
        // them index-wise with every histogram's bucket array (the
        // final bucket is the overflow; its bound is that histo's max).
        let uppers: Vec<u64> = (0..OVERFLOW_BUCKET)
            .map(|i| bucket_upper_us(i).expect("finite bucket"))
            .collect();
        Json::obj()
            .set("bucket_uppers_us", uppers)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histos)
    }
}

static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();

/// The process-wide default registry: the batch CLI path
/// (`embed_dataset`, experiments) records here, and it is the fallback
/// for components constructed without an explicit registry. Serve
/// daemons do **not** use it — each owns an instance-scoped
/// [`Arc<Registry>`] (see [`global_arc`] for an owned handle).
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(|| Arc::new(Registry::new()))
}

/// Owned handle on the process-wide default registry, for components
/// that thread an `Arc<Registry>` (pipeline, cache, store, span ring)
/// and need a default when the caller didn't supply one.
pub fn global_arc() -> Arc<Registry> {
    GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        for i in 1..OVERFLOW_BUCKET {
            let lo = 1u64 << (i - 1);
            let hi = (1u64 << i) - 1;
            assert_eq!(bucket_index(lo), i, "low edge of bucket {i}");
            assert_eq!(bucket_index(hi), i, "high edge of bucket {i}");
        }
        assert_eq!(bucket_index(1u64 << 39), OVERFLOW_BUCKET);
        assert_eq!(bucket_index(u64::MAX), OVERFLOW_BUCKET);
    }

    #[test]
    fn percentiles_are_bucket_uppers_and_deterministic() {
        let h = Histo::new();
        for v in [0u64, 1, 2, 3, 900, 1000, 1100, 50_000] {
            h.record_us(v);
        }
        let s1 = h.snapshot();
        let s2 = h.snapshot();
        assert_eq!(s1.buckets, s2.buckets);
        assert_eq!(s1.count, 8);
        assert_eq!(s1.sum_us, 53_006);
        assert_eq!(s1.max_us, 50_000);
        // rank(50%) = 4 -> the bucket holding value 3 -> upper 3.
        assert_eq!(s1.percentile_us(50.0), 3);
        // rank(100%) = 8 -> bucket of 50_000 (2^15..2^16) -> upper 65535.
        assert_eq!(s1.percentile_us(100.0), 65_535);
    }

    #[test]
    fn empty_histogram_percentiles_are_zero() {
        let s = Histo::new().snapshot();
        assert_eq!(s.percentile_us(50.0), 0);
        assert_eq!(s.percentile_us(99.0), 0);
        assert_eq!(s.mean_us(), 0.0);
    }

    #[test]
    fn registry_handles_share_state_and_snapshot_sorts() {
        let r = Registry::new();
        let a = r.histo("z.lat");
        let b = r.histo("z.lat");
        a.record_us(5);
        b.record_us(7);
        assert_eq!(r.histo_snapshot("z.lat").unwrap().count, 2);
        r.counter("a.count").add(3);
        r.gauge("m.depth").set(2);
        let j = r.snapshot_json();
        assert_eq!(
            j.get("counters").and_then(|c| c.get("a.count")).and_then(Json::as_u64),
            Some(3)
        );
        assert_eq!(
            j.get("gauges").and_then(|g| g.get("m.depth")).and_then(Json::as_u64),
            Some(2)
        );
        let h = j.get("histograms").and_then(|h| h.get("z.lat")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_u64), Some(2));
        assert_eq!(
            h.get("buckets").and_then(Json::as_array).map(|b| b.len()),
            Some(NUM_BUCKETS)
        );
    }

    #[test]
    fn gauge_dec_saturates() {
        let g = Gauge::default();
        g.dec();
        assert_eq!(g.get(), 0);
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
    }
}
